#!/usr/bin/env python
"""Duration-balanced tier-1 test sharding for scripts/ci.sh.

    python scripts/shard_tests.py --shard 0 --num-shards 2

prints the test files assigned to that shard (space-separated), split by
LPT (longest-processing-time-first) over per-file durations recorded by
the conftest ``--durations-path`` hook into ``.cache/test_durations/``.
Every shard invocation re-records its files, so the balance tracks the
suite as it grows.  Files with no recording yet fall back to a small
table of priors (jax model-zoo modules dwarf the simulator ones by ~50x,
so a flat default would re-create the naive-split imbalance on cold
caches).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DURATIONS_DIR = os.path.join(REPO, ".cache", "test_durations")

# cold-start priors (seconds, warm XLA cache, 2-core host) for files that
# have never been timed; anything unknown gets DEFAULT_S
PRIOR_S = {
    "tests/test_models.py": 60.0,
    "tests/test_serve_paged_equiv.py": 80.0,
    "tests/test_serve_engine.py": 35.0,
    "tests/test_training.py": 35.0,
    "tests/test_distributed.py": 30.0,
    "tests/test_spectrum_models.py": 20.0,
    "tests/test_kernels.py": 15.0,
    "tests/test_kernels_extra.py": 15.0,
    "tests/test_pipeline.py": 15.0,
    "tests/test_serve_soak.py": 32.0,
    "tests/test_engine_equivalence.py": 10.0,
    "tests/test_engine_equivalence_jax.py": 25.0,
    "tests/test_serve_fleet.py": 35.0,
    "tests/test_serve_tiers.py": 25.0,
    "tests/test_serve_tiers_prop.py": 2.0,
    "tests/test_serve_faults.py": 35.0,
    "tests/test_serve_faults_prop.py": 10.0,
    "tests/test_serve_sharded.py": 25.0,
    "tests/test_serve_sharded_prop.py": 10.0,
    "tests/test_serve_donation.py": 10.0,
    "tests/test_serve_frontend.py": 5.0,
    "tests/test_serve_workload.py": 4.0,
    "tests/test_serve_workload_prop.py": 2.0,
}
DEFAULT_S = 5.0


def recorded_durations() -> dict[str, float]:
    merged: dict[str, float] = {}
    for path in sorted(glob.glob(os.path.join(DURATIONS_DIR, "*.json"))):
        try:
            with open(path) as fh:
                merged.update(json.load(fh))
        except (OSError, ValueError):
            continue
    return merged


def discover_files() -> list[str]:
    files = sorted(glob.glob(os.path.join(REPO, "tests", "test_*.py")))
    return [os.path.relpath(f, REPO) for f in files]


def split(files: list[str], durations: dict[str, float],
          num_shards: int) -> list[list[str]]:
    """Greedy LPT: heaviest file to the lightest shard; deterministic."""
    cost = {f: float(durations.get(f, PRIOR_S.get(f, DEFAULT_S)))
            for f in files}
    shards: list[list[str]] = [[] for _ in range(num_shards)]
    totals = [0.0] * num_shards
    for f in sorted(files, key=lambda f: (-cost[f], f)):
        i = min(range(num_shards), key=lambda i: (totals[i], i))
        shards[i].append(f)
        totals[i] += cost[f]
    return [sorted(s) for s in shards]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--shard", type=int, required=True)
    ap.add_argument("--num-shards", type=int, default=2)
    ap.add_argument("--explain", action="store_true",
                    help="print every shard with per-file costs to stderr")
    args = ap.parse_args(argv)
    if not 0 <= args.shard < args.num_shards:
        ap.error(f"--shard must be in [0, {args.num_shards})")
    durations = recorded_durations()
    files = discover_files()
    shards = split(files, durations, args.num_shards)
    if args.explain:
        for i, shard in enumerate(shards):
            total = sum(durations.get(f, PRIOR_S.get(f, DEFAULT_S))
                        for f in shard)
            print(f"# shard {i} (~{total:.0f}s, "
                  f"{'recorded' if durations else 'priors'}):",
                  file=sys.stderr)
            for f in shard:
                src = durations.get(f)
                cost = src if src is not None else PRIOR_S.get(f, DEFAULT_S)
                tag = "" if src is not None else " (prior)"
                print(f"#   {cost:7.1f}s{tag}  {f}", file=sys.stderr)
    print(" ".join(shards[args.shard]))
    return 0


if __name__ == "__main__":
    sys.exit(main())
