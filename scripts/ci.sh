#!/usr/bin/env bash
# CI smoke: tier-1 tests + the quick dissection sweep on the simulator
# backends.  Fails on any test regression or any DEVIATION/ERROR verdict.
#
#   bash scripts/ci.sh            # from the repo root
#
# Stages:
#   1. tier-1: python -m pytest -q   (optional deps are importorskip'd)
#   2. docs freshness: docs/experiments.md must match the registry
#   3. python -m repro.bench run --quick --strict  (exit 1 on DEVIATION)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
# tests/test_pipeline.py has been failing since the seed (all 3 tests;
# tracked in ROADMAP.md); the gate here is "no worse than seed", so it is
# excluded and everything else must pass.
python -m pytest -q --ignore=tests/test_pipeline.py

echo "== docs freshness =="
python -m repro.bench docs --check

echo "== quick dissection sweep (strict) =="
python -m repro.bench run --quick --strict --no-csv \
  --out experiments/bench/ci.json --report experiments/bench/ci.md

echo "CI OK"
