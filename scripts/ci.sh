#!/usr/bin/env bash
# CI smoke: tier-1 tests + the quick dissection sweep on the simulator
# backends.  Fails on any test regression, any DEVIATION/ERROR verdict, or
# a blown wall-clock budget.
#
#   bash scripts/ci.sh            # from the repo root
#
# Stages:
#   1. tier-1: python -m pytest -q   (optional deps are importorskip'd)
#   2. docs freshness: every generated doc must match its source —
#      docs/experiments.md (registry), docs/serving.md (serving-layer
#      constants), docs/profiles.md (committed profile artifacts),
#      docs/cli.md (the argparse definitions themselves)
#   2b. profile artifacts: experiments/profiles/*.json must validate
#       against the repro.profile/v1 schema and be fresh (dissected under
#       the current trace-engine version + device-registry fingerprint)
#   2c. example smoke: the fleet streaming example end to end (--quick)
#       plus the sharded-serve example on a forced 2-device host mesh
#   2d. fault-campaign smoke: the chaos tier through the launcher's
#       --faults path — the seeded campaign runs twice and must replay
#       bit-identically (leaks/unclassified requests also exit 1)
#   2d'. workload smoke: a seeded chat trace through the launcher's
#        --workload path with --workload-replay — the trace, SLO report
#        and decision log must be bit-identical across two fresh fleets
#        (divergence, leaks, or dropped requests exit 1); plus the
#        capacity planner on the jax-free --plan path
#   2d'''. tier smoke: a 3-replica auto-tiered fleet on a seeded chat
#        trace with --workload-replay — two-stage (admit + handoff)
#        decisions, SLO report and trace must replay bit-identically
#        (divergence, leaked pages, or dropped requests exit 1); plus
#        the per-tier capacity planner on the jax-free --plan path
#   2d''''. dissect-speed: the full blind GTX980 structure search through
#        the batched jax engine — no quick mode, trace cache bypassed —
#        under CI_DISSECT_BUDGET_S (default 60); plus the
#        dissect-on-start fleet example smoke (examples/dissect_serve.py)
#   2e. mesh stage: the sharded-serving suite re-run in-process on an
#       8-way forced host-device mesh (the skipif'd width tests only
#       activate here — the single-device tier-1 run covers the rest)
#   3. python -m repro.bench run --quick --strict  (exit 1 on DEVIATION)
#   4. wall-clock budgets: tier-1 < CI_TIER1_BUDGET_S (default 300 —
#      raised from 240 when the fleet suite + generated-docs CLI tests
#      landed in PR 5; both shards run ~245s balanced on 2 cores),
#      quick sweep < CI_SWEEP_BUDGET_S (default 60).  Budgets assume the
#      warm caches a CI workspace keeps between runs (.cache/jax XLA
#      artifacts, experiments/traces); a cold container pays one-time
#      compile costs — set CI_SKIP_BUDGET=1 there, or when bisecting
#      under load.  The dissection-harness tests themselves finish in
#      ~15 s; the budget's floor is the jax model-zoo compute, so tier-1
#      runs as two parallel pytest shards, duration-balanced by
#      scripts/shard_tests.py from recorded per-file timings,
#      and the default budget reflects a 2-core host — tighten it on
#      bigger CI machines.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

TIER1_BUDGET="${CI_TIER1_BUDGET_S:-300}"
SWEEP_BUDGET="${CI_SWEEP_BUDGET_S:-60}"
DISSECT_BUDGET="${CI_DISSECT_BUDGET_S:-60}"

echo "== tier-1 tests (2 duration-balanced shards) =="
# shards are split by the per-file durations the previous run recorded
# (.cache/test_durations/, written via the conftest --durations-path
# hook); a cold workspace falls back to the priors in shard_tests.py
mkdir -p .cache/test_durations
shard0_files=$(python scripts/shard_tests.py --shard 0 --num-shards 2)
shard1_files=$(python scripts/shard_tests.py --shard 1 --num-shards 2)
t0=$SECONDS
python -m pytest -q $shard0_files \
  --durations-path .cache/test_durations/shard0.json &
shard_a=$!
rc_b=0
python -m pytest -q $shard1_files \
  --durations-path .cache/test_durations/shard1.json || rc_b=$?
rc_a=0
wait "$shard_a" || rc_a=$?
[[ $rc_a == 0 && $rc_b == 0 ]] || exit 1
tier1_s=$((SECONDS - t0))
echo "tier-1 wall time: ${tier1_s}s (budget ${TIER1_BUDGET}s)"

echo "== docs freshness =="
python -m repro.bench docs --check

echo "== profile artifacts (repro.profile/v1 schema + staleness) =="
# committed profiles must validate against the schema AND be fresh: a
# profile dissected under an older trace-engine version or a different
# device registry cannot be reproduced, so it fails the build
python -m repro.bench profile validate

echo "== example smoke (fleet streaming front end) =="
python examples/fleet_serve.py --quick

echo "== example smoke (mesh-sharded paged serving, 2-way host mesh) =="
XLA_FLAGS=--xla_force_host_platform_device_count=2 \
  python examples/sharded_serve.py --quick

echo "== fault-campaign smoke (chaos tier, replay-verified) =="
# seeded kill/corrupt/degrade campaign run twice through the launcher;
# it exits 1 itself on any replay divergence, leaked page, or
# unclassified request
python -m repro.launch.serve --arch granite-8b --smoke --engine fleet \
  --replicas 2 --requests 10 --slots 3 --max-len 48 \
  --faults 1 --fault-rate 0.15

echo "== workload smoke (seeded traffic + SLO accounting, replay-verified) =="
# seeded chat trace replayed twice through fresh fleets; the launcher
# exits 1 itself on any trace/SLO-report/decision-log divergence, leaked
# page, or dropped request
python -m repro.launch.serve --arch granite-8b --smoke --engine fleet \
  --replicas 2 --slots 3 --max-len 48 \
  --workload chat --rate 0.5 --horizon 16 --workload-replay
# capacity planner on the jax-free accounting path (ranks profiles,
# never builds a fleet)
python -m repro.launch.serve --arch granite-8b --smoke --engine fleet \
  --fleet-profiles tpu_v5e,TeslaV100 --workload rag --rate 0.8 --plan

echo "== tier smoke (disaggregated prefill/decode, replay-verified) =="
# auto-tiered 3-replica fleet on a seeded chat trace: the launcher runs
# the trace twice and exits 1 itself on any divergence in the merged
# admit+handoff decision log, the SLO report, or the streamed tokens —
# or on leaked pages / unclassified requests
python -m repro.launch.serve --arch granite-8b --smoke --engine fleet \
  --replicas 3 --slots 3 --max-len 48 --fleet-tiers auto \
  --workload chat --rate 0.5 --horizon 16 --workload-replay
# per-tier capacity planner on the jax-free accounting path: how many
# prefill vs decode replicas of which profile, handoff folded into TTFT
python -m repro.launch.serve --arch granite-8b --smoke --engine fleet \
  --fleet-profiles tpu_v5e,TeslaV100 --fleet-tiers auto \
  --workload rag --rate 0.8 --plan

echo "== dissect-speed (full blind GTX980 search, batched jax engine) =="
# the whole structure search — no quick mode, no skipped structures —
# with the trace cache bypassed so the budget times real simulation
# work, not cache replay.  Sub-second warm; the budget's floor is the
# one-time XLA compile of the scan kernel on a cold workspace.
t0=$SECONDS
python - <<'PY'
from repro.core import tracecache
from repro.profile.pipeline import dissect_device
with tracecache.disabled():
    prof = dissect_device("GTX980", engine="jax")
measured = sum(1 for c in prof.caches.values() if c.provenance == "measured")
assert prof.engine == "jax", prof.engine
assert measured >= 3, f"only {measured} structures measured"
assert prof.timings.get("total", 0.0) > 0.0, prof.timings
print(f"GTX980: {measured} structures, engine={prof.engine}, "
      f"stage total {prof.timings['total']:.3f}s")
PY
dissect_s=$((SECONDS - t0))
echo "blind dissection wall time: ${dissect_s}s (budget ${DISSECT_BUDGET}s)"

echo "== example smoke (dissect-on-start fleet binding) =="
python examples/dissect_serve.py --quick

echo "== mesh stage (sharded serving on an 8-way host-device mesh) =="
# the width-invariance tests skip themselves on a single-device host;
# forcing 8 host devices runs them in-process (the tier-1 pass above
# already ran this file's subprocess variants on 1 device)
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  python -m pytest -q tests/test_serve_sharded.py tests/test_serve_donation.py

echo "== quick dissection sweep (strict) =="
t0=$SECONDS
python -m repro.bench run --quick --strict --no-csv \
  --out experiments/bench/ci.json --report experiments/bench/ci.md
sweep_s=$((SECONDS - t0))
echo "quick sweep wall time: ${sweep_s}s (budget ${SWEEP_BUDGET}s)"

echo "== wall-clock budgets =="
if [[ "${CI_SKIP_BUDGET:-0}" != "1" ]]; then
  fail=0
  if (( tier1_s >= TIER1_BUDGET )); then
    echo "BUDGET EXCEEDED: tier-1 took ${tier1_s}s >= ${TIER1_BUDGET}s" >&2
    fail=1
  fi
  if (( sweep_s >= SWEEP_BUDGET )); then
    echo "BUDGET EXCEEDED: quick sweep took ${sweep_s}s >= ${SWEEP_BUDGET}s" >&2
    fail=1
  fi
  if (( dissect_s >= DISSECT_BUDGET )); then
    echo "BUDGET EXCEEDED: blind dissection took ${dissect_s}s >= ${DISSECT_BUDGET}s" >&2
    fail=1
  fi
  [[ $fail == 0 ]] || exit 1
else
  echo "(skipped: CI_SKIP_BUDGET=1)"
fi

echo "CI OK"
