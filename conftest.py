"""Repo-wide pytest configuration.

* Makes ``src/`` importable even without PYTHONPATH, so a bare ``pytest``
  works from the repo root.
* Turns on the persistent JAX compilation cache (``.cache/jax``): the
  tier-1 suite is dominated by XLA recompiling identical model graphs, and
  a warm cache removes nearly all of that.  Set ``REPRO_NO_JAX_CACHE=1``
  to measure cold-compile behaviour.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "src"))

from repro import jaxcache  # noqa: E402

# env-var route: configures the cache without importing jax, so jax-free
# test subsets don't pay the import at collection time
jaxcache.enable_env()
