"""Repo-wide pytest configuration.

* Makes ``src/`` importable even without PYTHONPATH, so a bare ``pytest``
  works from the repo root.
* Turns on the persistent JAX compilation cache (``.cache/jax``): the
  tier-1 suite is dominated by XLA recompiling identical model graphs, and
  a warm cache removes nearly all of that.  Set ``REPRO_NO_JAX_CACHE=1``
  to measure cold-compile behaviour.
* ``--durations-path FILE``: record per-test-FILE wall time (setup + call
  + teardown) as a JSON artifact.  scripts/ci.sh points each tier-1 shard
  at ``.cache/test_durations/shard<N>.json``; scripts/shard_tests.py then
  splits the next run's shards by these recorded durations so the two
  shards' makespans stay balanced as the suite grows.
"""

import collections
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "src"))

from repro import jaxcache  # noqa: E402

# env-var route: configures the cache without importing jax, so jax-free
# test subsets don't pay the import at collection time
jaxcache.enable_env()


def pytest_addoption(parser):
    parser.addoption(
        "--durations-path", default=None, metavar="FILE",
        help="write accumulated per-test-file durations (JSON seconds) "
             "here at session end; used by scripts/shard_tests.py")


_SESSION_DURATIONS = collections.defaultdict(float)


def pytest_runtest_logreport(report):
    # accumulate every phase so fixture-heavy modules are priced fairly
    path = report.nodeid.split("::", 1)[0]
    _SESSION_DURATIONS[path] += report.duration


def pytest_sessionfinish(session, exitstatus):
    out = session.config.getoption("--durations-path")
    if not out or not _SESSION_DURATIONS:
        return
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as fh:
        json.dump({k: round(v, 3) for k, v in
                   sorted(_SESSION_DURATIONS.items())}, fh, indent=1)
        fh.write("\n")
