"""Trace cache + parallel runner: reuse must be invisible except in speed."""

import json
import os

import numpy as np
import pytest

from repro.bench import registry, runner
from repro.bench.runner import RunOptions, record_seed, run_experiments
from repro.core import devices, tracecache
from repro.core.pchase import cache_backend, fine_grained
from repro.core.trace import PChaseConfig


@pytest.fixture
def cache_root(tmp_path):
    tracecache.configure(str(tmp_path / "traces"))
    yield str(tmp_path / "traces")
    tracecache.configure(None)


class TestTraceCacheRoundTrip:
    def test_second_run_skips_simulation(self, cache_root):
        calls = []

        def mk():
            calls.append(1)
            return devices.kepler_texture_l1()

        be = cache_backend(mk, trace_id="kepler_texture_l1")
        tr1 = fine_grained(be, 12 << 10, 32, passes=4)
        assert calls, "first run must simulate"
        calls.clear()
        tr2 = fine_grained(be, 12 << 10, 32, passes=4)
        assert not calls, "second run must come from the trace cache"
        np.testing.assert_array_equal(tr1.indices, tr2.indices)
        np.testing.assert_array_equal(tr1.latencies, tr2.latencies)
        np.testing.assert_array_equal(tr1.meta["true_miss"],
                                      tr2.meta["true_miss"])
        assert tr2.meta["miss_threshold"] == tr1.meta["miss_threshold"]

    def test_shared_across_backend_instances(self, cache_root):
        be1 = devices.sim_cache_backend("l2_tlb")
        be2 = devices.sim_cache_backend("l2_tlb")
        tr1 = fine_grained(be1, 134 * (1 << 20), 2 << 20, passes=3)
        tc = tracecache.default_cache()
        h0 = tc.hits
        tr2 = fine_grained(be2, 134 * (1 << 20), 2 << 20, passes=3)
        assert tc.hits == h0 + 1
        np.testing.assert_array_equal(tr1.latencies, tr2.latencies)

    def test_custom_indices_round_trip(self, cache_root):
        be = devices.sim_cache_backend("kepler_texture_l1")
        idx = np.resize(np.arange(97, dtype=np.int64) * 8, 500)
        cfg = PChaseConfig(16 << 10, 32, len(idx), 4, 0)
        tr1 = be(cfg, indices=idx)
        tr2 = be(cfg, indices=idx)
        np.testing.assert_array_equal(tr1.indices, tr2.indices)
        np.testing.assert_array_equal(tr1.latencies, tr2.latencies)

    def test_disabled_by_default(self, tmp_path, monkeypatch):
        monkeypatch.delenv("REPRO_TRACE_CACHE_DIR", raising=False)
        tracecache.configure(None)
        assert tracecache.default_cache() is None


class TestTraceCacheKeys:
    def test_key_sensitivity(self, cache_root):
        tc = tracecache.default_cache()
        cfg = PChaseConfig(4096, 32, 100, 4, 2)
        base = tc.key("a", cfg)
        assert tc.key("b", cfg) != base
        assert tc.key("a", cfg, seed=1) != base
        assert tc.key("a", PChaseConfig(4096, 64, 100, 4, 2)) != base
        assert tc.key("a", cfg, extra={"t_hit": 10.0}) != base
        idx = np.arange(5, dtype=np.int64)
        assert tc.key("a", cfg, indices=idx) != base
        assert tc.key("a", cfg, indices=idx) == tc.key("a", cfg, indices=idx)

    def test_engine_versions_never_cross_serve(self, cache_root):
        """A jax-engine entry must never satisfy a numpy-engine lookup.

        The two engines agree bit-for-bit on deterministic geometries, but
        their cache entries are keyed under distinct engine-version prefixes
        so a semantics change in either engine invalidates only its own
        entries.
        """
        jax = pytest.importorskip("jax")  # noqa: F841
        from repro.core.cachesim import ENGINE_VERSION
        from repro.core.cachesim_jax import JAX_ENGINE_VERSION

        tc = tracecache.default_cache()
        cfg = PChaseConfig(4096, 32, 100, 4, 2)
        k_np = tc.key("kepler_texture_l1", cfg)
        k_jx = tc.key("kepler_texture_l1", cfg,
                      engine_version=JAX_ENGINE_VERSION)
        assert k_np != k_jx
        assert k_np.startswith(ENGINE_VERSION.replace("/", "-") + "/")
        assert k_jx.startswith(JAX_ENGINE_VERSION.replace("/", "-") + "/")
        # distinct on-disk directories, so neither can shadow the other
        assert os.path.dirname(os.path.dirname(tc._path(k_np))) != \
               os.path.dirname(os.path.dirname(tc._path(k_jx)))

        # end-to-end: populate via the jax backend, then show the numpy
        # backend still simulates (cache miss), and vice versa.
        be_jx = cache_backend(devices.kepler_texture_l1,
                              trace_id="kepler_texture_l1", engine="jax")
        be_np = cache_backend(devices.kepler_texture_l1,
                              trace_id="kepler_texture_l1", engine="vector")
        fine_grained(be_jx, 12 << 10, 32, passes=4)
        h0 = tc.hits
        tr_np = fine_grained(be_np, 12 << 10, 32, passes=4)
        assert tc.hits == h0, "numpy lookup must not hit the jax entry"
        tr_jx = fine_grained(be_jx, 12 << 10, 32, passes=4)
        assert tc.hits == h0 + 1, "jax entry serves only the jax engine"
        np.testing.assert_array_equal(tr_np.latencies, tr_jx.latencies)

    def test_corrupt_entry_is_a_miss(self, cache_root):
        tc = tracecache.default_cache()
        cfg = PChaseConfig(4096, 32, 100, 4, 2)
        key = tc.key("x", cfg)
        path = tc._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "wb") as fh:
            fh.write(b"not an npz")
        assert tc.get(key, cfg, rebuild_indices=np.arange(100)) is None
        assert not os.path.exists(path), "corrupt entries are dropped"


class TestEviction:
    def test_size_cap_prunes_oldest(self, tmp_path):
        tc = tracecache.TraceCache(str(tmp_path), max_bytes=1)
        tc._EVICT_EVERY = 0                       # evict on every put
        cfg = PChaseConfig(4096, 32, 2048, 4, 2)
        be = cache_backend(devices.kepler_texture_l1,
                           trace_id="kepler_texture_l1")
        tracecache._default = tc
        tracecache._configured = True
        try:
            for s in (32, 64, 128, 256):
                fine_grained(be, 12 << 10, s, passes=2)
            files = [os.path.join(dp, f) for dp, _, fs in os.walk(str(tmp_path))
                     for f in fs if f.endswith(".npz")]
            assert len(files) <= 1, "cap must prune all but the newest"
        finally:
            tracecache.configure(None)


class TestParallelRunner:
    def test_record_seed_deterministic(self):
        assert record_seed(0, "e", "d") == record_seed(0, "e", "d")
        assert record_seed(0, "e", "d") != record_seed(1, "e", "d")
        assert record_seed(0, "e", "d1") != record_seed(0, "e", "d2")

    def test_pooled_matches_serial(self):
        """jobs=2 must return the same records, same order, as jobs=1."""
        registry.discover()
        names = ("fig19_kepler_modes", "table8_bank_conflict")
        serial = run_experiments(RunOptions(names=names, quick=True, jobs=1,
                                            device="GTX780"))
        pooled = run_experiments(RunOptions(names=names, quick=True, jobs=2,
                                            device="GTX780"))
        assert [(r.experiment, r.device) for r in serial] == \
               [(r.experiment, r.device) for r in pooled]
        for a, b in zip(serial, pooled):
            assert a.verdict == b.verdict
            assert [(m.name, m.measured) for m in a.metrics] == \
                   [(m.name, m.measured) for m in b.metrics]

    def test_historical_costs_tolerates_missing(self, tmp_path):
        assert runner._historical_costs(str(tmp_path / "nope.json")) == {}
        p = tmp_path / "bad.json"
        p.write_text("{")
        assert runner._historical_costs(str(p)) == {}
        good = tmp_path / "good.json"
        good.write_text(json.dumps({"records": [
            {"experiment": "e", "device": "d", "elapsed_s": 1.5}]}))
        assert runner._historical_costs(str(good)) == {("e", "d"): 1.5}
