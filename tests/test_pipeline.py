"""Pipeline-parallelism primitive: exactness vs sequential execution and
differentiability (subprocess: needs multiple host devices)."""

import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 4) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=600)


BODY = """
import jax, jax.numpy as jnp
from repro.parallel.pipeline import pipeline_apply, stack_stages

S, M, B, D = {stages}, {micro}, 2, 16
mesh = jax.make_mesh(({stages},), ("stage",),
                     devices=jax.devices()[:{stages}])
ws = jax.random.normal(jax.random.key(0), (4, D, D)) * 0.3

def stage_fn(w, x):
    w = w.reshape(-1, D, D)
    def body(c, wi):
        return jnp.tanh(c @ wi), None
    return jax.lax.scan(body, x, w)[0]

params = stack_stages(ws, S)
x = jax.random.normal(jax.random.key(1), (M, B, D))
y = pipeline_apply(stage_fn, params, x, mesh=mesh)
ref = x
for s in range(4):
    ref = jnp.tanh(ref @ ws[s])
err = float(jnp.abs(y - ref).max())
assert err < 1e-6, err

def loss(p, x):
    return jnp.sum(pipeline_apply(stage_fn, p, x, mesh=mesh) ** 2)
g = jax.grad(loss)(params, x)
import numpy as np
assert all(np.isfinite(np.asarray(t, np.float32)).all()
           for t in jax.tree.leaves(g))
print("OK", err)
"""


class TestPipeline:
    def test_four_stages_exact_and_differentiable(self):
        r = run_py(BODY.format(stages=4, micro=8))
        assert "OK" in r.stdout, r.stdout + r.stderr

    def test_two_stages_two_units_each(self):
        r = run_py(BODY.format(stages=2, micro=6))
        assert "OK" in r.stdout, r.stdout + r.stderr

    def test_single_microbatch_edge(self):
        r = run_py(BODY.format(stages=4, micro=1))
        assert "OK" in r.stdout, r.stdout + r.stderr
