"""Buffer-donation regression: the paged cache updates in place.

``PagedServeEngine`` jits its step functions with ``donate_argnums`` on
the cache operand; under a mesh it additionally pins ``out_shardings``
to the input cache's exact layout so XLA aliases every pool shard
(copy-free update).  Donation silently degrades to a copy when the
aliasing fails — XLA only *warns* — so this pins the contract directly:

* after every step the PREVIOUS cache's leaves are deleted (the buffers
  were really consumed, not copied),
* the process-wide live-buffer count stays flat across N decode steps
  (no per-step cache ghost), and
* no "donated buffer" warning is raised anywhere in the run.

Both the unsharded engine and the 1-device-mesh engine (the
``out_shardings`` + ``shard_map`` path) are held to the same contract.
"""

import warnings

import jax
import numpy as np
import pytest

from repro.launch.mesh import make_serve_mesh
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serve.engine import PagedServeEngine, Request

MICRO = ModelConfig(name="micro", family="dense", num_layers=2, d_model=32,
                    d_ff=64, vocab_size=64, num_heads=2, num_kv_heads=2,
                    dtype="float32", param_dtype="float32")


@pytest.fixture(scope="module")
def params():
    return T.init_params(MICRO, jax.random.key(0))


def _engine(params, mesh):
    eng = PagedServeEngine(MICRO, params, max_slots=2, max_len=32,
                           page_len=4, mesh=mesh)
    eng.submit(Request(0, np.arange(4, dtype=np.int32) + 1, 20))
    return eng


@pytest.mark.parametrize("meshed", [False, True],
                         ids=["unsharded", "mesh1"])
class TestDonation:
    def test_cache_buffers_consumed_every_step(self, params, meshed):
        eng = _engine(params, make_serve_mesh(1) if meshed else None)
        for _ in range(6):
            before = jax.tree.leaves(eng.cache)
            eng.step()
            assert all(leaf.is_deleted() for leaf in before), \
                "step copied the cache instead of donating it"
            assert not any(leaf.is_deleted()
                           for leaf in jax.tree.leaves(eng.cache))

    def test_live_buffer_count_flat_across_steps(self, params, meshed):
        eng = _engine(params, make_serve_mesh(1) if meshed else None)
        for _ in range(4):          # warm-up: compile both step kinds
            eng.step()
        jax.block_until_ready(eng.cache)
        baseline = len(jax.live_arrays())
        for _ in range(8):
            eng.step()
        jax.block_until_ready(eng.cache)
        assert len(jax.live_arrays()) == baseline, \
            "decode steps leak device buffers (donation not in place?)"

    def test_no_donation_warning_raised(self, params, meshed):
        """XLA reports an unusable donated buffer as a warning, not an
        error — absence of that warning is the actual pass signal."""
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            eng = _engine(params, make_serve_mesh(1) if meshed else None)
            eng.run_to_completion()
        bad = [w for w in caught if "donat" in str(w.message).lower()]
        assert not bad, [str(w.message) for w in bad]
