"""Hypothesis-widened sharding oracle (optional dependency).

Property: for ANY admission/cancel schedule — arbitrary prompt lengths,
token budgets, arrival ticks and mid-flight cancellations — a 1-device
mesh replica produces exactly the unsharded paged engine's token
streams, finishes on the same tick, cancels the same uids, and drains
with zero leaked pages.  The deterministic cases in
``tests/test_serve_sharded.py`` pin the named scenarios; this module
explores the rest of the schedule space.
"""

import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.launch.mesh import make_serve_mesh
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serve.engine import PagedServeEngine, Request

MICRO = ModelConfig(name="micro", family="dense", num_layers=2, d_model=32,
                    d_ff=64, vocab_size=64, num_heads=2, num_kv_heads=2,
                    dtype="float32", param_dtype="float32")
PARAMS = T.init_params(MICRO, jax.random.key(0))

# (prompt_len, max_new, ticks_before_submit, cancel_after_ticks|None)
jobs = st.lists(
    st.tuples(st.integers(1, 12), st.integers(1, 8),
              st.integers(0, 4), st.none() | st.integers(0, 6)),
    min_size=1, max_size=6)


def _drive(mesh, schedule):
    """Replay one admission/cancel schedule tick-for-tick; returns the
    full observable trace (streams, cancels, tick count)."""
    eng = PagedServeEngine(MICRO, PARAMS, max_slots=3, max_len=24,
                           page_len=4, num_pages=14, mesh=mesh)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(MICRO.vocab_size, size=plen).astype(np.int32)
               for plen, _, _, _ in schedule]
    pending = sorted(enumerate(schedule), key=lambda kv: kv[1][2])
    cancel_at = {}          # tick -> [uid]
    tick = 0
    while pending or eng.waiting or eng.prefilling or eng.active:
        while pending and pending[0][1][2] <= tick:
            uid, (plen, n_new, _, cancel) = pending.pop(0)
            eng.submit(Request(uid, prompts[uid], n_new))
            if cancel is not None:
                cancel_at.setdefault(tick + cancel, []).append(uid)
        for uid in cancel_at.pop(tick, ()):
            eng.cancel(uid)
        eng.step()
        eng.check_invariants()
        tick += 1
        assert tick < 500, "schedule failed to drain"
    assert eng.alloc.allocated_pages == 0, "pages leaked at drain"
    return ({r.uid: tuple(r.generated) for r in eng.finished},
            sorted(r.uid for r in eng.cancelled), tick)


@settings(max_examples=25, deadline=None)
@given(schedule=jobs)
def test_any_schedule_mesh1_equals_unsharded(schedule):
    streams_u, cancelled_u, ticks_u = _drive(None, schedule)
    streams_m, cancelled_m, ticks_m = _drive(make_serve_mesh(1), schedule)
    assert streams_m == streams_u, "mesh-1 token streams diverged"
    assert cancelled_m == cancelled_u
    assert ticks_m == ticks_u, "mesh-1 tick schedule diverged"
    # nothing silently dropped: every uid ends finished xor cancelled
    assert set(streams_u) | set(cancelled_u) == set(range(len(schedule)))
    assert set(streams_u).isdisjoint(cancelled_u)
