"""The paper-claims validation: the fine-grained analyzer must re-derive
every Table 5 structure blind from (index, latency) traces.

Deterministic only — these run on bare environments (no hypothesis).
The property-based recovery tests over random geometries live in
tests/test_inference_prop.py, which importorskips hypothesis as a module
so THIS module is never skipped with it."""

import numpy as np

from repro.core import devices, inference
from repro.core.cachesim import Cache, CacheGeometry
from repro.core.pchase import cache_backend

MB = 1 << 20


class TestTable5:
    """Each entry of the paper's Table 5, recovered blind."""

    def test_kepler_texture_l1(self):
        p = inference.dissect(cache_backend(devices.kepler_texture_l1),
                              n_max=64 << 10, max_line=4096)
        assert p.size_bytes == 12 << 10
        assert p.line_bytes == 32
        assert p.num_sets == 4
        assert p.way_counts == [96, 96, 96, 96]
        assert p.is_lru
        assert p.set_bits == (7, 9), "2D-locality mapping: bits 7-8 (Fig 7)"

    def test_kepler_readonly_cache(self):
        p = inference.dissect(cache_backend(devices.kepler_readonly),
                              n_max=64 << 10, max_line=4096)
        assert (p.size_bytes, p.line_bytes, p.num_sets) == (12 << 10, 32, 4)
        assert p.is_lru

    def test_maxwell_unified_l1(self):
        p = inference.dissect(cache_backend(devices.maxwell_unified_l1),
                              n_max=128 << 10, max_line=4096)
        assert p.size_bytes == 24 << 10
        assert p.line_bytes == 32
        assert p.num_sets == 4
        assert p.way_counts == [192, 192, 192, 192]
        assert p.is_lru

    def test_fermi_l1_structure(self):
        p = inference.dissect(cache_backend(devices.fermi_l1_data),
                              n_max=64 << 10, max_line=4096)
        assert p.size_bytes == 16 << 10
        assert p.line_bytes == 128
        assert p.num_sets == 32
        assert not p.is_lru, "Fermi L1 is not LRU (Fig 11)"

    def test_fermi_l1_way_probabilities(self):
        rep = inference.detect_replacement(
            cache_backend(devices.fermi_l1_data), 16 << 10, 128, passes=2000)
        assert not rep.is_lru
        probs = sorted(rep.way_probs)
        np.testing.assert_allclose(probs, [1/6, 1/6, 1/6, 1/2], atol=0.04)

    def test_l1_tlb(self):
        be = cache_backend(devices.l1_tlb)
        c = inference.find_cache_size(be, n_max=256 * MB, n_min=4 * MB,
                                      stride_bytes=2 * MB, granularity=2 * MB)
        assert c == 32 * MB            # 16 entries x 2 MB pages
        ways = inference.conflict_set_ways(be, c, 2 * MB)
        assert ways == 16              # fully associative

    def test_l2_tlb_unequal_sets(self):
        be = cache_backend(devices.l2_tlb)
        c = inference.find_cache_size(be, n_max=512 * MB, n_min=8 * MB,
                                      stride_bytes=2 * MB, granularity=2 * MB)
        assert c == 130 * MB           # 65 entries
        page = inference.find_line_size(be, c, stride_bytes=2 * MB,
                                        granularity=256 << 10,
                                        max_line=8 * MB)
        assert page == 2 * MB
        st_ = inference.recover_set_structure(be, c, 2 * MB, max_steps=80)
        assert st_.way_counts == [17, 8, 8, 8, 8, 8, 8], \
            "the unequal-set L2 TLB (Fig 9)"
        assert not st_.uniform
        rep = inference.detect_replacement(be, c, 2 * MB, passes=10)
        assert rep.is_lru


class TestL2DataCacheFindings:
    """The paper's three L2 findings (§4.6)."""

    def test_aperiodic_replacement(self):
        be = cache_backend(lambda: devices.l2_data(64 << 10))
        rep = inference.detect_replacement(be, 64 << 10, 32, passes=30)
        assert not rep.is_lru

    def test_line_size_32(self):
        be = cache_backend(lambda: devices.l2_data(64 << 10))
        # min-gap signal from overflow-by-one (modulo map, random policy)
        tr_line = inference.find_line_size(be, 64 << 10, max_line=1024)
        assert tr_line == 32

    def test_prefetch_no_cold_misses(self):
        # stream an array < 2/3 of capacity on a COLD cache: only the very
        # first access may miss
        cache = devices.l2_data(512 << 10)
        n = int(0.6 * (512 << 10))
        misses = sum(not cache.access(a) for a in range(0, n, 32))
        assert misses <= 1


class TestFindSetBits:
    def test_traditional_vs_texture(self):
        # same shape as texture L1 but classical adjacent-bits mapping
        trad = lambda: Cache(CacheGeometry.uniform("trad", 12 << 10, 32, 4))
        bits = inference.find_set_bits(cache_backend(trad), 32, 96, 4)
        assert bits == (5, 7)
        bits = inference.find_set_bits(
            cache_backend(devices.kepler_texture_l1), 32, 96, 4)
        assert bits == (7, 9)


# The hypothesis-widened random-geometry recovery properties live in
# tests/test_inference_prop.py (importorskip'd as a module, so the
# deterministic Table 5 validations above run on bare environments).
