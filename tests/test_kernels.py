"""Per-kernel validation: interpret-mode Pallas vs the pure-jnp oracle,
with shape/dtype sweeps and hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.pchase import uniform_init


class TestPChaseKernel:
    @pytest.mark.parametrize("n,stride", [(64, 4), (128, 8), (96, 12),
                                          (1024, 32)])
    def test_uniform_chase_matches_ref(self, n, stride):
        a = uniform_init(n, stride)
        k = 2 * n // stride
        tr = ops.pchase_trace(a, k)
        np.testing.assert_array_equal(np.asarray(tr),
                                      ref.pchase_ref(np.asarray(a), k))

    def test_nonuniform_init(self):
        """Fig 13b: arbitrary pointer graphs chase identically."""
        rng = np.random.default_rng(0)
        a = rng.permutation(256).astype(np.int32)
        tr = ops.pchase_trace(a, 300)
        np.testing.assert_array_equal(np.asarray(tr), ref.pchase_ref(a, 300))

    def test_start_offset(self):
        a = uniform_init(64, 4)
        tr = ops.pchase_trace(a, 10, start=8)
        np.testing.assert_array_equal(np.asarray(tr),
                                      ref.pchase_ref(np.asarray(a), 10, 8))

    @settings(max_examples=10, deadline=None)
    @given(st.integers(16, 512), st.data())
    def test_property_any_permutation(self, n, data):
        """Invariant: the kernel trace equals the serial chase for ANY
        single-cycle pointer graph."""
        seed = data.draw(st.integers(0, 2**31 - 1))
        rng = np.random.default_rng(seed)
        perm = rng.permutation(n)
        a = np.empty(n, dtype=np.int32)
        a[perm] = np.roll(perm, -1)          # one n-cycle
        tr = ops.pchase_trace(a, n + 7)
        np.testing.assert_array_equal(np.asarray(tr), ref.pchase_ref(a, n + 7))


class TestMemcpyKernel:
    @pytest.mark.parametrize("shape,block", [((512, 128), 128),
                                             ((1024, 256), 256),
                                             ((256, 512), 64)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
    def test_copy(self, shape, block, dtype):
        x = jnp.arange(np.prod(shape)).reshape(shape).astype(dtype)
        y = ops.memcpy(x, block_rows=block)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))

    def test_bad_block_raises(self):
        with pytest.raises(ValueError):
            ops.memcpy(jnp.ones((100, 128)), block_rows=64)


class TestStridedKernel:
    @pytest.mark.parametrize("stride", [1, 2, 4, 6, 8, 16, 32, 64, 128])
    def test_strides(self, stride):
        x = jnp.arange(128 * 8, dtype=jnp.float32).reshape(128, 8)
        y = ops.strided_gather(x, stride)
        np.testing.assert_array_equal(np.asarray(y),
                                      np.asarray(ref.strided_ref(x, stride)))

    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 257), st.sampled_from([32, 64, 128]))
    def test_property(self, stride, n):
        x = jnp.arange(n * 4, dtype=jnp.float32).reshape(n, 4)
        y = ops.strided_gather(x, stride)
        np.testing.assert_array_equal(np.asarray(y),
                                      np.asarray(ref.strided_ref(x, stride)))


class TestFlashAttention:
    def _run(self, batch, h, hkv, sq, sk, d, causal, dtype, bq=128, bk=128):
        kq = jax.random.key(0)
        q = jax.random.normal(kq, (batch * h, sq, d), dtype)
        k = jax.random.normal(jax.random.key(1), (batch * hkv, sk, d), dtype)
        v = jax.random.normal(jax.random.key(2), (batch * hkv, sk, d), dtype)
        out = ops.flash_attention(q, k, v, num_q_heads=h, num_kv_heads=hkv,
                                  causal=causal, block_q=bq, block_k=bk)
        exp = ref.attention_ref(q, k, v, num_q_heads=h, num_kv_heads=hkv,
                                causal=causal)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(exp, np.float32),
                                   atol=tol, rtol=tol)

    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_mha(self, causal, dtype):
        self._run(2, 4, 4, 256, 256, 64, causal, dtype)

    @pytest.mark.parametrize("h,hkv", [(8, 2), (4, 1), (16, 8)])
    def test_gqa_ratios(self, h, hkv):
        self._run(1, h, hkv, 256, 256, 64, True, jnp.float32)

    @pytest.mark.parametrize("bq,bk", [(64, 128), (128, 64), (256, 256),
                                       (64, 64)])
    def test_block_shapes(self, bq, bk):
        self._run(1, 2, 2, 256, 256, 64, True, jnp.float32, bq, bk)

    def test_rectangular_and_small_head_dim(self):
        self._run(1, 2, 1, 128, 512, 32, False, jnp.float32)

    def test_long_seq_small_blocks(self):
        self._run(1, 1, 1, 1024, 1024, 64, True, jnp.float32, 128, 128)

    def test_bad_divisibility_raises(self):
        q = jnp.ones((2, 100, 64))
        with pytest.raises(ValueError):
            ops.flash_attention(q, q, q, num_q_heads=2, num_kv_heads=2,
                                block_q=64, block_k=64)

    def test_attention_dispatch(self):
        q = jax.random.normal(jax.random.key(3), (2, 128, 64))
        a = ops.attention(q, q, q, num_q_heads=2, num_kv_heads=2, impl="ref")
        b = ops.attention(q, q, q, num_q_heads=2, num_kv_heads=2,
                          impl="flash", block_q=64, block_k=64)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
