"""Three-way differential: reference ``Cache`` vs ``VectorCache`` vs the
batched ``BatchCache`` jax engine.

The oracle chain is ``Cache`` → ``VectorCache`` → ``BatchCache``: for
deterministic policies (lru/fifo) every engine must produce bit-identical
hit/miss streams on both BatchCache paths (cyclic closed form AND the
vmapped ``lax.scan``); stochastic policies (random/prob) are validated
distributionally, per the RNG-lane equivalence policy documented in
``core/cachesim_jax.py``.  On top of the engine, the batched inference
drivers (wave search) must recover exactly the same structures as the
serial drivers.

The whole module is skipped when jax is absent, matching the repo's
stub-or-gate convention; the numpy differentials in
``test_engine_equivalence.py`` still run there.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import devices, inference
from repro.core.cachesim import Cache, CacheGeometry, ReplacementPolicy
from repro.core.cachesim_jax import JAX_ENGINE_VERSION, BatchCache
from repro.core.pchase import cache_backend, fine_grained
from repro.core.trace import PChaseConfig

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def _device_cache_factories():
    cases = [(name, mk) for name, mk in devices.SIM_CACHES.items()]
    cases.append(("l2_data_64k", lambda: devices.l2_data(64 << 10)))
    return cases


_CUSTOM_GEOMS = [
    CacheGeometry("lru_uniform", 32, (4,) * 8),
    CacheGeometry("fifo_uniform", 64, (2,) * 16,
                  replacement=ReplacementPolicy("fifo")),
    CacheGeometry("lru_unequal", 32, (1, 3, 5, 2)),
    CacheGeometry("fifo_unequal", 32, (2, 7, 1, 4),
                  replacement=ReplacementPolicy("fifo")),
    CacheGeometry("rand_uniform", 32, (4,) * 4,
                  replacement=ReplacementPolicy("random")),
    CacheGeometry("prob_skewed", 32, (4,) * 4,
                  replacement=ReplacementPolicy(
                      "prob", (1 / 6, 1 / 2, 1 / 6, 1 / 6))),
    # NB prob + unequal way counts is outside every engine's envelope:
    # the reference oracle draws rng.choice(ways, p=way_probs), which
    # requires one probability per way of the widest uniform set.
    CacheGeometry("prob_flat", 32, (3,) * 8,
                  replacement=ReplacementPolicy(
                      "prob", (0.6, 0.25, 0.15))),
]


def _streams_for(geom, rng):
    c, b = geom.size_bytes, geom.line_bytes
    fit = (np.arange(2048, dtype=np.int64) * b) % c
    thrash = (np.arange(2048, dtype=np.int64) * b) % (c + 4 * b)
    rand = np.asarray(rng.integers(0, 4 * c, size=1500), dtype=np.int64)
    mixed = np.concatenate([fit[:600], rand[:400], thrash[:600]])
    return {"fit": fit, "thrash": thrash, "random": rand, "mixed": mixed}


def _ref_hits(geom, addrs):
    ref = Cache(geom)
    return np.fromiter((ref.access(int(a)) for a in addrs),
                       dtype=bool, count=len(addrs))


class TestThreeWayDifferential:
    """BatchCache (both paths) vs the per-access oracle (which
    test_engine_equivalence.py already pins VectorCache against)."""

    @pytest.mark.parametrize("geom", _CUSTOM_GEOMS, ids=lambda g: g.name)
    def test_custom_geometries(self, geom):
        rng = np.random.default_rng(hash(geom.name) % (2 ** 31))
        streams = _streams_for(geom, rng)
        sim = BatchCache([geom] * len(streams))
        lanes = list(streams.values())
        auto = sim.simulate(lanes)
        scan = sim.simulate(lanes, force_scan=True)
        deterministic = geom.replacement.kind in ("lru", "fifo")
        for label, addrs, h_auto, h_scan in zip(streams, lanes, auto, scan):
            if deterministic:
                expect = _ref_hits(geom, addrs)
                np.testing.assert_array_equal(h_scan, expect,
                                              err_msg=f"scan/{label}")
                np.testing.assert_array_equal(h_auto, expect,
                                              err_msg=f"auto/{label}")
            else:
                # stochastic: identical distributions, different draws —
                # miss *rates* must agree closely on long streams
                expect = _ref_hits(geom, addrs)
                assert abs(h_scan.mean() - expect.mean()) < 0.05, label
                np.testing.assert_array_equal(h_auto, h_scan)

    @pytest.mark.parametrize("name,mk", _device_cache_factories())
    def test_registered_devices(self, name, mk):
        geom = mk().geom
        if geom.prefetch_lines:
            return  # rejected geometries are covered below
        rng = np.random.default_rng(hash(name) % (2 ** 31))
        addrs = _streams_for(geom, rng)["mixed"]
        sim = BatchCache([geom])
        got = sim.simulate([addrs], force_scan=True)[0]
        expect = _ref_hits(geom, addrs)
        if geom.replacement.kind in ("lru", "fifo"):
            np.testing.assert_array_equal(got, expect)
        else:
            assert abs(got.mean() - expect.mean()) < 0.05

    def test_closed_form_matches_scan_on_cyclic_streams(self):
        """The two BatchCache paths against each other, where both apply."""
        for geom in _CUSTOM_GEOMS:
            if geom.replacement.kind not in ("lru", "fifo"):
                continue
            c, b = geom.size_bytes, geom.line_bytes
            for n in (c // 2, c + b, c + 5 * b):
                pattern = (np.arange(n // b, dtype=np.int64) * b) % n
                stream = np.resize(pattern, 4 * len(pattern))
                sim = BatchCache([geom])
                auto = sim.simulate([stream])[0]
                scan = sim.simulate([stream], force_scan=True)[0]
                np.testing.assert_array_equal(auto, scan,
                                              err_msg=f"{geom.name} n={n}")

    def test_steady_miss_count_matches_simulation(self):
        for geom in _CUSTOM_GEOMS:
            if geom.replacement.kind not in ("lru", "fifo"):
                assert BatchCache([geom]).steady_miss_count(
                    0, np.arange(4) * geom.line_bytes) is None
                continue
            c, b = geom.size_bytes, geom.line_bytes
            n = c + 3 * b
            lines = np.arange(n // b, dtype=np.int64) * b
            sim = BatchCache([geom])
            count = sim.steady_miss_count(0, lines)
            stream = np.resize(lines, 4 * len(lines))
            hits = sim.simulate([stream], force_scan=True)[0]
            steady = ~hits[2 * len(lines):3 * len(lines)]
            assert count == float(steady.sum()), geom.name

    def test_prefetch_geometry_rejected(self):
        geom = CacheGeometry("pf", 32, (8,), prefetch_lines=4)
        with pytest.raises(ValueError, match="prefetch"):
            BatchCache([geom])

    def test_heterogeneous_lane_batch(self):
        """Unequal geometries in ONE batch: padding must not leak state
        across lanes or ways beyond a lane's true way count."""
        geoms = [g for g in _CUSTOM_GEOMS
                 if g.replacement.kind in ("lru", "fifo")]
        rng = np.random.default_rng(11)
        lanes = [_streams_for(g, rng)["mixed"] for g in geoms]
        got = BatchCache(geoms).simulate(lanes, force_scan=True)
        for g, addrs, hits in zip(geoms, lanes, got):
            np.testing.assert_array_equal(hits, _ref_hits(g, addrs),
                                          err_msg=g.name)


class TestBackendTraces:
    """engine="jax" cache_backend vs the reference engine."""

    @pytest.mark.parametrize("name", ["kepler_texture_l1", "l1_tlb",
                                      "maxwell_unified_l1"])
    def test_uniform_chase_traces_identical(self, name):
        mk = devices.SIM_CACHES[name]
        geom = mk().geom
        c, b = geom.size_bytes, geom.line_bytes
        for n, s, passes in [(c + b, b, 12), (c + 3 * b, b, 6),
                             (c // 2, b, 4), (c + 2 * b, 3 * b, 5)]:
            ref = fine_grained(cache_backend(mk, engine="reference"),
                               n, s, passes=passes, warmup_passes=2)
            jx = fine_grained(cache_backend(mk, engine="jax"),
                              n, s, passes=passes, warmup_passes=2)
            np.testing.assert_array_equal(ref.indices, jx.indices)
            np.testing.assert_array_equal(ref.latencies, jx.latencies)
            np.testing.assert_array_equal(ref.meta["true_miss"],
                                          jx.meta["true_miss"])

    def test_custom_index_probe_traces_identical(self):
        mk = devices.SIM_CACHES["kepler_texture_l1"]
        probe = np.resize(np.arange(97, dtype=np.int64) * 32, 97 * 6)
        cfg = PChaseConfig(12 << 10, 128, len(probe), 4, 0)
        ref = cache_backend(mk, engine="reference")(cfg, indices=probe)
        jx = cache_backend(mk, engine="jax")(cfg, indices=probe)
        np.testing.assert_array_equal(ref.latencies, jx.latencies)

    def test_stochastic_backend_delegates_to_vector(self):
        """Stochastic policies route to the serial vector core (no scan
        win on CPU), so their traces stay bit-identical across engine
        selections — stronger than the distributional contract."""
        mk = devices.SIM_CACHES["fermi_l1_data"]
        geom = mk().geom
        run = cache_backend(mk, engine="jax")
        assert not hasattr(run, "steady_misses")
        c, b = geom.size_bytes, geom.line_bytes
        vec = fine_grained(cache_backend(mk, engine="vector"),
                           c + b, b, passes=8, warmup_passes=2)
        jx = fine_grained(run, c + b, b, passes=8, warmup_passes=2)
        np.testing.assert_array_equal(vec.latencies, jx.latencies)

    def test_batch_and_lean_paths_match_run(self):
        mk = devices.SIM_CACHES["maxwell_unified_l1"]
        geom = mk().geom
        run = cache_backend(mk, engine="jax")
        assert run.engine == "jax"
        c, b = geom.size_bytes, geom.line_bytes
        cfgs = []
        for n in (c // 2, c + b, c + 9 * b):
            elems = n // 4
            iters = int(np.ceil(2.0 * elems / (b // 4)))
            cfgs.append(PChaseConfig(n, b, iters, 4, 2))
        traces = run.batch([(cfg, None) for cfg in cfgs])
        lean = run.steady_misses(cfgs)
        for cfg, tr, v in zip(cfgs, traces, lean):
            serial = run(cfg)
            np.testing.assert_array_equal(serial.latencies, tr.latencies)
            assert v == inference._per_pass_misses(serial)


class TestBatchedDrivers:
    """Wave search == serial search, structure for structure."""

    @pytest.mark.parametrize("name", ["kepler_texture_l1", "l1_tlb",
                                      "maxwell_unified_l1", "l2_tlb"])
    def test_dissect_identical(self, name):
        from repro.profile.pipeline import DEVICE_STRUCTURES
        spec = next(s for specs in DEVICE_STRUCTURES.values()
                    for s in specs if s.sim_name == name)
        pv = inference.dissect(devices.sim_cache_backend(name),
                               n_max=spec.n_max, **spec.dissect_kw)
        pj = inference.dissect(
            devices.sim_cache_backend(name, engine="jax"),
            n_max=spec.n_max, **spec.dissect_kw)
        assert pv == pj

    def test_wave_bisection_matches_serial_sizes(self):
        """find_cache_size across strides/granularities on one geometry."""
        mk = devices.SIM_CACHES["kepler_texture_l1"]
        bv = cache_backend(mk, engine="vector")
        bj = cache_backend(mk, engine="jax")
        # granularities compatible with the stride (probe N stays a
        # stride multiple or the stride stays sub-line): the regimes the
        # dissection plans issue.  Incompatible pairs make the all-hit
        # predicate non-monotone on the probe grid, where serial and
        # wave bisection may land on different (equally arbitrary)
        # fixed points.
        for g, s in [(4, 4), (128, 128), (128, 32), (512, 32)]:
            sv = inference.find_cache_size(bv, n_max=1 << 16,
                                           granularity=g, stride_bytes=s)
            sj = inference.find_cache_size(bj, n_max=1 << 16,
                                           granularity=g, stride_bytes=s)
            assert sv == sj, (g, s)

    def test_engine_version_distinct(self):
        from repro.core.cachesim import ENGINE_VERSION
        assert JAX_ENGINE_VERSION != ENGINE_VERSION
