"""Mesh-sharded paged serving: the oracle chain for PR 7.

The trusted oracle is the UNSHARDED paged engine (itself pinned
token-for-token to the dense engine by test_serve_paged_equiv).  The
chain extends it in two links:

1. a 1-device-mesh replica must equal the unsharded engine
   token-for-token ON THE SAME TICK SCHEDULE (same ``eng.steps``), and
2. 2/4/8-way host-device meshes must be bit-identical to the 1-device
   mesh (verified in a subprocess under
   ``XLA_FLAGS=--xla_force_host_platform_device_count``).

Only the pool storage and the scatter/gather are sharded; the gather
output is constrained back to replicated, so every downstream matmul
sees width-invariant operands — equality across widths holds by
construction, and these tests pin that construction.  The allocator and
page tables stay host-side: the engine-level invariants are asserted
unchanged on every sharded run.
"""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch.mesh import make_serve_mesh
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.parallel import sharding
from repro.serve import paging
from repro.serve.engine import MESH_SERVE_RULES, PagedServeEngine, Request

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

MICRO = ModelConfig(name="micro", family="dense", num_layers=2, d_model=32,
                    d_ff=64, vocab_size=64, num_heads=2, num_kv_heads=2,
                    dtype="float32", param_dtype="float32")

WORK = [(8, 6), (12, 4), (5, 9), (16, 3)]


def run_py(code: str, devices: int = 8) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=600)


def _requests(cfg, work=WORK, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(uid, rng.integers(cfg.vocab_size, size=plen)
                    .astype(np.int32), n_new)
            for uid, (plen, n_new) in enumerate(work)]


def _run(cfg, params, mesh, *, page_len=8, max_len=32):
    """One full workload; returns (token streams, tick count, shards)."""
    eng = PagedServeEngine(cfg, params, max_slots=3, max_len=max_len,
                           page_len=page_len, mesh=mesh)
    for r in _requests(cfg):
        eng.submit(r)
    fin = eng.run_to_completion()
    eng.check_invariants()
    assert eng.alloc.allocated_pages == 0, "pages leaked"
    return ({r.uid: tuple(r.generated) for r in fin}, eng.steps, eng.shards)


# ---------------------------------------------------------------------------
# make_serve_mesh
# ---------------------------------------------------------------------------


class TestMakeServeMesh:
    def test_default_takes_all_devices_on_model(self):
        mesh = make_serve_mesh()
        assert mesh.axis_names == ("model",)
        assert mesh.shape["model"] == jax.device_count()

    def test_int_and_tuple_shapes(self):
        assert make_serve_mesh(1).shape == {"model": 1}
        assert make_serve_mesh((1,)).shape == {"model": 1}
        m = make_serve_mesh((1, 1))
        assert m.axis_names == ("data", "model")

    def test_bad_shapes_raise(self):
        with pytest.raises(ValueError):
            make_serve_mesh((0,))
        with pytest.raises(ValueError):
            make_serve_mesh((1, 1, 1))      # >2-D needs explicit axes

    def test_insufficient_devices_names_the_flag(self):
        """The error must carry the exact XLA_FLAGS incantation."""
        need = jax.device_count() + 1
        with pytest.raises(RuntimeError) as e:
            make_serve_mesh(need)
        msg = str(e.value)
        assert f"--xla_force_host_platform_device_count={need}" in msg

    def test_2d_mesh_on_forced_host_devices(self):
        code = """
        from repro.launch.mesh import make_serve_mesh
        m = make_serve_mesh((2, 4))
        assert m.axis_names == ("data", "model"), m.axis_names
        assert m.shape == {"data": 2, "model": 4}, m.shape
        print("OK")
        """
        r = run_py(code)
        assert "OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# rule tables
# ---------------------------------------------------------------------------


class TestRules:
    def test_cache_pages_rule_registered_and_replicated(self):
        assert "cache_pages" in sharding.DEFAULT_RULES
        assert sharding.DEFAULT_RULES["cache_pages"] is None

    def test_mesh_serve_rules_shard_only_kv_heads(self):
        """The serving rule table is DEFAULT_RULES with everything muted
        except the pool's heads axis — activations stay replicated, which
        is what makes tokens width-invariant by construction."""
        assert set(MESH_SERVE_RULES) == set(sharding.DEFAULT_RULES)
        assert MESH_SERVE_RULES["cache_kv_heads"] == "model"
        assert all(v is None for k, v in MESH_SERVE_RULES.items()
                   if k != "cache_kv_heads")

    def test_pool_spec_resolution_and_gqa_fallback(self):
        code = """
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_serve_mesh
        from repro.parallel.sharding import ShardingCtx
        from repro.serve.engine import MESH_SERVE_RULES
        ctx = ShardingCtx(make_serve_mesh(4), MESH_SERVE_RULES)
        axes = ("cache_pages", None, "cache_kv_heads", "cache_head_dim")
        # 8 KV heads on a 4-way model axis: heads shard, rest replicate
        s = ctx.spec(axes, (16, 8, 8, 16))
        assert s == P(None, None, "model", None), s
        # 3 KV heads do not divide 4: the GQA replication fallback
        s = ctx.spec(axes, (16, 8, 3, 16))
        assert s == P(None, None, None, None), s
        print("OK")
        """
        r = run_py(code, devices=4)
        assert "OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# per-shard page-length pricing
# ---------------------------------------------------------------------------


class TestPerShardPricing:
    def test_shards_1_is_exactly_the_unsharded_pricing(self):
        a = paging.page_len_rationale(MICRO)
        b = paging.page_len_rationale(MICRO, shards=1)
        assert a == b
        assert paging.choose_page_len(MICRO) == \
            paging.choose_page_len(MICRO, shards=1)

    def test_rows_thin_and_gather_frac_rises_with_shards(self):
        """Each shard gathers 1/shards of a row against its own
        partition's full latency — so the setup fraction of every
        candidate is monotone in the shard count."""
        by_shards = {s: paging.page_len_rationale(MICRO, shards=s)
                     for s in (1, 2, 4)}
        for t1, t2, t4 in zip(by_shards[1], by_shards[2], by_shards[4]):
            assert t2.row_bytes == max(t1.page_len, t1.row_bytes // 2)
            assert t1.gather_frac < t2.gather_frac < t4.gather_frac
            assert (t1.shards, t2.shards, t4.shards) == (1, 2, 4)

    def test_gather_shards_resolution(self):
        # no mesh -> unsharded pricing
        assert paging.gather_shards(MICRO, None) == 1
        # MLA's rank-3 compressed leaves never shard heads
        mla = configs.get_smoke_config("deepseek-v2-lite-16b")
        ctx = sharding.ShardingCtx(make_serve_mesh(1), MESH_SERVE_RULES)
        assert paging.gather_shards(mla, ctx) == 1
        # a 1-way mesh prices like the unsharded engine
        assert paging.gather_shards(MICRO, ctx) == 1

    def test_gather_shards_divisible_and_fallback(self):
        code = """
        from repro.launch.mesh import make_serve_mesh
        from repro.parallel.sharding import ShardingCtx
        from repro.serve import paging
        from repro.serve.engine import MESH_SERVE_RULES
        from repro.models.config import ModelConfig
        mk = lambda hkv: ModelConfig(name="m", family="dense", num_layers=2,
                                     d_model=32, d_ff=64, vocab_size=64,
                                     num_heads=4, num_kv_heads=hkv,
                                     dtype="float32", param_dtype="float32")
        ctx = ShardingCtx(make_serve_mesh(4), MESH_SERVE_RULES)
        assert paging.gather_shards(mk(4), ctx) == 4
        assert paging.gather_shards(mk(8), ctx) == 4
        assert paging.gather_shards(mk(3), ctx) == 1   # GQA fallback
        print("OK")
        """
        r = run_py(code, devices=4)
        assert "OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# oracle link 1: 1-device mesh == unsharded, same tick schedule
# ---------------------------------------------------------------------------


class TestOneDeviceMeshOracle:
    @pytest.mark.parametrize("arch", ["micro", "deepseek-v2-lite-16b"])
    def test_mesh1_token_identical_to_unsharded(self, arch):
        """GQA (shard_map path) and MLA (rank-3 fallback path) both ride
        the mesh seam; a 1-way mesh must change nothing observable."""
        if arch == "micro":
            cfg = MICRO
        else:
            cfg = configs.get_smoke_config(arch)
            if cfg.is_moe:
                cfg = dataclasses.replace(
                    cfg, capacity_factor=float(cfg.num_experts))
        params = T.init_params(cfg, jax.random.key(0))
        want, steps0, sh0 = _run(cfg, params, None)
        got, steps1, sh1 = _run(cfg, params, make_serve_mesh(1))
        assert sh0 == 1 and sh1 == 1
        assert got == want, "1-device mesh diverged from unsharded"
        assert steps1 == steps0, "tick schedule changed under the mesh"

    def test_mesh1_cache_lives_on_the_mesh(self):
        params = T.init_params(MICRO, jax.random.key(0))
        eng = PagedServeEngine(MICRO, params, max_slots=2, max_len=16,
                               page_len=4, mesh=make_serve_mesh(1))
        for _, leaf in jax.tree_util.tree_leaves_with_path(eng.cache):
            assert leaf.sharding.mesh.axis_names == ("model",)
        assert eng.stats()["gather_shards"] == 1

    def test_paged_cache_logical_axes_mirror_the_tree(self):
        cache = T.init_paged_cache(MICRO, 8, 4, 3)
        axes = T.paged_cache_logical_axes(cache)
        flat = dict(jax.tree_util.tree_flatten_with_path(
            axes, is_leaf=lambda x: isinstance(x, tuple))[0])
        for path, ax in flat.items():
            name = path[-1].key
            assert ax == T.PAGED_CACHE_AXES[name], (name, ax)


# ---------------------------------------------------------------------------
# oracle link 2: width invariance (subprocess host-device meshes)
# ---------------------------------------------------------------------------

WIDTH_CODE = """
import jax, numpy as np
import jax.tree_util as jtu
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_serve_mesh
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serve.engine import PagedServeEngine, Request

# 4 KV heads: widths 1/2/4 shard the pool; 8 exercises the GQA fallback
CFG = ModelConfig(name="micro4", family="dense", num_layers=2, d_model=32,
                  d_ff=64, vocab_size=64, num_heads=4, num_kv_heads=4,
                  dtype="float32", param_dtype="float32")
PARAMS = T.init_params(CFG, jax.random.key(0))
WORK = [(8, 6), (12, 4), (5, 9), (16, 3)]

def requests():
    rng = np.random.default_rng(3)
    return [Request(uid, rng.integers(CFG.vocab_size, size=plen)
                    .astype(np.int32), n)
            for uid, (plen, n) in enumerate(WORK)]

def run(mesh):
    # page_len pinned: per-shard pricing may legitimately choose different
    # pages per width, and the oracle isolates token equality from sizing
    eng = PagedServeEngine(CFG, PARAMS, max_slots=3, max_len=32,
                           page_len=8, mesh=mesh)
    for r in requests():
        eng.submit(r)
    fin = eng.run_to_completion()
    eng.check_invariants()
    assert eng.alloc.allocated_pages == 0, "pages leaked"
    return {r.uid: tuple(r.generated) for r in fin}, eng.steps, eng

base, steps0, _ = run(make_serve_mesh(1))
expected_shards = {1: 1, 2: 2, 4: 4, 8: 1}
for w in (2, 4, 8):
    got, steps, eng = run(make_serve_mesh(w))
    assert got == base, f"width {w} diverged from the 1-device mesh"
    assert steps == steps0, f"width {w} changed the tick schedule"
    assert eng.shards == expected_shards[w], (w, eng.shards)
    if eng.shards > 1:
        for path, leaf in jtu.tree_leaves_with_path(eng.cache):
            name = path[-1].key
            if name in ("k", "v"):
                assert leaf.sharding.spec == \
                    P(None, None, None, "model", None), \
                    (w, name, leaf.sharding.spec)
print("OK", steps0, sorted(base))
"""


class TestWidthInvariance:
    def test_widths_1_2_4_8_bit_identical(self):
        """The tentpole oracle: every host-device mesh width produces the
        1-device mesh's exact tokens on the exact tick schedule, pool
        leaves really shard over "model", and the 8-way/4-head case
        falls back to shards=1 without diverging."""
        r = run_py(WIDTH_CODE, devices=8)
        assert "OK" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]

    @pytest.mark.parametrize("width", [2, 4, 8])
    @pytest.mark.skipif(jax.device_count() < 2,
                        reason="needs forced host devices (CI mesh stage)")
    def test_width_inprocess(self, width):
        """In-process flavor for the CI mesh stage, which runs pytest
        under XLA_FLAGS=--xla_force_host_platform_device_count=8."""
        if jax.device_count() < width:
            pytest.skip(f"needs {width} devices")
        cfg = dataclasses.replace(MICRO, name="micro4", num_heads=4,
                                  num_kv_heads=4)
        params = T.init_params(cfg, jax.random.key(0))
        want, steps0, _ = _run(cfg, params, make_serve_mesh(1))
        got, steps, shards = _run(cfg, params, make_serve_mesh(width))
        assert got == want and steps == steps0
        assert shards == (width if cfg.num_kv_heads % width == 0 else 1)
