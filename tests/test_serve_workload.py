"""Seeded workload generation, trace replay, and the capacity planner.

The discipline under test is the chaos tier's, applied to traffic: one
``np.random.default_rng(seed)`` stream consumed strictly in tick order
makes every trace a pure function of its spec — so generation is
bit-reproducible, a prefix of ticks yields a prefix of requests, and a
replay through the fleet front end lands a bit-identical SLO report.
The planner half is pure accounting: feasibility honors its own SLO,
replica counts are minimal and monotone in load.
"""

import dataclasses

import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.serve.planner import (SLOTarget, characterize_replica,
                                 plan_capacity, plan_for_trace)
from repro.serve.workload import (ARRIVALS, SCENARIOS, WorkloadSpec,
                                  generate_trace)

MICRO = ModelConfig(name="micro", family="dense", num_layers=2, d_model=32,
                    d_ff=64, vocab_size=64, num_heads=2, num_kv_heads=2,
                    dtype="float32", param_dtype="float32")


class TestTraceGeneration:
    @pytest.mark.parametrize("scenario", sorted(SCENARIOS))
    @pytest.mark.parametrize("arrival", ARRIVALS)
    def test_trace_is_pure_function_of_spec(self, scenario, arrival):
        spec = WorkloadSpec(scenario=scenario, arrival=arrival, rate=0.8,
                            horizon=32, seed=3, max_len=48)
        assert (generate_trace(spec).fingerprint()
                == generate_trace(spec).fingerprint())

    def test_seed_changes_trace(self):
        mk = lambda s: WorkloadSpec(rate=1.0, horizon=32, seed=s)  # noqa
        assert (generate_trace(mk(0)).fingerprint()
                != generate_trace(mk(1)).fingerprint())

    def test_tick_order_stream_gives_prefix_property(self):
        """The RNG is consumed in tick order, so a shorter horizon yields
        exactly the longer trace's requests born in the common window
        (chat: no sessions, so births never outrun their arrival tick)."""
        short = generate_trace(WorkloadSpec(rate=0.9, horizon=8, seed=5))
        long = generate_trace(WorkloadSpec(rate=0.9, horizon=20, seed=5))
        want = [r for r in long.requests if r.tick < 8]
        assert len(short.requests) == len(want)
        for a, b in zip(short.requests, want):
            assert (a.uid, a.tick, a.max_new_tokens) == \
                (b.uid, b.tick, b.max_new_tokens)
            np.testing.assert_array_equal(a.prompt, b.prompt)

    @pytest.mark.parametrize("scenario", sorted(SCENARIOS))
    def test_lengths_fit_engine_geometry(self, scenario):
        spec = WorkloadSpec(scenario=scenario, rate=1.5, horizon=24,
                            seed=1, max_len=40)
        trace = generate_trace(spec)
        assert trace.requests, "expected a non-empty trace at rate 1.5"
        for r in trace.requests:
            assert 1 <= len(r.prompt) <= spec.max_len - 1
            assert r.max_new_tokens >= 1
            assert len(r.prompt) + r.max_new_tokens <= spec.max_len
            assert r.prompt.dtype == np.int32
            assert r.prompt.max() < spec.vocab_size

    def test_uid_is_arrival_order(self):
        trace = generate_trace(WorkloadSpec(scenario="agent", rate=1.0,
                                            horizon=24, seed=2))
        ticks = [r.tick for r in trace.requests]
        assert [r.uid for r in trace.requests] == list(range(len(ticks)))
        assert ticks == sorted(ticks), "uid order must follow arrival order"

    def test_agent_sessions_expand_turns(self):
        trace = generate_trace(WorkloadSpec(scenario="agent", rate=1.0,
                                            horizon=32, seed=0))
        st = trace.stats()
        assert st["requests"] > st["sessions"], \
            "agent sessions should emit multiple turns"
        by_session: dict[int, list] = {}
        for r in trace.requests:
            by_session.setdefault(r.session, []).append(r.tick)
        assert any(len(t) > 1 for t in by_session.values())

    def test_stats_measure_the_trace(self):
        trace = generate_trace(WorkloadSpec(rate=0.7, horizon=16, seed=4))
        st = trace.stats()
        n = len(trace.requests)
        assert st["requests"] == n
        assert st["arrival_per_tick"] == pytest.approx(n / st["span_ticks"])
        assert st["total_tokens"] == sum(len(r.prompt) + r.max_new_tokens
                                         for r in trace.requests)

    def test_spec_validation(self):
        with pytest.raises(ValueError, match="scenario"):
            WorkloadSpec(scenario="nope")
        with pytest.raises(ValueError, match="arrival"):
            WorkloadSpec(arrival="weekly")
        with pytest.raises(ValueError, match="rate"):
            WorkloadSpec(rate=0.0)
        with pytest.raises(ValueError, match="horizon"):
            WorkloadSpec(horizon=0)
        with pytest.raises(ValueError, match="max_len"):
            WorkloadSpec(max_len=1)


class TestReplay:
    @pytest.fixture(scope="class")
    def micro_params(self):
        import jax

        from repro.models import transformer as T
        return T.init_params(MICRO, jax.random.key(0))

    def _replay(self, params, trace, replicas=1, max_pending=None):
        from repro.serve.fleet import FleetEngine
        from repro.serve.frontend import FleetFrontend
        from repro.serve.workload import replay_trace
        fleet = FleetEngine(MICRO, params, max_slots=2, max_len=32,
                            replicas=replicas)
        front = FleetFrontend(fleet, max_pending=max_pending)
        replay_trace(front, trace)
        fleet.check_invariants()
        return front

    def test_replay_bit_identical_and_complete(self, micro_params):
        trace = generate_trace(WorkloadSpec(rate=0.4, horizon=10, seed=0,
                                            max_len=32))
        a = self._replay(micro_params, trace)
        b = self._replay(micro_params, trace)
        assert a.slo.report().key() == b.slo.report().key()
        assert a.fleet.decision_log() == b.fleet.decision_log()
        rep = a.slo.report()
        assert rep.outcome_counts["finished"] == len(trace.requests)
        assert a.fleet.stats()["pages_leaked"] == 0

    def test_backpressured_arrivals_keep_original_clock(self, micro_params):
        """A tight queue bound forces deferred retries; TTFT must still
        count from the trace's arrival tick, not the retry tick."""
        trace = generate_trace(WorkloadSpec(scenario="batch", rate=1.2,
                                            horizon=8, seed=1, max_len=32))
        front = self._replay(micro_params, trace, max_pending=1)
        for r in trace.requests:
            assert front.slo.timings[r.uid].submit_tick == r.tick, \
                f"uid {r.uid}: queueing hid its arrival tick"
        rep = front.slo.report()
        assert rep.outcome_counts["finished"] == len(trace.requests)


class TestPlanner:
    KW = dict(max_slots=2, max_len=32, mean_prompt=6.0, mean_new=10.0)

    def test_replicas_monotone_in_load(self):
        ns = [plan_capacity(MICRO, arrival_per_tick=lam, **self.KW).replicas
              for lam in (0.05, 0.2, 0.4, 0.8)]
        assert ns == sorted(ns), f"replica count must grow with load: {ns}"
        assert ns[0] == 1

    def test_chosen_n_is_minimal_and_meets_slo(self):
        slo = SLOTarget(ttft_p99_ticks=16.0, max_utilization=0.8)
        plan = plan_capacity(MICRO, arrival_per_tick=0.6, slo=slo,
                             **self.KW)
        assert plan.feasible
        assert plan.utilization <= slo.max_utilization
        assert plan.predicted_ttft_ticks <= slo.ttft_p99_ticks
        if plan.replicas > 1:
            mu = plan.replica.service_rate
            rho_less = 0.6 / ((plan.replicas - 1) * mu)
            ttft_less = (plan.replica.prefill_ticks / (1 - rho_less)
                         if rho_less < 1 else float("inf"))
            assert (rho_less > slo.max_utilization
                    or ttft_less > slo.ttft_p99_ticks), \
                "one fewer replica would also have met the SLO"

    def test_infeasible_is_reported_not_raised(self):
        plan = plan_capacity(MICRO, arrival_per_tick=50.0, max_replicas=2,
                             **self.KW)
        assert not plan.feasible
        assert plan.replicas == 2
        assert plan.predicted_ttft_ticks == float("inf")

    def test_inflight_bound_can_bind_concurrency(self):
        """A spec with almost no latency-hiding quantum must cap C at the
        Little's-law bound, making the device profile the binding
        constraint (the planner's whole point)."""
        from repro.core.profile import resolve_spec
        tiny = dataclasses.replace(resolve_spec(None),
                                   hbm_bytes_per_s=1e6, hbm_latency_s=1e-9)
        rep = characterize_replica(MICRO, spec=tiny, max_slots=8,
                                   max_len=32, mean_prompt=6.0,
                                   mean_new=10.0)
        assert rep.binding == "inflight"
        assert rep.concurrency == rep.inflight_bound == 1

    def test_plan_for_trace_uses_measured_traffic(self):
        trace = generate_trace(WorkloadSpec(scenario="agent", rate=0.5,
                                            horizon=24, seed=0, max_len=32))
        st = trace.stats()
        plan = plan_for_trace(MICRO, trace, max_slots=2, max_len=32)
        assert plan.arrival_per_tick == pytest.approx(
            st["arrival_per_tick"])
        assert plan.mean_prompt == pytest.approx(st["mean_prompt"])

    def test_validation(self):
        with pytest.raises(ValueError, match="arrival_per_tick"):
            plan_capacity(MICRO, arrival_per_tick=0.0, **self.KW)
        with pytest.raises(ValueError, match="ttft"):
            SLOTarget(ttft_p99_ticks=0.0)
        with pytest.raises(ValueError, match="max_utilization"):
            SLOTarget(max_utilization=1.0)
        empty = generate_trace(WorkloadSpec(rate=1e-6, horizon=1))
        if not empty.requests:
            with pytest.raises(ValueError, match="empty trace"):
                plan_for_trace(MICRO, empty, max_slots=2, max_len=32)
