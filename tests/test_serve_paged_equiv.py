"""Differential oracle: the paged engine vs the dense-slot engine.

The dense ``ServeEngine`` is the trusted oracle (itself pinned to isolated
prefill+greedy-decode by test_serve_engine).  The paged engine must
produce token-for-token identical greedy outputs across the model zoo —
GQA, pure-SSM, MLA+MoE and hybrid caches — under mixed prompt/max_new
workloads, tight page pools (admission gating + on-demand growth), and
multi-page prefill chunks.  Same oracle/blind pattern as
test_engine_equivalence.py: the paged engine never sees the dense
engine's internals, only its outputs.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.serve import paging
from repro.serve.engine import PagedServeEngine, Request, ServeEngine

# ≥3 registered model-zoo configs, one per cache family
ARCHS = ["granite-8b", "mamba2-1.3b", "deepseek-v2-lite-16b",
         "jamba-1.5-large-398b"]

WORK = [(8, 6), (12, 4), (5, 9), (16, 3), (7, 7), (3, 5)]


def _setup(arch):
    cfg = configs.get_smoke_config(arch)
    if cfg.is_moe:
        # garbage tokens (inactive slots, padded chunk tails) share MoE
        # expert capacity with real tokens; lift the capacity limit so
        # routing stays batch-independent, as test_serve_engine does
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
    params = T.init_params(cfg, jax.random.key(0))
    return cfg, params


def _requests(cfg, work=WORK, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid, rng.integers(cfg.vocab_size, size=plen)
                    .astype(np.int32), n_new)
            for uid, (plen, n_new) in enumerate(work)]


def _oracle(cfg, params, work=WORK, seed=0, max_len=48):
    dense = ServeEngine(cfg, params, max_slots=3, max_len=max_len)
    for r in _requests(cfg, work, seed):
        dense.submit(r)
    return {r.uid: r.generated for r in dense.run_to_completion()}


def _assert_matches(engine, want):
    finished = engine.run_to_completion()
    engine.alloc.check_invariants()
    assert engine.alloc.allocated_pages == 0, "pages leaked past completion"
    got = {r.uid: r.generated for r in finished}
    assert set(got) == set(want)
    for uid in want:
        assert got[uid] == want[uid], \
            f"req {uid} diverged: {got[uid]} vs {want[uid]}"


class TestPagedEquivalence:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_token_identical_roomy_pool(self, arch):
        """Dense-equivalent capacity, cost-model-chosen page_len."""
        cfg, params = _setup(arch)
        want = _oracle(cfg, params)
        eng = PagedServeEngine(cfg, params, max_slots=3, max_len=48)
        for r in _requests(cfg):
            eng.submit(r)
        _assert_matches(eng, want)

    @pytest.mark.parametrize("arch", ARCHS)
    def test_token_identical_tight_pool(self, arch):
        """A pool far below worst case: admission gating, on-demand page
        growth and (possibly) preemption must not change a single token."""
        cfg, params = _setup(arch)
        want = _oracle(cfg, params)
        eng = PagedServeEngine(cfg, params, max_slots=3, max_len=48,
                               page_len=8, num_pages=8)
        for r in _requests(cfg):
            eng.submit(r)
        _assert_matches(eng, want)

    def test_token_identical_multi_page_chunks(self):
        """prefill_chunk > page_len: chunked prefill spanning two pages per
        tick (and the bigger padded tail that comes with it)."""
        cfg, params = _setup("granite-8b")
        want = _oracle(cfg, params)
        eng = PagedServeEngine(cfg, params, max_slots=3, max_len=48,
                               page_len=4, prefill_chunk=8)
        for r in _requests(cfg):
            eng.submit(r)
        _assert_matches(eng, want)

    def test_token_identical_under_preemption(self):
        """A pool so small decode growth must evict younger requests;
        preempted work re-runs from scratch and still matches greedily."""
        cfg, params = _setup("granite-8b")
        work = [(2, 10), (2, 10), (2, 10)]
        want = _oracle(cfg, params, work=work, max_len=32)
        eng = PagedServeEngine(cfg, params, max_slots=3, max_len=32,
                               page_len=4, num_pages=5)
        for r in _requests(cfg, work):
            eng.submit(r)
        _assert_matches(eng, want)
        assert eng.preemptions > 0, "pool was sized to force preemption"

    def test_reserved_hbm_tracks_generated_length(self):
        """The acceptance property: live HBM is proportional to tokens
        actually produced — at most one page of slack per live request."""
        cfg, params = _setup("granite-8b")
        eng = PagedServeEngine(cfg, params, max_slots=3, max_len=48,
                               page_len=8)
        for r in _requests(cfg):
            eng.submit(r)
        while eng.waiting or eng.prefilling or eng.active:
            eng.step()
            eng.alloc.check_invariants()
        assert eng.max_slack_tokens <= eng.page_len
        dense_bytes = ServeEngine(cfg, params, max_slots=3,
                                  max_len=48).hbm_reserved_bytes()
        peak_bytes = (eng.peak_pages * eng.page_len
                      * paging.kv_bytes_per_token(cfg))
        assert peak_bytes < dense_bytes, \
            "paged peak should undercut the dense max_slots*max_len block"

    def test_oldest_request_is_never_preempted(self):
        """Victims must be strictly younger than the grower, and seniority
        survives preemption — otherwise a continuous arrival stream can
        starve a long request forever (review finding)."""
        cfg, params = _setup("granite-8b")
        work = [(2, 12), (2, 12), (2, 12), (2, 12)]
        want = _oracle(cfg, params, work=work, max_len=32)
        eng = PagedServeEngine(cfg, params, max_slots=3, max_len=32,
                               page_len=4, num_pages=6)
        orig = eng._preempt

        def spying_preempt(victim):
            live = eng._live()
            oldest = min(r.admit_seq for r in live)
            assert victim.admit_seq > oldest, \
                f"preempted uid {victim.uid} was the oldest live request"
            orig(victim)

        eng._preempt = spying_preempt
        for r in _requests(cfg, work):
            eng.submit(r)
        _assert_matches(eng, want)
        assert eng.preemptions > 0

    def test_chunk_padded_frontier_fits_page_table(self):
        """prefill_chunk that does not divide max_len: the padded frontier
        of a near-max_len prompt must not overrun the page-table row
        (review finding: _sync_table broadcast crash)."""
        cfg, params = _setup("granite-8b")
        eng = PagedServeEngine(cfg, params, max_slots=2, max_len=50,
                               page_len=5, prefill_chunk=15)
        rng = np.random.default_rng(5)
        eng.submit(Request(0, rng.integers(cfg.vocab_size, size=49)
                           .astype(np.int32), 1))
        done = eng.run_to_completion()
        eng.alloc.check_invariants()
        assert len(done) == 1 and len(done[0].generated) == 1

    def test_rejects_unservable_request(self):
        cfg, params = _setup("granite-8b")
        eng = PagedServeEngine(cfg, params, max_slots=1, max_len=16,
                               page_len=4, num_pages=3)
        with pytest.raises(ValueError):
            eng.submit(Request(0, np.zeros(8, np.int32), 8))   # > max_len
        with pytest.raises(ValueError):
            # fits max_len but can never fit the 2-page pool
            eng.submit(Request(1, np.zeros(8, np.int32), 4))


class TestAllocatorBookkeeping:
    """PR-8 regression: allocator edge cases that corrupt the books."""

    def test_zero_alloc_leaves_no_phantom_entry(self):
        """alloc(uid, 0) must not create an empty page-list entry — a
        uid that owns nothing must not appear in `pages` at all (the
        phantom survives release() and trips per-uid invariants)."""
        alloc = paging.PageAllocator(num_pages=6, page_len=4)
        assert alloc.alloc(7, 0) == []
        assert 7 not in alloc.pages, "phantom empty page-list entry"
        alloc.check_invariants()
        # a real allocation afterwards works and releases cleanly
        assert len(alloc.alloc(7, 2)) == 2
        alloc.check_invariants()
        assert alloc.release(7) == 2
        alloc.check_invariants()

    def test_invariants_reject_empty_page_list(self):
        alloc = paging.PageAllocator(num_pages=6, page_len=4)
        alloc.pages[3] = []               # corrupt the books directly
        with pytest.raises(AssertionError, match="empty page list"):
            alloc.check_invariants()
        assert alloc.violations(), "violations() must surface it too"

    def test_negative_alloc_rejected(self):
        alloc = paging.PageAllocator(num_pages=6, page_len=4)
        with pytest.raises(ValueError):
            alloc.alloc(0, -1)


class TestPageLenPricing:
    """PR-8 regression: the page-table term is host-side bookkeeping and
    must not inflate with the shard count."""

    def test_table_term_is_shard_invariant(self):
        cfg = configs.get_smoke_config("granite-8b")
        bpt = paging.kv_bytes_per_token_layer(cfg)
        for shards in (1, 2, 4, 8):
            for t in paging.page_len_rationale(cfg, shards=shards):
                assert t.table_frac == round(4.0 / (t.page_len * bpt), 6), \
                    (f"shards={shards} pl={t.page_len}: table term priced "
                     "on per-shard bytes")

    def test_gather_term_does_scale_with_shards(self):
        """Sanity check the fix hit ONLY the table term: thinner
        per-shard rows leave more of the inflight quantum uncovered."""
        cfg = configs.get_smoke_config("granite-8b")
        one = paging.page_len_rationale(cfg, shards=1)
        four = paging.page_len_rationale(cfg, shards=4)
        for a, b in zip(one, four):
            assert b.gather_frac > a.gather_frac
            assert b.row_bytes < a.row_bytes

    def test_unsharded_scores_unchanged_by_fix(self):
        """shards=1: table term equals the pre-fix formula byte-for-byte
        (bpt == full_bpt), so the chosen page length cannot move."""
        cfg = configs.get_smoke_config("granite-8b")
        for t in paging.page_len_rationale(cfg, shards=1):
            # at shards=1 the unsharded row IS the per-shard row, so the
            # fixed term must equal the old per-shard formula exactly
            assert t.table_frac == round(4.0 / t.row_bytes, 6)
        assert paging.choose_page_len(cfg) == paging.choose_page_len(
            cfg, shards=1)
