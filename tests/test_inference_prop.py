"""Property-based analyzer recovery over RANDOM classical geometries.

Split out of tests/test_inference.py so its module-level hypothesis skip
no longer silences the deterministic Table 5 validations on bare
environments (hypothesis is optional; this whole module skips without
it, mirroring test_engine_equivalence_prop.py)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import inference
from repro.core.cachesim import Cache, CacheGeometry, ReplacementPolicy
from repro.core.pchase import cache_backend


@st.composite
def lru_geometries(draw):
    line = draw(st.sampled_from([16, 32, 64, 128]))
    sets = draw(st.sampled_from([1, 2, 4, 8]))
    ways = draw(st.sampled_from([1, 2, 4, 8]))
    return line, sets, ways


class TestPropertyRecovery:
    @settings(max_examples=12, deadline=None)
    @given(lru_geometries())
    def test_recovers_random_lru_geometry(self, geom):
        """Invariant: for ANY classical LRU set-associative cache, the
        two-stage procedure recovers (C, b, T, a) exactly."""
        line, sets, ways = geom
        size = line * sets * ways
        mk = lambda: Cache(CacheGeometry.uniform("rnd", size, line, sets))
        p = inference.dissect(cache_backend(mk), n_max=max(4 * size, 4096),
                              max_line=2048, probe_set_bits=False,
                              structure_max_steps=sets + 4)
        assert p.size_bytes == size
        assert p.line_bytes == line
        assert p.num_sets == sets
        assert p.way_counts == [ways] * sets
        assert p.is_lru

    @settings(max_examples=6, deadline=None)
    @given(st.sampled_from([16, 32, 64]),
           st.sampled_from([2, 4]),
           st.integers(min_value=2, max_value=4))
    def test_detects_random_replacement(self, line, sets, ways):
        size = line * sets * ways
        mk = lambda: Cache(
            CacheGeometry("rnd", line, (ways,) * sets,
                          replacement=ReplacementPolicy("random")),
            np.random.default_rng(3))
        rep = inference.detect_replacement(cache_backend(mk), size, line,
                                           passes=40)
        assert not rep.is_lru
