"""The dissection harness itself: registry registration/dedup, verdict
tolerance logic, JSON artifact round-trip, and CLI list/run smoke tests."""

import json
import os
import subprocess
import sys

import pytest

from repro.bench import registry as reg
from repro.bench.registry import Context, Experiment
from repro.bench.result import (DEVIATION, ERROR, INFO, PASS,
                                ExperimentRecord, Metric, info,
                                load_artifact, summarize, write_artifact)
from repro.bench.runner import RunOptions, records_to_rows, run_experiments
from repro.bench import report
from repro.core import devices

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture
def scratch_registry(monkeypatch):
    """An empty registry the test can populate without global side effects."""
    monkeypatch.setattr(reg, "REGISTRY", {})
    return reg.REGISTRY


def _register(name, fn=None, devices_=("GTX780",), **kw):
    fn = fn or (lambda ctx: [Metric("m", 1, 1, cmp="eq")])
    return reg.experiment(name=name, title=kw.pop("title", name),
                          section=kw.pop("section", "§0"),
                          artifact=kw.pop("artifact", "Fig 0"),
                          devices=devices_, **kw)(fn)


class TestRegistry:
    def test_register_and_get(self, scratch_registry):
        _register("exp_a")
        assert reg.get("exp_a").name == "exp_a"
        assert [e.name for e in reg.all_experiments()] == ["exp_a"]

    def test_duplicate_name_rejected(self, scratch_registry):
        _register("exp_a")

        def other(ctx):
            return []

        with pytest.raises(ValueError, match="already registered"):
            _register("exp_a", fn=other)

    def test_reimport_same_function_tolerated(self, scratch_registry):
        def fn(ctx):
            return []

        _register("exp_a", fn=fn)
        _register("exp_a", fn=fn)      # idempotent re-registration
        assert len(scratch_registry) == 1

    def test_unknown_device_rejected(self, scratch_registry):
        with pytest.raises(KeyError, match="unknown device"):
            _register("exp_b", devices_=("GTX9999",))

    def test_select_filters(self, scratch_registry):
        _register("exp_a", devices_=("GTX780",), tags=("cache",))
        _register("exp_b", devices_=("GTX980",), section="§4.4")
        assert [e.name for e in reg.select(device="GTX980")] == ["exp_b"]
        assert [e.name for e in reg.select(tag="cache")] == ["exp_a"]
        assert [e.name for e in reg.select(section="4.4")] == ["exp_b"]
        with pytest.raises(KeyError, match="unknown experiments"):
            reg.select(names=["nope"])

    def test_discover_registers_all_ten(self):
        # the real registry: importing the benchmarks package must yield
        # exactly the ten paper experiments, each on a registered device
        mods = reg.discover()
        assert len(reg.REGISTRY) >= 10
        assert set(mods) >= set(reg.REGISTRY)
        for e in reg.all_experiments():
            assert e.devices, e.name
            for d in e.devices:
                devices.get_device(d)


class TestVerdicts:
    def test_eq(self):
        assert Metric("m", 4, 4, cmp="eq").verdict == PASS
        assert Metric("m", 4, 5, cmp="eq").verdict == DEVIATION
        assert Metric("m", "a", "a", cmp="eq").verdict == PASS

    def test_close_relative_tolerance(self):
        assert Metric("m", 104.9, 100.0, tol=0.05).verdict == PASS
        assert Metric("m", 106.0, 100.0, tol=0.05).verdict == DEVIATION
        # tiny expected values use absolute slack (max(1, |e|))
        assert Metric("m", 0.04, 0.0, tol=0.05).verdict == PASS

    def test_le_ge_range(self):
        assert Metric("m", 90, 100, cmp="le", tol=0).verdict == PASS
        assert Metric("m", 110, 100, cmp="le", tol=0).verdict == DEVIATION
        assert Metric("m", 110, 100, cmp="ge", tol=0).verdict == PASS
        assert Metric("m", 2.5, [2.0, 3.5], cmp="range").verdict == PASS
        assert Metric("m", 3.6, [2.0, 3.5], cmp="range").verdict == DEVIATION

    def test_info_never_deviates(self):
        assert info("m", "whatever").verdict == INFO

    def test_non_numeric_measured_deviates(self):
        assert Metric("m", "nan?", 1.0, cmp="close").verdict == DEVIATION

    def test_expected_required_unless_info(self):
        with pytest.raises(ValueError, match="requires an expected"):
            Metric("m", 1)

    def test_record_verdict_folding(self):
        ok = Metric("a", 1, 1, cmp="eq")
        bad = Metric("b", 1, 2, cmp="eq")
        rec = ExperimentRecord("e", "d", "§", "T", [ok, info("c", 0)])
        assert rec.verdict == PASS
        rec = ExperimentRecord("e", "d", "§", "T", [ok, bad])
        assert rec.verdict == DEVIATION
        assert [m.name for m in rec.deviations] == ["b"]
        rec = ExperimentRecord("e", "d", "§", "T", [], error="boom")
        assert rec.verdict == ERROR
        assert summarize([rec]) == {PASS: 0, DEVIATION: 0, INFO: 0, ERROR: 1}


class TestArtifactRoundTrip:
    def test_json_round_trip(self, tmp_path):
        recs = [
            ExperimentRecord(
                "exp_a", "GTX780", "§4.4", "Fig 8",
                [Metric("reach", 130, 130, cmp="eq", unit="MB"),
                 Metric("eff", 0.75, [0.65, 0.85], cmp="range"),
                 info("curve", "[1, 2, 3]")],
                elapsed_s=1.25),
            ExperimentRecord("exp_b", "tpu_v5e", "§5", "Table 6", [],
                             error="Traceback: ..."),
        ]
        path = str(tmp_path / "a.json")
        payload = write_artifact(recs, path, extra={"quick": True})
        loaded = load_artifact(path)
        assert [r.to_json() for r in loaded] == [r.to_json() for r in recs]
        assert payload["schema"] == "repro.bench/v1"
        assert payload["summary"] == {PASS: 1, DEVIATION: 0, INFO: 0,
                                      ERROR: 1}
        # verdicts are materialized in the file for jq/diff consumers
        raw = json.loads(open(path).read())
        assert raw["records"][0]["verdict"] == PASS
        assert raw["records"][0]["metrics"][0]["verdict"] == PASS
        assert raw["quick"] is True

    def test_schema_mismatch_rejected(self, tmp_path):
        p = tmp_path / "bad.json"
        p.write_text('{"schema": "other/v9", "records": []}')
        with pytest.raises(ValueError, match="unknown schema"):
            load_artifact(str(p))


class TestRunner:
    def test_run_experiments_per_device_records(self, scratch_registry):
        calls = []

        def fn(ctx):
            calls.append((ctx.device.name, ctx.quick))
            return [Metric("one", 1, 1, cmp="eq")]

        _register("exp_a", fn=fn, devices_=("GTX780", "GTX980"))
        recs = run_experiments(RunOptions(quick=True))
        assert [(r.experiment, r.device) for r in recs] == [
            ("exp_a", "GTX780"), ("exp_a", "GTX980")]
        assert calls == [("GTX780", True), ("GTX980", True)]
        assert all(r.verdict == PASS for r in recs)

    def test_device_filter(self, scratch_registry):
        _register("exp_a", devices_=("GTX780", "GTX980"))
        recs = run_experiments(RunOptions(device="GTX980"))
        assert [(r.experiment, r.device) for r in recs] == [
            ("exp_a", "GTX980")]

    def test_experiment_error_is_captured(self, scratch_registry):
        def boom(ctx):
            raise RuntimeError("probe failed")

        _register("exp_a", fn=boom)
        recs = run_experiments(RunOptions())
        assert recs[0].verdict == ERROR
        assert "probe failed" in recs[0].error

    def test_records_to_rows_csv_shape(self, scratch_registry):
        _register("exp_a", fn=lambda ctx: [
            Metric("m", 130, 130, cmp="eq", unit="MB", us=12.5),
            info("i", "x,y")])
        rows = records_to_rows(run_experiments(RunOptions()))
        assert rows[0][0] == "exp_a/GTX780/m"
        assert rows[0][1] == 12.5
        assert "PASS" in rows[0][2]
        assert "," not in rows[1][2]          # CSV-safe derived field


class TestReport:
    def test_render_report_contains_verdicts(self, scratch_registry):
        _register("exp_a", fn=lambda ctx: [
            Metric("m", 2, 1, cmp="eq", detail="off by one")])
        text = report.render_report(run_experiments(RunOptions()))
        assert "DEVIATION" in text
        assert "exp_a" in text and "GTX780" in text
        assert "m: 2 vs 1" in text

    def test_experiments_doc_from_metadata(self, scratch_registry):
        _register("exp_a", expected={"claim": "16 KB"}, tags=("cache",))
        text = report.experiments_doc()
        assert "GENERATED FILE" in text
        assert "`exp_a`" in text and "16 KB" in text
        assert "tpu_v5e" in text              # device table


def _cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.bench", *args], cwd=REPO_ROOT,
        env=env, capture_output=True, text=True, timeout=300)


class TestCli:
    def test_list_smoke(self):
        p = _cli("list")
        assert p.returncode == 0, p.stderr
        assert "table5_cache_params" in p.stdout
        assert "fig8_tlb" in p.stdout

    def test_run_smoke_single_cheap_experiment(self, tmp_path):
        out = str(tmp_path / "a.json")
        rep = str(tmp_path / "a.md")
        p = _cli("run", "--only", "fig19_kepler_modes", "--quick",
                 "--strict", "--out", out, "--report", rep)
        assert p.returncode == 0, p.stderr
        assert "name,us_per_call,derived" in p.stdout
        recs = load_artifact(out)
        assert [(r.experiment, r.device) for r in recs] == [
            ("fig19_kepler_modes", "GTX780")]
        assert recs[0].verdict == PASS
        assert "PASS" in open(rep).read()

    def test_docs_check_detects_staleness(self, tmp_path):
        stale = tmp_path / "experiments.md"
        stale.write_text("# wrong\n")
        p = _cli("docs", "--check", "-o", str(stale))
        assert p.returncode == 1
        assert "stale" in p.stderr
        p = _cli("docs", "-o", str(stale))
        assert p.returncode == 0
        p = _cli("docs", "--check", "-o", str(stale))
        assert p.returncode == 0, p.stderr

    def test_committed_docs_are_fresh(self):
        """Covers every generated doc: experiments, serving, profiles
        and the argparse-derived CLI reference."""
        p = _cli("docs", "--check")
        assert p.returncode == 0, (
            "a generated doc is stale; regenerate with "
            "`PYTHONPATH=src python -m repro.bench docs`\n" + p.stderr)
        for name in ("experiments", "serving", "profiles", "cli"):
            assert f"docs/{name}.md is up to date" in p.stderr

    def test_docs_single_target_to_path(self, tmp_path):
        # one CLI call only: the cli renderer imports the launchers (jax)
        out = tmp_path / "cli.md"
        p = _cli("docs", "--only", "cli", "-o", str(out))
        assert p.returncode == 0, p.stderr
        text = out.read_text()
        assert "GENERATED FILE" in text
        assert "--fleet-profiles" in text       # launch flags documented
        assert "profile" in text and "repro.bench run" in text
