"""Differential tests: the vectorized engine vs the per-access oracle.

The vectorized trace engine (``cachesim.VectorCache`` + the backend-level
steady-state tiling) must be *bit-exact* against the reference ``Cache``
on the observable contract: per-access hit/miss, latency streams, and —
at the engine level, where no tiling is involved — eviction bookkeeping
and RNG consumption.  Seeded-numpy differentials run everywhere; the
hypothesis property test widens the geometry/policy space when hypothesis
is installed.
"""

import numpy as np
import pytest

from repro.core import devices
from repro.core.cachesim import (
    Cache, CacheGeometry, ReplacementPolicy, VectorCache, bitfield_map,
    range_cyclic_map, split_bitfield_map,
)
from repro.core.pchase import cache_backend, fine_grained

MB = 1 << 20


def _device_cache_factories():
    cases = [(name, mk) for name, mk in devices.SIM_CACHES.items()]
    cases.append(("l2_data_64k", lambda: devices.l2_data(64 << 10)))
    cases.append(("l2_data_512k", lambda: devices.l2_data(512 << 10)))
    return cases


def _streams_for(geom, rng):
    """Cyclic chases (the harness's real workloads) plus a random stream,
    scaled to the structure under test."""
    c, b = geom.size_bytes, geom.line_bytes
    fit = (np.arange(4096, dtype=np.int64) * b) % c
    thrash = (np.arange(4096, dtype=np.int64) * b) % (c + 4 * b)
    rand = rng.integers(0, 4 * c, size=3000)
    mixed = np.concatenate([fit[:1000], rand[:500], thrash[:1000]])
    return {"fit": fit, "thrash": thrash, "random": rand, "mixed": mixed}


def assert_engines_match(mk, addrs, chunk=None):
    ref, vec = mk(), VectorCache.from_cache(mk())
    ref_hits = np.fromiter((ref.access(int(a)) for a in addrs),
                           dtype=bool, count=len(addrs))
    if chunk is None:
        vec_hits = vec.access_chunk(addrs)
    else:
        vec_hits = np.concatenate([vec.access_chunk(addrs[i:i + chunk])
                                   for i in range(0, len(addrs), chunk)])
    np.testing.assert_array_equal(ref_hits, vec_hits)
    assert (ref.hits, ref.misses) == (vec.hits, vec.misses)
    assert ref.replaced_ways == vec.replaced_ways


class TestDeviceCacheEquivalence:
    """Every registered device structure, engine vs oracle, seeded."""

    @pytest.mark.parametrize("name,mk", _device_cache_factories())
    def test_hit_streams_identical(self, name, mk):
        rng = np.random.default_rng(hash(name) % (2 ** 31))
        geom = mk().geom
        for label, addrs in _streams_for(geom, rng).items():
            assert_engines_match(mk, np.asarray(addrs, dtype=np.int64))

    @pytest.mark.parametrize("name,mk", _device_cache_factories())
    def test_chunk_boundaries_are_invisible(self, name, mk):
        rng = np.random.default_rng(7)
        geom = mk().geom
        addrs = _streams_for(geom, rng)["mixed"]
        assert_engines_match(mk, addrs, chunk=137)

    @pytest.mark.parametrize("name,mk", _device_cache_factories())
    def test_backend_traces_identical(self, name, mk):
        """Full trace contract through cache_backend, multi-pass configs —
        this pins the steady-state tiling against the oracle."""
        geom = mk().geom
        c, b = geom.size_bytes, geom.line_bytes
        for n, s, passes in [(c + b, b, 12), (c + 3 * b, b, 6),
                             (c // 2, b, 4)]:
            ref = fine_grained(cache_backend(mk, engine="reference"),
                               n, s, passes=passes, warmup_passes=2)
            vec = fine_grained(cache_backend(mk, engine="vector"),
                               n, s, passes=passes, warmup_passes=2)
            np.testing.assert_array_equal(ref.indices, vec.indices)
            np.testing.assert_array_equal(ref.latencies, vec.latencies)
            np.testing.assert_array_equal(ref.meta["true_miss"],
                                          vec.meta["true_miss"])
            if not vec.meta.get("steady_state_tiled"):
                # beyond the tiling point replaced_ways is only defined up
                # to the unobservable physical-way permutation
                assert ref.meta["replaced_ways"] == vec.meta["replaced_ways"]

    def test_custom_index_streams(self):
        """Explicit (non-uniform) streams — the find_set_bits probe path."""
        mk = devices.SIM_CACHES["kepler_texture_l1"]
        probe = np.resize(np.arange(97, dtype=np.int64) * 32, 97 * 6)
        ref = cache_backend(mk, engine="reference")(
            _cfg(12 << 10, 128, len(probe)), indices=probe)
        vec = cache_backend(mk, engine="vector")(
            _cfg(12 << 10, 128, len(probe)), indices=probe)
        np.testing.assert_array_equal(ref.latencies, vec.latencies)
        assert ref.meta["replaced_ways"] == vec.meta["replaced_ways"]


def _cfg(n, s, k):
    from repro.core.trace import PChaseConfig
    return PChaseConfig(n, s, k, 4, 0)


class TestPrefetchCoalescing:
    def test_interval_membership_matches_unmerged_semantics(self):
        geom = CacheGeometry("t", 32, (64,), prefetch_lines=40)
        ref, vec = Cache(geom), VectorCache(geom)
        rng = np.random.default_rng(3)
        addrs = rng.integers(0, 1 << 16, size=4000) // 32 * 32
        for a in addrs:
            assert ref.access(int(a)) == vec.access(int(a))
        assert ref.hits == vec.hits and ref.misses == vec.misses

    def test_intervals_stay_coalesced(self):
        geom = CacheGeometry("t", 32, (4096,), prefetch_lines=8)
        c = Cache(geom)
        # descending stride-8 walk: every compulsory miss opens a window
        # abutting the previous one, so the store must collapse them
        for tag in range(2000, 0, -8):
            c.access(tag * 32)
        assert len(c._prefetched) <= 2
        assert c._in_prefetch(1999) and not c._in_prefetch(5000)


class TestThreeWayDifferential:
    """Cache / VectorCache / BatchCache on the same streams.

    Deterministic policies (lru/fifo, uniform and unequal way counts) are
    bit-exact across all three engines.  Stochastic policies share the
    victim *distribution* but not the RNG stream (see the RNG-lane
    equivalence policy in ``cachesim_jax``), so the batched lane is held
    to the exact policy-independent invariants instead: every first touch
    of a line misses, and hits only land on previously-touched lines.
    The deeper batched-engine differentials (closed form vs scan, driver
    parity, trace contract) live in ``test_engine_equivalence_jax.py``.
    """

    GEOMS = [
        ("lru_uniform", CacheGeometry("lu", 32, (4,) * 8)),
        ("fifo_uniform", CacheGeometry(
            "fu", 64, (2,) * 16, replacement=ReplacementPolicy("fifo"))),
        ("lru_unequal", CacheGeometry(
            "lq", 32, (1, 3, 5, 2),
            set_map=range_cyclic_map(32, (1, 3, 5, 2)))),
        ("fifo_unequal", CacheGeometry(
            "fq", 32, (2, 7, 1, 4), replacement=ReplacementPolicy("fifo"),
            set_map=range_cyclic_map(32, (2, 7, 1, 4)))),
        ("random_uniform", CacheGeometry(
            "ru", 32, (4,) * 4, replacement=ReplacementPolicy("random"))),
        ("prob_skewed", CacheGeometry(
            "pu", 32, (4,) * 4,
            replacement=ReplacementPolicy(
                "prob", (1 / 6, 1 / 2, 1 / 6, 1 / 6)))),
    ]

    @pytest.mark.parametrize("name,geom", GEOMS)
    def test_three_way_streams(self, name, geom):
        pytest.importorskip("jax")
        from repro.core.cachesim_jax import BatchCache

        rng = np.random.default_rng(hash(name) % (2 ** 31))
        for label, addrs in _streams_for(geom, rng).items():
            addrs = np.asarray(addrs, dtype=np.int64)
            mk = lambda: Cache(geom, np.random.default_rng(5))
            assert_engines_match(mk, addrs)        # Cache vs VectorCache
            ref = mk()
            ref_hits = np.fromiter((ref.access(int(a)) for a in addrs),
                                   dtype=bool, count=len(addrs))
            bat = BatchCache([geom], seed=5).simulate(
                [addrs], force_scan=True)[0]
            if geom.replacement.kind in ("lru", "fifo"):
                np.testing.assert_array_equal(ref_hits, bat, err_msg=label)
            else:
                _assert_policy_invariants(geom, addrs, bat, label)


def _assert_policy_invariants(geom, addrs, hits, label):
    """Policy-independent exactness for stochastic lanes: compulsory
    misses and no hit without a prior touch of the same line."""
    tags = np.asarray(addrs, dtype=np.int64) // geom.line_bytes
    _, first_idx = np.unique(tags, return_index=True)
    assert not hits[first_idx].any(), f"{label}: first touches must miss"
    seen = np.zeros(len(addrs), dtype=bool)
    prior = {}
    for i, t in enumerate(tags):
        seen[i] = t in prior
        prior[t] = i
    assert not hits[~seen].any(), f"{label}: hit without a prior touch"


# The hypothesis-widened property differential lives in
# tests/test_engine_equivalence_prop.py (importorskip'd as a module, so
# these deterministic differentials still run on bare environments).
