"""Fused RMSNorm + manual multi-buffered DMA copy kernels vs oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.dbuf_copy import dbuf_copy
from repro.kernels.rmsnorm import rmsnorm
from repro.models.layers import rms_norm


class TestRMSNormKernel:
    @pytest.mark.parametrize("rows,d,block", [(256, 128, 64), (512, 256, 256),
                                              (128, 512, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_reference(self, rows, d, block, dtype):
        x = jax.random.normal(jax.random.key(0), (rows, d), dtype)
        sc = (jax.random.normal(jax.random.key(1), (d,), dtype) * 0.1 + 1)
        y = rmsnorm(x, sc, block_rows=block)
        ref = rms_norm(x, sc, 1e-6)
        tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=tol, rtol=tol)

    def test_bad_block_raises(self):
        with pytest.raises(ValueError):
            rmsnorm(jnp.ones((100, 64)), jnp.ones((64,)), block_rows=64)


class TestDbufCopy:
    @pytest.mark.parametrize("num_buffers", [1, 2, 3, 4])
    @pytest.mark.parametrize("rows,block", [(256, 64), (512, 128), (64, 64)])
    def test_exact_copy(self, num_buffers, rows, block):
        x = jnp.arange(rows * 32, dtype=jnp.float32).reshape(rows, 32)
        y = dbuf_copy(x, block_rows=block, num_buffers=num_buffers)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))

    @settings(max_examples=8, deadline=None)
    @given(st.sampled_from([1, 2, 3]), st.sampled_from([2, 4, 8]),
           st.integers(0, 2 ** 31 - 1))
    def test_property_random_contents(self, nb, nblocks, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((nblocks * 32, 16)),
                        jnp.float32)
        y = dbuf_copy(x, block_rows=32, num_buffers=nb)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))

    def test_more_buffers_than_blocks(self):
        x = jnp.ones((64, 8))
        y = dbuf_copy(x, block_rows=64, num_buffers=4)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
