"""Unit tests for the cache/TLB/hierarchy simulator."""

import numpy as np
import pytest

from repro.core import cachesim
from repro.core.cachesim import (
    Cache, CacheGeometry, LatencyModel, MemoryHierarchy, ReplacementPolicy,
    bitfield_map, modulo_map, range_cyclic_map, split_bitfield_map,
)


def small_lru(ways=2, sets=4, line=32):
    return Cache(CacheGeometry.uniform("t", line * ways * sets, line, sets))


class TestCacheBasics:
    def test_fill_then_hit(self):
        c = small_lru()
        assert not c.access(0)          # compulsory miss
        assert c.access(0)              # hit
        assert c.access(4)              # same line, hit
        assert c.hits == 2 and c.misses == 1

    def test_capacity_no_eviction(self):
        c = small_lru()
        size = c.geom.size_bytes
        for addr in range(0, size, c.geom.line_bytes):
            c.access(addr)
        for addr in range(0, size, c.geom.line_bytes):
            assert c.access(addr), "N == C must be all hits on pass 2"

    def test_lru_eviction_order(self):
        # one set, 2 ways: access lines A, B, C -> A evicted
        c = Cache(CacheGeometry("t", 32, (2,)))
        a, b, d = 0, 32, 64
        c.access(a); c.access(b); c.access(d)
        assert not c.access(a)          # A was LRU victim
        assert c.access(d) or True      # no exception path

    def test_lru_touch_refreshes(self):
        c = Cache(CacheGeometry("t", 32, (2,)))
        a, b, d = 0, 32, 64
        c.access(a); c.access(b)
        c.access(a)                     # A now MRU
        c.access(d)                     # evicts B
        assert c.access(a)
        assert not c.access(b)

    def test_unequal_sets(self):
        ways = (3, 1)
        geom = CacheGeometry("t", 32, ways,
                             set_map=range_cyclic_map(32, ways))
        c = Cache(geom)
        # lines 0,1,2 -> set 0; line 3 -> set 1; line 4 wraps to set 0
        for ln in range(4):
            c.access(ln * 32)
        assert all(c.access(ln * 32) for ln in range(4))
        c.access(4 * 32)                # 4 % 4 -> set 0, evicts LRU line 0
        assert not c.access(0)

    def test_probe_no_state_change(self):
        c = small_lru()
        c.access(0)
        h0 = c.hits
        assert c.probe(0)
        assert c.hits == h0


class TestMappings:
    def test_modulo(self):
        f = modulo_map(32, 4)
        assert [f(i * 32) for i in range(6)] == [0, 1, 2, 3, 0, 1]

    def test_bitfield(self):
        f = bitfield_map(7, 2)          # texture L1 mapping
        assert f(0) == 0 and f(127) == 0
        assert f(128) == 1 and f(384) == 3 and f(512) == 0

    def test_split_bitfield(self):
        f = split_bitfield_map([(9, 3), (12, 2)])
        assert f(0) == 0
        assert f(1 << 9) == 1
        assert f(1 << 12) == 8
        assert f((1 << 9) | (1 << 12)) == 9
        assert f(1 << 7) == 0           # bits 7-8 unused

    def test_range_cyclic(self):
        f = range_cyclic_map(1, (17, 8))
        assert f(0) == 0 and f(16) == 0 and f(17) == 1 and f(24) == 1
        assert f(25) == 0               # wraps at 25 entries


class TestReplacementPolicies:
    def test_prob_validation(self):
        with pytest.raises(ValueError):
            ReplacementPolicy("prob")
        with pytest.raises(ValueError):
            ReplacementPolicy("prob", (0.5, 0.6))
        with pytest.raises(ValueError):
            ReplacementPolicy("bogus")

    def test_prob_way_frequencies(self):
        probs = (1 / 6, 1 / 2, 1 / 6, 1 / 6)
        geom = CacheGeometry("t", 32, (4,),
                             replacement=ReplacementPolicy("prob", probs))
        c = Cache(geom, np.random.default_rng(7))
        # cycle 5 lines through the 4-way set
        for t in range(8000):
            c.access((t % 5) * 32)
        ways = np.array([w for _, w in c.replaced_ways])
        freq = np.bincount(ways, minlength=4) / len(ways)
        np.testing.assert_allclose(freq, probs, atol=0.03)

    def test_prefetch_hides_cold_misses(self):
        geom = CacheGeometry("t", 32, (64,), prefetch_lines=40)
        c = Cache(geom)
        for addr in range(0, 32 * 32, 32):    # stream 32 lines < prefetch
            c.access(addr)
        assert c.misses == 1, "sequential prefetch must hide cold misses"


class TestHierarchy:
    def make(self):
        lat = LatencyModel(l1_hit=10, l2_hit=20, dram=100,
                           l1tlb_miss=5, pagewalk=50, context_switch=1000)
        return MemoryHierarchy(
            name="toy", latency=lat,
            l1=Cache(CacheGeometry.uniform("l1", 1024, 32, 4)),
            l2=Cache(CacheGeometry.uniform("l2", 4096, 32, 4)),
            l1tlb=Cache(CacheGeometry("t1", 1 << 20, (4,))),
            l2tlb=Cache(CacheGeometry("t2", 1 << 20, (8,))),
            page_bytes=1 << 20,
            active_window_bytes=64 << 20)

    def test_patterns(self):
        h = self.make()
        cyc, info = h.access(0)
        assert info["pattern"] == "P5"          # cold: both TLB+data miss
        cyc, info = h.access(0)
        assert info["pattern"] == "P1" and cyc == 10
        cyc, info = h.access(128 << 20)          # outside active window
        assert info["pattern"] == "P6" and cyc >= 1000

    def test_virtually_addressed_l1_skips_tlb(self):
        h = self.make()
        h.l1_virtually_addressed = True
        h.access(0)
        cyc, info = h.access(0)
        assert info["pattern"] == "P1" and cyc == 10
        assert "l1tlb" not in info
