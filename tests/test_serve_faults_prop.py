"""Hypothesis-widened chaos tier (optional dependency).

Property: for ANY fault schedule the injector can express — any mix of
kills, corruptions (every variant), degradations and recoveries, at any
ticks, targeted or untargeted — the campaign

* **replays bit-identically**: two runs of the same schedule on
  identical fleets produce the same merged decision+fault log, the same
  outcome classification, and the same byte streams;
* **loses nothing silently**: every submitted uid ends in exactly one
  outcome class, and the fleet's cross-replica invariants hold after
  the drain.

The deterministic campaigns in ``tests/test_serve_faults.py`` pin the
named scenarios; this module explores the rest of the schedule space.
"""

import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serve.faults import Fault, FaultInjector, run_campaign
from repro.serve.fleet import OUTCOME_CLASSES, FleetEngine

MICRO = ModelConfig(name="micro", family="dense", num_layers=2, d_model=32,
                    d_ff=64, vocab_size=64, num_heads=2, num_kv_heads=2,
                    dtype="float32", param_dtype="float32")
PARAMS = T.init_params(MICRO, jax.random.key(0))
N_REQ = 6


def _mk_fleet():
    return FleetEngine(MICRO, PARAMS, replicas=2, max_slots=3, max_len=32,
                       page_len=4, num_pages=12, prefill_chunk=8)


def _work():
    rng = np.random.default_rng(11)
    return [(rng.integers(1, MICRO.vocab_size,
                          size=int(rng.integers(3, 9))).astype(np.int32),
             int(rng.integers(3, 7)))
            for _ in range(N_REQ)]


faults = st.builds(
    Fault,
    tick=st.integers(0, 25),
    kind=st.sampled_from(("kill", "corrupt", "degrade", "recover")),
    replica=st.sampled_from((None, 0, 1)),
    factor=st.sampled_from((2.0, 4.0, 8.0)),
    variant=st.integers(0, 2))

schedules = st.lists(faults, min_size=1, max_size=5)


@settings(max_examples=25, deadline=None)
@given(schedule=schedules)
def test_any_schedule_replays_and_classifies(schedule):
    a = run_campaign(_mk_fleet(), _work(), FaultInjector(schedule))
    b = run_campaign(_mk_fleet(), _work(), FaultInjector(schedule))
    # bit-identical replay: log, outcomes, streams
    assert a.log == b.log
    assert a.outcomes == b.outcomes
    assert a.streams == b.streams
    # nothing silently lost: every uid classified, books closed
    assert sorted(a.outcomes) == list(range(N_REQ))
    assert set(a.outcomes.values()) <= set(OUTCOME_CLASSES)
    assert a.stats["pages_leaked"] == 0
    # what finished really finished: its stream is its full budget
    work = _work()
    for uid, cls in a.outcomes.items():
        if cls in ("completed", "migrated", "requeued"):
            assert len(a.streams[uid]) == work[uid][1]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**32 - 1),
       rate=st.sampled_from((0.05, 0.15, 0.3)))
def test_any_seeded_campaign_replays(seed, rate):
    mk = lambda: FaultInjector.campaign(seed, rate=rate,  # noqa: E731
                                        horizon=40)
    a = run_campaign(_mk_fleet(), _work(), mk())
    b = run_campaign(_mk_fleet(), _work(), mk())
    assert a.log == b.log
    assert a.outcomes == b.outcomes
    assert a.streams == b.streams
    assert sorted(a.outcomes) == list(range(N_REQ))
