"""Chaos-tier campaign suite: the fleet's failover contracts under
seeded and scripted fault injection.

The load-bearing facts, each pinned by a test below:

* replica death mid-prefill or mid-decode re-homes every stranded
  request through the ordinary ``_migrate`` machinery and the finished
  streams stay byte-identical to the fault-free oracle (greedy outputs
  are schedule-independent);
* page-table/allocator corruption is *detected* by the per-tick
  integrity poll before any dispatch or decode can consume the corrupt
  books, and the quarantine → heal → readmit lifecycle returns the
  replica to service with no token changed;
* a latency-spike degradation re-prices the replica through
  ``decode_cell_cost`` so the router organically drains load — and
  never changes a token;
* every fault schedule replays bit-identically (merged decision+fault
  log, outcomes, streams), and every submitted uid ends in exactly one
  outcome class — nothing is silently lost, and what IS lost is said so.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serve import fleet as fleet_mod
from repro.serve.engine import Request
from repro.serve.faults import (CAMPAIGN_HORIZON, DEGRADE_FACTOR,
                                FAULT_KINDS, Fault, FaultInjector,
                                run_campaign)
from repro.serve.fleet import (DEAD, DEGRADED, HEALTHY, OUTCOME_CLASSES,
                               QUARANTINED, FleetEngine)
from repro.serve.frontend import FleetFrontend

MICRO = ModelConfig(name="micro", family="dense", num_layers=2, d_model=32,
                    d_ff=64, vocab_size=64, num_heads=2, num_kv_heads=2,
                    dtype="float32", param_dtype="float32")

#: (prompt_len, max_new_tokens) — long enough that kills at tick 1 land
#: mid-prefill (prefill_chunk=16 over up-to-11-token prompts finishes in
#: one chunk, so the mid-prefill test kills during the admission tick)
#: and kills at tick 6+ land mid-decode
N_REQ = 10


@pytest.fixture(scope="module")
def setup():
    params = T.init_params(MICRO, jax.random.key(0))
    return MICRO, params


def _mk_fleet(setup, replicas=2, **kw):
    cfg, params = setup
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_len", 48)
    kw.setdefault("page_len", 8)
    kw.setdefault("prefill_chunk", 16)
    return FleetEngine(cfg, params, replicas=replicas, **kw)


def _work(cfg, n=N_REQ, seed=7):
    rng = np.random.default_rng(seed)
    return [(rng.integers(1, cfg.vocab_size,
                          size=int(rng.integers(4, 12))).astype(np.int32),
             int(rng.integers(4, 10)))
            for _ in range(n)]


@pytest.fixture(scope="module")
def oracle(setup):
    """Fault-free campaign: the byte-identity reference for every test."""
    cfg, _ = setup
    return run_campaign(_mk_fleet(setup), _work(cfg))


def _finished_match_oracle(report, oracle):
    fin = [u for u, c in report.outcomes.items()
           if c in ("completed", "migrated", "requeued")]
    assert fin, "campaign finished nothing — schedule too brutal to test"
    for u in fin:
        assert report.streams[u] == oracle.streams[u], \
            f"uid {u} ({report.outcomes[u]}) diverged from the oracle"
    return fin


class TestReplicaDeath:
    def test_kill_mid_decode_rehomes_and_matches_oracle(self, setup, oracle):
        cfg, _ = setup
        # tick 6: prompts are prefilled, decode is in flight
        r = run_campaign(_mk_fleet(setup), _work(cfg),
                         FaultInjector((Fault(6, "kill"),)))
        assert r.stats["deaths"] == 1
        assert r.event_counts.get("kill") == 1
        assert set(r.outcomes.values()) <= {"completed", "migrated",
                                            "requeued"}
        assert "migrated" in r.outcomes.values(), \
            "a mid-decode kill must strand work onto the survivor"
        _finished_match_oracle(r, oracle)

    def test_kill_mid_prefill(self, setup, oracle):
        cfg, _ = setup
        # tick 1: the very first chunked prefill wave is still landing
        r = run_campaign(_mk_fleet(setup), _work(cfg),
                         FaultInjector((Fault(1, "kill"),)))
        assert r.stats["deaths"] == 1
        _finished_match_oracle(r, oracle)

    def test_zero_pages_leaked_after_death(self, setup):
        cfg, _ = setup
        fleet = _mk_fleet(setup)
        report = run_campaign(fleet, _work(cfg),
                              FaultInjector((Fault(5, "kill"),)))
        assert report.stats["pages_leaked"] == 0
        for rep in fleet.replicas:
            assert rep.engine.alloc.allocated_pages == 0
        dead = [rep for rep in fleet.replicas if rep.state == DEAD]
        assert len(dead) == 1 and dead[0].engine.live_count() == 0

    def test_kill_last_replica_loses_classified(self, setup):
        cfg, _ = setup
        # single replica, max_kills raised so the injector may take it:
        # everything in flight is reaped as lost, loudly
        fleet = _mk_fleet(setup, replicas=1)
        inj = FaultInjector((Fault(4, "kill", replica=0),), max_kills=1)
        r = run_campaign(fleet, _work(cfg), inj)
        assert r.stats["deaths"] == 1
        assert sorted(r.outcomes) == list(range(N_REQ))
        assert "lost" in r.outcomes.values()
        assert all(c in ("completed", "lost") for c in r.outcomes.values())
        assert r.event_counts.get("lost", 0) >= 1, \
            "reaped requests must be recorded as fault events"
        assert fleet.live() == 0 and r.stats["pages_leaked"] == 0

    def test_lost_handles_flagged_through_frontend(self, setup):
        cfg, _ = setup
        fleet = _mk_fleet(setup, replicas=1)
        fleet.attach_injector(
            FaultInjector((Fault(4, "kill", replica=0),), max_kills=1))
        front = FleetFrontend(fleet)
        finishes = []
        for uid, (p, n) in enumerate(_work(cfg)):
            front.submit_blocking(p, n, uid=uid,
                                  on_finish=lambda h: finishes.append(h.uid))
        front.run()
        lost = [h for h in front.handles.values() if h.lost]
        assert lost, "the kill must strand at least one stream"
        for h in lost:
            assert not h.done and h.settled
            assert h.uid in fleet.lost
        # on_finish fired exactly once per handle, lost included
        assert sorted(finishes) == sorted(front.handles)


class TestCorruptionQuarantine:
    @pytest.mark.parametrize("variant", [0, 1, 2])
    def test_corruption_detected_quarantined_healed(self, setup, oracle,
                                                    variant):
        cfg, _ = setup
        r = run_campaign(_mk_fleet(setup), _work(cfg),
                         FaultInjector((Fault(5, "corrupt",
                                              variant=variant),)))
        assert r.event_counts.get("corrupt") == 1
        assert r.event_counts.get("quarantine") == 1, \
            "the integrity poll must catch the corruption the same tick"
        assert r.event_counts.get("readmit") == 1
        assert r.stats["quarantines"] == 1 and r.stats["readmits"] == 1
        _finished_match_oracle(r, oracle)
        assert r.stats["pages_leaked"] == 0

    def test_no_dispatch_while_quarantined(self, setup):
        cfg, _ = setup
        fleet = _mk_fleet(setup)
        fleet.attach_injector(FaultInjector((Fault(5, "corrupt"),)))
        front = FleetFrontend(fleet)
        for uid, (p, n) in enumerate(_work(cfg)):
            front.submit_blocking(p, n, uid=uid)
        saw_quarantine = False
        for _ in range(500):
            live = front.tick()
            q = [rep for rep in fleet.replicas
                 if rep.state == QUARANTINED]
            for rep in q:
                saw_quarantine = True
                assert rep.engine.live_count() == 0
                assert rep.engine.alloc.allocated_pages == 0
                assert not rep.dispatchable
            fleet.check_invariants()
            if not live:
                break
        assert saw_quarantine, "campaign never entered quarantine"
        assert all(rep.state == HEALTHY for rep in fleet.replicas), \
            "quarantine must end in readmission"

    def test_quarantine_rebuilds_allocator(self, setup):
        cfg, _ = setup
        fleet = _mk_fleet(setup)
        # corrupt variant 1 aliases a FREE page into a live list — the
        # nastiest case: release() would double-free it.  reset_paging
        # must rebuild the allocator wholesale.
        r = run_campaign(fleet, _work(cfg),
                         FaultInjector((Fault(5, "corrupt", variant=1),)))
        assert r.event_counts.get("quarantine") == 1
        for rep in fleet.replicas:
            rep.engine.alloc.check_invariants()
        fleet.check_invariants()


class TestDegrade:
    def test_degrade_drains_router(self, setup):
        cfg, _ = setup
        fleet = _mk_fleet(setup)
        fleet.attach_injector(
            FaultInjector((Fault(0, "degrade", replica=0, factor=16.0),)))
        report = run_campaign(fleet, _work(cfg))
        assert report.event_counts.get("degrade") == 1
        assert fleet.replicas[0].state == DEGRADED
        # the router re-prices through decode_cell_cost: a 16x-slower
        # replica is far outside the margin, so it only ever wins a
        # decision when the healthy replica is not a candidate at all
        # (full slots / no headroom)
        contested = [d for d in fleet.decisions
                     if any(s.replica == 1 for s in d.scores)]
        assert contested, "the healthy replica never even competed"
        assert all(d.chosen == 1 for d in contested), \
            [(d.uid, d.chosen) for d in contested]
        assert any(d.chosen == 0 for d in fleet.decisions), \
            "overflow should still spill to the slow replica"

    def test_degrade_changes_no_token(self, setup, oracle):
        cfg, _ = setup
        r = run_campaign(_mk_fleet(setup), _work(cfg),
                         FaultInjector((Fault(0, "degrade", replica=0),)))
        assert sorted(r.outcomes) == list(range(N_REQ))
        assert set(r.outcomes.values()) <= {"completed", "migrated"}
        for u in r.outcomes:
            assert r.streams[u] == oracle.streams[u]

    def test_recover_restores_base_spec(self, setup):
        cfg, _ = setup
        fleet = _mk_fleet(setup)
        base = fleet.replicas[0].spec
        fleet.attach_injector(FaultInjector(
            (Fault(0, "degrade", replica=0, factor=DEGRADE_FACTOR),
             Fault(6, "recover"))))
        run_campaign(fleet, _work(cfg))
        assert fleet.replicas[0].state == HEALTHY
        assert fleet.replicas[0].spec == base
        assert fleet.stats()["degrades"] == 1


class TestReplayAndClassification:
    def test_scripted_replay_bit_identical(self, setup):
        cfg, _ = setup
        sched = (Fault(2, "degrade", factor=4.0), Fault(5, "corrupt"),
                 Fault(8, "kill"), Fault(12, "recover"))
        a = run_campaign(_mk_fleet(setup), _work(cfg), FaultInjector(sched))
        b = run_campaign(_mk_fleet(setup), _work(cfg), FaultInjector(sched))
        assert a.log == b.log
        assert a.outcomes == b.outcomes
        assert a.streams == b.streams

    def test_log_interleaves_decisions_and_faults_on_one_seq(self, setup):
        cfg, _ = setup
        r = run_campaign(_mk_fleet(setup), _work(cfg),
                         FaultInjector((Fault(6, "kill"),)))
        seqs = [k[0] for k in r.log]
        assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs), \
            "decisions and fault events must share one strict sequence"
        kinds = {k[2] for k in r.log if isinstance(k[2], str)}
        assert any(k.startswith("fault:") for k in kinds)
        route_kinds = {k[3] for k in r.log
                       if not (isinstance(k[2], str)
                               and k[2].startswith("fault:"))}
        assert "admit" in route_kinds

    @pytest.mark.parametrize("seed", [1, 3, 5])
    def test_seeded_campaign_replay(self, setup, seed):
        cfg, _ = setup
        mk_inj = lambda: FaultInjector.campaign(seed, rate=0.15,  # noqa: E731
                                                horizon=60)
        a = run_campaign(_mk_fleet(setup), _work(cfg), mk_inj())
        b = run_campaign(_mk_fleet(setup), _work(cfg), mk_inj())
        assert a.log == b.log
        assert a.outcomes == b.outcomes
        assert a.streams == b.streams
        assert a.event_counts, f"seed {seed} fired no faults at rate 0.15"

    def test_distinct_seeds_distinct_campaigns(self, setup):
        cfg, _ = setup
        a = run_campaign(_mk_fleet(setup), _work(cfg),
                         FaultInjector.campaign(1, rate=0.15, horizon=60))
        b = run_campaign(_mk_fleet(setup), _work(cfg),
                         FaultInjector.campaign(10, rate=0.15, horizon=60))
        assert a.event_counts != b.event_counts or a.log != b.log

    def test_every_uid_classified(self, setup):
        cfg, _ = setup
        for seed in (0, 1, 2, 3):
            r = run_campaign(_mk_fleet(setup), _work(cfg),
                             FaultInjector.campaign(seed, rate=0.15,
                                                    horizon=60))
            assert sorted(r.outcomes) == list(range(N_REQ))
            assert set(r.outcomes.values()) <= set(OUTCOME_CLASSES)

    def test_unaffected_streams_byte_identical(self, setup, oracle):
        """Requests that never touched the dead replica stream the same
        bytes at the same granularity as in the fault-free run."""
        cfg, _ = setup
        fleet = _mk_fleet(setup)
        r = run_campaign(fleet, _work(cfg),
                         FaultInjector((Fault(6, "kill"),)))
        untouched = [u for u, c in r.outcomes.items() if c == "completed"]
        assert untouched, "the kill should leave some requests unaffected"
        for u in untouched:
            assert r.streams[u] == oracle.streams[u]
            assert len(fleet._homes[u]) == 1


class TestFleetInvariants:
    def test_detects_cross_replica_double_ownership(self, setup):
        cfg, _ = setup
        fleet = _mk_fleet(setup)
        req = Request(99, np.arange(4, dtype=np.int32), 3)
        fleet.replicas[0].engine.waiting.append(req)
        fleet.replicas[1].engine.waiting.append(
            Request(99, np.arange(4, dtype=np.int32), 3))
        with pytest.raises(AssertionError, match="owned by replicas"):
            fleet.check_invariants()

    def test_detects_quarantined_replica_with_live_work(self, setup):
        cfg, _ = setup
        fleet = _mk_fleet(setup)
        fleet.replicas[0].state = QUARANTINED
        fleet.replicas[0].engine.submit(
            Request(5, np.arange(4, dtype=np.int32), 3))
        fleet.replicas[0].engine.step()
        with pytest.raises(AssertionError):
            fleet.check_invariants()

    def test_invariant_violation_crashes_without_injector(self, setup):
        """Outside a campaign a corrupt allocator is a BUG: step() must
        not silently quarantine-and-continue."""
        cfg, _ = setup
        fleet = _mk_fleet(setup)
        front = FleetFrontend(fleet)
        for uid, (p, n) in enumerate(_work(cfg)[:4]):
            front.submit_blocking(p, n, uid=uid)
        front.tick()
        eng = max((r.engine for r in fleet.replicas),
                  key=lambda e: e.alloc.allocated_pages)
        assert eng.alloc.allocated_pages
        uid = sorted(eng.alloc.pages)[0]
        eng.alloc.owner[eng.alloc.pages[uid][0]] = -1
        with pytest.raises(AssertionError):
            for _ in range(50):
                front.tick()
                fleet.check_invariants()


class TestFaultAPI:
    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            Fault(0, "meteor")
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultInjector.campaign(0, kinds=("kill", "meteor"))
        assert set(FAULT_KINDS) == {"kill", "corrupt", "degrade", "recover"}

    def test_skip_recorded_when_no_target(self, setup):
        cfg, _ = setup
        fleet = _mk_fleet(setup)
        # corrupt at tick 0: nothing admitted yet, no books to corrupt
        r = run_campaign(fleet, _work(cfg),
                         FaultInjector((Fault(0, "corrupt"),)))
        assert r.event_counts.get("skip") == 1
        assert r.event_counts.get("quarantine") is None
        assert set(r.outcomes.values()) == {"completed"}

    def test_max_kills_defaults_to_sparing_one_replica(self, setup):
        cfg, _ = setup
        fleet = _mk_fleet(setup)
        r = run_campaign(fleet, _work(cfg),
                         FaultInjector((Fault(3, "kill"), Fault(6, "kill"),
                                        Fault(9, "kill"))))
        assert r.stats["deaths"] == 1
        assert r.event_counts.get("skip") == 2
        assert sum(rep.state != DEAD for rep in fleet.replicas) == 1
        assert "lost" not in r.outcomes.values()
