"""Substrate tests: optimizer math, data determinism, checkpoint/restart
(bit-exact resume after preemption), watchdog, gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import SyntheticLM
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.parallel import compression
from repro.train import checkpoint as ckpt
from repro.train.fault import SimulatedPreemption, StepWatchdog, run_training
from repro.train.loop import init_state, make_train_step


class TestAdamW:
    def numpy_adamw(self, params, grads, m, v, t, cfg, lr):
        gnorm = np.sqrt(sum((g.astype(np.float64) ** 2).sum()
                            for g in jax.tree.leaves(grads)))
        scale = min(1.0, cfg.clip_norm / max(gnorm, 1e-9))
        out_p, out_m, out_v = {}, {}, {}
        for k in params:
            g = grads[k].astype(np.float64) * scale
            m2 = cfg.b1 * m[k] + (1 - cfg.b1) * g
            v2 = cfg.b2 * v[k] + (1 - cfg.b2) * g * g
            mh = m2 / (1 - cfg.b1 ** t)
            vh = v2 / (1 - cfg.b2 ** t)
            step = mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * params[k]
            out_p[k] = params[k] - lr * step
            out_m[k], out_v[k] = m2, v2
        return out_p, out_m, out_v

    def test_matches_numpy_reference(self):
        cfg = AdamWConfig(lr=1e-2)
        rng = np.random.default_rng(0)
        params = {"a": rng.standard_normal((4, 5)).astype(np.float32),
                  "b": rng.standard_normal((7,)).astype(np.float32)}
        jparams = jax.tree.map(jnp.asarray, params)
        state = adamw_init(jparams, cfg)
        m = {k: np.zeros_like(v, dtype=np.float64) for k, v in params.items()}
        v = {k: np.zeros_like(val, dtype=np.float64)
             for k, val in params.items()}
        cur = {k: p.copy() for k, p in params.items()}
        for t in range(1, 4):
            grads = {k: rng.standard_normal(p.shape).astype(np.float32)
                     for k, p in params.items()}
            jparams, state, _ = adamw_update(
                jax.tree.map(jnp.asarray, grads), state, jparams, cfg, 1e-2)
            cur, m, v = self.numpy_adamw(cur, grads, m, v, t, cfg, 1e-2)
        for k in params:
            np.testing.assert_allclose(np.asarray(jparams[k]), cur[k],
                                       atol=1e-5, rtol=1e-5)

    def test_cosine_schedule(self):
        lr = cosine_schedule(1.0, warmup=10, total=110)
        assert float(lr(0)) == 0.0
        assert float(lr(10)) == pytest.approx(1.0)
        assert float(lr(110)) == pytest.approx(0.0, abs=1e-6)
        assert float(lr(5)) == pytest.approx(0.5)

    def test_bf16_moments_halve_memory(self):
        params = {"w": jnp.zeros((128, 128))}
        s32 = adamw_init(params, AdamWConfig(moment_dtype="float32"))
        s16 = adamw_init(params, AdamWConfig(moment_dtype="bfloat16"))
        assert s16["m"]["w"].dtype == jnp.bfloat16
        assert s16["m"]["w"].nbytes * 2 == s32["m"]["w"].nbytes


class TestDataPipeline:
    def test_deterministic_across_restarts(self):
        d1 = SyntheticLM(100, 16, 8, seed=3)
        d2 = SyntheticLM(100, 16, 8, seed=3)
        for s in (0, 5, 17):
            np.testing.assert_array_equal(d1.batch(s)["tokens"],
                                          d2.batch(s)["tokens"])

    def test_host_sharding_partitions_global_batch(self):
        full = SyntheticLM(100, 16, 8, seed=3)
        parts = [SyntheticLM(100, 16, 8, seed=3, host_id=i, num_hosts=4)
                 for i in range(4)]
        got = np.concatenate([p.batch(2)["tokens"] for p in parts])
        np.testing.assert_array_equal(got, full.batch(2)["tokens"])

    def test_learnable_structure(self):
        d = SyntheticLM(97, 128, 2, seed=0, noise=0.0)
        b = d.batch(0)
        t, l = b["tokens"][0], b["labels"][0]
        np.testing.assert_array_equal(l[:-1], t[1:])
        assert np.all(l == (31 * t.astype(np.int64) + 7) % 97)


def tiny_setup(tmp, seed=0):
    cfg = configs.get_smoke_config("granite-8b")
    opt = AdamWConfig(lr=1e-3)
    state = init_state(cfg, opt, jax.random.key(seed))
    step = jax.jit(make_train_step(cfg, opt))
    data = SyntheticLM(cfg.vocab_size, 16, 4, seed=1)

    def data_fn(s):
        b = data.batch(s)
        return {"tokens": jnp.asarray(b["tokens"]),
                "labels": jnp.asarray(b["labels"])}

    return cfg, state, step, data_fn


class TestCheckpointRestart:
    def test_roundtrip(self, tmp_path):
        _, state, step, data_fn = tiny_setup(tmp_path)
        state, _ = step(state, data_fn(0))
        ckpt.save(str(tmp_path), 1, state)
        restored, got_step = ckpt.restore(str(tmp_path), state)
        assert got_step == 1
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_retention(self, tmp_path):
        _, state, _, _ = tiny_setup(tmp_path)
        for s in range(1, 6):
            ckpt.save(str(tmp_path), s, {"x": jnp.ones(3)}, keep=2)
        assert ckpt.all_steps(str(tmp_path)) == [4, 5]

    def test_preempt_resume_bit_exact(self, tmp_path):
        """Kill at step 7 of 12, resume from checkpoint — final params must
        equal the uninterrupted run exactly."""
        _, state0, step, data_fn = tiny_setup(tmp_path)

        # uninterrupted reference
        ref, _ = run_training(state0, step, data_fn, num_steps=12)

        cdir = str(tmp_path / "ckpt")
        with pytest.raises(SimulatedPreemption):
            run_training(state0, step, data_fn, num_steps=12, ckpt_dir=cdir,
                         ckpt_every=3, preempt_at=7)
        # restart: auto-resumes from step 6
        resumed, _ = run_training(state0, step, data_fn, num_steps=12,
                                  ckpt_dir=cdir, ckpt_every=3)
        for a, b in zip(jax.tree.leaves(ref.params),
                        jax.tree.leaves(resumed.params)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_loss_decreases(self, tmp_path):
        _, state, step, data_fn = tiny_setup(tmp_path)
        losses = []
        run_training(state, step, data_fn, num_steps=30,
                     on_metrics=lambda s, m: losses.append(float(m["ce"])))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]), \
            "training should reduce loss on the synthetic bigram task"


class TestWatchdog:
    def test_flags_stragglers(self):
        wd = StepWatchdog(straggler_factor=2.0)
        flags = [wd.record(t) for t in [1.0, 1.0, 1.1, 5.0, 1.0, 4.0]]
        assert flags == [False, False, False, True, False, True]
        assert wd.stragglers == 2
        assert wd.ema is not None and wd.ema < 1.5


class TestCompression:
    def test_quantize_roundtrip_error_bound(self):
        x = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                        jnp.float32)
        q, s = compression.quantize_int8(x)
        err = np.abs(np.asarray(compression.dequantize_int8(q, s) - x))
        assert err.max() <= float(s) / 2 + 1e-9

    def test_error_feedback_unbiased_over_time(self):
        """With EF, the accumulated applied update converges to the true
        gradient sum (the 1-bit-Adam argument)."""
        rng = np.random.default_rng(0)
        g_true = [rng.standard_normal(64).astype(np.float32) * 0.01
                  for _ in range(50)]
        ef = jnp.zeros(64)
        applied = np.zeros(64)
        for g in g_true:
            deq, ef = compression.compress_leaf(jnp.asarray(g), ef)
            applied += np.asarray(deq)
        total = np.sum(g_true, axis=0)
        resid = np.abs(applied + np.asarray(ef) - total).max()
        assert resid < 1e-4

    def test_compressed_training_converges(self, tmp_path):
        cfg = configs.get_smoke_config("granite-8b")
        opt = AdamWConfig(lr=1e-3)
        data = SyntheticLM(cfg.vocab_size, 16, 4, seed=1)

        def data_fn(s):
            b = data.batch(s)
            return {"tokens": jnp.asarray(b["tokens"]),
                    "labels": jnp.asarray(b["labels"])}

        losses = {}
        for compress in (False, True):
            state = init_state(cfg, opt, jax.random.key(0),
                               compress=compress)
            step = jax.jit(make_train_step(cfg, opt,
                                           compress_grads=compress))
            ls = []
            for s in range(25):
                state, m = step(state, data_fn(s))
                ls.append(float(m["ce"]))
            losses[compress] = np.mean(ls[-5:])
        assert losses[True] < losses[False] * 1.15, \
            f"compressed {losses[True]} vs plain {losses[False]}"

    def test_microbatch_grad_accum_matches(self):
        cfg = configs.get_smoke_config("granite-8b")
        opt = AdamWConfig(lr=1e-3)
        data = SyntheticLM(cfg.vocab_size, 16, 4, seed=1)
        b = data.batch(0)
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "labels": jnp.asarray(b["labels"])}
        s1 = init_state(cfg, opt, jax.random.key(0))
        s2 = init_state(cfg, opt, jax.random.key(0))
        st1, _ = jax.jit(make_train_step(cfg, opt))(s1, batch)
        st2, _ = jax.jit(make_train_step(cfg, opt, microbatches=2))(s2, batch)
        for a, b_ in zip(jax.tree.leaves(st1.params),
                         jax.tree.leaves(st2.params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b_, np.float32),
                                       atol=5e-5, rtol=5e-5)
