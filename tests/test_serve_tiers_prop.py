"""Hypothesis-widened tiered fleet (optional dependency).

Property: for ANY admission/cancel schedule over ANY valid tier
assignment of a 3-replica fleet,

* **bit-identical replay**: two runs of the same schedule produce the
  same merged admit+handoff decision log, the same outcome
  classification, and the same token streams;
* **every uid classified**: each submitted request ends in exactly one
  outcome class, books closed, zero pages leaked;
* **single residency**: at every tick, no stream's pages are resident
  in more than one replica's page table — the handoff releases the
  source's pages before (never after) the destination allocates.

The scripted differentials in ``tests/test_serve_tiers.py`` pin the
named scenarios; this module explores the schedule × tier-plan space.
"""

import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serve.engine import Request
from repro.serve.fleet import OUTCOME_CLASSES, FleetEngine

MICRO = ModelConfig(name="micro", family="dense", num_layers=2, d_model=32,
                    d_ff=64, vocab_size=64, num_heads=2, num_kv_heads=2,
                    dtype="float32", param_dtype="float32")
PARAMS = T.init_params(MICRO, jax.random.key(0))
N_REP = 3
MAX_TICKS = 2000

# every valid 3-replica plan: both tiers non-empty, no replica orphaned
_SUBSETS = [s for i in range(1, 1 << N_REP)
            for s in [tuple(j for j in range(N_REP) if i >> j & 1)]]
PLANS = [f"prefill:{','.join(map(str, p))}/decode:{','.join(map(str, d))}"
         for p in _SUBSETS for d in _SUBSETS
         if set(p) | set(d) == set(range(N_REP))]


def _assert_single_residency(fleet, uids):
    for uid in uids:
        homes = [r.name for r in fleet.replicas
                 if r.engine.alloc.pages.get(uid)]
        assert len(homes) <= 1, \
            f"uid {uid} resident in two tiers' page tables: {homes}"


def _run(plan, schedule):
    """Drive one fleet through the schedule, checking the single-
    residency and allocator invariants every tick."""
    fleet = FleetEngine(MICRO, PARAMS, replicas=N_REP, max_slots=3,
                        max_len=32, page_len=4, prefill_chunk=8,
                        tiers=plan)
    rng = np.random.default_rng(7)
    prompts = {uid: rng.integers(1, MICRO.vocab_size, size=2 + uid % 7)
               .astype(np.int32) for uid in range(8)}
    by_tick: dict[int, list] = {}
    for tick, action, uid, n_new in schedule:
        by_tick.setdefault(tick, []).append((action, uid, n_new))
    submitted: set[int] = set()
    horizon = (max(by_tick) + 1) if by_tick else 0
    ticks = 0
    while ticks < horizon or fleet.live() or fleet.pending:
        assert ticks < MAX_TICKS, "tiered fleet failed to drain"
        for action, uid, n_new in by_tick.get(ticks, ()):
            if action == "admit" and uid not in submitted:
                fleet.submit(Request(uid, prompts[uid], n_new))
                submitted.add(uid)
            elif action == "cancel" and uid in submitted:
                fleet.cancel(uid)
        fleet.step()
        ticks += 1
        _assert_single_residency(fleet, submitted)
        fleet.check_invariants()
    assert fleet.stats()["pages_leaked"] == 0
    streams = {}
    for r in fleet.replicas:
        for req in r.engine.finished:
            streams[req.uid] = tuple(req.generated)
    return fleet, submitted, streams


events = st.tuples(st.integers(0, 12),
                   st.sampled_from(("admit", "cancel")),
                   st.integers(0, 7),
                   st.integers(1, 8))
schedules = st.lists(events, min_size=1, max_size=10)


@settings(max_examples=25, deadline=None)
@given(plan=st.sampled_from(PLANS), schedule=schedules)
def test_any_schedule_any_tiers_replays_and_classifies(plan, schedule):
    a, submitted, streams_a = _run(plan, schedule)
    b, _, streams_b = _run(plan, schedule)
    # bit-identical replay: merged two-stage log, outcomes, streams
    assert a.decision_log() == b.decision_log()
    assert a.classify() == b.classify()
    assert streams_a == streams_b
    # every submitted uid classified, exactly once, in a known class
    cls = a.classify()
    assert sorted(cls) == sorted(submitted)
    assert set(cls.values()) <= set(OUTCOME_CLASSES)


@settings(max_examples=10, deadline=None)
@given(plan=st.sampled_from(PLANS))
def test_full_admission_burst_drains_on_any_plan(plan):
    schedule = [(0, "admit", uid, 1 + uid % 6) for uid in range(8)]
    fleet, submitted, streams = _run(plan, schedule)
    cls = fleet.classify()
    assert sorted(cls) == sorted(submitted)
    assert set(cls.values()) == {"completed"}
    assert sorted(streams) == sorted(submitted)
