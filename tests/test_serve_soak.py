"""Seeded soak: randomized admission/completion/cancellation against the
page allocator's invariants, asserted EVERY tick.

Plain seeded ``np.random`` (hypothesis is not installed in the bare
container) drives a few hundred engine ticks over a deliberately tiny
page pool on a micro model, interleaving submits and cancels.  After
every tick: no leaked pages, free + allocated == capacity, no page owned
by two live requests, page tables consistent with the allocator, and at
the end every non-cancelled request has completed with exactly its
requested number of tokens.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serve.engine import PagedServeEngine, Request
from repro.serve.paging import SCRATCH_PAGES, OutOfPages, PageAllocator

MICRO = ModelConfig(name="micro", family="dense", num_layers=2, d_model=32,
                    d_ff=64, vocab_size=64, num_heads=2, num_kv_heads=2,
                    dtype="float32", param_dtype="float32")


@pytest.fixture(scope="module")
def setup():
    params = T.init_params(MICRO, jax.random.key(0))
    return MICRO, params


def _check_engine(eng: PagedServeEngine) -> None:
    """Allocator invariants plus engine<->allocator cross-consistency —
    now the engine's own consolidated sweep (``check_invariants``), the
    same poll the fleet's chaos tier uses for corruption detection, so
    the soak and the fault campaigns assert one set of books."""
    eng.check_invariants()


class TestPageAllocatorUnit:
    def test_accounting_and_double_free(self):
        a = PageAllocator(num_pages=8, page_len=4)
        assert a.capacity == 8 - SCRATCH_PAGES
        a.alloc(1, 3)
        a.alloc(2, 2)
        a.check_invariants()
        assert a.free_pages == a.capacity - 5
        with pytest.raises(OutOfPages):
            a.alloc(3, 3)              # all-or-nothing: 2 free < 3
        a.check_invariants()           # failed alloc must not leak
        assert a.release(1) == 3
        assert a.release(1) == 0       # double release is a no-op
        a.check_invariants()
        got = a.alloc(3, 3)
        assert len(set(got)) == 3 and all(p >= SCRATCH_PAGES for p in got)
        a.check_invariants()

    def test_ensure_grows_monotonically(self):
        a = PageAllocator(num_pages=16, page_len=4)
        assert a.ensure(7, 1) == 1
        assert a.ensure(7, 4) == 0     # 4 tokens still fit one page
        assert a.ensure(7, 5) == 1
        assert a.ensure(7, 3) == 0     # never shrinks
        a.check_invariants()


class TestSoak:
    def test_soak_200_ticks_invariants_every_tick(self, setup):
        cfg, params = setup
        rng = np.random.default_rng(1234)
        # tiny pool (7 usable pages x 4 tokens) under 3 slots: constant
        # admission pressure, regular preemption
        eng = PagedServeEngine(cfg, params, max_slots=3, max_len=24,
                               page_len=4, num_pages=8)
        submitted, uid = {}, 0
        cancelled_uids = set()
        ticks = 0
        while ticks < 200 or submitted:
            # random arrivals (bursty early, drained at the end)
            if ticks < 160:
                for _ in range(rng.integers(0, 3)):
                    plen = int(rng.integers(1, 9))
                    n_new = int(rng.integers(1, 7))
                    r = Request(uid, rng.integers(cfg.vocab_size, size=plen)
                                .astype(np.int32), n_new)
                    eng.submit(r)
                    submitted[uid] = r
                    uid += 1
            # random cancellation of an in-flight request
            if submitted and rng.random() < 0.08:
                victim = int(rng.choice(sorted(submitted)))
                if eng.cancel(victim):
                    cancelled_uids.add(victim)
                    del submitted[victim]
            eng.step()
            _check_engine(eng)
            for r in eng.finished:
                submitted.pop(r.uid, None)
            ticks += 1
            assert ticks < 2000, "soak failed to drain"

        assert ticks >= 200
        assert not (eng.waiting or eng.prefilling or eng.active)
        assert eng.alloc.allocated_pages == 0, "pages leaked at drain"
        assert eng.alloc.free_pages == eng.alloc.capacity
        # every non-cancelled request completed with exactly its budget
        done_uids = {r.uid for r in eng.finished}
        assert done_uids.isdisjoint(cancelled_uids)
        assert done_uids | cancelled_uids == set(range(uid))
        for r in eng.finished:
            assert len(r.generated) == r.max_new_tokens
        assert eng.preemptions > 0, \
            "pool was sized so the soak must exercise preemption"

    def test_fleet_fault_soak_200_ticks(self, setup):
        """Chaos soak: a seeded fault campaign (replica death, page-table
        corruption, latency spikes) against a 2-replica fleet under
        constant admission pressure, with BOTH the allocator-level and
        fleet-level invariants asserted after every tick.  Seed 8 is
        pinned because its campaign provably exercises >=1 kill and >=1
        corruption->quarantine->readmit cycle in this configuration."""
        from repro.serve.faults import FaultInjector
        from repro.serve.fleet import DEAD, FleetEngine
        from repro.serve.frontend import Backpressure, FleetFrontend

        cfg, params = setup
        fleet = FleetEngine(cfg, params, replicas=2, max_slots=3,
                            max_len=24, page_len=4, num_pages=10,
                            prefill_chunk=8)
        fleet.attach_injector(FaultInjector.campaign(8, rate=0.06,
                                                     horizon=160))
        front = FleetFrontend(fleet)
        rng = np.random.default_rng(4321)
        uid = 0
        while True:
            if fleet.ticks < 160:
                for _ in range(rng.integers(0, 3)):
                    plen = int(rng.integers(1, 9))
                    n_new = int(rng.integers(1, 7))
                    try:
                        front.submit(rng.integers(cfg.vocab_size, size=plen)
                                     .astype(np.int32), n_new, uid=uid)
                        uid += 1
                    except (Backpressure, ValueError):
                        break          # queue full / capacity gone: shed
            live = front.tick()
            # every tick: per-replica books + cross-replica ownership +
            # quarantined/dead replicas hold nothing
            fleet.check_invariants()
            for rep in fleet.replicas:
                if rep.state != DEAD:
                    _check_engine(rep.engine)
            if fleet.ticks >= 200 and not live:
                break
            assert fleet.ticks < 2000, "fault soak failed to drain"

        ev = {e.kind for e in fleet.events}
        assert "kill" in ev, "seed 8 must kill a replica (it does)"
        assert "quarantine" in ev and "readmit" in ev, \
            "seed 8 must exercise the corruption lifecycle (it does)"
        assert fleet.stats()["pages_leaked"] == 0
        # every submitted uid ends classified; nothing silently dropped
        outcomes = fleet.classify()
        assert sorted(outcomes) == list(range(uid))
        assert uid > 100, "admission pressure collapsed"
        # the campaign replays bit-identically is pinned in
        # tests/test_serve_faults.py; here the soak only has to survive

    def test_tiered_fleet_fault_soak_200_ticks(self, setup):
        """Tiered chaos soak: a 3-replica disaggregated fleet
        (prefill:0,1/decode:1,2) under constant admission pressure,
        with scripted kills of one prefill specialist and one decode
        specialist mid-run.  After EVERY tick: per-replica allocator
        books, cross-replica ownership (no stream resident in two
        tiers' page tables), zero leaked pages across handoffs — and at
        drain every submitted uid is classified."""
        from repro.serve.faults import Fault, FaultInjector
        from repro.serve.fleet import DEAD, FleetEngine
        from repro.serve.frontend import Backpressure, FleetFrontend

        cfg, params = setup
        fleet = FleetEngine(cfg, params, replicas=3, max_slots=3,
                            max_len=24, page_len=4, num_pages=12,
                            prefill_chunk=8, tiers="prefill:0,1/decode:1,2")
        assert fleet.tiered
        fleet.attach_injector(FaultInjector((
            Fault(tick=40, kind="kill", replica=0),    # prefill specialist
            Fault(tick=90, kind="kill", replica=2))))  # decode specialist
        front = FleetFrontend(fleet)
        rng = np.random.default_rng(2024)
        uid = 0
        while True:
            if fleet.ticks < 160:
                for _ in range(rng.integers(0, 3)):
                    plen = int(rng.integers(1, 9))
                    n_new = int(rng.integers(1, 7))
                    try:
                        front.submit(rng.integers(cfg.vocab_size, size=plen)
                                     .astype(np.int32), n_new, uid=uid)
                        uid += 1
                    except (Backpressure, ValueError):
                        break          # queue full / capacity gone: shed
            live = front.tick()
            fleet.check_invariants()
            for rep in fleet.replicas:
                if rep.state != DEAD:
                    _check_engine(rep.engine)
            # single residency: handoffs release the source's pages
            # before the destination allocates, never after
            for u in range(uid):
                homes = [rep.name for rep in fleet.replicas
                         if rep.engine.alloc.pages.get(u)]
                assert len(homes) <= 1, \
                    f"uid {u} resident in two tiers: {homes}"
            if fleet.ticks >= 200 and not live:
                break
            assert fleet.ticks < 2000, "tiered soak failed to drain"

        s = fleet.stats()
        assert s["handoffs"] > 0, "tiered soak must exercise handoffs"
        assert s["pages_leaked"] == 0, "pages leaked across handoffs"
        assert {e.kind for e in fleet.events} >= {"kill"}
        outcomes = fleet.classify()
        assert sorted(outcomes) == list(range(uid))
        assert uid > 100, "admission pressure collapsed"

    def test_sharded_replica_soak_invariants_every_tick(self, setup):
        """The mesh seam under sustained churn: a 2-replica fleet whose
        replicas each hold a 1-device mesh slice, driven by the same
        randomized admission/cancel pressure as the unsharded soak, with
        the per-replica allocator books and the fleet's cross-replica
        invariants asserted after EVERY tick.  The host-side routing,
        admission and page accounting must not notice the mesh at all —
        only the pool leaves moved."""
        from repro.launch.mesh import make_serve_mesh
        from repro.serve.fleet import FleetEngine
        from repro.serve.frontend import Backpressure, FleetFrontend

        cfg, params = setup
        fleet = FleetEngine(cfg, params, replicas=2, max_slots=3,
                            max_len=24, page_len=4, num_pages=10,
                            prefill_chunk=8, mesh=make_serve_mesh(1))
        for rep in fleet.replicas:
            assert rep.engine.mesh is not None
            assert rep.engine.stats()["gather_shards"] == 1
        front = FleetFrontend(fleet)
        rng = np.random.default_rng(97)
        uid, cancelled = 0, set()
        while True:
            if fleet.ticks < 100:
                for _ in range(rng.integers(0, 3)):
                    plen = int(rng.integers(1, 9))
                    n_new = int(rng.integers(1, 7))
                    try:
                        front.submit(rng.integers(cfg.vocab_size, size=plen)
                                     .astype(np.int32), n_new, uid=uid)
                        uid += 1
                    except (Backpressure, ValueError):
                        break
            if uid and rng.random() < 0.08:
                victim = int(rng.integers(uid))
                if front.cancel(victim):
                    cancelled.add(victim)
            live = front.tick()
            fleet.check_invariants()
            for rep in fleet.replicas:
                _check_engine(rep.engine)
            if fleet.ticks >= 120 and not live:
                break
            assert fleet.ticks < 2000, "sharded soak failed to drain"

        assert fleet.stats()["pages_leaked"] == 0
        outcomes = fleet.classify()
        assert sorted(outcomes) == list(range(uid))
        assert uid > 60, "admission pressure collapsed"

    def test_drain_and_reuse(self, setup):
        """Two full workloads through one engine: the second must start
        from a completely recycled pool."""
        cfg, params = setup
        rng = np.random.default_rng(7)
        eng = PagedServeEngine(cfg, params, max_slots=2, max_len=16,
                               page_len=4, num_pages=6)
        for round_ in range(2):
            for i in range(5):
                eng.submit(Request(round_ * 10 + i,
                                   rng.integers(cfg.vocab_size, size=3)
                                   .astype(np.int32), 4))
            fin = eng.run_to_completion()
            _check_engine(eng)
            assert eng.alloc.allocated_pages == 0
        assert len(fin) == 10
