"""Continuous-batching engine: every request's output must equal its
isolated prefill+greedy-decode generation, regardless of slot contention,
admission order, or prompt-length mix."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine


def isolated_generate(cfg, params, prompt, n_new, max_len):
    logits, cache = T.prefill(params, cfg, {"tokens": prompt[None]},
                              max_len=max_len)
    tok = int(jnp.argmax(logits[0, -1]))
    out = [tok]
    pos = len(prompt)
    for _ in range(n_new - 1):
        lg, cache = T.decode(params, cfg, cache,
                             jnp.asarray([[tok]], jnp.int32), jnp.int32(pos))
        tok = int(jnp.argmax(lg[0, 0]))
        out.append(tok)
        pos += 1
    return out


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config("granite-8b")
    params = T.init_params(cfg, jax.random.key(0))
    return cfg, params


class TestServeEngine:
    def test_matches_isolated_generation(self, setup):
        cfg, params = setup
        rng = np.random.default_rng(0)
        max_len = 48
        reqs = []
        for uid, (plen, n_new) in enumerate([(8, 6), (12, 4), (5, 9),
                                             (16, 3), (7, 7)]):
            prompt = rng.integers(cfg.vocab_size, size=plen).astype(np.int32)
            reqs.append(Request(uid, prompt, n_new))

        engine = ServeEngine(cfg, params, max_slots=3, max_len=max_len)
        for r in reqs:
            engine.submit(r)
        finished = engine.run_to_completion()
        assert len(finished) == len(reqs)

        for r in finished:
            want = isolated_generate(cfg, params, jnp.asarray(r.prompt),
                                     r.max_new_tokens, max_len)
            assert r.generated == want, f"req {r.uid} diverged"

    def test_slots_recycled(self, setup):
        cfg, params = setup
        rng = np.random.default_rng(1)
        engine = ServeEngine(cfg, params, max_slots=2, max_len=32)
        for uid in range(6):
            engine.submit(Request(
                uid, rng.integers(cfg.vocab_size, size=4).astype(np.int32),
                3))
        finished = engine.run_to_completion()
        assert len(finished) == 6
        s = engine.stats()
        assert s["decoded_tokens"] > 0
        assert 0 < s["avg_batch_occupancy"] <= 1

    @pytest.mark.parametrize("arch", ["mamba2-1.3b", "deepseek-v2-lite-16b",
                                      "jamba-1.5-large-398b"])
    def test_other_families(self, arch):
        """Continuous batching over SSM, MLA and hybrid caches."""
        import dataclasses
        cfg = configs.get_smoke_config(arch)
        if cfg.is_moe:
            cfg = dataclasses.replace(cfg,
                                      capacity_factor=float(cfg.num_experts))
        params = T.init_params(cfg, jax.random.key(0))
        rng = np.random.default_rng(3)
        engine = ServeEngine(cfg, params, max_slots=2, max_len=32)
        reqs = [Request(uid, rng.integers(cfg.vocab_size, size=plen)
                        .astype(np.int32), n_new)
                for uid, (plen, n_new) in enumerate([(6, 4), (9, 3), (4, 5)])]
        for r in reqs:
            engine.submit(r)
        finished = engine.run_to_completion()
        assert len(finished) == 3
        for r in finished:
            want = isolated_generate(cfg, params, jnp.asarray(r.prompt),
                                     r.max_new_tokens, 32)
            assert r.generated == want, f"{arch} req {r.uid} diverged"

    def test_rejects_oversized_request(self, setup):
        cfg, params = setup
        engine = ServeEngine(cfg, params, max_slots=1, max_len=16)
        with pytest.raises(ValueError):
            engine.submit(Request(0, np.zeros(10, np.int32), 10))

    def test_vector_cache_index_decode(self, setup):
        """The model-level primitive: per-slot positions must equal
        per-request scalar decodes."""
        cfg, params = setup
        rng = np.random.default_rng(2)
        max_len = 24
        p1 = rng.integers(cfg.vocab_size, size=6).astype(np.int32)
        p2 = rng.integers(cfg.vocab_size, size=11).astype(np.int32)
        caches, toks, poss = [], [], []
        for p in (p1, p2):
            lg, c = T.prefill(params, cfg, {"tokens": jnp.asarray(p[None])},
                              max_len=max_len)
            caches.append(c)
            toks.append(int(jnp.argmax(lg[0, -1])))
            poss.append(len(p))
        batched = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=1),
                               caches[0], caches[1])
        lg, _ = T.decode(params, cfg, batched,
                         jnp.asarray([[toks[0]], [toks[1]]], jnp.int32),
                         jnp.asarray(poss, jnp.int32))
        for i, (c, t, pos) in enumerate(zip(caches, toks, poss)):
            ref, _ = T.decode(params, cfg, c,
                              jnp.asarray([[t]], jnp.int32), jnp.int32(pos))
            np.testing.assert_allclose(np.asarray(lg[i, 0], np.float32),
                                       np.asarray(ref[0, 0], np.float32),
                                       atol=2e-4, rtol=2e-4)
