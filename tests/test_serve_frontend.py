"""Frontend contracts: submission bounds, terminal-state guards, and the
SLO tracker's tick accounting.

Regression coverage for the PR-8 bugfix sweep:

* an explicit ``max_pending=0`` (or any non-positive bound) must be
  REJECTED with a clear error, never silently replaced by the default
  (the old ``max_pending or 2 * total_slots`` idiom ate the zero);
* ``cancel()`` on a LOST handle must be a no-op — the lost transition
  already fired ``on_finish``, and re-entering would double-fire it;
* a submission the fleet rejects as unservable must not burn a
  ``_next_uid`` increment (uid streams stay dense under rejection);
* TTFT/TPOT come from the frontend's ``SLOTracker`` in fleet-tick units,
  with ``arrival_tick`` backdating for callers that retried through
  backpressure.
"""

import math

import jax
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serve.fleet import FleetEngine
from repro.serve.frontend import Backpressure, FleetFrontend
from repro.serve.slo import SLOTracker, percentile

MAX_LEN = 32


@pytest.fixture(scope="module")
def micro():
    cfg = ModelConfig(name="micro", family="dense", num_layers=2,
                      d_model=32, d_ff=64, vocab_size=64, num_heads=2,
                      num_kv_heads=2, dtype="float32",
                      param_dtype="float32")
    return cfg, T.init_params(cfg, jax.random.key(0))


def _fleet(micro, **kw):
    cfg, params = micro
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("replicas", 1)
    return FleetEngine(cfg, params, **kw)


def _prompt(cfg, n=4, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(cfg.vocab_size, size=n).astype(np.int32)


class TestMaxPendingBound:
    def test_explicit_zero_rejected(self, micro):
        """max_pending=0 must error, not silently become the default."""
        fleet = _fleet(micro)
        with pytest.raises(ValueError, match="max_pending"):
            FleetFrontend(fleet, max_pending=0)

    def test_negative_rejected(self, micro):
        with pytest.raises(ValueError, match="max_pending"):
            FleetFrontend(_fleet(micro), max_pending=-3)

    def test_none_gets_default(self, micro):
        fleet = _fleet(micro, replicas=2)
        front = FleetFrontend(fleet)
        total = sum(r.engine.max_slots for r in fleet.replicas)
        assert front.max_pending == 2 * total

    def test_explicit_positive_kept(self, micro):
        front = FleetFrontend(_fleet(micro), max_pending=1)
        assert front.max_pending == 1


class TestUidNotBurnedOnReject:
    def test_unservable_submission_keeps_uid_stream_dense(self, micro):
        cfg, _ = micro
        front = FleetFrontend(_fleet(micro))
        with pytest.raises(ValueError, match="fits no replica"):
            front.submit(_prompt(cfg, MAX_LEN), MAX_LEN)   # > max_len
        assert not front.handles, "rejected submission left a handle"
        h = front.submit(_prompt(cfg), 2)
        assert h.uid == 0, "rejected submission burned a uid"

    def test_rejected_uid_leaves_no_slo_row(self, micro):
        cfg, _ = micro
        front = FleetFrontend(_fleet(micro))
        with pytest.raises(ValueError):
            front.submit(_prompt(cfg, MAX_LEN), MAX_LEN)
        assert not front.slo.timings


class TestCancelGuards:
    def test_cancel_after_lost_is_noop(self, micro):
        """A lost handle already fired on_finish; cancel() must never
        fire it again, even if the fleet would still accept the cancel
        (the guard is on handle.settled, not done-or-cancelled)."""
        cfg, _ = micro
        fleet = _fleet(micro)
        front = FleetFrontend(fleet)
        fires = []
        h = front.submit(_prompt(cfg), 6, on_finish=fires.append)
        front.tick()
        fleet.kill(0)                  # only replica dies: request doomed
        front.tick()
        assert h.lost and not h.done
        assert fires == [h], "lost transition must fire on_finish once"
        # force the fleet-level cancel to look available — the frontend
        # guard alone must refuse the re-entry
        fleet.cancel = lambda uid: True
        assert front.cancel(h.uid) is False
        assert fires == [h], "cancel after lost double-fired on_finish"
        assert not h.cancelled

    def test_cancel_after_finish_is_noop(self, micro):
        cfg, _ = micro
        front = FleetFrontend(_fleet(micro))
        fires = []
        h = front.submit(_prompt(cfg), 2, on_finish=fires.append)
        front.run()
        assert h.done and fires == [h]
        assert front.cancel(h.uid) is False
        assert fires == [h]

    def test_cancel_live_fires_once_and_tracks_outcome(self, micro):
        cfg, _ = micro
        front = FleetFrontend(_fleet(micro))
        fires = []
        h = front.submit(_prompt(cfg), 8, on_finish=fires.append)
        assert front.cancel(h.uid) is True
        assert h.cancelled and fires == [h]
        assert front.slo.timings[h.uid].outcome == "cancelled"
        assert front.cancel(h.uid) is False, "second cancel must no-op"


class TestSLOAccounting:
    def test_ttft_tpot_recorded_in_ticks(self, micro):
        cfg, _ = micro
        front = FleetFrontend(_fleet(micro))
        h = front.submit(_prompt(cfg, 6), 5)
        front.run()
        t = front.slo.timings[h.uid]
        assert t.outcome == "finished"
        assert t.tokens == len(h.tokens) == 5
        assert t.ttft_ticks is not None and t.ttft_ticks >= 1
        assert t.tpot_ticks is not None and t.tpot_ticks <= 1.0
        assert t.residence_ticks >= t.ttft_ticks

    def test_arrival_tick_backdates_ttft(self, micro):
        cfg, _ = micro
        front = FleetFrontend(_fleet(micro))
        warm = front.submit(_prompt(cfg), 3)
        front.run()
        assert front.fleet.ticks > 0
        h = front.submit(_prompt(cfg, 5, seed=1), 3, arrival_tick=0)
        front.run()
        t = front.slo.timings[h.uid]
        assert t.submit_tick == 0, "arrival_tick must backdate the clock"
        assert t.ttft_ticks > front.slo.timings[warm.uid].ttft_ticks

    def test_report_is_deterministic_and_consistent(self, micro):
        cfg, _ = micro
        def run():
            front = FleetFrontend(_fleet(micro))
            for i, (plen, n_new) in enumerate([(4, 3), (7, 5), (2, 6)]):
                front.submit(_prompt(cfg, plen, seed=i), n_new)
            front.run()
            return front.slo.report()
        a, b = run(), run()
        assert a.key() == b.key(), "identical runs must report identically"
        assert a.outcome_counts["finished"] == a.requests == 3
        assert a.tokens == 3 + 5 + 6
        # Little's law is an accounting identity on the report:
        # L = lambda * W with lambda = n/makespan, W = mean residence
        lam = a.requests / a.makespan_ticks
        assert math.isclose(a.mean_concurrency,
                            lam * a.mean_residence_ticks, rel_tol=1e-12)

    def test_tracker_rejects_misuse(self):
        trk = SLOTracker()
        trk.on_submit(0, 5)
        with pytest.raises(ValueError, match="already tracked"):
            trk.on_submit(0, 6)
        trk.on_finish(0, 9, "finished")
        with pytest.raises(ValueError, match="already settled"):
            trk.on_finish(0, 10, "cancelled")
        with pytest.raises(ValueError, match="unknown outcome"):
            trk.on_finish(0, 10, "exploded")

    def test_percentile_nearest_rank(self):
        vals = [10, 20, 30, 40]
        assert percentile(vals, 50) == 20.0
        assert percentile(vals, 99) == 40.0
        assert percentile([7], 50) == 7.0
        assert percentile(vals, 100) == 40.0
        with pytest.raises(ValueError):
            percentile(vals, 0)
        with pytest.raises(ValueError):
            percentile([], 50)


class TestBackpressureRetry:
    def test_bound_raises_and_drains(self, micro):
        cfg, _ = micro
        front = FleetFrontend(_fleet(micro), max_pending=1)
        front.submit(_prompt(cfg), 4)
        # with a saturated bound, immediate resubmission must backpressure
        with pytest.raises(Backpressure):
            while True:
                front.submit(_prompt(cfg, seed=2), 4)
        handles = front.run()
        assert all(h.done for h in handles)
