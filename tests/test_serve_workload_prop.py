"""Property tests (hypothesis): workload determinism and planner honesty.

Pure numpy/accounting — no jax, no engines — so the search space can be
wide.  Skipped wholesale when hypothesis is not installed (the repo
never requires it; CI images that have it get the extra coverage).
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models.config import ModelConfig  # noqa: E402
from repro.serve.planner import SLOTarget, plan_capacity  # noqa: E402
from repro.serve.workload import (ARRIVALS, SCENARIOS,  # noqa: E402
                                  WorkloadSpec, generate_trace)

MICRO = ModelConfig(name="micro", family="dense", num_layers=2, d_model=32,
                    d_ff=64, vocab_size=64, num_heads=2, num_kv_heads=2,
                    dtype="float32", param_dtype="float32")

specs = st.builds(
    WorkloadSpec,
    scenario=st.sampled_from(sorted(SCENARIOS)),
    arrival=st.sampled_from(ARRIVALS),
    rate=st.floats(min_value=0.05, max_value=2.0, allow_nan=False),
    horizon=st.integers(min_value=1, max_value=40),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    max_len=st.integers(min_value=4, max_value=64),
)


@settings(max_examples=40, deadline=None)
@given(spec=specs)
def test_trace_is_deterministic_and_well_formed(spec):
    """Any spec: bit-identical regeneration, engine-fitting lengths,
    uid = arrival order."""
    trace = generate_trace(spec)
    assert generate_trace(spec).fingerprint() == trace.fingerprint()
    last_tick = -1
    for uid, r in enumerate(trace.requests):
        assert r.uid == uid
        assert r.tick >= last_tick, "births must be sorted by tick"
        last_tick = r.tick
        assert 1 <= len(r.prompt) <= spec.max_len - 1
        assert 1 <= r.max_new_tokens
        assert len(r.prompt) + r.max_new_tokens <= spec.max_len
    if trace.requests:
        st_ = trace.stats()
        assert st_["arrival_per_tick"] > 0
        assert st_["span_ticks"] >= spec.horizon


@settings(max_examples=40, deadline=None)
@given(lam=st.floats(min_value=0.01, max_value=4.0, allow_nan=False),
       mean_prompt=st.floats(min_value=1.0, max_value=30.0),
       mean_new=st.floats(min_value=1.0, max_value=30.0),
       slots=st.integers(min_value=1, max_value=8),
       ttft=st.floats(min_value=2.0, max_value=64.0),
       util=st.floats(min_value=0.2, max_value=0.95))
def test_planner_honors_its_own_slo(lam, mean_prompt, mean_new, slots,
                                    ttft, util):
    """A feasible plan satisfies the SLO it was asked for; an infeasible
    one admits it.  The chosen N is minimal: N-1 violates the SLO."""
    slo = SLOTarget(ttft_p99_ticks=ttft, max_utilization=util)
    plan = plan_capacity(MICRO, arrival_per_tick=lam,
                         mean_prompt=mean_prompt, mean_new=mean_new,
                         max_slots=slots, max_len=64, slo=slo)
    mu = plan.replica.service_rate
    assert mu > 0
    if plan.feasible:
        assert plan.utilization <= util + 1e-12
        assert plan.predicted_ttft_ticks <= ttft + 1e-9
        if plan.replicas > 1:
            rho = lam / ((plan.replicas - 1) * mu)
            ttft_less = (plan.replica.prefill_ticks / (1 - rho)
                         if rho < 1 else float("inf"))
            assert rho > util + 1e-12 or ttft_less > ttft + 1e-9, \
                "chosen N was not minimal"
    else:
        from repro.serve.planner import MAX_REPLICAS
        rho = lam / (MAX_REPLICAS * mu)
        ttft_max = (plan.replica.prefill_ticks / (1 - rho)
                    if rho < 1 else float("inf"))
        assert rho > util + 1e-12 or ttft_max > ttft + 1e-9, \
            "planner declared infeasible a load its own model accepts"
