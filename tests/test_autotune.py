"""Memory-model-driven autotuner: VMEM feasibility, Little's-law floors,
and monotonicity properties."""

import dataclasses

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import autotune
from repro.core.devices import TPU_V5E


class TestFlashBlocks:
    def test_fits_vmem_budget(self):
        p = autotune.flash_attention_blocks(32768, 32768, 128)
        assert p.vmem_bytes <= TPU_V5E.vmem_bytes * 0.5
        assert p.block_q >= 128 and p.block_k >= 128

    def test_bigger_q_block_cuts_traffic(self):
        """Each q block re-streams K/V: traffic must fall with block_q."""
        p = autotune.flash_attention_blocks(32768, 32768, 64)
        small_traffic = (32768 * 64 * 2 * 2 +
                         (32768 / 128) * 32768 * 64 * 2 * 2)
        assert p.hbm_bytes < small_traffic

    def test_tiny_vmem_fallback(self):
        tiny = dataclasses.replace(TPU_V5E, vmem_bytes=1 << 16)
        p = autotune.flash_attention_blocks(4096, 4096, 128, spec=tiny)
        assert (p.block_q, p.block_k) == (128, 128)

    @settings(max_examples=15, deadline=None)
    @given(st.sampled_from([1024, 4096, 32768]),
           st.sampled_from([64, 128, 256]))
    def test_property_blocks_divide_and_fit(self, seq, d):
        p = autotune.flash_attention_blocks(seq, seq, d)
        assert p.block_q <= seq and p.block_k <= seq
        assert p.vmem_bytes <= TPU_V5E.vmem_bytes * 0.5
        assert p.hbm_bytes >= seq * d * 2 * 2   # at least q in + o out


class TestMemcpyBlock:
    def test_inflight_floor(self):
        p = autotune.memcpy_block(512)
        assert p.block_bytes >= p.inflight_bytes
        assert p.block_rows % TPU_V5E.sublanes == 0

    def test_wider_rows_fewer_needed(self):
        narrow = autotune.memcpy_block(128)
        wide = autotune.memcpy_block(4096)
        assert wide.block_rows <= narrow.block_rows
