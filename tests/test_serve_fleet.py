"""Fleet + streaming front end: the differential-oracle and router
contracts.

* an N=1 fleet reproduces the single paged engine (itself pinned to the
  dense oracle) token-for-token, request-for-request — including through
  the streaming front end's callbacks;
* routing is deterministic: two identical runs replay the decision log
  bit-identically;
* the router never picks a replica whose predicted step cost exceeds the
  best candidate's by more than its own margin, and per-replica pricing
  is correctly scoped — a mixed GTX980/TeslaV100/tpu_v5e fleet must not
  emit a single SpecMixWarning;
* saturation surfaces as Backpressure at the front end and drains;
* a preempted request stranded behind a page-dry replica migrates to one
  with headroom, without changing any token.
"""

import warnings

import jax
import numpy as np
import pytest

from repro.core.devices import TpuSpec
from repro.core.profile import SpecMixWarning
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.profile import published_profile
from repro.serve.engine import PagedServeEngine, Request, ServeEngine
from repro.serve.fleet import FleetEngine
from repro.serve.frontend import Backpressure, FleetFrontend

WORK = [(8, 6), (12, 4), (5, 9), (16, 3), (7, 7), (3, 5)]
MAX_LEN = 32


@pytest.fixture(scope="module")
def micro():
    cfg = ModelConfig(name="micro", family="dense", num_layers=2,
                      d_model=32, d_ff=64, vocab_size=64, num_heads=2,
                      num_kv_heads=2, dtype="float32",
                      param_dtype="float32")
    return cfg, T.init_params(cfg, jax.random.key(0))


def _requests(cfg, work=WORK, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid, rng.integers(cfg.vocab_size, size=plen)
                    .astype(np.int32), n_new)
            for uid, (plen, n_new) in enumerate(work)]


@pytest.fixture(scope="module")
def oracle(micro):
    """Dense-slot greedy outputs: the fleet must reproduce these."""
    cfg, params = micro
    dense = ServeEngine(cfg, params, max_slots=2, max_len=MAX_LEN)
    for r in _requests(cfg):
        dense.submit(r)
    return {r.uid: r.generated for r in dense.run_to_completion()}


def _drained(fleet):
    fleet.check_invariants()
    assert fleet.stats()["pages_leaked"] == 0, "pages leaked across fleet"


class TestOracleEquivalence:
    def test_n1_fleet_matches_paged_and_dense(self, micro, oracle):
        """N=1: same admission predicate, same FIFO ⇒ the fleet IS the
        single paged engine, tick-for-tick and token-for-token."""
        cfg, params = micro
        paged = PagedServeEngine(cfg, params, max_slots=2, max_len=MAX_LEN)
        for r in _requests(cfg):
            paged.submit(r)
        paged_out = {r.uid: r.generated for r in paged.run_to_completion()}
        assert paged_out == oracle

        fleet = FleetEngine(cfg, params, max_slots=2, max_len=MAX_LEN,
                            replicas=1)
        for r in _requests(cfg):
            fleet.submit(r)
        out = {r.uid: r.generated for r in fleet.run_to_completion()}
        assert out == oracle
        assert fleet.ticks == paged.steps, \
            "N=1 fleet must follow the single engine's schedule exactly"
        _drained(fleet)

    def test_n1_streaming_frontend_matches_oracle(self, micro, oracle):
        """The per-token callbacks see the oracle stream, in order."""
        cfg, params = micro
        fleet = FleetEngine(cfg, params, max_slots=2, max_len=MAX_LEN,
                            replicas=1)
        front = FleetFrontend(fleet, max_pending=len(WORK))
        streamed: dict[int, list[int]] = {}
        finished: list[int] = []
        for r in _requests(cfg):
            front.submit(r.prompt, r.max_new_tokens, uid=r.uid,
                         on_token=lambda u, t:
                         streamed.setdefault(u, []).append(t),
                         on_finish=lambda h: finished.append(h.uid))
        handles = front.run()
        assert streamed == oracle
        assert {h.uid: h.tokens for h in handles} == oracle
        assert sorted(finished) == sorted(oracle)
        _drained(fleet)

    def test_mixed_profile_fleet_matches_oracle_per_request(
            self, micro, oracle):
        """Greedy outputs are schedule-independent, so even an N=3
        heterogeneous fleet must reproduce the oracle per request —
        and per-replica pricing must never mix specs."""
        cfg, params = micro
        with warnings.catch_warnings():
            warnings.simplefilter("error", SpecMixWarning)
            profs = [published_profile(d)
                     for d in ("GTX980", "TeslaV100", "tpu_v5e")]
            fleet = FleetEngine(cfg, params, max_slots=2, max_len=MAX_LEN,
                                profiles=profs)
            for r in _requests(cfg):
                fleet.submit(r)
            out = {r.uid: r.generated for r in fleet.run_to_completion()}
        assert out == oracle
        assert not fleet.margin_violations()
        # the fleet actually spread load (router, not round-robin-by-luck)
        used = [p["finished"] for p in fleet.stats()["per_replica"]]
        assert sum(1 for u in used if u) >= 2
        _drained(fleet)


class TestRouter:
    def test_deterministic_replay(self, micro):
        """Same workload, same fleet ⇒ bit-identical decision log."""
        cfg, params = micro

        def run():
            profs = [published_profile(d) for d in ("TeslaV100", "tpu_v5e")]
            fleet = FleetEngine(cfg, params, max_slots=2, max_len=MAX_LEN,
                                profiles=profs)
            for r in _requests(cfg):
                fleet.submit(r)
            fleet.run_to_completion()
            return fleet

        a, b = run(), run()
        assert a.decision_log() == b.decision_log()
        sa, sb = a.stats(), b.stats()
        for k in ("ticks", "decisions", "migrations", "preemptions",
                  "decoded_tokens", "peak_pages"):
            assert sa[k] == sb[k], k

    def test_margin_invariant_and_fast_replica_preference(self, micro):
        """A replica 20× slower on paper is outside the margin: the first
        requests must land on the fast one, and no decision may ever
        choose beyond the margin of the best candidate."""
        cfg, params = micro
        fast = TpuSpec(name="fast")
        slow = TpuSpec(name="slow",
                       peak_bf16_flops=fast.peak_bf16_flops / 20,
                       hbm_bytes_per_s=fast.hbm_bytes_per_s / 20)
        fleet = FleetEngine(cfg, params, max_slots=2, max_len=MAX_LEN,
                            profiles=[slow, fast])
        for r in _requests(cfg):
            fleet.submit(r)
        fleet.run_to_completion()
        assert not fleet.margin_violations()
        first = fleet.decisions[0]
        assert first.chosen == 1, "router must prefer the fast replica"
        by_cost = {s.replica: s.step_cost_s for s in first.scores}
        assert by_cost[0] > by_cost[1] * (1 + fleet.margin)
        _drained(fleet)

    def test_littles_law_overage_spreads_load(self, micro):
        """Once a replica's live count covers its Little's-law inflight
        bound, extra concurrency is penalized: with equal specs the
        second request must go to the empty replica even though the
        first one has more free pages."""
        cfg, params = micro
        # a spec whose latency×bandwidth quantum is ~one gather row
        tiny = TpuSpec(name="tiny", hbm_bytes_per_s=1e6,
                       hbm_latency_s=1e-6)
        fleet = FleetEngine(cfg, params, max_slots=2, max_len=MAX_LEN,
                            profiles=[tiny, tiny], page_len=4,
                            num_pages=[40, 10])
        assert all(r.inflight_bound == 1 for r in fleet.replicas)
        for r in _requests(cfg, work=[(4, 4), (4, 4)]):
            fleet.submit(r)
        fleet.step()
        chosen = [d.chosen for d in fleet.decisions[:2]]
        assert chosen == [0, 1], \
            "overage must beat the bigger pool's headroom"
        fleet.run_to_completion()
        _drained(fleet)

    def test_unservable_request_rejected(self, micro):
        cfg, params = micro
        fleet = FleetEngine(cfg, params, max_slots=1, max_len=16,
                            replicas=2, page_len=4, num_pages=3)
        with pytest.raises(ValueError):
            fleet.submit(Request(0, np.zeros(8, np.int32), 12))  # > max_len
        with pytest.raises(ValueError):
            # fits max_len but no replica's 2-page pool can ever hold it
            fleet.submit(Request(1, np.zeros(8, np.int32), 4))


class TestBackpressureAndCancel:
    def test_saturation_backpressure_then_drain(self, micro):
        cfg, params = micro
        fleet = FleetEngine(cfg, params, max_slots=1, max_len=MAX_LEN,
                            replicas=1, page_len=4)
        front = FleetFrontend(fleet, max_pending=2)
        toks = {}
        work = _requests(cfg, work=[(4, 6)] * 4)

        def sub(r):
            front.submit(r.prompt, r.max_new_tokens, uid=r.uid,
                         on_token=lambda u, t:
                         toks.setdefault(u, []).append(t))

        sub(work[0])
        front.tick()                 # admit 0 out of the queue
        sub(work[1])
        sub(work[2])                 # queue now at its bound of 2
        with pytest.raises(Backpressure):
            sub(work[3])
        assert front.backpressure
        while front.backpressure:    # progress drains the queue
            front.tick()
        sub(work[3])                 # accepted after drain
        handles = front.run()
        assert len(handles) == 4 and all(h.done for h in handles)
        assert all(len(toks[r.uid]) == r.max_new_tokens for r in work)
        _drained(fleet)

    def test_cancellation_everywhere(self, micro):
        """Cancel one queued, one live request; the rest stream on."""
        cfg, params = micro
        fleet = FleetEngine(cfg, params, max_slots=1, max_len=MAX_LEN,
                            replicas=1)
        front = FleetFrontend(fleet, max_pending=8)
        ended = []
        work = _requests(cfg, work=[(4, 8), (4, 8), (4, 8)])
        for r in work:
            front.submit(r.prompt, r.max_new_tokens, uid=r.uid,
                         on_finish=lambda h: ended.append(
                             (h.uid, h.cancelled)))
        front.tick()
        assert front.cancel(0)       # live (admitted) request
        assert front.cancel(2)       # still queued in the fleet
        assert not front.cancel(2)   # idempotent
        handles = front.run()
        assert [h.cancelled for h in handles] == [True, False, True]
        assert handles[1].done and len(handles[1].tokens) == 8
        assert set(ended) == {(0, True), (1, False), (2, True)}
        _drained(fleet)


class TestMigration:
    def test_stranded_preemption_migrates_without_token_drift(self, micro):
        """Overload replica 0 (externally placed work, as after a capacity
        loss): preemption strands a rollback behind a page-dry pool, the
        router moves it to the idle replica, and every token still
        matches the dense oracle."""
        cfg, params = micro
        work = [(2, 8)] * 3
        dense = ServeEngine(cfg, params, max_slots=3, max_len=16)
        for r in _requests(cfg, work=work, seed=1):
            dense.submit(r)
        want = {r.uid: r.generated for r in dense.run_to_completion()}

        fleet = FleetEngine(cfg, params, max_slots=3, max_len=16,
                            replicas=2, page_len=2, num_pages=[8, 12])
        for r in _requests(cfg, work=work, seed=1):
            fleet.replicas[0].engine.submit(r)
        out = {r.uid: r.generated for r in fleet.run_to_completion()}
        s = fleet.stats()
        assert s["migrations"] >= 1, "pool was sized to strand a rollback"
        assert s["preemptions"] >= 1
        assert any(d.kind == "migrate" for d in fleet.decisions)
        assert out == want
        _drained(fleet)
