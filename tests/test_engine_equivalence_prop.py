"""Hypothesis-widened engine differential (optional dependency).

Property: for ANY cache geometry the simulator can express — every
replacement policy, equal and unequal sets, every set-mapping family,
prefetch on or off — and any seeded index stream, the vectorized engine
produces bit-identical hit/miss/latency streams to the per-access
reference oracle.  The deterministic differentials in
``test_engine_equivalence.py`` cover the registered device structures;
this module explores the rest of the space.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.cachesim import (
    Cache, CacheGeometry, ReplacementPolicy, bitfield_map, range_cyclic_map,
    split_bitfield_map,
)
from repro.core.pchase import cache_backend, fine_grained
from tests.test_engine_equivalence import assert_engines_match


@st.composite
def geometries(draw):
    line = draw(st.sampled_from([16, 32, 64, 128]))
    kind = draw(st.sampled_from(["lru", "fifo", "random", "prob", "unequal",
                                 "bitfield", "split", "prefetch"]))
    sets = draw(st.sampled_from([1, 2, 4, 8]))
    ways = draw(st.sampled_from([1, 2, 3, 4, 8]))
    if kind == "prob":
        p = np.asarray(draw(st.lists(st.integers(1, 6), min_size=ways,
                                     max_size=ways)), dtype=np.float64)
        pol = ReplacementPolicy("prob", tuple(p / p.sum()))
        return CacheGeometry("h", line, (ways,) * sets, replacement=pol)
    if kind == "unequal":
        counts = tuple(draw(st.lists(st.integers(1, 9), min_size=sets,
                                     max_size=sets)))
        return CacheGeometry("h", line, counts,
                             set_map=range_cyclic_map(line, counts))
    if kind == "bitfield":
        lo = draw(st.integers(5, 9))
        return CacheGeometry("h", line, (ways,) * 4,
                             set_map=bitfield_map(lo, 2))
    if kind == "split":
        return CacheGeometry("h", line, (ways,) * 8,
                             set_map=split_bitfield_map([(7, 2), (10, 1)]))
    if kind == "prefetch":
        return CacheGeometry("h", line, (ways,) * sets,
                             prefetch_lines=draw(st.integers(1, 64)))
    return CacheGeometry("h", line, (ways,) * sets,
                         replacement=ReplacementPolicy(kind))


class TestPropertyEquivalence:
    @settings(max_examples=40, deadline=None)
    @given(geometries(), st.integers(0, 2 ** 31 - 1), st.integers(1, 400))
    def test_any_geometry_any_stream(self, geom, seed, chunk):
        rng = np.random.default_rng(seed)
        span = 8 * geom.size_bytes
        addrs = np.concatenate([
            rng.integers(0, span, size=600),
            (np.arange(600, dtype=np.int64) * geom.line_bytes) % span,
        ])
        mk = lambda: Cache(geom, np.random.default_rng(seed))
        assert_engines_match(mk, addrs, chunk=chunk)

    @settings(max_examples=15, deadline=None)
    @given(geometries(), st.integers(0, 2 ** 31 - 1))
    def test_backend_stream_with_tiling(self, geom, seed):
        """Multi-pass overflow chases: pins steady-state tiling to the
        oracle's hit/miss/latency streams for any deterministic policy (and
        the untiled sequential path for stochastic ones)."""
        mk = lambda: Cache(geom, np.random.default_rng(seed))
        c, b = geom.size_bytes, geom.line_bytes
        ref = fine_grained(cache_backend(mk, engine="reference"),
                           c + b, b, passes=10, warmup_passes=2)
        vec = fine_grained(cache_backend(mk, engine="vector"),
                           c + b, b, passes=10, warmup_passes=2)
        np.testing.assert_array_equal(ref.latencies, vec.latencies)
        np.testing.assert_array_equal(ref.meta["true_miss"],
                                      vec.meta["true_miss"])


class TestThreeWayProperty:
    """Widen the Cache/VectorCache/BatchCache differential to the full
    expressible geometry space (minus prefetch, which BatchCache rejects
    by contract)."""

    @settings(max_examples=25, deadline=None)
    @given(geometries(), st.integers(0, 2 ** 31 - 1))
    def test_batched_engine_matches_oracle(self, geom, seed):
        pytest.importorskip("jax")
        from repro.core.cachesim_jax import BatchCache
        from tests.test_engine_equivalence import _assert_policy_invariants

        if geom.prefetch_lines:
            with pytest.raises(ValueError):
                BatchCache([geom])
            return
        rng = np.random.default_rng(seed)
        span = 8 * geom.size_bytes
        addrs = np.concatenate([
            (np.arange(400, dtype=np.int64) * geom.line_bytes) % span,
            rng.integers(0, span, size=400),
        ])
        ref = Cache(geom, np.random.default_rng(seed))
        ref_hits = np.fromiter((ref.access(int(a)) for a in addrs),
                               dtype=bool, count=len(addrs))
        bat = BatchCache([geom], seed=seed).simulate(
            [addrs], force_scan=True)[0]
        if geom.replacement.kind in ("lru", "fifo"):
            np.testing.assert_array_equal(ref_hits, bat)
        else:
            # stochastic lanes: different RNG streams by design — hold the
            # batched lane to the exact policy-independent invariants
            _assert_policy_invariants(geom, addrs, bat, "hypothesis")
