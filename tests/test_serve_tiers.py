"""Disaggregated prefill/decode tiers: the oracle-chain and two-stage
router contracts.

* a single-tier plan (every replica in both tiers) reproduces the
  symmetric fleet token-for-token, tick-for-tick, with a bit-identical
  decision log — extending the oracle chain dense→paged→fleet→tiered;
* a heterogeneous 2-tier fleet streams byte-identical tokens vs the
  symmetric oracle (greedy outputs are schedule-independent);
* handoff-priced routing: prefill placements go to bandwidth-rich
  replicas, the margin audit covers BOTH stages, and handoff ticks land
  in TTFT instead of vanishing between tiers;
* the admission-pricing regression (satellite of this PR): admissions
  are priced with ``prefill_cell_cost``, so a bandwidth-rich replica
  wins a contested prefill-heavy admission that the old live-load
  ``decode_cell_cost`` pricing would have routed away from it.
"""

import jax
import numpy as np
import pytest

from repro.core.devices import TpuSpec
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.profile import published_profile
from repro.serve import tiers as tiering
from repro.serve.engine import Request
from repro.serve.fleet import FleetEngine
from repro.serve.frontend import FleetFrontend
from repro.serve.planner import plan_tiers
from repro.serve.tiers import TierPlan

WORK = [(8, 6), (12, 4), (5, 9), (16, 3), (7, 7), (3, 5)]
MAX_LEN = 32


@pytest.fixture(scope="module")
def micro():
    cfg = ModelConfig(name="micro", family="dense", num_layers=2,
                      d_model=32, d_ff=64, vocab_size=64, num_heads=2,
                      num_kv_heads=2, dtype="float32",
                      param_dtype="float32")
    return cfg, T.init_params(cfg, jax.random.key(0))


def _requests(cfg, work=WORK, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid, rng.integers(cfg.vocab_size, size=plen)
                    .astype(np.int32), n_new)
            for uid, (plen, n_new) in enumerate(work)]


def _run(fleet, cfg, work=WORK, seed=0):
    for r in _requests(cfg, work=work, seed=seed):
        fleet.submit(r)
    out = {r.uid: r.generated for r in fleet.run_to_completion()}
    fleet.check_invariants()
    assert fleet.stats()["pages_leaked"] == 0
    return out


class TestTierPlan:
    def test_parse_roundtrip(self):
        plan = tiering.parse_tiers("prefill:0,1/decode:2,3", 4)
        assert plan.prefill == (0, 1) and plan.decode == (2, 3)
        assert plan.tiered
        assert tiering.parse_tiers(plan.describe(), 4) == plan
        # either order, overlap allowed
        plan = tiering.parse_tiers("decode:0,1/prefill:1", 2)
        assert plan.prefill == (1,) and plan.decode == (0, 1)

    @pytest.mark.parametrize("bad", [
        "prefill:0",                     # missing decode
        "prefill:0/decode:",             # empty tier
        "prefill:0/decode:x",            # non-integer
        "prefill:0/prefill:1",           # duplicate tier
        "prefill:0/decode:5",            # out of range (n=2)
        "warmup:0/decode:1",             # unknown tier name
        "prefill:0/decode:0",            # replica 1 orphaned (n=2)
    ])
    def test_parse_rejects(self, bad):
        with pytest.raises(ValueError):
            tiering.parse_tiers(bad, 2)

    def test_symmetric_plan_is_not_tiered(self):
        assert not tiering.symmetric(3).tiered
        assert not TierPlan(prefill=(0, 1), decode=(1, 0)).tiered

    def test_auto_ranks_bandwidth_to_prefill_latency_to_decode(self):
        fat = TpuSpec(name="fat", hbm_bytes_per_s=2e12,
                      hbm_latency_s=2e-6)           # bandwidth-rich
        quick = TpuSpec(name="quick", hbm_bytes_per_s=4e11,
                        hbm_latency_s=2e-7)         # latency-lean
        plan = tiering.auto_tiers([quick, fat])
        assert plan.prefill == (1,) and plan.decode == (0,)
        # deterministic under permutation of the same specs
        plan = tiering.auto_tiers([fat, quick])
        assert plan.prefill == (0,) and plan.decode == (1,)
        # a 1-replica fleet has nothing to specialize
        assert not tiering.auto_tiers([fat]).tiered

    def test_resolve_front_door(self):
        specs = [TpuSpec(name="a"), TpuSpec(name="b")]
        assert not tiering.resolve_tiers(None, 2, specs).tiered
        assert not tiering.resolve_tiers("none", 2, specs).tiered
        assert not tiering.resolve_tiers("symmetric", 2, specs).tiered
        got = tiering.resolve_tiers("prefill:0/decode:1", 2, specs)
        assert got.tiered
        assert tiering.resolve_tiers(got, 2, specs) == got
        with pytest.raises(TypeError):
            tiering.resolve_tiers(3.14, 2, specs)

    def test_handoff_pricing_monotone_and_never_free(self):
        fast = TpuSpec(name="f", hbm_bytes_per_s=1e12, hbm_latency_s=1e-7)
        slow = TpuSpec(name="s", hbm_bytes_per_s=1e11, hbm_latency_s=1e-6)
        # the slower endpoint gates the wire, either direction
        t = tiering.handoff_seconds(1 << 20, fast, slow)
        assert t == tiering.handoff_seconds(1 << 20, slow, fast)
        assert t > tiering.handoff_seconds(1 << 20, fast, fast)
        # whole pages move: bytes scale with the page count
        cfg = ModelConfig(name="m", family="dense", num_layers=2,
                          d_model=32, d_ff=64, vocab_size=64, num_heads=2,
                          num_kv_heads=2)
        assert (tiering.handoff_bytes(cfg, 4, 8)
                == 2 * tiering.handoff_bytes(cfg, 2, 8))
        # quantization never rounds to zero ticks
        assert tiering.handoff_ticks(1e-12, 1.0) == 1
        assert tiering.handoff_ticks(2.5, 1.0) == 3


class TestOracleChain:
    def test_single_tier_equals_symmetric_bit_for_bit(self, micro):
        """Every replica in both tiers ⇒ the two-stage router must
        degenerate to the symmetric fleet exactly: same tokens, same
        tick schedule, same decision log."""
        cfg, params = micro
        sym = FleetEngine(cfg, params, max_slots=2, max_len=MAX_LEN,
                          replicas=2, page_len=4)
        want = _run(sym, cfg)
        single = FleetEngine(cfg, params, max_slots=2, max_len=MAX_LEN,
                             replicas=2, page_len=4,
                             tiers="prefill:0,1/decode:0,1")
        assert not single.tiered
        got = _run(single, cfg)
        assert got == want
        assert single.ticks == sym.ticks
        assert single.decision_log() == sym.decision_log()
        assert single.stats()["handoffs"] == 0

    def test_two_tier_tokens_match_symmetric_oracle(self, micro):
        cfg, params = micro
        sym = FleetEngine(cfg, params, max_slots=3, max_len=MAX_LEN,
                          replicas=2, page_len=4)
        want = _run(sym, cfg)
        tiered = FleetEngine(cfg, params, max_slots=3, max_len=MAX_LEN,
                             replicas=2, page_len=4,
                             tiers="prefill:0/decode:1")
        got = _run(tiered, cfg)
        assert got == want
        s = tiered.stats()
        assert s["handoffs"] >= len(WORK) - s["handoff_aborts"]
        # the prefill specialist never decoded a single token
        assert tiered.replicas[0].engine.stats()["decoded_tokens"] == 0
        assert {d.kind for d in tiered.decisions} >= {"admit", "handoff"}

    def test_hetero_two_tier_streams_match_oracle(self, micro):
        """TeslaV100 prefilling for tpu_v5e: the streamed (frontend)
        bytes must equal the symmetric hetero oracle's, request for
        request."""
        cfg, params = micro

        def mk(tiers):
            profs = [published_profile(d)
                     for d in ("TeslaV100", "tpu_v5e")]
            return FleetEngine(cfg, params, max_slots=2, max_len=MAX_LEN,
                               profiles=profs, page_len=4, tiers=tiers)

        want = _run(mk(None), cfg)
        fleet = mk("prefill:0/decode:1")
        front = FleetFrontend(fleet, max_pending=len(WORK))
        streamed: dict[int, list[int]] = {}
        for r in _requests(cfg):
            front.submit(r.prompt, r.max_new_tokens, uid=r.uid,
                         on_token=lambda u, t:
                         streamed.setdefault(u, []).append(t))
        front.run()
        fleet.check_invariants()
        assert streamed == want
        assert fleet.stats()["pages_leaked"] == 0
        assert not fleet.margin_violations()

    def test_two_stage_replay_bit_identical(self, micro):
        cfg, params = micro

        def run():
            fleet = FleetEngine(cfg, params, max_slots=2, max_len=MAX_LEN,
                                replicas=3, page_len=4,
                                tiers="prefill:0,1/decode:2")
            _run(fleet, cfg)
            return fleet

        a, b = run(), run()
        assert a.decision_log() == b.decision_log()
        assert any(d.kind == "handoff" for d in a.decisions)
        sa, sb = a.stats(), b.stats()
        for k in ("ticks", "decisions", "handoffs", "handoff_aborts",
                  "decoded_tokens"):
            assert sa[k] == sb[k], k


class TestHandoffRouting:
    def test_handoff_ticks_land_in_ttft(self, micro):
        """The tiered fleet's TTFT must exceed the symmetric fleet's by
        at least the (nonzero) handoff quantization — latency cannot
        vanish between tiers."""
        cfg, params = micro
        work = [(8, 4)]

        def ttft(tiers):
            fleet = FleetEngine(cfg, params, max_slots=2, max_len=MAX_LEN,
                                replicas=2, page_len=4, tiers=tiers)
            front = FleetFrontend(fleet)
            for r in _requests(cfg, work=work):
                front.submit(r.prompt, r.max_new_tokens, uid=r.uid)
            front.run()
            fleet.check_invariants()
            [t] = front.slo.ttfts()
            return t, fleet

        base, _ = ttft(None)
        tiered, fleet = ttft("prefill:0/decode:1")
        assert fleet.stats()["handoffs"] == 1
        assert tiered >= base + 1, \
            "handoff ticks must show up in TTFT"

    def test_prefill_placement_prefers_bandwidth(self, micro):
        """Stage-1 routing is prefill-priced: the bandwidth-rich
        prefill replica takes the admissions; stage-2 margin holds."""
        cfg, params = micro
        # huge peak FLOPs make the prefill price memory-bound, so the
        # 20x bandwidth gap is the whole story
        fat = TpuSpec(name="fat", hbm_bytes_per_s=8e11,
                      peak_bf16_flops=1e18)
        thin = TpuSpec(name="thin", hbm_bytes_per_s=8e11 / 20,
                       peak_bf16_flops=1e18)
        dec = TpuSpec(name="dec")
        fleet = FleetEngine(cfg, params, max_slots=2, max_len=MAX_LEN,
                            profiles=[thin, fat, dec], page_len=4,
                            tiers="prefill:0,1/decode:2")
        _run(fleet, cfg)
        admits = [d for d in fleet.decisions if d.kind == "admit"]
        # whenever BOTH prefill replicas can accept, bandwidth wins;
        # single-candidate decisions are overflow, not preference
        contested = [d for d in admits if len(d.scores) > 1]
        assert contested and all(d.chosen == 1 for d in contested), \
            "bandwidth-rich prefill replica must win contested admissions"
        assert not fleet.margin_violations(), \
            "margin audit covers both routing stages"

    def test_handoff_prices_the_transfer(self, micro):
        """Stage-2 scores carry the KV-transfer term: every handoff
        decision's chosen score includes a positive handoff_s computed
        from min-endpoint bandwidth."""
        cfg, params = micro
        fleet = FleetEngine(cfg, params, max_slots=2, max_len=MAX_LEN,
                            replicas=2, page_len=4,
                            tiers="prefill:0/decode:1")
        _run(fleet, cfg)
        handoffs = [d for d in fleet.decisions if d.kind == "handoff"]
        assert handoffs
        for d in handoffs:
            by_rep = {s.replica: s for s in d.scores}
            chosen = by_rep[d.chosen]
            assert chosen.handoff_s > 0
            assert chosen.step_cost_s > chosen.handoff_s


class TestAdmissionPricingRegression:
    def test_bandwidth_rich_wins_contested_prefill_heavy_admission(
            self, micro):
        """Admissions are priced with ``prefill_cell_cost`` (this PR's
        fix): a bandwidth-rich-but-busy replica wins a prefill-heavy
        admission that the old live-load ``decode_cell_cost`` pricing —
        still used for stage-2 handoffs and recomputed here — would
        have priced OUT of the margin."""
        cfg, params = micro
        # memory-bound pricing (huge peak FLOPs): the 1.5x bandwidth
        # edge decides prefill, while live load decides decode
        fast = TpuSpec(name="fast", hbm_bytes_per_s=8e11,
                       peak_bf16_flops=1e18)
        slow = TpuSpec(name="slow", hbm_bytes_per_s=8e11 / 1.5,
                       peak_bf16_flops=1e18)
        fleet = FleetEngine(cfg, params, max_slots=8, max_len=64,
                            profiles=[fast, slow], page_len=4)
        # contested: the fast replica is already busy (externally placed
        # work, as after a failover) with long decode commitments that
        # swell its live-load decode price; the slow one is idle
        for r in _requests(cfg, work=[(4, 44)] * 7, seed=1):
            r.uid += 100
            fleet.replicas[0].engine.submit(r)
        req = _requests(cfg, work=[(16, 2)], seed=2)[0]
        req.uid = 99
        fleet.submit(req)

        # old pricing, recomputed: decode_cell_cost at live load (the
        # formula the "handoff" stage still uses)
        old = {r.index: r.score(req, kind="handoff").step_cost_s
               for r in fleet.replicas}
        assert old[0] > old[1] * (1 + fleet.margin), \
            "under decode pricing the busy fast replica is out of margin"

        fleet.step()
        d = fleet.decisions[0]
        assert d.kind == "admit" and d.chosen == 0, \
            "prefill pricing must route the prompt to the fast replica"
        new = {s.replica: s.step_cost_s for s in d.scores}
        assert new[1] > new[0] * (1 + fleet.margin)
        fleet.run_to_completion()
        fleet.check_invariants()


class TestTieredPlanner:
    def test_plan_tiers_answers_per_tier(self, micro):
        cfg, _ = micro
        tp = plan_tiers(cfg, ["GTX980", "TeslaV100", "tpu_v5e"],
                        arrival_per_tick=0.2, mean_prompt=12,
                        mean_new=8, max_slots=4, max_len=64)
        assert tp.prefill.tier == "prefill"
        assert tp.decode.tier == "decode"
        assert tp.prefill.replicas >= 1 and tp.decode.replicas >= 1
        assert tp.handoff_ticks >= 1
        assert tp.predicted_ttft_ticks > tp.handoff_ticks
        assert len(tp.ranked_prefill) == 3 == len(tp.ranked_decode)
        # ranked best-first: the winner leads its list
        assert tp.ranked_prefill[0] == tp.prefill
        assert tp.ranked_decode[0] == tp.decode
        assert any("handoff" in ln for ln in tp.lines())

    def test_plan_tiers_deterministic(self, micro):
        cfg, _ = micro
        kw = dict(arrival_per_tick=0.4, mean_prompt=10, mean_new=6,
                  max_slots=3, max_len=48)
        a = plan_tiers(cfg, ["TeslaV100", "tpu_v5e"], **kw)
        b = plan_tiers(cfg, ["TeslaV100", "tpu_v5e"], **kw)
        assert a == b
