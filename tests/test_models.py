"""Per-architecture smoke tests (reduced configs, one forward/train step on
CPU, shape + finiteness asserts) + decode-vs-forward consistency + SSD math
vs the naive recurrence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import make_batch_specs
from repro.models import ssm as S
from repro.models import transformer as T
from repro.optim import AdamWConfig
from repro.train.loop import init_state, make_train_step

ARCHS = configs.list_archs()


def smoke_batch(cfg, batch=2, seq=32):
    return {k: jnp.asarray(v) for k, v in
            make_batch_specs(cfg, batch, seq).items()}


class TestSmokeForward:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_forward_shapes_and_finite(self, arch):
        cfg = configs.get_smoke_config(arch)
        params = T.init_params(cfg, jax.random.key(0))
        batch = smoke_batch(cfg)
        logits, aux = T.forward(params, cfg, batch)
        assert logits.shape == (2, 32, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN/inf logits"
        assert bool(jnp.isfinite(aux))

    @pytest.mark.parametrize("arch", ARCHS)
    def test_one_train_step(self, arch):
        cfg = configs.get_smoke_config(arch)
        opt = AdamWConfig(lr=1e-3)
        state = init_state(cfg, opt, jax.random.key(0))
        step = jax.jit(make_train_step(cfg, opt))
        batch = smoke_batch(cfg)
        state, metrics = step(state, batch)
        assert bool(jnp.isfinite(metrics["loss"]))
        assert int(state.step) == 1
        # params actually moved
        moved = any(
            not np.allclose(np.asarray(a, np.float32),
                            np.asarray(b, np.float32))
            for a, b in zip(jax.tree.leaves(state.params),
                            jax.tree.leaves(
                                T.init_params(cfg, jax.random.key(0)))))
        assert moved, f"{arch}: optimizer did not update params"


class TestDecodeConsistency:
    """prefill(S) + decode(1) must equal forward(S+1) at the last position —
    for every decoder family (GQA, MLA, MoE, SSM, hybrid, VLM)."""

    DECODER_ARCHS = [a for a in ARCHS
                     if configs.get_config(a).has_decoder]

    @pytest.mark.parametrize("arch", DECODER_ARCHS)
    def test_decode_matches_forward(self, arch):
        cfg = configs.get_smoke_config(arch)
        if cfg.is_moe:   # drop-free capacity: token dropping is batch-global
            cfg = dataclasses.replace(cfg,
                                      capacity_factor=float(cfg.num_experts))
        params = T.init_params(cfg, jax.random.key(1))
        B, S = 2, 24
        if cfg.frontend == "vision":
            P = cfg.num_patches
            pat = jax.random.normal(jax.random.key(5),
                                    (B, P, cfg.frontend_dim))
            toks = jax.random.randint(jax.random.key(2), (B, S + 1 - P), 0,
                                      cfg.vocab_size)
            full, _ = T.forward(params, cfg, {"patches": pat, "tokens": toks})
            _, cache = T.prefill(params, cfg,
                                 {"patches": pat, "tokens": toks[:, :-1]},
                                 max_len=S + 8)
            dec, _ = T.decode(params, cfg, cache, toks[:, -1:], jnp.int32(S))
        else:
            toks = jax.random.randint(jax.random.key(2), (B, S + 1), 0,
                                      cfg.vocab_size)
            full, _ = T.forward(params, cfg, {"tokens": toks})
            _, cache = T.prefill(params, cfg, {"tokens": toks[:, :S]},
                                 max_len=S + 8)
            dec, _ = T.decode(params, cfg, cache, toks[:, S:S + 1],
                              jnp.int32(S))
        a = np.asarray(full[:, S], np.float32)
        b = np.asarray(dec[:, 0], np.float32)
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-4)

    def test_multi_token_decode(self):
        """Greedy-decode 8 tokens; each step must match teacher forcing."""
        cfg = configs.get_smoke_config("granite-8b")
        params = T.init_params(cfg, jax.random.key(1))
        B, S, N = 1, 16, 8
        toks = jax.random.randint(jax.random.key(2), (B, S + N), 0,
                                  cfg.vocab_size)
        full, _ = T.forward(params, cfg, {"tokens": toks})
        _, cache = T.prefill(params, cfg, {"tokens": toks[:, :S]},
                             max_len=S + N)
        for t in range(N):
            dec, cache = T.decode(params, cfg, cache, toks[:, S + t:S + t + 1],
                                  jnp.int32(S + t))
            np.testing.assert_allclose(np.asarray(full[:, S + t], np.float32),
                                       np.asarray(dec[:, 0], np.float32),
                                       atol=2e-4, rtol=2e-4)

    def test_absorbed_mla_decode_exact(self):
        """The absorbed-matmul MLA decode (§Perf optimization) is EXACT —
        same math, reordered against the compressed cache."""
        cfg = configs.get_smoke_config("deepseek-v2-lite-16b")
        cfg = dataclasses.replace(cfg,
                                  capacity_factor=float(cfg.num_experts))
        params = T.init_params(cfg, jax.random.key(1))
        B, S = 2, 24
        toks = jax.random.randint(jax.random.key(2), (B, S + 1), 0,
                                  cfg.vocab_size)
        _, cache = T.prefill(params, cfg, {"tokens": toks[:, :S]},
                             max_len=S + 8)
        naive, _ = T.decode(params, cfg, cache, toks[:, S:S + 1],
                            jnp.int32(S))
        cfg_abs = dataclasses.replace(cfg, mla_absorbed=True)
        absorbed, _ = T.decode(params, cfg_abs, cache, toks[:, S:S + 1],
                               jnp.int32(S))
        np.testing.assert_allclose(np.asarray(naive), np.asarray(absorbed),
                                   atol=1e-4, rtol=1e-4)

    def test_int8_kv_cache_decode_accuracy(self):
        """int8-quantized KV cache (§Perf): decode logits within ~1% of the
        exact forward."""
        cfg = configs.get_smoke_config("granite-8b")
        cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
        params = T.init_params(cfg, jax.random.key(1))
        B, S = 2, 16
        toks = jax.random.randint(jax.random.key(3), (B, S + 1), 0,
                                  cfg.vocab_size)
        full, _ = T.forward(params, cfg, {"tokens": toks})
        cache = T.init_cache(cfg8, B, S + 4)
        for t in range(S + 1):
            logits, cache = T.decode(params, cfg8, cache, toks[:, t:t + 1],
                                     jnp.int32(t))
        a = np.asarray(full[:, S], np.float32)
        b = np.asarray(logits[:, 0], np.float32)
        rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
        assert rel < 0.03, f"int8 cache degraded logits by {rel:.3f}"

    def test_encoder_has_no_decode_shapes(self):
        cfg = configs.get_config("hubert-xlarge")
        supported = [s.name for s in configs.supported_cells(cfg)]
        assert "decode_32k" not in supported
        assert "long_500k" not in supported


class TestSSDMath:
    def naive(self, x, dt, a_log, b, c, d_skip):
        bs, s, h, p = x.shape
        g, n = b.shape[2], b.shape[3]
        rep = h // g
        a = -np.exp(np.asarray(a_log, np.float64))
        hstate = np.zeros((bs, h, n, p))
        y = np.zeros((bs, s, h, p))
        xb = np.asarray(x, np.float64) * np.asarray(dt)[..., None]
        bfull = np.repeat(np.asarray(b, np.float64), rep, axis=2)
        cfull = np.repeat(np.asarray(c, np.float64), rep, axis=2)
        for t in range(s):
            decay = np.exp(np.asarray(dt, np.float64)[:, t] * a)  # (B,H)
            hstate = (hstate * decay[..., None, None] +
                      np.einsum("bhn,bhp->bhnp", bfull[:, t], xb[:, t]))
            y[:, t] = (np.einsum("bhn,bhnp->bhp", cfull[:, t], hstate) +
                       np.asarray(d_skip)[None, :, None] *
                       np.asarray(x, np.float64)[:, t])
        return y, hstate

    @pytest.mark.parametrize("s,chunk", [(32, 8), (48, 16), (30, 8)])
    @pytest.mark.parametrize("g", [1, 2])
    def test_chunked_equals_recurrence(self, s, chunk, g):
        bs, h, p, n = 2, 4, 8, 16
        k = jax.random.key(0)
        ks = jax.random.split(k, 5)
        x = jax.random.normal(ks[0], (bs, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (bs, s, h)))
        a_log = jax.random.normal(ks[2], (h,)) * 0.5
        b = jax.random.normal(ks[3], (bs, s, g, n)) * 0.3
        c = jax.random.normal(ks[4], (bs, s, g, n)) * 0.3
        d_skip = jnp.ones((h,))
        y, hlast = S.ssd_chunked(x, dt, a_log, b, c, d_skip, chunk)
        y_ref, h_ref = self.naive(x, dt, a_log, b, c, d_skip)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   y_ref[:, :s].astype(np.float32),
                                   atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(hlast, np.float32),
                                   h_ref.astype(np.float32),
                                   atol=1e-3, rtol=1e-3)


class TestAccounting:
    def test_param_counts_match_scale_class(self):
        """Full configs must land near their nameplate parameter counts."""
        expected = {
            "deepseek-v2-lite-16b": (14e9, 18e9),
            "phi3.5-moe-42b-a6.6b": (39e9, 45e9),
            "mamba2-1.3b": (1.1e9, 1.5e9),
            "mistral-large-123b": (115e9, 130e9),
            "minitron-8b": (7.5e9, 10e9),
            "granite-8b": (7.5e9, 9e9),
            "deepseek-coder-33b": (31e9, 35e9),
            "hubert-xlarge": (0.9e9, 1.3e9),
            "internvl2-2b": (1.6e9, 2.4e9),
            "jamba-1.5-large-398b": (350e9, 420e9),
        }
        for arch, (lo, hi) in expected.items():
            n = configs.get_config(arch).param_count()
            assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}," \
                                  f"{hi/1e9}]B"

    def test_active_params_moe(self):
        cfg = configs.get_config("phi3.5-moe-42b-a6.6b")
        act = cfg.active_param_count()
        assert 5e9 <= act <= 8.5e9, f"active {act/1e9:.2f}B"
        assert act < cfg.param_count() / 4
