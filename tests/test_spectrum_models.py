"""Latency spectrum (Fig 14), Little's-law throughput (Fig 12/15/16,
Tables 6/7), bank conflicts (Table 8, Figs 17-19), classic-method
contradiction (Fig 4/5)."""

import numpy as np
import pytest

from repro.core import bankconflict, classic, devices, littles_law, spectrum
from repro.core.littles_law import OccupancyPoint
from repro.core.pchase import cache_backend, saavedra1992, wong2010


def spect(dev, l1=True):
    return spectrum.measure_spectrum(
        lambda: devices.make_hierarchy(dev, l1_enabled=l1))


class TestLatencySpectrum:
    def test_pattern_ordering_all_devices(self):
        for dev in ("GTX560Ti", "GTX780", "GTX980"):
            sp = spect(dev, l1=False)
            assert sp["P1"] < sp["P2"] < sp["P3"], dev
            assert sp["P4"] < sp["P5"], dev

    def test_fermi_l1_tlb_penalties(self):
        """§5.2-3: L1 TLB miss penalty is 288 cycles when data is in L1,
        27-class when in L2 — the paper's exact numbers."""
        on = spect("GTX560Ti", l1=True)
        assert on["P2"] - on["P1"] == pytest.approx(288, abs=1)

    def test_maxwell_l1_bypasses_tlb(self):
        """§5.2-2: with the unified L1 on, P2/P3 collapse onto P1."""
        on = spect("GTX980", l1=True)
        assert on["P1"] == on["P2"] == on["P3"]
        off = spect("GTX980", l1=False)
        assert off["P2"] > off["P1"] and off["P3"] > off["P2"]

    def test_p6_only_on_kepler_maxwell(self):
        assert "P6" not in spect("GTX560Ti")
        for dev in ("GTX780", "GTX980"):
            sp = spect(dev)
            assert sp["P6"] == max(sp.values()), dev

    def test_maxwell_cold_miss_regression(self):
        """§5.2-4: Maxwell P5 ≈ 2x Fermi's and > Kepler's; Kepler has the
        shortest P2-P5 class latencies of the three."""
        f, k, m = spect("GTX560Ti"), spect("GTX780"), spect("GTX980")
        assert m["P5"] > 1.8 * k["P5"]
        assert m["P5"] > 1.05 * f["P5"]
        for p in ("P2", "P3", "P4", "P5"):
            assert k[p] < f[p]


class TestLittlesLaw:
    def test_required_warps_gtx780(self):
        """The paper's own napkin number: ~94 warps required at ILP=1,
        vs 64 allowed — why Kepler shared throughput sits at 37.5%."""
        spec = devices.GTX780
        required = (spec.shared_banks * spec.bank_bytes *
                    spec.shared_base_latency) / (32 * 4)
        assert round(required) == 94
        assert spec.max_warps_per_sm == 64

    def test_ilp_preference_by_generation(self):
        """Fig 16: ILP=1 best on Kepler; ILP=4 best on Fermi/Maxwell."""
        for dev, best_ilp in (("GTX560Ti", 4), ("GTX780", 1), ("GTX980", 4)):
            spec = devices.GPU_SPECS[dev]
            pt, _ = littles_law.best_occupancy(spec, "shared")
            assert pt.ilp == best_ilp, dev

    def test_saturation_monotone_in_warps(self):
        spec = devices.GTX980
        vals = [littles_law.global_throughput_gbps(
            spec, OccupancyPoint(n, 256, 2)) for n in (1, 4, 16, 64, 256)]
        assert all(b >= a for a, b in zip(vals, vals[1:]))
        assert vals[-1] == spec.measured_peak_gbps

    def test_theoretical_bandwidth_table6(self):
        np.testing.assert_allclose(devices.GTX560TI.theoretical_gbps, 134.4,
                                   rtol=1e-3)
        np.testing.assert_allclose(devices.GTX780.theoretical_gbps, 288.38,
                                   rtol=1e-3)
        np.testing.assert_allclose(devices.GTX980.theoretical_gbps, 224.38,
                                   rtol=1e-3)

    def test_tpu_inflight_sizing(self):
        need = littles_law.tpu_required_inflight_bytes(devices.TPU_V5E)
        assert need == int(819e9 * 1e-6)
        blk = littles_law.tpu_min_block_bytes(devices.TPU_V5E)
        assert blk % (8 * 128 * 4) == 0 and blk >= need


class TestBankConflicts:
    def test_fermi_gcd_rule(self):
        """§6.2: potential conflicts = gcd(stride, 32); odd strides free."""
        for s in range(1, 33):
            ways = bankconflict.conflict_ways(s, "fermi")
            assert ways == np.gcd(s, 32)

    def test_kepler_modes_fig19(self):
        # stride 2: no conflict in either mode (vs 2-way on Fermi)
        assert bankconflict.conflict_ways(2, "kepler", 4) == 1
        assert bankconflict.conflict_ways(2, "kepler", 8) == 1
        assert bankconflict.conflict_ways(2, "fermi") == 2
        # stride 4: 2-way in both modes
        assert bankconflict.conflict_ways(4, "kepler", 4) == 2
        assert bankconflict.conflict_ways(4, "kepler", 8) == 2
        # stride 6: 2-way in 4B mode, conflict-free in 8B mode (Fig 18)
        assert bankconflict.conflict_ways(6, "kepler", 4) == 2
        assert bankconflict.conflict_ways(6, "kepler", 8) == 1

    def test_power_of_two_strides_equal_modes(self):
        """8B mode beats 4B mode only for non-power-of-two even strides."""
        for s in (4, 8, 16, 32):
            assert (bankconflict.conflict_ways(s, "kepler", 4) ==
                    bankconflict.conflict_ways(s, "kepler", 8))

    def test_latency_linear_and_maxwell_flat(self):
        """Table 8: latency ~ linear in ways; Maxwell's slope is tiny — the
        paper's headline Maxwell result."""
        base_f, slope_f = bankconflict.linear_fit("GTX560Ti")
        base_m, slope_m = bankconflict.linear_fit("GTX980")
        assert slope_f > 30
        assert slope_m < 3
        # 32-way conflict on Maxwell is cheaper than its own global-memory
        # cache-hit latency (82 cycles)
        assert bankconflict.latency_for_ways("GTX980", 32) < 100
        # ... while on Fermi it exceeds global memory latency by far
        assert bankconflict.latency_for_ways("GTX560Ti", 32) > 1000

    def test_tpu_degree(self):
        assert bankconflict.tpu_conflict_degree(1) == 1
        assert bankconflict.tpu_conflict_degree(128) == 128
        d64 = bankconflict.tpu_conflict_degree(64)
        assert 1 < d64 <= 64


class TestClassicContradiction:
    """Fig 4 vs Fig 5: the two classic methods disagree on the SAME cache;
    the fine-grained method resolves it (paper §4.1)."""

    def test_methods_contradict_on_texture_l1(self):
        be = cache_backend(devices.kepler_texture_l1)
        sv_curve = saavedra1992(be, 48 << 10,
                                [2 ** p for p in range(5, 12)])
        sv = classic.interpret_saavedra(sv_curve, 48 << 10, 12 << 10)
        sizes = list(range(12 << 10, (12 << 10) + 640, 32))
        wg_curve = wong2010(be, sizes, 32)
        wg = classic.interpret_wong(wg_curve, 12 << 10)
        # Wong2010 reads exactly the paper's Fig-5 numbers: b=128, T=4, a=24
        assert wg.line_bytes == 128
        assert wg.num_sets == 4
        assert wg.assoc == pytest.approx(24)
        # Saavedra1992 reads the ramp knee correctly (b=32) but a different
        # structure — the two methods CONTRADICT on the same cache (Fig 4/5)
        assert sv.line_bytes == 32
        assert sv.num_sets != wg.num_sets
        # and each disagrees with the fine-grained ground truth
        # (b=32, T=4, a=96 — TestTable5) in at least one parameter:
        assert (sv.num_sets, sv.assoc) != (4, 96)
        assert wg.line_bytes != 32
