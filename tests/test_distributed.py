"""Distribution tests: sharding rules, tiny-mesh dry-run integration, and
elastic checkpoint resharding.  Multi-device cases run in subprocesses so
the main test process keeps its single-device view."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=600)


class TestShardingRules:
    def test_spec_resolution_and_divisibility(self):
        code = """
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_production_mesh
        from repro.parallel.sharding import ShardingCtx
        mesh = make_production_mesh(shape=(2, 4), axes=("data", "model"))
        ctx = ShardingCtx(mesh)
        # divisible: heads sharded over model
        assert ctx.spec(("batch", "seq", "heads"), (8, 128, 8)) == \
            P("data", None, "model"), ctx.spec(("batch","seq","heads"), (8,128,8))
        # indivisible head count falls back to replication
        s = ctx.spec(("batch", "seq", "kv_heads"), (8, 128, 3))
        assert s == P("data", None, None), s
        # absent mesh axis ("pod") is dropped
        s = ctx.spec(("batch",), (8,))
        assert s == P("data"), s
        print("OK")
        """
        r = run_py(code)
        assert "OK" in r.stdout, r.stdout + r.stderr

    def test_fsdp_shards_largest_free_dim(self):
        code = """
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_production_mesh
        from repro.parallel import sharding as sh
        mesh = make_production_mesh(shape=(4, 2), axes=("data", "model"))
        ctx = sh.ShardingCtx(mesh)
        w = jax.ShapeDtypeStruct((64, 128), jax.numpy.float32)
        shd = sh.param_shardings(("embed", "mlp"), w, ctx)
        # mlp -> model; embed free -> fsdp over data
        assert shd.spec == P("data", "model"), shd.spec
        print("OK")
        """
        r = run_py(code)
        assert "OK" in r.stdout, r.stdout + r.stderr


class TestTinyMeshDryrun:
    """The full dry-run path (lower+compile+roofline) on a 2×2 mesh with
    reduced configs — one cell per step-kind and per family."""

    @pytest.mark.parametrize("arch,shape", [
        ("granite-8b", "train_4k"),
        ("phi3.5-moe-42b-a6.6b", "train_4k"),
        ("mamba2-1.3b", "long_500k"),
        ("deepseek-v2-lite-16b", "decode_32k"),
        ("jamba-1.5-large-398b", "prefill_32k"),
        ("hubert-xlarge", "train_4k"),
    ])
    def test_cell_compiles(self, arch, shape, tmp_path):
        code = f"""
        import json
        from repro.launch import dryrun
        from repro import configs
        small = {{k: v for k, v in vars(configs.get_smoke_config({arch!r})).items()
                 if k in ('num_layers','d_model','d_ff','vocab_size','num_heads',
                          'num_kv_heads','head_dim','num_experts','top_k',
                          'd_ff_expert','kv_lora_rank','qk_nope_dim','qk_rope_dim',
                          'v_head_dim','ssm_state','ssm_head_dim','ssm_chunk',
                          'frontend_dim','num_patches','num_shared_experts')}}
        rec = dryrun.run_cell({arch!r}, {shape!r}, "tiny", {str(tmp_path)!r},
                              cfg_overrides=small)
        assert rec["roofline"]["step_s"] > 0
        assert rec["memory"]["fits_16gb"]
        print("OK", rec["roofline"]["dominant"])
        """
        r = run_py(code)
        assert "OK" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]

    def test_multi_pod_axis_shards(self, tmp_path):
        """The 3-axis (pod, data, model) mesh must compile — proves the pod
        axis participates in the sharding."""
        code = f"""
        from repro.launch import dryrun
        rec = dryrun.run_cell("granite-8b", "train_4k", "tiny_multi",
                              {str(tmp_path)!r},
                              cfg_overrides=dict(num_layers=2, d_model=64,
                                                 d_ff=128, vocab_size=256,
                                                 num_heads=4, num_kv_heads=2,
                                                 head_dim=16))
        assert rec["chips"] == 8
        print("OK")
        """
        r = run_py(code)
        assert "OK" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]


class TestElasticReshard:
    def test_checkpoint_restores_across_mesh_sizes(self, tmp_path):
        """Save on a 4×2 mesh, restore onto 2×2 — the elasticity path."""
        code = f"""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro import configs
        from repro.launch.mesh import make_production_mesh
        from repro.models import transformer as T
        from repro.parallel import sharding as sh
        from repro.train import checkpoint as ckpt

        cfg = configs.get_smoke_config("granite-8b")
        params = T.init_params(cfg, jax.random.key(0))
        axes = T.param_logical_axes(params)

        mesh_a = make_production_mesh(shape=(4, 2), axes=("data", "model"))
        ctx_a = sh.ShardingCtx(mesh_a)
        shard_a = jax.tree.map(lambda l, a: sh.param_shardings(a, l, ctx_a),
                               params, axes,
                               is_leaf=lambda x: hasattr(x, "shape"))
        pa = jax.tree.map(jax.device_put, params, shard_a)
        ckpt.save({str(tmp_path)!r}, 1, pa)

        mesh_b = make_production_mesh(shape=(2, 2), axes=("data", "model"))
        ctx_b = sh.ShardingCtx(mesh_b)
        shard_b = jax.tree.map(lambda l, a: sh.param_shardings(a, l, ctx_b),
                               params, axes,
                               is_leaf=lambda x: hasattr(x, "shape"))
        pb, step = ckpt.restore({str(tmp_path)!r}, params, shardings=shard_b)
        assert step == 1
        for x, y in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
            np.testing.assert_array_equal(np.asarray(x, np.float32),
                                          np.asarray(y, np.float32))
        # restored leaves really live on the new mesh
        leaf = jax.tree.leaves(pb)[0]
        assert leaf.sharding.mesh.shape == mesh_b.shape
        print("OK")
        """
        r = run_py(code)
        assert "OK" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]


class TestCollectiveParsing:
    def test_roofline_sees_collectives_on_tiny_mesh(self):
        code = """
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import roofline
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(shape=(2, 4), axes=("data", "model"))
        x = jax.ShapeDtypeStruct((8, 512), jnp.float32,
                                 sharding=NamedSharding(mesh, P("data", "model")))
        w = jax.ShapeDtypeStruct((512, 512), jnp.float32,
                                 sharding=NamedSharding(mesh, P("model", None)))
        comp = jax.jit(lambda a, b: a @ b).lower(x, w).compile()
        coll = roofline.collective_bytes(comp.as_text())
        assert coll, "contracting a model-sharded dim must emit a collective"
        print("OK", coll)
        """
        r = run_py(code)
        assert "OK" in r.stdout, r.stdout + r.stderr
