"""Profile layer: P6 spectrum coverage, held-out Volta recovery, artifact
round-trip driving identical consumer decisions, the default-spec trap,
and repro.profile/v1 validation."""

import dataclasses
import json
import warnings

import pytest

from repro import profile as P
from repro.core import autotune, devices, inference, littles_law
from repro.core import profile as core_profile
from repro.core import spectrum
from repro.core.devices import TPU_V5E, TpuSpec

MB = 1 << 20
KB = 1 << 10


# ---------------------------------------------------------------------------
# spectrum P6 (page-table context switch)
# ---------------------------------------------------------------------------


class TestSpectrumP6:
    def test_maxwell_context_switch_measured(self):
        """P6 on the Maxwell hierarchy: touching a page entry beyond the
        512 MB active window pays the context-switch penalty on top of a
        cold pagewalk miss (§5.2-1: Maxwell's is much larger)."""
        sp = spectrum.measure_spectrum(
            lambda: devices.make_hierarchy("GTX980"))
        exp = devices.expected_spectrum("GTX980")
        assert sp["P6"] == pytest.approx(exp["P6"], rel=0.02)      # 6412
        assert sp["P6"] > sp["P5"] > sp["P4"]
        # Maxwell's P6 dwarfs Kepler's (the §5.2-1 comparison)
        kp = spectrum.measure_spectrum(
            lambda: devices.make_hierarchy("GTX780"))
        assert sp["P6"] > 2 * kp["P6"]

    def test_kepler_context_switch_measured(self):
        sp = spectrum.measure_spectrum(
            lambda: devices.make_hierarchy("GTX780"))
        assert sp["P6"] == pytest.approx(2665, rel=0.02)

    def test_no_window_no_p6(self):
        """Fermi and Volta expose no active-window behaviour: the phase
        program must not fabricate a P6 class for them."""
        for dev in ("GTX560Ti", "TeslaV100"):
            sp = spectrum.measure_spectrum(
                lambda dev=dev: devices.make_hierarchy(dev))
            assert "P6" not in sp
            assert "P6" not in devices.expected_spectrum(dev)

    def test_expected_spectrum_matches_fig14_calibration(self):
        """The derived expectation reproduces the former hand-written
        Fig 14 table for every device — including the virtually-addressed
        branch (Maxwell/Volta: P1=P2=P3 collapse) and the P6 window."""
        assert devices.expected_spectrum("GTX560Ti") == {
            "P1": 96, "P2": 384, "P3": 812, "P4": 564, "P5": 1280}
        assert devices.expected_spectrum("GTX780") == {
            "P1": 188, "P2": 215, "P3": 552, "P4": 301, "P5": 665,
            "P6": 2665}
        assert devices.expected_spectrum("GTX980") == {
            "P1": 82, "P2": 82, "P3": 82, "P4": 1052, "P5": 1412,
            "P6": 6412}
        assert devices.expected_spectrum("TeslaV100") == {
            "P1": 28, "P2": 28, "P3": 28, "P4": 375, "P5": 775}


# ---------------------------------------------------------------------------
# held-out Volta recovery
# ---------------------------------------------------------------------------


class TestVoltaHeldOut:
    def test_l1_size_and_sector_recovered_blind(self):
        be = devices.sim_cache_backend("volta_l1_data")
        size = inference.find_cache_size(be, n_max=512 * KB,
                                         granularity=1 * KB)
        assert size == 128 * KB
        line = inference.find_line_size(be, size, max_line=4096,
                                        granularity=1 * KB)
        assert line == 32                     # the 32 B sector, not 128 B

    def test_l2_tlb_equal_sets_recovered_blind(self):
        """Volta's L2 TLB has EQUAL sets again — the staircase analyzer
        must report uniform 16×8, not pattern-match the 17+6×8 shape it
        was developed against."""
        params = inference.dissect(
            devices.sim_cache_backend("volta_l2_tlb"), n_max=1024 * MB,
            stride_for_size=2 * MB, granularity=2 * MB,
            line_stride_bytes=2 * MB, max_line=8 * MB,
            structure_max_steps=40, set_bits_max_log2=26)
        assert params.size_bytes == 256 * MB
        assert params.line_bytes == 2 * MB
        assert params.num_sets == 16
        assert params.way_counts == [8] * 16
        assert params.uniform_sets and params.is_lru
        assert params.set_bits == (21, 25)

    def test_quick_profile_measures_slow_structures(self):
        """With the batched engine, quick mode no longer skips the slow
        data-cache stages: every dissectable structure is measured, and
        the only published rows left are the deliberate fallbacks
        (l2_data) — both provenances still visible in one artifact."""
        prof = P.dissect_device("TeslaV100", quick=True)
        assert prof.quick
        assert prof.caches["volta_l2_tlb"].provenance == "measured"
        assert prof.caches["volta_l1_data"].provenance == "measured"
        assert prof.caches["l2_data"].provenance == "published"
        assert prof.latency_provenance["P1"] == "measured"
        assert prof.timings["volta_l1_data"] > 0
        assert prof.timings["total"] >= prof.timings["volta_l1_data"]
        rows = P.diff_profiles(prof, P.published_profile("TeslaV100"))
        assert not [r for r in rows if not r.ok]


# ---------------------------------------------------------------------------
# artifact round-trip -> identical consumer decisions
# ---------------------------------------------------------------------------


class TestRoundTrip:
    def test_serialize_load_identical(self, tmp_path):
        prof = P.dissect_device("tpu_v5e")
        path = P.save_profile(prof, str(tmp_path / "tpu_v5e.json"))
        loaded = P.load_profile(path)
        assert loaded.to_json() == prof.to_json()
        assert loaded.is_stale() == []

    def test_consumers_reproduce_decisions_from_loaded_artifact(self, tmp_path):
        from repro import configs
        from repro.serve import paging
        prof = P.dissect_device("tpu_v5e")
        loaded = P.load_profile(P.save_profile(
            prof, str(tmp_path / "tpu_v5e.json")))
        cfg = configs.get_smoke_config("granite-8b")
        assert paging.choose_page_len(cfg, spec=loaded) == \
            paging.choose_page_len(cfg)
        assert autotune.flash_attention_blocks(4096, 4096, 128,
                                               spec=loaded) == \
            autotune.flash_attention_blocks(4096, 4096, 128)
        assert autotune.memcpy_block(512, spec=loaded) == \
            autotune.memcpy_block(512)

    def test_gpu_profile_has_no_tpu_view(self):
        with pytest.raises(ValueError, match="kind"):
            P.published_profile("GTX980").tpu_spec()

    def test_tpu_spec_restores_int_fields(self):
        loaded = core_profile.DeviceProfile.from_json(
            json.loads(json.dumps(P.dissect_device("tpu_v5e").to_json())))
        spec = loaded.tpu_spec()
        for field in ("sublanes", "lanes", "vmem_bytes", "hbm_bytes",
                      "ici_links", "mxu_dim"):
            assert isinstance(getattr(spec, field), int), field

    def test_diff_fails_on_lost_latency_class(self):
        """A measured profile that lost a published spectrum class is a
        regression, not a published fallback."""
        prof = P.dissect_device("GTX980", quick=True)
        del prof.latency["P6"]
        del prof.latency_provenance["P6"]
        rows = P.diff_profiles(prof, P.published_profile("GTX980"))
        bad = [r for r in rows if not r.ok]
        assert ["latency/P6"] == [r.field for r in bad]

    def test_diff_catches_hand_edited_spec_field(self):
        """A tpu profile's spec section is its whole consumer surface;
        the diff must verify it rather than report zero fields green."""
        prof = P.dissect_device("tpu_v5e")
        rows = P.diff_profiles(prof, P.published_profile("tpu_v5e"))
        assert rows and all(r.ok for r in rows)
        prof.spec["hbm_bytes_per_s"] *= 2
        rows = P.diff_profiles(prof, P.published_profile("tpu_v5e"))
        bad = [r.field for r in rows if not r.ok]
        assert bad == ["spec/hbm_bytes_per_s"]

    def test_from_json_rejects_wrong_schema(self):
        payload = P.published_profile("tpu_v5e").to_json()
        payload["schema"] = "repro.bench/v1"
        with pytest.raises(ValueError, match="schema"):
            core_profile.DeviceProfile.from_json(payload)


# ---------------------------------------------------------------------------
# the default-spec trap
# ---------------------------------------------------------------------------


class TestDefaultSpecResolution:
    def test_active_profile_reaches_every_consumer(self):
        """Installing one profile must change littles_law, autotune and
        paging decisions without any call site passing spec=."""
        prof = P.dissect_device("tpu_v5e")
        prof.spec["hbm_bytes_per_s"] = prof.spec["hbm_bytes_per_s"] / 2
        base_need = littles_law.tpu_required_inflight_bytes()
        base_plan = autotune.memcpy_block(512)
        with core_profile.use_profile(prof):
            assert littles_law.tpu_required_inflight_bytes() == base_need // 2
            plan = autotune.memcpy_block(512)
            # inflight is the tile-rounded min block for the halved-HBM
            # profile — strictly below the full-bandwidth plan's
            assert plan.inflight_bytes == \
                littles_law.tpu_min_block_bytes(prof)
            assert plan.inflight_bytes < base_plan.inflight_bytes
        # context restored
        assert littles_law.tpu_required_inflight_bytes() == base_need
        assert autotune.memcpy_block(512) == base_plan

    def test_hbm_latency_comes_from_profile(self):
        slow = dataclasses.replace(TPU_V5E, name="slow-hbm",
                                   hbm_latency_s=2.0e-6)
        assert littles_law.tpu_required_inflight_bytes(slow) == \
            2 * littles_law.tpu_required_inflight_bytes(TPU_V5E)

    def test_cell_cost_warns_once_on_mixed_profiles(self):
        from repro.core import costmodel
        cc = costmodel.CellCost("mix-probe", 1e15, 1e15, 1e12, 1e9, 1e8, {})
        cc.terms()                                  # pins tpu_v5e
        other = TpuSpec(name="other-device")
        with pytest.warns(core_profile.SpecMixWarning):
            cc.terms(other)
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            cc.terms(other)                         # second time: silent
        assert not [w for w in rec
                    if issubclass(w.category, core_profile.SpecMixWarning)]

    def test_mix_detected_by_value_not_name(self):
        """A dissected tpu_v5e profile shares the built-in constant's
        name while disagreeing with its numbers — the exact trap the
        seam exists to close must still warn."""
        from repro.core import costmodel
        prof = P.dissect_device("tpu_v5e")
        prof.spec["hbm_bytes_per_s"] = prof.spec["hbm_bytes_per_s"] / 2
        assert prof.tpu_spec().name == TPU_V5E.name
        cc = costmodel.CellCost("samename-probe", 1e15, 1e15, 1e12, 1e9,
                                1e8, {})
        cc.terms()                                  # pins TPU_V5E values
        with pytest.warns(core_profile.SpecMixWarning):
            cc.terms(prof)

    def test_equal_valued_profile_never_warns(self):
        """A published-fallback tpu profile is numerically the constant;
        alternating between them is not a mix."""
        from repro.core import costmodel
        prof = P.dissect_device("tpu_v5e")
        cc = costmodel.CellCost("eqvalue-probe", 1e15, 1e15, 1e12, 1e9,
                                1e8, {})
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            cc.terms()
            cc.terms(prof)
        assert not [w for w in rec
                    if issubclass(w.category, core_profile.SpecMixWarning)]

    def test_same_profile_never_warns(self):
        from repro.core import costmodel
        cc = costmodel.CellCost("same-probe", 1e15, 1e15, 1e12, 1e9, 1e8, {})
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            cc.terms()
            cc.step_s()
            cc.roofline_fraction()
        assert not [w for w in rec
                    if issubclass(w.category, core_profile.SpecMixWarning)]


# ---------------------------------------------------------------------------
# repro.profile/v1 validation (the CI stage)
# ---------------------------------------------------------------------------


class TestValidation:
    def _save(self, tmp_path, mutate=None, name="tpu_v5e"):
        prof = P.dissect_device("tpu_v5e")
        payload = prof.to_json()
        if mutate:
            mutate(payload)
        path = tmp_path / f"{name}.json"
        path.write_text(json.dumps(payload))
        return str(path)

    def test_fresh_artifact_validates(self, tmp_path):
        assert P.validate_file(self._save(tmp_path)) == []

    def test_stale_engine_version_fails(self, tmp_path):
        def mutate(p):
            p["engine_version"] = "trace-engine/0"
        problems = P.validate_file(self._save(tmp_path, mutate))
        assert any("stale" in p and "engine" in p for p in problems)

    def test_stale_registry_hash_fails(self, tmp_path):
        def mutate(p):
            p["registry_hash"] = "deadbeef"
        problems = P.validate_file(self._save(tmp_path, mutate))
        assert any("stale" in p and "registry" in p for p in problems)

    def test_missing_key_fails(self, tmp_path):
        def mutate(p):
            del p["latency_provenance"]
        problems = P.validate_file(self._save(tmp_path, mutate))
        assert any("latency_provenance" in p for p in problems)

    def test_filename_device_mismatch_fails(self, tmp_path):
        problems = P.validate_file(self._save(tmp_path, name="GTX980"))
        assert any("filename" in p for p in problems)

    def test_provenance_without_field_entry_fails(self, tmp_path):
        def mutate(p):
            p["spec_provenance"].pop("vmem_bytes")
        problems = P.validate_file(self._save(tmp_path, mutate))
        assert any("without provenance" in p for p in problems)

    def test_validate_all_scans_root(self, tmp_path):
        self._save(tmp_path)
        out = P.validate_all(str(tmp_path))
        assert list(out.values()) == [[]]
