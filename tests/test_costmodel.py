"""Validate the analytic cost model against XLA on scan-free programs,
and pin the scan-undercount fact that motivates it."""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.core import costmodel
from repro.core.costmodel import ParallelismPlan
from repro.configs.shapes import ShapeSpec
from repro.models import transformer as T
from repro.optim import AdamWConfig
from repro.train.loop import init_state, make_train_step


def xla_flops(fn, *args):
    cost = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return float(cost["flops"])


class TestScanUndercount:
    def test_while_bodies_counted_once(self):
        """The fact that forces analytic accounting (DESIGN/EXPERIMENTS)."""
        def body(c, x):
            return c @ x, ()

        def scanned(c0, xs):
            return jax.lax.scan(body, c0, xs)[0]

        c0 = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        xs = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
        f_scan = xla_flops(scanned, c0, xs)

        def unrolled(c0, xs):
            for i in range(8):
                c0 = c0 @ xs[i]
            return c0

        f_unroll = xla_flops(unrolled, c0, xs)
        assert f_unroll > 6 * f_scan, \
            "XLA counts the while body once; if this starts failing, " \
            "cost_analysis became trip-count-aware and dryrun can use it"


class TestAnalyticVsXLA:
    """Unrolled (scan-free) small-but-real configs: analytic forward FLOPs
    must match XLA within tolerance."""

    def _forward_flops(self, cfg, batch, seq):
        params = jax.eval_shape(
            lambda k: T.init_params(cfg, k), jax.random.key(0))
        toks = jax.ShapeDtypeStruct((batch, seq), jnp.int32)

        def fwd(p, t):
            logits, _ = T.forward(p, cfg, {"tokens": t})
            return logits

        return xla_flops(fwd, params, toks)

    @pytest.mark.parametrize("arch", ["granite-8b", "phi3.5-moe-42b-a6.6b",
                                      "mistral-large-123b"])
    def test_dense_and_moe_forward(self, arch):
        cfg = configs.get_smoke_config(arch)
        cfg = dataclasses.replace(cfg, scan_layers=False, remat=False,
                                  attention_impl="ref", capacity_factor=1.0,
                                  # big enough that matmuls dominate the
                                  # elementwise ops the model ignores
                                  d_model=128, d_ff=512, vocab_size=1024)
        b, s = 4, 128
        got = self._forward_flops(cfg, b, s)
        want = costmodel.forward_flops_per_token(cfg, kv_len=s) * b * s
        assert got == pytest.approx(want, rel=0.25), \
            f"{arch}: xla={got:.3e} analytic={want:.3e}"

    def test_mla_forward(self):
        cfg = configs.get_smoke_config("deepseek-v2-lite-16b")
        cfg = dataclasses.replace(cfg, scan_layers=False, remat=False,
                                  attention_impl="ref", capacity_factor=1.0)
        b, s = 4, 128
        got = self._forward_flops(cfg, b, s)
        want = costmodel.forward_flops_per_token(cfg, kv_len=s) * b * s
        assert got == pytest.approx(want, rel=0.3)

    def test_train_multiplier(self):
        """Backward ≈ 2× forward; remat adds ≈ 1× more."""
        cfg = configs.get_smoke_config("granite-8b")
        cfg = dataclasses.replace(cfg, scan_layers=False, remat=False,
                                  attention_impl="ref")
        opt = AdamWConfig()
        state = jax.eval_shape(
            lambda k: init_state(cfg, opt, k), jax.random.key(0))
        b, s = 4, 128
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        got = xla_flops(make_train_step(cfg, opt), state, batch)
        fwd = costmodel.forward_flops_per_token(cfg, kv_len=s) * b * s
        assert 2.5 * fwd <= got <= 4.2 * fwd


class TestCellCosts:
    def mk_plan(self):
        return ParallelismPlan(dp=16, tp=16)

    def test_train_cell_sane(self):
        cfg = configs.get_config("mistral-large-123b")
        shape = ShapeSpec("train_4k", 4096, 256, "train")
        c = costmodel.cell_cost(cfg, shape, self.mk_plan())
        assert 0.5 < c.useful_ratio() <= 1.0
        assert c.dominant() in ("compute", "memory", "collective")
        # a 123B dense model at 1M tokens/step is compute-dominated
        assert c.dominant() == "compute"
        assert 0.3 < c.roofline_fraction() <= 1.0

    def test_decode_memory_bound(self):
        cfg = configs.get_config("mistral-large-123b")
        shape = ShapeSpec("decode_32k", 32768, 128, "decode")
        c = costmodel.cell_cost(cfg, shape, self.mk_plan())
        assert c.dominant() in ("memory", "collective"), \
            "batched decode must be bandwidth-bound, not compute-bound"

    def test_moe_cheaper_than_dense_equivalent(self):
        moe = configs.get_config("phi3.5-moe-42b-a6.6b")
        shape = ShapeSpec("train_4k", 4096, 256, "train")
        c = costmodel.cell_cost(moe, shape, self.mk_plan())
        dense_like = dataclasses.replace(
            moe, num_experts=0, top_k=0, d_ff=16 * moe.d_ff_expert)
        cd = costmodel.cell_cost(dense_like, shape, self.mk_plan())
        assert c.global_flops < 0.35 * cd.global_flops

    def test_mla_decode_expansion_term(self):
        """Naive MLA decode FLOPs grow with cache length (the §Perf target)."""
        cfg = configs.get_config("deepseek-v2-lite-16b")
        f1 = costmodel.forward_flops_per_token(cfg, kv_len=1024, decode=True)
        f2 = costmodel.forward_flops_per_token(cfg, kv_len=32768, decode=True)
        assert f2 > 5 * f1
