"""Beyond-paper: disaggregated prefill/decode tiers priced by the profile.

The tiered fleet (``repro.serve.tiers`` + the two-stage router in
``repro.serve.fleet``) splits replicas into prefill specialists and
decode specialists and moves the KV cache between them as a paged-page
transfer priced by ``min(src, dst)`` measured global-memory bandwidth
plus one worst-endpoint DRAM round trip — Table 7 and the P1–P6 latency
spectrum doing placement.  Every verdict is deterministic accounting:

* **oracle chain**: a single-tier plan (every replica in both tiers)
  must reproduce the symmetric fleet token-for-token on the same tick
  schedule with a bit-identical decision log — the tiered router
  degenerates, never diverges;
* **tiered correctness**: greedy outputs are schedule-independent, so a
  2-tier fleet's streams must equal the symmetric oracle per request;
* **zero pages leaked across handoffs** (export releases, import
  allocates, both ends run allocator invariants);
* **two-stage margin contract**: no admit/migrate (stage 1) or handoff
  (stage 2) decision exceeds the best candidate's predicted cost by
  more than the router margin;
* **two-stage replay**: the merged admit+handoff decision log replays
  bit-identically, scripted AND under a seeded fault campaign;
* **classification under faults**: killing a prefill and a decode
  replica mid-run still classifies every uid.

Handoff counts/aborts ride along as info metrics.
"""

from __future__ import annotations

from repro.bench import Context, Metric, experiment, info


def _stream(fleet):
    from repro.serve.frontend import FleetFrontend
    front = FleetFrontend(fleet)
    streamed: dict[int, list[int]] = {}
    return front, streamed, (lambda u, t: streamed.setdefault(u, [])
                             .append(t))


@experiment(
    title="Disaggregated prefill/decode fleet tiers",
    section="§4+§5.1 applied",
    artifact="beyond-paper",
    devices=("tpu_v5e",),
    tags=("serve", "fleet", "tiers", "handoff", "routing", "profile",
          "tpu"),
    expected={
        "Oracle chain": "a single-tier plan reproduces the symmetric "
                        "fleet token-for-token, tick-for-tick, with a "
                        "bit-identical decision log",
        "Handoff accounting": "zero pages leaked across exports/imports",
        "Two-stage replay": "admit+handoff decisions replay "
                            "bit-identically, scripted and seeded",
        "Classification": "every uid classified when a prefill and a "
                          "decode replica die mid-run",
    })
def run(ctx: Context) -> list[Metric]:
    # lazy: keep registry.discover() jax-free (see tpu_roofline)
    import jax
    import numpy as np

    from repro import configs
    from repro.models import transformer as T
    from repro.models.config import ModelConfig
    from repro.serve.engine import Request
    from repro.serve.faults import Fault, FaultInjector
    from repro.serve.fleet import FleetEngine

    if ctx.quick:
        cfg = ModelConfig(name="micro", family="dense", num_layers=2,
                          d_model=32, d_ff=64, vocab_size=64, num_heads=2,
                          num_kv_heads=2, dtype="float32",
                          param_dtype="float32")
        n_req, max_slots, max_len = 5, 3, 24
    else:
        cfg = configs.get_smoke_config("granite-8b")
        n_req, max_slots, max_len = 8, 3, 48
    params = T.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(ctx.seed)
    work = []
    for _ in range(n_req):
        plen = int(rng.integers(3, max_len // 3))
        n_new = int(rng.integers(3, max_len // 3))
        work.append((rng.integers(cfg.vocab_size, size=plen)
                     .astype(np.int32), n_new))

    def mk_fleet(tiers=None, replicas=2):
        return FleetEngine(cfg, params, max_slots=max_slots,
                           max_len=max_len, replicas=replicas,
                           page_len=4, tiers=tiers)

    def run_fleet(fleet):
        for uid, (prompt, n_new) in enumerate(work):
            fleet.submit(Request(uid, prompt, n_new))
        out = {r.uid: r.generated for r in fleet.run_to_completion()}
        fleet.check_invariants()
        return out

    # symmetric fleet: the oracle this PR chains onto (itself pinned to
    # the dense engine by benchmarks/serve_fleet.py)
    sym = mk_fleet()
    oracle = run_fleet(sym)

    # single-tier plan: every replica in both tiers -> must degenerate
    n = len(sym.replicas)
    single = mk_fleet(tiers=f"prefill:0,{n - 1}/decode:0,{n - 1}")
    single_out = run_fleet(single)

    # 2-tier fleet: replica 0 prefills, replica 1 decodes
    tiered = mk_fleet(tiers="prefill:0/decode:1")
    tiered_out = run_fleet(tiered)
    tiered_b = mk_fleet(tiers="prefill:0/decode:1")
    run_fleet(tiered_b)

    # seeded fault campaign on a 3-replica tiered fleet, run twice:
    # kill one prefill specialist and one decode specialist mid-run
    def fault_run():
        fleet = FleetEngine(cfg, params, max_slots=max_slots,
                            max_len=max_len, replicas=3, page_len=4,
                            tiers="prefill:0,1/decode:1,2")
        fleet.attach_injector(FaultInjector((
            Fault(tick=3, kind="kill", replica=0),
            Fault(tick=6, kind="kill", replica=2))))
        for uid, (prompt, n_new) in enumerate(work):
            fleet.submit(Request(uid, prompt, n_new))
        fleet.run_to_completion(max_ticks=2000)
        fleet.check_invariants()
        return fleet

    fa, fb = fault_run(), fault_run()
    cls = fa.classify()

    st_sym, st_single, st_tier = sym.stats(), single.stats(), tiered.stats()
    st_fault = fa.stats()
    gen_tokens = sum(len(v) for v in oracle.values())
    leaked = (st_single["pages_leaked"] + st_tier["pages_leaked"]
              + st_fault["pages_leaked"])
    stage1 = [d for d in tiered.decisions if d.kind in ("admit", "migrate")]
    stage2 = [d for d in tiered.decisions if d.kind == "handoff"]
    metrics = [
        Metric("single_tier_tokens_identical_to_symmetric",
               single_out == oracle, True, cmp="eq",
               detail=f"{len(oracle)} requests, {gen_tokens} tokens"),
        Metric("single_tier_tick_schedule_matches",
               single.ticks == sym.ticks, True, cmp="eq",
               detail=f"single-tier {single.ticks} ticks vs symmetric "
                      f"{sym.ticks}"),
        Metric("single_tier_decision_log_bit_identical",
               single.decision_log() == sym.decision_log(), True,
               cmp="eq",
               detail="the tiered router degenerates to the symmetric "
                      "one when no replica is specialized"),
        Metric("tiered_tokens_identical_to_oracle",
               tiered_out == oracle, True, cmp="eq",
               detail=f"{st_tier['handoffs']} KV handoffs en route"),
        Metric("pages_leaked_across_handoffs", leaked, 0, cmp="eq",
               detail=f"{st_tier['handoffs'] + st_fault['handoffs']} "
                      "exports/imports audited"),
        Metric("two_stage_margin_violations",
               len(single.margin_violations())
               + len(tiered.margin_violations())
               + len(fa.margin_violations()), 0, cmp="eq",
               detail=f"{len(stage1)} stage-1 (admit/migrate) + "
                      f"{len(stage2)} stage-2 (handoff) decisions "
                      "audited on the scripted tiered run"),
        Metric("two_stage_replay_scripted",
               tiered.decision_log() == tiered_b.decision_log(), True,
               cmp="eq",
               detail=f"{st_tier['decisions']} decisions incl. "
                      f"{len(stage2)} handoffs"),
        Metric("two_stage_replay_seeded_faults",
               fa.decision_log() == fb.decision_log()
               and fa.classify() == fb.classify(), True, cmp="eq",
               detail="kill prefill@t3 + decode@t6, run twice"),
        Metric("all_uids_classified_under_faults",
               sorted(cls) == list(range(n_req)), True, cmp="eq",
               detail=f"outcomes: "
                      f"{sorted(set(cls.values()))}"),
        info("tiered_handoffs", st_tier["handoffs"]),
        info("tiered_handoff_aborts", st_tier["handoff_aborts"]),
        info("fault_run_handoffs", st_fault["handoffs"]),
        info("symmetric_ticks", st_sym["ticks"]),
        info("tiered_ticks", st_tier["ticks"],
             detail="handoff ticks land in TTFT, so a tiered fleet "
                    "trades latency for specialization"),
    ]
    return metrics
