"""Paper Table 5: parameters of common GPU caches, re-derived blind by the
fine-grained P-chase analyzer from the calibrated simulators."""

from __future__ import annotations

from benchmarks.common import timed
from repro.bench import Context, Metric, experiment
from repro.core import devices, inference

# device -> [(registered sim-cache name, n_max for size search, paper row)];
# the name keys devices.SIM_CACHES and the shared trace cache
CASES = {
    "GTX560Ti": [("fermi_l1_data", 64 << 10,
                  dict(size_kb=16, line_b=128, sets=32, assoc=4, lru=False))],
    "GTX780": [("kepler_texture_l1", 64 << 10,
                dict(size_kb=12, line_b=32, sets=4, assoc=96, lru=True)),
               ("kepler_readonly", 64 << 10,
                dict(size_kb=12, line_b=32, sets=4, assoc=96, lru=True))],
    "GTX980": [("maxwell_unified_l1", 128 << 10,
                dict(size_kb=24, line_b=32, sets=4, assoc=192, lru=True))],
}

FERMI_WAY_PROBS = [1 / 6, 1 / 6, 1 / 6, 1 / 2]        # Fig 11


@experiment(
    title="Common cache parameters, recovered blind",
    section="§4.3–4.5",
    artifact="Table 5",
    devices=("GTX560Ti", "GTX780", "GTX980"),
    tags=("cache", "pchase"),
    expected={
        "Fermi L1 data": "16 KB, 128 B lines, 32 sets, 4-way, non-LRU "
                         "(way probs 1/6, 1/2, 1/6, 1/6)",
        "Kepler texture L1": "12 KB, 32 B lines, 4 sets, 96-way, LRU, "
                             "set bits 7–8",
        "Kepler read-only data": "12 KB, 32 B lines, 4 sets, 96-way, LRU",
        "Maxwell unified L1": "24 KB, 32 B lines, 4 sets, 192-way, LRU",
    })
def run(ctx: Context) -> list[Metric]:
    metrics: list[Metric] = []
    for label, n_max, exp in CASES[ctx.device.name]:
        be = devices.sim_cache_backend(label)
        if ctx.quick:
            # size + line only: the two cheap stage-1 searches
            size, us1 = timed(inference.find_cache_size, be, n_max=n_max,
                              granularity=1 << 10)
            line, us2 = timed(inference.find_line_size, be, size,
                              max_line=4096, granularity=1 << 10)
            metrics += [
                Metric(f"{label}/size_kb", size >> 10, exp["size_kb"],
                       cmp="eq", unit="KB", us=us1),
                Metric(f"{label}/line_bytes", line, exp["line_b"],
                       cmp="eq", unit="B", us=us2),
            ]
            continue
        params, us = timed(inference.dissect, be, n_max=n_max, max_line=4096)
        metrics += [
            Metric(f"{label}/size_kb", params.size_bytes >> 10,
                   exp["size_kb"], cmp="eq", unit="KB", us=us),
            Metric(f"{label}/line_bytes", params.line_bytes, exp["line_b"],
                   cmp="eq", unit="B"),
            Metric(f"{label}/num_sets", params.num_sets, exp["sets"],
                   cmp="eq"),
            Metric(f"{label}/assoc", params.assoc, exp["assoc"], cmp="eq"),
            Metric(f"{label}/is_lru", params.is_lru, exp["lru"], cmp="eq",
                   detail=params.summary()),
        ]
    if ctx.device.name == "GTX560Ti" and not ctx.quick:
        # Fig 11 way-probability estimate for the Fermi non-LRU policy
        rep, us = timed(inference.detect_replacement,
                        devices.sim_cache_backend("fermi_l1_data"),
                        16 << 10, 128, passes=800)
        probs = sorted(rep.way_probs)
        err = max(abs(p - e) for p, e in zip(probs, sorted(FERMI_WAY_PROBS)))
        metrics.append(Metric(
            "fermi_l1_way_probs/max_abs_err", round(err, 4), 0.05, cmp="le",
            us=us, detail=f"sorted={[round(p, 3) for p in probs]} "
            f"expect={[round(p, 3) for p in sorted(FERMI_WAY_PROBS)]}"))
    return metrics
