"""Paper Table 5: parameters of common GPU caches, re-derived blind by the
fine-grained P-chase analyzer from calibrated simulators."""

from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import devices, inference
from repro.core.pchase import cache_backend

EXPECTED = {
    "fermi_l1_data": "C=16KB b=128B T=32 non-LRU",
    "kepler_texture_l1": "C=12KB b=32B T=4 a=96 LRU bits7-8",
    "kepler_readonly": "C=12KB b=32B T=4 a=96 LRU",
    "maxwell_unified_l1": "C=24KB b=32B T=4 a=192 LRU",
}


def run() -> list[Row]:
    rows: list[Row] = []
    cases = [
        ("fermi_l1_data", devices.fermi_l1_data, 64 << 10),
        ("kepler_texture_l1", devices.kepler_texture_l1, 64 << 10),
        ("kepler_readonly", devices.kepler_readonly, 64 << 10),
        ("maxwell_unified_l1", devices.maxwell_unified_l1, 128 << 10),
    ]
    for name, mk, nmax in cases:
        params, us = timed(inference.dissect, cache_backend(mk), n_max=nmax,
                           max_line=4096)
        rows.append((f"table5/{name}", us, params.summary().replace(",", ";")))
    # the Fermi way-probability estimate (Fig 11 analysis)
    rep, us = timed(inference.detect_replacement,
                    cache_backend(devices.fermi_l1_data), 16 << 10, 128,
                    passes=800)
    probs = sorted(round(p, 3) for p in rep.way_probs)
    rows.append(("table5/fermi_l1_way_probs", us,
                 f"sorted={probs} expect=[1/6;1/6;1/6;1/2]"))
    # L1/L2 TLB structure
    MB = 1 << 20
    be = cache_backend(devices.l2_tlb)
    st, us = timed(inference.recover_set_structure, be, 130 * MB, 2 * MB,
                   max_steps=80)
    rows.append(("table5/l2_tlb_sets", us,
                 f"ways={st.way_counts} (unequal sets; Fig 9)".replace(",", ";")))
    return rows
