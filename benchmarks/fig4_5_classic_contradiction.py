"""Paper Fig 4 vs Fig 5: the two classic P-chase methods CONTRADICT each
other on the Kepler texture L1 (the motivation for fine-grained P-chase)."""

from __future__ import annotations

from benchmarks.common import timed
from repro.bench import Context, Metric, experiment, info
from repro.core import classic, devices
from repro.core.pchase import saavedra1992, wong2010

TRUTH = "b=32 T=4 a=96"


@experiment(
    title="Classic P-chase methods contradict each other on the texture L1",
    section="§3.2",
    artifact="Fig 4/5",
    devices=("GTX780",),
    tags=("cache", "pchase", "classic"),
    expected={
        "Ground truth (texture L1)": TRUTH,
        "Saavedra1992 vs Wong2010": "the two classic methods report "
                                    "different line sizes and set counts",
    })
def run(ctx: Context) -> list[Metric]:
    be = devices.sim_cache_backend("kepler_texture_l1")

    def saav():
        curve = saavedra1992(be, 48 << 10, [2 ** p for p in range(5, 12)])
        return classic.interpret_saavedra(curve, 48 << 10, 12 << 10)

    def wong():
        sizes = list(range(12 << 10, (12 << 10) + 640, 32))
        curve = wong2010(be, sizes, 32)
        return classic.interpret_wong(curve, 12 << 10)

    sv, us1 = timed(saav)
    wg, us2 = timed(wong)
    disagree = (sv.line_bytes != wg.line_bytes or sv.num_sets != wg.num_sets)
    return [
        info("saavedra1992", f"b={sv.line_bytes} T={sv.num_sets} "
             f"a={sv.assoc:g}", detail=f"truth {TRUTH}", us=us1),
        info("wong2010", f"b={wg.line_bytes} T={wg.num_sets} "
             f"a={wg.assoc:g}", detail=f"truth {TRUTH}", us=us2),
        Metric("methods_disagree", disagree, True, cmp="eq",
               detail=f"b {sv.line_bytes} vs {wg.line_bytes}; "
                      f"T {sv.num_sets} vs {wg.num_sets}"),
        # neither classic method recovers the true structure (the paper's
        # point): at least one parameter is wrong for each
        Metric("saavedra_wrong", (sv.line_bytes, sv.num_sets) != (32, 4),
               True, cmp="eq"),
        Metric("wong_wrong", (wg.line_bytes, wg.num_sets) != (32, 4),
               True, cmp="eq"),
    ]
