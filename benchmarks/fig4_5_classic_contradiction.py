"""Paper Fig 4 vs Fig 5: the two classic P-chase methods CONTRADICT each
other on the Kepler texture L1 (the motivation for fine-grained P-chase)."""

from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import classic, devices
from repro.core.pchase import cache_backend, saavedra1992, wong2010


def run() -> list[Row]:
    be = cache_backend(devices.kepler_texture_l1)

    def saav():
        curve = saavedra1992(be, 48 << 10, [2 ** p for p in range(5, 12)])
        return classic.interpret_saavedra(curve, 48 << 10, 12 << 10)

    def wong():
        sizes = list(range(12 << 10, (12 << 10) + 640, 32))
        curve = wong2010(be, sizes, 32)
        return classic.interpret_wong(curve, 12 << 10)

    sv, us1 = timed(saav)
    wg, us2 = timed(wong)
    truth = "b=32 T=4 a=96"
    return [
        ("fig4/saavedra1992", us1,
         f"b={sv.line_bytes} T={sv.num_sets} a={sv.assoc:g} (truth {truth})"),
        ("fig5/wong2010", us2,
         f"b={wg.line_bytes} T={wg.num_sets} a={wg.assoc:g} (truth {truth})"),
        ("fig4_5/contradiction", us1 + us2,
         f"methods disagree: b {sv.line_bytes} vs {wg.line_bytes}; "
         f"T {sv.num_sets} vs {wg.num_sets}"),
    ]
