"""Beyond-paper: the dissect→deploy loop, closed.

For every simulated GPU — the paper's three plus the held-out Volta
(TeslaV100, Jia et al. 2018), which the blind pipeline was never tuned on
— this experiment dissects a full :class:`~repro.core.profile.
DeviceProfile` from traces alone, diffs it field-by-field against the
published tables (Table 5 structural parameters exactly, Fig 14 latency
classes within tolerance), and proves the artifact survives a JSON
round-trip bit-identically.

On the TPU target it closes the *deploy* half: the profile artifact is
written, re-loaded, and fed to the three downstream consumers —
``serve.paging.choose_page_len``, ``core.autotune.flash_attention_blocks``
and ``costmodel.CellCost.step_s`` — which must (a) reproduce the
constants-path decisions when the profile equals the published spec and
(b) demonstrably *move* when a profile field moves (halving the profile's
HBM bandwidth halves the Little's-law in-flight requirement), proving the
decisions consume the loaded artifact rather than module constants.
"""

from __future__ import annotations

import os
import tempfile

from benchmarks.common import timed
from repro.bench import Context, Metric, experiment, info
from repro import profile as P

GPU_DEVICES = ("GTX560Ti", "GTX780", "GTX980", "TeslaV100")


def _roundtrip(prof) -> bool:
    fd, path = tempfile.mkstemp(suffix=".json")
    os.close(fd)
    try:
        P.save_profile(prof, path)
        loaded = P.load_profile(path)
        return loaded.to_json() == prof.to_json()
    finally:
        os.unlink(path)


def _gpu_metrics(ctx: Context) -> list[Metric]:
    prof, us = timed(P.dissect_device, ctx.device.name,
                     quick=ctx.quick, seed=ctx.seed)
    pub = P.published_profile(ctx.device.name)
    rows = P.diff_profiles(prof, pub)
    checked = [r for r in rows if r.rule != "info"]
    bad = [r for r in checked if not r.ok]
    metrics = [
        Metric("diff_mismatches", len(bad), 0, cmp="eq", us=us,
               detail=f"{len(checked)} checked fields; mismatched: "
                      f"{[r.field for r in bad] or '-'}"),
    ]
    for cls in sorted(pub.latency):
        mv = prof.latency.get(cls)
        metrics.append(Metric(f"latency_{cls}_cycles", mv, pub.latency[cls],
                              cmp="close", tol=0.02, unit="cyc"))
    structural = [r for r in rows
                  if r.rule == "eq" and not r.field.startswith(
                      ("latency/", "bandwidth/", "bank_conflict/"))]
    metrics.append(Metric("structural_fields_exact",
                          sum(r.ok for r in structural), len(structural),
                          cmp="eq",
                          detail="size/line/sets/ways/policy/mapping bits"))
    pc = prof.provenance_counts()
    pub_caches = [n for n, c in prof.caches.items()
                  if c.provenance == "published"]
    # the batched engine made the slow structures cheap, so quick mode
    # measures everything too: "ge" in BOTH modes, and the only cache
    # row left on published fallback is the whole-L2 data array
    metrics.append(Metric("measured_fields", pc["measured"], 10, cmp="ge",
                          detail="published-fallback cache rows: "
                                 f"{pub_caches or '-'}"))
    metrics.append(Metric("quick_measures_data_caches",
                          not [n for n in pub_caches if n != "l2_data"],
                          True, cmp="eq",
                          detail="no structure is skipped in quick mode"))
    metrics.append(Metric("json_roundtrip_identical", _roundtrip(prof),
                          True, cmp="eq"))
    if ctx.device.name == "GTX980":
        metrics.append(_engine_speedup_metric(ctx))
    return metrics


def _engine_speedup_metric(ctx: Context) -> Metric:
    """Race the full blind structure search, vector vs batched jax.

    The trace cache is bypassed so both engines pay for real simulation;
    best-of-2 per engine absorbs the one-time XLA compile (the
    persistent compilation cache makes it a non-cost on warm hosts)."""
    from repro.core import tracecache
    from repro.profile.pipeline import dissect_structures, resolve_engine

    if resolve_engine("auto") != "jax":
        return info("batched_engine_speedup",
                    "jax unavailable on this host; nothing to race")
    best: dict[str, float] = {}
    for eng in ("vector", "jax"):
        runs = []
        for _ in range(2):
            with tracecache.disabled():
                _, us = timed(dissect_structures, ctx.device.name,
                              engine=eng)
            runs.append(us)
        best[eng] = min(runs)
    ratio = best["vector"] / max(best["jax"], 1.0)
    return Metric("batched_engine_speedup", round(ratio, 1), 10, cmp="ge",
                  us=best["jax"],
                  detail="full blind structure search, trace cache "
                         f"bypassed: vector {best['vector'] / 1e6:.3f}s -> "
                         f"jax {best['jax'] / 1e6:.3f}s (best of 2)")


def _tpu_metrics(ctx: Context) -> list[Metric]:
    # heavyweight imports stay inside the tpu branch: the sim workers of
    # the parallel runner must not pay the jax import
    from repro import configs
    from repro.core import autotune, costmodel, littles_law
    from repro.serve import paging

    prof, us = timed(P.dissect_device, ctx.device.name, seed=ctx.seed)
    fd, path = tempfile.mkstemp(suffix=".json")
    os.close(fd)
    try:
        P.save_profile(prof, path)
        loaded = P.load_profile(path)
    finally:
        os.unlink(path)
    metrics = [Metric("json_roundtrip_identical",
                      loaded.to_json() == prof.to_json(), True, cmp="eq",
                      us=us)]

    cfg = configs.get_smoke_config("granite-8b")
    pl_const = paging.choose_page_len(cfg, expected_tokens=256)
    pl_prof = paging.choose_page_len(cfg, spec=loaded, expected_tokens=256)
    metrics.append(Metric("choose_page_len_from_profile", pl_prof, pl_const,
                          cmp="eq", detail="loaded artifact reproduces the "
                          "constants-path decision"))

    fp_const = autotune.flash_attention_blocks(4096, 4096, 128)
    fp_prof = autotune.flash_attention_blocks(4096, 4096, 128, spec=loaded)
    metrics.append(Metric("flash_blocks_from_profile",
                          f"{fp_prof.block_q}x{fp_prof.block_k}",
                          f"{fp_const.block_q}x{fp_const.block_k}", cmp="eq",
                          detail=f"plan priced against {fp_prof.spec_name!r}"))

    plan = costmodel.ParallelismPlan(dp=1, tp=1)
    cc = costmodel.decode_cell_cost(cfg, global_batch=4, seq=256, plan=plan)
    cc2 = costmodel.decode_cell_cost(cfg, global_batch=4, seq=256, plan=plan)
    metrics.append(Metric("step_s_from_profile", cc2.step_s(loaded),
                          cc.step_s(), cmp="close", tol=1e-9, unit="s"))

    # sensitivity: the decisions must MOVE with the artifact, or they are
    # not consuming it.  Halve the profile's HBM bandwidth: Little's law
    # says the in-flight requirement (and the paging gather setup term)
    # halves with it.
    slow = P.DeviceProfile.from_json(loaded.to_json())
    slow.spec["hbm_bytes_per_s"] = loaded.spec["hbm_bytes_per_s"] / 2
    need = littles_law.tpu_required_inflight_bytes(loaded)
    need_slow = littles_law.tpu_required_inflight_bytes(slow)
    metrics.append(Metric("inflight_scales_with_profile_hbm",
                          round(need / max(need_slow, 1), 4), 2.0,
                          cmp="close", tol=1e-6,
                          detail="halved profile HBM bw halves the "
                          "Little's-law in-flight bytes"))
    metrics.append(info("provenance",
                        f"{prof.provenance_counts()['published']} published "
                        "fields (no on-hardware dissection on this host)"))
    return metrics


@experiment(
    title="DeviceProfile round-trip: blind dissection feeds the consumers",
    section="§4–§6 applied",
    artifact="beyond-paper",
    devices=GPU_DEVICES + ("tpu_v5e",),
    tags=("profile", "pchase", "spectrum", "consumer", "held-out"),
    expected={
        "Structural parameters": "size/line/sets/ways/policy recovered "
                                 "blind match Table 5 (and Jia et al. for "
                                 "the held-out TeslaV100) exactly",
        "Latency classes": "P1–P6 within 2% of the Fig-14 calibration",
        "Artifact": "repro.profile/v1 JSON survives save->load "
                    "bit-identically",
        "Consumers": "choose_page_len, flash_attention_blocks and "
                     "CellCost.step_s reproduce constants-path decisions "
                     "from the loaded artifact and track its fields",
    })
def run(ctx: Context) -> list[Metric]:
    if ctx.device.kind == "tpu":
        return _tpu_metrics(ctx)
    return _gpu_metrics(ctx)
