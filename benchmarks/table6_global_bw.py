"""Paper Table 6: theoretical vs achieved global-memory bandwidth, plus the
TPU-side streaming-copy measurement (Pallas memcpy kernel on this host)."""

from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import devices, littles_law
from repro.kernels import ops


def run() -> list[Row]:
    rows: list[Row] = []
    for name, spec in devices.GPU_SPECS.items():
        def best():
            return littles_law.best_occupancy(spec, kind="global")
        (pt, bw), us = timed(best)
        rows.append((
            f"table6/{name}", us,
            f"theory={spec.theoretical_gbps:.2f}GB/s "
            f"model_peak={bw:.2f}GB/s paper_meas={spec.measured_peak_gbps}"
            f"GB/s eff={bw / spec.theoretical_gbps:.1%}"))
    # TPU analogue: in-flight bytes required to saturate HBM (Little's law)
    need = littles_law.tpu_required_inflight_bytes(devices.TPU_V5E)
    blk = littles_law.tpu_min_block_bytes(devices.TPU_V5E)
    rows.append(("table6/tpu_v5e_littles_law", 0.0,
                 f"inflight={need / 1024:.0f}KiB min_double_buffer_block="
                 f"{blk / 1024:.0f}KiB"))
    # host-side kernel sanity (interpret mode: correctness-scale only)
    bw, us = timed(ops.memcpy_throughput_gbps, (2048, 512), repeats=2)
    rows.append(("table6/host_memcpy_kernel", us,
                 f"{bw:.2f}GB/s (interpret-mode, correctness only)"))
    return rows
