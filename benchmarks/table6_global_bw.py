"""Paper Table 6: theoretical vs achieved global-memory bandwidth, plus the
TPU-side streaming-copy analogue (Little's law + Pallas memcpy kernel)."""

from __future__ import annotations

from benchmarks.common import timed
from repro.bench import Context, Metric, experiment, info
from repro.core import devices, littles_law

# Paper Table 6 achieved/theoretical efficiency band: 70–81 %.
EFFICIENCY_BAND = [0.65, 0.85]


@experiment(
    title="Global-memory throughput: theory, model peak, paper measurement",
    section="§5.1",
    artifact="Table 6",
    devices=("GTX560Ti", "GTX780", "GTX980", "tpu_v5e"),
    tags=("throughput", "littles-law", "tpu"),
    expected={
        "GTX560Ti achieved": "109.38 GB/s of 134.40 GB/s theoretical (81%)",
        "GTX780 achieved": "215.92 GB/s of 288.38 GB/s theoretical (75%)",
        "GTX980 achieved": "156.25 GB/s of 224.38 GB/s theoretical (70%)",
        "Efficiency band": "achieved/theoretical within 70–81 %",
    })
def run(ctx: Context) -> list[Metric]:
    if ctx.device.kind == "tpu":
        return _tpu_metrics(ctx)
    spec = ctx.device.spec
    (pt, bw), us = timed(littles_law.best_occupancy, spec, "global")
    eff = bw / spec.theoretical_gbps
    return [
        Metric("model_peak_gbps", round(bw, 2),
               round(spec.measured_peak_gbps, 2), cmp="close", tol=0.01,
               unit="GB/s", us=us,
               detail=f"theory={spec.theoretical_gbps:.2f}GB/s "
                      f"best=({pt.cta_size}thr x{pt.num_ctas}ctas "
                      f"ILP{pt.ilp})"),
        Metric("efficiency", round(eff, 3), EFFICIENCY_BAND, cmp="range",
               detail="achieved/theoretical (Table 6: 70-81%)"),
        info("theoretical_gbps", round(spec.theoretical_gbps, 2),
             unit="GB/s"),
    ]


def _tpu_metrics(ctx: Context) -> list[Metric]:
    spec = ctx.device.spec
    need = littles_law.tpu_required_inflight_bytes(spec)
    blk = littles_law.tpu_min_block_bytes(spec)
    tile = spec.sublanes * spec.lanes * 4
    metrics = [
        Metric("littles_law_inflight_kib", need / 1024,
               spec.hbm_bytes_per_s * 1e-6 / 1024, cmp="close", tol=0.01,
               unit="KiB", detail="bytes in flight to hide ~1us HBM latency"),
        Metric("min_block_tile_aligned", blk % tile == 0, True, cmp="eq",
               detail=f"block={blk / 1024:.0f}KiB tile={tile}B"),
    ]
    if not ctx.quick:
        # host-side kernel sanity (interpret mode: correctness-scale only)
        from repro.kernels import ops
        bw, us = timed(ops.memcpy_throughput_gbps, (2048, 512), repeats=2)
        metrics.append(info("host_memcpy_gbps", round(bw, 2), unit="GB/s",
                            detail="interpret-mode, correctness only",
                            us=us))
    return metrics
