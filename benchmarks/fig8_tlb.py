"""Paper Fig 8/9: L2 TLB miss-rate staircase and the unequal-set structure."""

from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import devices, inference
from repro.core.pchase import cache_backend

MB = 1 << 20


def run() -> list[Row]:
    be = cache_backend(devices.l2_tlb)
    rows: list[Row] = []

    c, us = timed(inference.find_cache_size, be, n_max=512 * MB,
                  n_min=8 * MB, stride_bytes=2 * MB, granularity=2 * MB)
    rows.append(("fig8/l2_tlb_reach", us, f"{c // MB}MB (=65 pages)"))

    page, us = timed(inference.find_line_size, be, c, stride_bytes=2 * MB,
                     granularity=256 << 10, max_line=8 * MB)
    rows.append(("fig8/page_size", us, f"{page // MB}MB"))

    st, us = timed(inference.recover_set_structure, be, c, 2 * MB,
                   max_steps=80)
    rows.append(("fig9/set_structure", us,
                 f"ways={st.way_counts} uniform={st.uniform}".replace(",", ";")))

    # the measured miss-per-pass staircase itself (piecewise linear, Fig 8)
    def staircase():
        pts = []
        for extra in (1, 2, 9, 18, 27):
            m = inference.misses_per_pass(be, c + extra * 2 * MB, 2 * MB,
                                          passes=3)
            pts.append(round(m, 1))
        return pts

    pts, us = timed(staircase)
    rows.append(("fig8/miss_staircase", us,
                 f"misses/pass at +{{1;2;9;18;27}} pages = {pts}".replace(",", ";")))
    return rows
