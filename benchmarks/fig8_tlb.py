"""Paper Fig 8/9: L2 TLB miss-rate staircase and the unequal-set structure.

The paper finds the same TLB hierarchy on all three devices (§4.4), so the
experiment is registered for each and probes the shared calibrated model.
"""

from __future__ import annotations

from benchmarks.common import timed
from repro.bench import Context, Metric, experiment
from repro.core import devices, inference

MB = 1 << 20


@experiment(
    title="L2 TLB reach, page size, and unequal-set structure",
    section="§4.4",
    artifact="Fig 8/9",
    devices=("GTX560Ti", "GTX780", "GTX980"),
    tags=("tlb", "pchase"),
    expected={
        "L2 TLB reach": "130 MB (65 × 2 MB pages)",
        "Page size": "2 MB",
        "Set structure": "unequal sets: one 17-way + six 8-way (Fig 9)",
        "Overflow-by-one-page misses/pass": "18 (the large set thrashes)",
    })
def run(ctx: Context) -> list[Metric]:
    be = devices.sim_cache_backend("l2_tlb")
    metrics: list[Metric] = []

    c, us = timed(inference.find_cache_size, be, n_max=512 * MB,
                  n_min=8 * MB, stride_bytes=2 * MB, granularity=2 * MB)
    metrics.append(Metric("l2_tlb_reach_mb", c // MB, 130, cmp="eq",
                          unit="MB", us=us, detail="= 65 pages"))

    page, us = timed(inference.find_line_size, be, c, stride_bytes=2 * MB,
                     granularity=256 << 10, max_line=8 * MB)
    metrics.append(Metric("page_mb", page // MB, 2, cmp="eq", unit="MB",
                          us=us))
    if ctx.quick:
        return metrics

    st, us = timed(inference.recover_set_structure, be, c, 2 * MB,
                   max_steps=80)
    metrics.append(Metric("set_structure", str(sorted(st.way_counts)),
                          str(sorted([17, 8, 8, 8, 8, 8, 8])), cmp="eq",
                          us=us, detail=f"uniform={st.uniform}"))
    metrics.append(Metric("sets_unequal", not st.uniform, True, cmp="eq"))

    # the measured miss-per-pass staircase itself (piecewise linear, Fig 8)
    def staircase():
        pts = []
        for extra in (1, 2, 9, 18, 27):
            m = inference.misses_per_pass(be, c + extra * 2 * MB, 2 * MB,
                                          passes=3)
            pts.append(round(m, 1))
        return pts

    pts, us = timed(staircase)
    metrics.append(Metric("overflow_one_page_misses", pts[0], 18.0,
                          cmp="close", tol=0.1, us=us,
                          detail=f"misses/pass at +{{1,2,9,18,27}} pages "
                                 f"= {pts}"))
    return metrics
