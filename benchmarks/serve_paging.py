"""Beyond-paper: the serving layer as a consumer of the dissection laws.

The paged KV-cache engine (repro.serve) derives its page length from the
paper's models — Little's law prices the gather's per-transfer setup
(§5.1), the bank-conflict row model checks the page row tiles cleanly
(§6.2) — and its admission/accounting is exact bookkeeping.  This
experiment runs the same mixed workload through the dense-slot oracle
engine and the paged engine and reports:

* verdict metrics (deterministic accounting, safe to gate): greedy
  outputs token-identical, page slack bounded by one page, paged peak
  HBM strictly under the dense reservation, zero pages leaked;
* info metrics (CPU interpret-mode timings, NEVER gate verdicts):
  tokens/s for both engines, HBM bytes reserved per generated token,
  page-table overhead, and the page-length rationale table.
"""

from __future__ import annotations

import time

from repro.bench import Context, Metric, experiment, info


def _run_workload(engine, reqs):
    for r in reqs:
        engine.submit(r)
    t0 = time.perf_counter()
    finished = engine.run_to_completion()
    dt = time.perf_counter() - t0
    return finished, dt


@experiment(
    title="Paged KV-cache serving sized by the memory laws",
    section="§5.1+§6.2 applied",
    artifact="beyond-paper",
    devices=("tpu_v5e",),
    tags=("serve", "paging", "littles-law", "bank-conflict", "tpu"),
    expected={
        "Token equality": "paged engine reproduces the dense-slot "
                          "engine's greedy outputs token-for-token",
        "HBM law": "reserved HBM tracks generated length to within one "
                   "page per live request (vs max_slots*max_len dense)",
        "Page length": "derived from Little's law + the bank-conflict "
                       "row model, not hard-coded",
    })
def run(ctx: Context) -> list[Metric]:
    # lazy: keep registry.discover() jax-free (see tpu_roofline)
    import jax
    import numpy as np

    from repro import configs
    from repro.models import transformer as T
    from repro.models.config import ModelConfig
    from repro.serve import paging
    from repro.serve.engine import PagedServeEngine, Request, ServeEngine

    if ctx.quick:
        cfg = ModelConfig(name="micro", family="dense", num_layers=2,
                          d_model=32, d_ff=64, vocab_size=64, num_heads=2,
                          num_kv_heads=2, dtype="float32",
                          param_dtype="float32")
        n_req, max_slots, max_len = 5, 2, 24
    else:
        cfg = configs.get_smoke_config("granite-8b")
        n_req, max_slots, max_len = 8, 3, 48
    params = T.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(ctx.seed)

    def reqs():
        out = []
        for uid in range(n_req):
            plen = int(rng.integers(3, max_len // 3))
            n_new = int(rng.integers(3, max_len // 3))
            out.append(Request(uid, rng.integers(cfg.vocab_size, size=plen)
                               .astype(np.int32), n_new))
        return out

    work = reqs()

    def clone(rs):
        return [Request(r.uid, r.prompt, r.max_new_tokens) for r in rs]

    dense = ServeEngine(cfg, params, max_slots=max_slots, max_len=max_len)
    dense_fin, dense_dt = _run_workload(dense, clone(work))

    paged = PagedServeEngine(cfg, params, max_slots=max_slots,
                             max_len=max_len)
    paged_fin, paged_dt = _run_workload(paged, clone(work))
    paged.alloc.check_invariants()

    want = {r.uid: r.generated for r in dense_fin}
    got = {r.uid: r.generated for r in paged_fin}
    identical = set(want) == set(got) and all(got[u] == want[u]
                                              for u in want)
    gen_tokens = sum(len(r.generated) for r in paged_fin)
    bpt = paging.kv_bytes_per_token(cfg)
    dense_bytes = dense.hbm_reserved_bytes()
    paged_peak_bytes = paged.peak_pages * paged.page_len * bpt
    s = paged.stats()

    metrics = [
        # deterministic accounting -> real verdicts
        Metric("greedy_tokens_identical", identical, True, cmp="eq",
               detail=f"{len(want)} requests, {gen_tokens} tokens"),
        Metric("max_page_slack_tokens", s["max_slack_tokens"],
               paged.page_len, cmp="le", tol=0.0, unit="tokens",
               detail="HBM held per request tracks generated length to "
                      "<= 1 page (acceptance bound)"),
        Metric("paged_peak_over_dense_reserved",
               round(paged_peak_bytes / max(1, dense_bytes), 3), 1.0,
               cmp="le", tol=0.0,
               detail=f"peak {paged_peak_bytes} B vs dense "
                      f"{dense_bytes} B for the same workload"),
        Metric("pages_leaked_after_drain",
               paged.alloc.allocated_pages, 0, cmp="eq"),
        # CPU interpret-mode numbers: info only, never gate verdicts
        info("page_len_chosen", paged.page_len, unit="tokens",
             detail="argmin of the Little's-law + bank-conflict score"),
        info("tokens_per_s_dense", round(gen_tokens / max(dense_dt, 1e-9)),
             unit="tok/s", us=dense_dt * 1e6,
             detail="CPU interpret-mode; pair-run on one host"),
        info("tokens_per_s_paged", round(gen_tokens / max(paged_dt, 1e-9)),
             unit="tok/s", us=paged_dt * 1e6,
             detail="CPU interpret-mode; pair-run on one host"),
        info("hbm_bytes_per_token_dense",
             round(dense_bytes / max(1, gen_tokens)), unit="B/tok",
             detail="occupancy-blind max_slots*max_len reservation"),
        info("hbm_bytes_per_token_paged",
             round(paged_peak_bytes / max(1, gen_tokens)), unit="B/tok",
             detail="pages actually in circulation at peak"),
        info("page_table_overhead_bytes", paged.page_table_bytes(),
             unit="B", detail="int32 slot x pages_per_seq table"),
        info("preemptions", s["preemptions"]),
    ]
    for t in paging.page_len_rationale(cfg, expected_tokens=max_len):
        metrics.append(info(
            f"rationale/page_len_{t.page_len}",
            f"score={t.score} gather={t.gather_frac} frag={t.frag_frac} "
            f"table={t.table_frac} conflict_degree={t.conflict_degree}",
            detail=f"row_bytes={t.row_bytes}"))
    return metrics
