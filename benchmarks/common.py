"""Benchmark harness conventions.

Each ``benchmarks/<artifact>.py`` module registers ONE experiment with the
``repro.bench`` registry via the ``@experiment`` decorator: a function
``run(ctx) -> list[Metric]`` plus metadata (paper section, figure/table id,
applicable devices, published expected values).  The runner executes it
once per device and folds the metrics into a PASS/DEVIATION record; the
legacy ``name,us_per_call,derived`` CSV rows are derived from the same
metrics (see ``repro.bench.runner.records_to_rows``).

This module keeps the one helper shared by the experiment bodies.
"""

from __future__ import annotations

import time
from typing import Callable


def timed(fn: Callable, *args, **kw):
    """Call ``fn`` and return ``(result, elapsed_microseconds)``."""
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6
