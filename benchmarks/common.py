"""Benchmark harness conventions.

Each ``benchmarks/<artifact>.py`` module exposes ``run() -> list[Row]``;
a Row is ``(name, us_per_call, derived)`` where ``us_per_call`` is the
measured wall time of the underlying measurement routine and ``derived``
is the headline result (the number the paper's table/figure reports).
"""

from __future__ import annotations

import time
from typing import Callable

Row = tuple[str, float, str]


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def emit(rows: list[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
