"""Beyond-paper: the multi-replica fleet as a consumer of measured profiles.

The fleet router (``repro.serve.fleet``) prices request placement with
the same machinery the single-engine path consumes — ``CellCost.step_s``
against each replica's own device profile, free-page headroom, and the
Little's-law inflight bound — so a heterogeneous TeslaV100 + tpu_v5e
fleet is scheduled by *measured* numbers, not replica count.  Every
verdict below is deterministic accounting (no timings gate anything):

* **N=1 oracle**: a one-replica fleet must reproduce the single paged
  engine token-for-token, request-for-request, on the same tick
  schedule — the fleet layer adds routing, never semantics;
* **heterogeneous correctness**: greedy outputs are schedule-independent,
  so the mixed fleet's streamed tokens must equal the oracle per request;
* **zero page leaks** across every replica after drain;
* **router contract**: no decision ever picks a replica whose predicted
  step cost exceeds the best candidate's by more than the router's own
  margin (audited from the decision log);
* **replay**: an identical second run produces a bit-identical decision
  log — fleet runs are replayable by construction.

Fleet slack / migration / preemption stats ride along as info metrics in
the ``repro.bench/v1`` artifact.
"""

from __future__ import annotations

import time

from repro.bench import Context, Metric, experiment, info


def _run_frontend(fleet_factory, work, cfg):
    """Stream a workload through a fresh fleet; returns tokens + stats."""
    from repro.serve.frontend import FleetFrontend
    fleet = fleet_factory()
    front = FleetFrontend(fleet)
    streamed: dict[int, list[int]] = {}
    t0 = time.perf_counter()
    for uid, (prompt, n_new) in enumerate(work):
        front.submit_blocking(prompt, n_new, uid=uid,
                              on_token=lambda u, t:
                              streamed.setdefault(u, []).append(t))
    front.run()
    dt = time.perf_counter() - t0
    fleet.check_invariants()
    return fleet, streamed, dt


@experiment(
    title="Profile-aware multi-replica serving fleet",
    section="§5.1+§6.2 applied",
    artifact="beyond-paper",
    devices=("tpu_v5e",),
    tags=("serve", "fleet", "routing", "littles-law", "profile", "tpu"),
    expected={
        "N=1 oracle": "a one-replica fleet reproduces the single paged "
                      "engine token-for-token on the same tick schedule",
        "Router contract": "no decision exceeds the best candidate's "
                           "predicted step cost by more than the margin",
        "Replay": "routing decisions replay bit-identically",
        "Accounting": "zero pages leaked across replicas after drain",
    })
def run(ctx: Context) -> list[Metric]:
    # lazy: keep registry.discover() jax-free (see tpu_roofline)
    import jax
    import numpy as np

    from repro import configs
    from repro.models import transformer as T
    from repro.models.config import ModelConfig
    from repro.profile import published_profile
    from repro.serve.engine import PagedServeEngine, Request
    from repro.serve.fleet import FleetEngine

    if ctx.quick:
        cfg = ModelConfig(name="micro", family="dense", num_layers=2,
                          d_model=32, d_ff=64, vocab_size=64, num_heads=2,
                          num_kv_heads=2, dtype="float32",
                          param_dtype="float32")
        n_req, max_slots, max_len = 5, 2, 24
    else:
        cfg = configs.get_smoke_config("granite-8b")
        n_req, max_slots, max_len = 8, 3, 48
    params = T.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(ctx.seed)
    work = []
    for _ in range(n_req):
        plen = int(rng.integers(3, max_len // 3))
        n_new = int(rng.integers(3, max_len // 3))
        work.append((rng.integers(cfg.vocab_size, size=plen)
                     .astype(np.int32), n_new))

    # single paged engine: the oracle token stream
    paged = PagedServeEngine(cfg, params, max_slots=max_slots,
                             max_len=max_len)
    for uid, (prompt, n_new) in enumerate(work):
        paged.submit(Request(uid, prompt, n_new))
    oracle = {r.uid: r.generated for r in paged.run_to_completion()}
    paged.alloc.check_invariants()

    # N=1 fleet on the same workload
    f1, s1, dt1 = _run_frontend(
        lambda: FleetEngine(cfg, params, max_slots=max_slots,
                            max_len=max_len, replicas=1),
        work, cfg)

    # heterogeneous fleet: measured TeslaV100 profile next to tpu_v5e
    profs = lambda: [published_profile("TeslaV100"),          # noqa: E731
                     published_profile("tpu_v5e")]
    f2, s2, dt2 = _run_frontend(
        lambda: FleetEngine(cfg, params, max_slots=max_slots,
                            max_len=max_len, profiles=profs()),
        work, cfg)
    f2b, _, _ = _run_frontend(
        lambda: FleetEngine(cfg, params, max_slots=max_slots,
                            max_len=max_len, profiles=profs()),
        work, cfg)

    st1, st2 = f1.stats(), f2.stats()
    gen_tokens = sum(len(v) for v in oracle.values())
    metrics = [
        # deterministic accounting -> real verdicts
        Metric("n1_tokens_identical_to_paged_oracle", s1 == oracle, True,
               cmp="eq",
               detail=f"{len(oracle)} requests, {gen_tokens} tokens, "
                      "request-for-request"),
        Metric("n1_tick_schedule_matches_oracle",
               f1.ticks == paged.steps, True, cmp="eq",
               detail=f"fleet {f1.ticks} ticks vs engine {paged.steps}"),
        Metric("hetero_tokens_identical_to_oracle", s2 == oracle, True,
               cmp="eq",
               detail="TeslaV100+tpu_v5e fleet, greedy outputs are "
                      "schedule-independent"),
        Metric("pages_leaked_across_replicas",
               st1["pages_leaked"] + st2["pages_leaked"], 0, cmp="eq"),
        Metric("router_margin_violations",
               len(f1.margin_violations()) + len(f2.margin_violations()),
               0, cmp="eq",
               detail=f"margin={f2.margin:.0%}, audited over "
                      f"{st1['decisions'] + st2['decisions']} decisions"),
        Metric("routing_replay_bit_identical",
               f2.decision_log() == f2b.decision_log(), True, cmp="eq",
               detail=f"{st2['decisions']} decisions, fixed seed "
                      f"{ctx.seed}"),
        # fleet behavior stats: info only
        info("fleet_max_slack_tokens", st2["max_slack_tokens"],
             unit="tokens", detail="max over replicas of per-request "
                                   "page slack"),
        info("fleet_migrations", st2["migrations"]),
        info("fleet_preemptions", st2["preemptions"]),
        info("fleet_peak_pages", st2["peak_pages"],
             detail="summed across replicas"),
        info("tokens_per_s_n1_fleet", round(gen_tokens / max(dt1, 1e-9)),
             unit="tok/s", us=dt1 * 1e6,
             detail="CPU interpret-mode; pair-run on one host"),
        info("tokens_per_s_hetero_fleet",
             round(gen_tokens / max(dt2, 1e-9)),
             unit="tok/s", us=dt2 * 1e6,
             detail="CPU interpret-mode; pair-run on one host"),
    ]
    for p in st2["per_replica"]:
        metrics.append(info(
            f"replica/{p['replica']}",
            f"finished={p['finished']} peak_pages={p['peak_pages']} "
            f"preemptions={p['preemptions']} page_len={p['page_len']}",
            detail=f"inflight_bound={p['inflight_bound']} "
                   f"spec={p['spec']}"))
    return metrics
