"""Paper Fig 18/19: Kepler 4-byte vs 8-byte shared-memory bank modes."""

from __future__ import annotations

from repro.bench import Context, Metric, experiment, info
from repro.core import bankconflict

STRIDES = list(range(2, 33, 2))


@experiment(
    title="Kepler dual bank modes: 8-byte wins on non-power-of-two strides",
    section="§6.2",
    artifact="Fig 18/19",
    devices=("GTX780",),
    tags=("shared", "bank-conflict"),
    expected={
        "Stride 2 in 4 B mode": "conflict-free (words w and w+32 share an "
                                "8-byte row, Fig 18)",
        "8 B mode advantage": "strictly fewer conflicts on the 11 "
                              "non-power-of-two even strides in 2..32",
    })
def run(ctx: Context) -> list[Metric]:
    metrics: list[Metric] = []
    for mode in (4, 8):
        ways = [bankconflict.conflict_ways(s, "kepler", mode)
                for s in STRIDES]
        lat = [int(round(bankconflict.latency_for_ways("GTX780", w)))
               for w in ways]
        metrics.append(info(
            f"latency_{mode}B_mode",
            " ".join(f"s{s}:{l}" for s, l in zip(STRIDES, lat)), unit="cyc"))
    metrics.append(Metric(
        "stride2_conflict_free_4B", bankconflict.conflict_ways(2, "kepler", 4),
        1, cmp="eq", detail="Fig 18: stride-2 is conflict-free in 4B mode"))
    wins = sum(
        bankconflict.conflict_ways(s, "kepler", 8) <
        bankconflict.conflict_ways(s, "kepler", 4) for s in STRIDES)
    metrics.append(Metric(
        "8B_mode_wins", wins, 11, cmp="eq",
        detail=f"of {len(STRIDES)} even strides; the non-power-of-two "
               "ones (paper §6.2)"))
    return metrics
