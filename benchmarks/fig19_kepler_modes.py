"""Paper Fig 18/19: Kepler 4-byte vs 8-byte shared-memory bank modes."""

from __future__ import annotations

from benchmarks.common import Row
from repro.core import bankconflict


def run() -> list[Row]:
    rows: list[Row] = []
    strides = list(range(2, 33, 2))
    for mode in (4, 8):
        ways = [bankconflict.conflict_ways(s, "kepler", mode)
                for s in strides]
        lat = [round(bankconflict.latency_for_ways("GTX780", w), 0)
               for w in ways]
        rows.append((f"fig19/kepler_{mode}B_mode", 0.0,
                     " ".join(f"s{s}:{int(l)}" for s, l in zip(strides, lat))))
    wins = sum(
        bankconflict.conflict_ways(s, "kepler", 8) <
        bankconflict.conflict_ways(s, "kepler", 4) for s in strides)
    rows.append(("fig19/8B_mode_advantage", 0.0,
                 f"8B strictly better on {wins}/{len(strides)} even strides "
                 "(non-power-of-two ones; paper §6.2)"))
    return rows
