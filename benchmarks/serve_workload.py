"""Beyond-paper: seeded traffic, SLO accounting and the capacity planner.

The workload tier (``repro.serve.workload`` / ``slo`` / ``planner``)
closes the dissect→deploy loop: seeded scenario traces (chat / rag /
agent / batch under poisson / bursty / diurnal arrivals) drive the fleet
front end, the SLO tracker folds the run into deterministic TTFT/TPOT
percentiles in tick units, and the Little's-law capacity planner is held
to a falsifiable prediction against the simulated fleet.  Every verdict
below is deterministic accounting (no timings gate anything):

* **trace determinism**: every scenario's trace is a pure function of
  its spec — two generations produce bit-identical fingerprints;
* **replay**: the same trace replayed through two fresh fleets yields a
  bit-identical SLO report AND routing decision log;
* **zero leaks, nothing dropped**: after every scenario drains, no
  replica holds a page and every request settled as finished;
* **planner vs simulation**: a fleet built with exactly the planner's
  replica count measures a mean residence within a stated bound of the
  predicted ``W``, and its measured p99 TTFT meets the SLO target the
  plan promised (Little's law ``L = λ·W`` holds exactly by construction
  in the report, so the prediction of W is the honest claim).
"""

from __future__ import annotations

import time

from repro.bench import Context, Metric, experiment, info

#: planner honesty bound: |predicted W - measured W| / measured W
RESIDENCE_REL_BOUND = 0.5


@experiment(
    title="Seeded workload traffic, SLO accounting, capacity planner",
    section="§5.1/§6.1 applied",
    artifact="beyond-paper",
    devices=("tpu_v5e",),
    tags=("serve", "workload", "slo", "planner", "littles-law", "tpu"),
    expected={
        "Trace determinism": "every scenario trace is a pure function of "
                             "its spec (bit-identical fingerprints)",
        "Replay": "identical runs give bit-identical SLO reports and "
                  "decision logs",
        "Accounting": "zero pages leaked and zero requests dropped "
                      "across all four scenarios",
        "Planner": "simulated residence within the stated bound of the "
                   "predicted W; measured p99 TTFT meets the SLO target",
    })
def run(ctx: Context) -> list[Metric]:
    # lazy: keep registry.discover() jax-free (see tpu_roofline)
    import jax

    from repro import configs
    from repro.models import transformer as T
    from repro.models.config import ModelConfig
    from repro.serve.fleet import FleetEngine
    from repro.serve.frontend import FleetFrontend
    from repro.serve.planner import SLOTarget, plan_for_trace
    from repro.serve.workload import (WorkloadSpec, generate_trace,
                                      replay_trace)

    if ctx.quick:
        cfg = ModelConfig(name="micro", family="dense", num_layers=2,
                          d_model=32, d_ff=64, vocab_size=64, num_heads=2,
                          num_kv_heads=2, dtype="float32",
                          param_dtype="float32")
        max_slots, max_len, horizon, rate = 2, 32, 12, 0.4
    else:
        cfg = configs.get_smoke_config("granite-8b")
        max_slots, max_len, horizon, rate = 3, 48, 20, 0.4
    params = T.init_params(cfg, jax.random.key(0))

    def replay_once(trace, replicas):
        fleet = FleetEngine(cfg, params, max_slots=max_slots,
                            max_len=max_len, replicas=replicas)
        front = FleetFrontend(fleet)
        replay_trace(front, trace)
        fleet.check_invariants()
        return front

    # one arrival process per scenario so all three are exercised
    mix = (("chat", "poisson"), ("rag", "bursty"),
           ("agent", "diurnal"), ("batch", "poisson"))
    fingerprints_identical = True
    leaked = dropped = total_requests = 0
    t0 = time.perf_counter()
    scenario_info = []
    chat_trace = batch_trace = None
    for scenario, arrival in mix:
        spec = WorkloadSpec(scenario=scenario, arrival=arrival, rate=rate,
                            horizon=horizon, seed=ctx.seed, max_len=max_len,
                            vocab_size=cfg.vocab_size)
        trace = generate_trace(spec)
        fingerprints_identical &= (generate_trace(spec).fingerprint()
                                   == trace.fingerprint())
        if scenario == "chat":
            chat_trace = trace
        elif scenario == "batch":
            batch_trace = trace
        front = replay_once(trace, replicas=2)
        rep = front.slo.report()
        st = front.fleet.stats()
        leaked += st["pages_leaked"]
        dropped += rep.requests - rep.outcome_counts["finished"]
        total_requests += rep.requests
        scenario_info.append(info(
            f"scenario/{scenario}-{arrival}",
            f"requests={rep.requests} ttft_p99={rep.ttft['p99']:g} "
            f"tpot_p99={rep.tpot['p99']:g} "
            f"concurrency={rep.mean_concurrency:.2f}",
            detail=f"{st['decisions']} decisions, "
                   f"{st['preemptions']} preemptions, "
                   f"peak_pages={st['peak_pages']}"))
    dt_scen = time.perf_counter() - t0

    # replay contract on the chat trace: two fresh fleets, one trace
    fa, fb = replay_once(chat_trace, 2), replay_once(chat_trace, 2)
    slo_identical = fa.slo.report().key() == fb.slo.report().key()
    log_identical = fa.fleet.decision_log() == fb.fleet.decision_log()

    # the planner's falsifiable claim: build the fleet it asked for and
    # measure what it predicted.  Batch (long outputs) is the steady
    # decode regime the W0 + M/M/1-wait model describes; the 0.7
    # utilization target keeps the wait term in its accurate range
    slo = SLOTarget(ttft_p99_ticks=32.0, max_utilization=0.7)
    plan = plan_for_trace(cfg, batch_trace, max_slots=max_slots,
                          max_len=max_len, slo=slo)
    front = replay_once(batch_trace, plan.replicas)
    measured = front.slo.report()
    rel_err = (abs(plan.predicted_residence_ticks
                   - measured.mean_residence_ticks)
               / max(measured.mean_residence_ticks, 1e-9))

    return [
        Metric("trace_fingerprints_bit_identical", fingerprints_identical,
               True, cmp="eq",
               detail=f"{len(mix)} scenario/arrival pairs, seed "
                      f"{ctx.seed}"),
        Metric("slo_report_replay_bit_identical", slo_identical, True,
               cmp="eq", detail="chat trace, two fresh 2-replica fleets"),
        Metric("decision_log_replay_bit_identical", log_identical, True,
               cmp="eq"),
        Metric("pages_leaked_across_scenarios", leaked, 0, cmp="eq",
               detail=f"{total_requests} requests over {len(mix)} "
                      "scenarios"),
        Metric("requests_dropped_across_scenarios", dropped, 0, cmp="eq",
               detail="every submission must settle as finished"),
        Metric("plan_feasible", plan.feasible, True, cmp="eq",
               detail=f"N={plan.replicas} at rho="
                      f"{plan.utilization:.2f} for lambda="
                      f"{plan.arrival_per_tick:.3f}/tick"),
        Metric("planner_residence_rel_error", round(rel_err, 4),
               RESIDENCE_REL_BOUND, cmp="le",
               detail=f"predicted W={plan.predicted_residence_ticks:.1f} "
                      f"vs measured "
                      f"{measured.mean_residence_ticks:.1f} ticks on the "
                      f"planned {plan.replicas}-replica fleet"),
        Metric("measured_ttft_p99_meets_slo", measured.ttft["p99"],
               slo.ttft_p99_ticks, cmp="le", unit="ticks",
               detail="the SLO the plan promised, checked by simulation"),
        info("planner_binding_constraint", plan.replica.binding,
             detail=f"C={plan.replica.concurrency} from slots="
                    f"{plan.replica.max_slots}, inflight_bound="
                    f"{plan.replica.inflight_bound}"),
        info("little_mean_concurrency",
             round(measured.mean_concurrency, 3),
             detail="sum(residence)/makespan = lambda*W, exact by "
                    "construction"),
        info("scenario_wall_ms", round(dt_scen * 1e3),
             unit="ms", us=dt_scen * 1e6,
             detail="CPU interpret-mode; four scenario replays"),
        *scenario_info,
    ]
