"""Paper Fig 12: global-memory throughput vs (#CTAs, CTA size, ILP) —
saturation curves from the Little's-law model."""

from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import devices, littles_law
from repro.core.littles_law import OccupancyPoint


def run() -> list[Row]:
    rows: list[Row] = []

    def curve(spec, cta_size, ilp):
        return [round(littles_law.global_throughput_gbps(
            spec, OccupancyPoint(n, cta_size, ilp)), 1)
            for n in (1, 2, 4, 8, 16, 32, 64, 128)]

    for name, spec in devices.GPU_SPECS.items():
        c, us = timed(curve, spec, 256, 1)
        rows.append((f"fig12/{name}_T256_ILP1", us,
                     str(c).replace(",", ";")))
        c, us = timed(curve, spec, 256, 4)
        rows.append((f"fig12/{name}_T256_ILP4", us,
                     str(c).replace(",", ";")))
    # paper claim: 560Ti relies on ILP the most (fewest allowed warps) —
    # evaluate at full occupancy, where the warp cap binds
    gain = {}
    for name, spec in devices.GPU_SPECS.items():
        pt1 = OccupancyPoint(spec.sms * 16, 256, 1)
        pt4 = OccupancyPoint(spec.sms * 16, 256, 4)
        gain[name] = (littles_law.global_throughput_gbps(spec, pt4) /
                      littles_law.global_throughput_gbps(spec, pt1))
    best = max(gain, key=gain.get)
    rows.append(("fig12/ilp_reliance", 0.0,
                 f"ILP4/ILP1 gains: " +
                 " ".join(f"{k}={v:.2f}x" for k, v in gain.items()) +
                 f" -> most ILP-reliant: {best}"))
    return rows
