"""Paper Fig 12: global-memory throughput vs (#CTAs, CTA size, ILP) —
saturation curves from the Little's-law model."""

from __future__ import annotations

from benchmarks.common import timed
from repro.bench import Context, Metric, experiment, info
from repro.core import devices, littles_law
from repro.core.littles_law import OccupancyPoint

CTA_COUNTS = (1, 2, 4, 8, 16, 32, 64, 128)


def _curve(spec, cta_size, ilp):
    return [round(littles_law.global_throughput_gbps(
        spec, OccupancyPoint(n, cta_size, ilp)), 1) for n in CTA_COUNTS]


def _ilp_gain(spec) -> float:
    pt1 = OccupancyPoint(spec.sms * 16, 256, 1)
    pt4 = OccupancyPoint(spec.sms * 16, 256, 4)
    return (littles_law.global_throughput_gbps(spec, pt4) /
            littles_law.global_throughput_gbps(spec, pt1))


@experiment(
    title="Throughput saturation vs occupancy and ILP",
    section="§5.1",
    artifact="Fig 12",
    devices=("GTX560Ti", "GTX780", "GTX980"),
    tags=("throughput", "littles-law"),
    expected={
        "Saturation": "every device reaches its Table 6 measured peak "
                      "at full occupancy with ILP4",
        "ILP reliance": "GTX560Ti gains the most from ILP (fewest "
                        "allowed warps per SM)",
    })
def run(ctx: Context) -> list[Metric]:
    spec = ctx.device.spec
    c1, us1 = timed(_curve, spec, 256, 1)
    c4, us4 = timed(_curve, spec, 256, 4)
    metrics = [
        info("curve_T256_ILP1", str(c1), unit="GB/s", us=us1),
        info("curve_T256_ILP4", str(c4), unit="GB/s", us=us4),
        Metric("saturated_peak_gbps", max(c4),
               round(spec.measured_peak_gbps, 2), cmp="close", tol=0.01,
               unit="GB/s", detail="ILP4 curve max vs Table 6 measured"),
        Metric("ilp4_gain", round(_ilp_gain(spec), 2), 1.0, cmp="ge",
               detail="ILP4/ILP1 at full occupancy"),
    ]
    if ctx.device.name == "GTX560Ti":
        # cross-device claim, evaluated from the shared analytic model
        gains = {n: _ilp_gain(s) for n, s in devices.GPU_SPECS.items()}
        most = max(gains, key=gains.get)
        metrics.append(Metric(
            "most_ilp_reliant", most, "GTX560Ti", cmp="eq",
            detail=" ".join(f"{k}={v:.2f}x" for k, v in gains.items())))
    return metrics
