"""Beyond-paper: mesh-sharded paged serving — the width-invariance oracle.

One fleet replica = one device slice: the paged KV pool's heads axis is
laid out over the mesh's ``"model"`` axis (``NamedSharding``), the paged
scatter/gather runs under ``shard_map``, and the cache operand is donated
with pinned ``out_shardings`` so the sharded update stays copy-free.  The
host-side allocator and page tables are untouched — sharding moves the
pool, never the books.

Every verdict is deterministic accounting (no timings gate anything):

* **mesh-1 oracle**: a 1-device-mesh engine equals the unsharded paged
  engine token-for-token on the same tick schedule;
* **width invariance**: 2/4/8-way host-device meshes
  (``XLA_FLAGS=--xla_force_host_platform_device_count``) are
  bit-identical to the 1-device mesh, including the 8-way GQA fallback;
* **zero page leaks** after drain on every width;
* **donation honored**: the previous cache's leaves are deleted after
  every step and no "donated buffer" warning is raised.

The per-shard Little's-law page pricing (thinner rows per partition)
rides along as info metrics.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from repro.bench import Context, Metric, experiment, info

# runs in a subprocess per width: XLA_FLAGS must precede jax init
_WIDTH_CODE = """
import json
import jax, numpy as np
from repro.launch.mesh import make_serve_mesh
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serve.engine import PagedServeEngine, Request

CFG = ModelConfig(name="micro4", family="dense", num_layers=2, d_model=32,
                  d_ff=64, vocab_size=64, num_heads=4, num_kv_heads=4,
                  dtype="float32", param_dtype="float32")
PARAMS = T.init_params(CFG, jax.random.key(0))
WORK = [(8, 6), (12, 4), (5, 9), (16, 3)]

def run(mesh):
    rng = np.random.default_rng(3)
    eng = PagedServeEngine(CFG, PARAMS, max_slots=3, max_len=32,
                           page_len=8, mesh=mesh)
    for uid, (plen, n) in enumerate(WORK):
        eng.submit(Request(uid, rng.integers(CFG.vocab_size, size=plen)
                           .astype(np.int32), n))
    fin = eng.run_to_completion()
    eng.check_invariants()
    return ({str(r.uid): [int(t) for t in r.generated] for r in fin},
            eng.steps, eng.shards, eng.alloc.allocated_pages)

base, steps0, _, leak0 = run(make_serve_mesh(1))
out = {"widths": {}, "equal": True, "schedule": True, "leaked": leak0}
for w in WIDTHS:
    got, steps, shards, leaked = run(make_serve_mesh(w))
    out["equal"] &= got == base
    out["schedule"] &= steps == steps0
    out["leaked"] += leaked
    out["widths"][str(w)] = {"shards": shards, "steps": steps}
print("RESULT " + json.dumps(out))
"""


def _src_path() -> str:
    # repro is a namespace package (__file__ is None): anchor on a module
    import repro.bench as _bench
    pkg = os.path.dirname(os.path.abspath(_bench.__file__))   # .../repro/bench
    return os.path.dirname(os.path.dirname(pkg))              # .../src


def _width_sweep(widths: tuple[int, ...]) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _src_path()
    code = f"WIDTHS = {widths!r}\n" + textwrap.dedent(_WIDTH_CODE)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    for line in r.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"width sweep failed:\n{r.stdout}\n{r.stderr[-2000:]}")


@experiment(
    title="Mesh-sharded paged KV cache",
    section="§5.1+§6.2 applied",
    artifact="beyond-paper",
    devices=("tpu_v5e",),
    tags=("serve", "paging", "sharding", "mesh", "shard-map", "tpu"),
    expected={
        "Mesh-1 oracle": "a 1-device-mesh replica equals the unsharded "
                         "paged engine token-for-token on the same ticks",
        "Width invariance": "2/4/8-way host-device meshes are "
                            "bit-identical to the 1-device mesh",
        "Donation": "the cache updates in place on the sharded path "
                    "(buffers consumed, no XLA donation warning)",
        "Accounting": "zero pages leaked after drain on every width",
    })
def run(ctx: Context) -> list[Metric]:
    # lazy: keep registry.discover() jax-free (see tpu_roofline)
    import warnings

    import jax
    import numpy as np

    from repro.launch.mesh import make_serve_mesh
    from repro.models import transformer as T
    from repro.models.config import ModelConfig
    from repro.serve import paging
    from repro.serve.engine import PagedServeEngine, Request

    cfg = ModelConfig(name="micro", family="dense", num_layers=2,
                      d_model=32, d_ff=64, vocab_size=64, num_heads=2,
                      num_kv_heads=2, dtype="float32",
                      param_dtype="float32")
    params = T.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(ctx.seed)
    n_req = 4 if ctx.quick else 6
    work = [(int(rng.integers(3, 12)), int(rng.integers(3, 9)))
            for _ in range(n_req)]

    def drive(mesh):
        rq = np.random.default_rng(ctx.seed + 1)
        eng = PagedServeEngine(cfg, params, max_slots=3, max_len=32,
                               page_len=8, mesh=mesh)
        for uid, (plen, n) in enumerate(work):
            eng.submit(Request(uid, rq.integers(cfg.vocab_size, size=plen)
                               .astype(np.int32), n))
        fin = eng.run_to_completion()
        eng.check_invariants()
        return ({r.uid: tuple(r.generated) for r in fin}, eng.steps,
                eng.alloc.allocated_pages)

    oracle, steps_u, leak_u = drive(None)
    mesh1, steps_1, leak_1 = drive(make_serve_mesh(1))

    # donation on the sharded path: buffers consumed, no XLA warning
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        eng = PagedServeEngine(cfg, params, max_slots=2, max_len=32,
                               page_len=4, mesh=make_serve_mesh(1))
        eng.submit(Request(0, np.arange(4, dtype=np.int32) + 1, 12))
        consumed = True
        for _ in range(6):
            before = jax.tree.leaves(eng.cache)
            eng.step()
            consumed &= all(leaf.is_deleted() for leaf in before)
    donation_warns = [str(w.message) for w in caught
                      if "donat" in str(w.message).lower()]

    widths = (2,) if ctx.quick else (2, 4, 8)
    sweep = _width_sweep(widths)
    shards_seen = {int(w): d["shards"] for w, d in sweep["widths"].items()}

    gen_tokens = sum(len(v) for v in oracle.values())
    metrics = [
        Metric("mesh1_tokens_identical_to_unsharded", mesh1 == oracle,
               True, cmp="eq",
               detail=f"{len(oracle)} requests, {gen_tokens} tokens"),
        Metric("mesh1_tick_schedule_matches", steps_1 == steps_u, True,
               cmp="eq", detail=f"mesh {steps_1} vs unsharded {steps_u}"),
        Metric("width_equality_bit_identical", bool(sweep["equal"]), True,
               cmp="eq",
               detail=f"widths {widths} vs 1-device mesh, forced "
                      "host-device mesh subprocess"),
        Metric("width_tick_schedules_match", bool(sweep["schedule"]), True,
               cmp="eq"),
        Metric("pages_leaked_all_widths",
               leak_u + leak_1 + int(sweep["leaked"]), 0, cmp="eq"),
        Metric("donation_cache_consumed_in_place", consumed, True,
               cmp="eq", detail="previous cache leaves deleted after "
                                "every sharded step"),
        Metric("donation_warnings", len(donation_warns), 0, cmp="eq",
               detail="; ".join(donation_warns) or "none raised"),
        info("gather_shards_by_width",
             " ".join(f"{w}->{s}" for w, s in sorted(shards_seen.items())),
             detail="8-way falls back to 1 when KV heads do not divide "
                    "(GQA replication fallback)"),
    ]
    if 8 in shards_seen:
        metrics.append(Metric("gqa_fallback_no_divergence",
                              shards_seen[8] == 1 and bool(sweep["equal"]),
                              True, cmp="eq",
                              detail="4 KV heads on an 8-way mesh "
                                     "replicate, tokens unchanged"))
    for s in (1, 2, 4, 8):
        terms = paging.page_len_rationale(cfg, expected_tokens=32, shards=s)
        best = min(terms, key=lambda t: (t.score, t.page_len))
        metrics.append(info(
            f"page_len_pricing/shards={s}",
            f"page_len={best.page_len} row_bytes={best.row_bytes} "
            f"gather_frac={best.gather_frac}",
            detail="per-partition bandwidth against 1/shards-thin rows"))
    return metrics
