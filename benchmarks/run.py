"""One function per paper table/figure. Prints ``name,us_per_call,derived``
CSV (see benchmarks/common.py)."""

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (fig4_5_classic_contradiction, fig8_tlb,
                            fig12_throughput, fig14_latency_spectrum,
                            fig19_kepler_modes, table5_cache_params,
                            table6_global_bw, table7_shared_bw,
                            table8_bank_conflict, tpu_roofline)
    from benchmarks.common import emit

    modules = [
        table5_cache_params,
        fig4_5_classic_contradiction,
        fig8_tlb,
        table6_global_bw,
        table7_shared_bw,
        table8_bank_conflict,
        fig12_throughput,
        fig14_latency_spectrum,
        fig19_kepler_modes,
        tpu_roofline,
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    t0 = time.time()
    for mod in modules:
        name = mod.__name__.split(".")[-1]
        if only and only not in name:
            continue
        emit(mod.run())
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
