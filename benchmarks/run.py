"""Legacy CSV entry point; delegates to the ``repro.bench`` registry.

Every module in this package self-registers via the ``@experiment``
decorator (discovered with ``repro.bench.discover()`` — no hand-maintained
module list).  Prefer the full CLI:

  PYTHONPATH=src python -m repro.bench run [--quick] [--strict] ...

This wrapper keeps the historical ``name,us_per_call,derived`` CSV
behavior: ``python benchmarks/run.py [substring]`` runs every experiment
whose name contains the substring and prints CSV rows to stdout.
"""

from __future__ import annotations

import os
import sys
import time

# make the `benchmarks` package importable when invoked as a script
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    from repro.bench import (discover, records_to_rows, registry,
                             run_experiments)
    from repro.bench.runner import RunOptions

    discover()
    only = sys.argv[1] if len(sys.argv) > 1 else None
    names = tuple(n for n in registry.REGISTRY
                  if only is None or only in n)
    if not names:
        print(f"no experiment matches {only!r}; registered: "
              f"{sorted(registry.REGISTRY)}", file=sys.stderr)
        raise SystemExit(2)
    print("name,us_per_call,derived")
    t0 = time.time()
    records = run_experiments(RunOptions(names=names))
    for name, us, derived in records_to_rows(records):
        print(f"{name},{us:.1f},{derived}")
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
