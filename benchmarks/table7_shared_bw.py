"""Paper Table 7 / Fig 15: shared-memory throughput per SM and the
required-vs-allowed warp analysis that explains Kepler's 37.5%."""

from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import devices, littles_law


def run() -> list[Row]:
    rows: list[Row] = []
    for name, spec in devices.GPU_SPECS.items():
        (pt, bw), us = timed(littles_law.best_occupancy, spec, "shared")
        warps = littles_law.active_warps_per_sm(spec, pt)
        rows.append((
            f"table7/{name}", us,
            f"W_SM={spec.shared_theoretical_gbps:.2f}GB/s model_peak={bw:.2f}"
            f"GB/s paper_meas={spec.measured_shared_peak_gbps}GB/s "
            f"best=({pt.cta_size}x{pt.num_ctas // spec.sms}ctas ILP{pt.ilp}"
            f"={warps:.0f}warps)"))
    spec = devices.GTX780
    required = (spec.shared_banks * spec.bank_bytes *
                spec.shared_base_latency) / (32 * 4)
    rows.append(("table7/kepler_warp_gap", 0.0,
                 f"required={required:.0f} warps vs allowed="
                 f"{spec.max_warps_per_sm} -> efficiency capped (paper: 37.5%)"))
    return rows
