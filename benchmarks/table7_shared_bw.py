"""Paper Table 7 / Fig 15: shared-memory throughput per SM and the
required-vs-allowed warp analysis that explains Kepler's 37.5%."""

from __future__ import annotations

from benchmarks.common import timed
from repro.bench import Context, Metric, experiment, info
from repro.core import devices, littles_law

WARP, WORD = 32, 4


@experiment(
    title="Shared-memory throughput and the Kepler warp gap",
    section="§6.1",
    artifact="Table 7",
    devices=("GTX560Ti", "GTX780", "GTX980"),
    tags=("throughput", "shared", "littles-law"),
    expected={
        "GTX560Ti measured W'_SM": "35.70 GB/s",
        "GTX780 measured W'_SM": "96.58 GB/s (37.5% of 257.5 GB/s — "
                                 "94 required warps vs 64 allowed)",
        "GTX980 measured W'_SM": "122.90 GB/s",
    })
def run(ctx: Context) -> list[Metric]:
    spec = ctx.device.spec
    (pt, bw), us = timed(littles_law.best_occupancy, spec, "shared")
    warps = littles_law.active_warps_per_sm(spec, pt)
    detail = (f"W_SM={spec.shared_theoretical_gbps:.2f}GB/s "
              f"best=({pt.cta_size}thr x{pt.num_ctas // spec.sms}ctas "
              f"ILP{pt.ilp}={warps:.0f}warps)")
    metrics: list[Metric] = []
    if spec.generation == "kepler":
        # Kepler's dual-mode banks serialize ILP: the model's peak is capped
        # *below* the paper's measurement; the warp-gap metric carries the
        # quantitative claim instead.
        metrics.append(Metric(
            "model_peak_gbps", round(bw, 2),
            round(spec.measured_shared_peak_gbps, 2), cmp="le",
            unit="GB/s", us=us, detail=detail))
        required = (spec.shared_banks * spec.bank_bytes *
                    spec.shared_base_latency) / (WARP * WORD)
        metrics += [
            Metric("required_warps", round(required), 94, cmp="eq",
                   detail=f"vs allowed={spec.max_warps_per_sm} -> "
                          "efficiency capped (paper: 37.5%)"),
            Metric("warp_gap_binds", required > spec.max_warps_per_sm, True,
                   cmp="eq"),
            Metric("measured_efficiency",
                   round(spec.measured_shared_peak_gbps /
                         spec.shared_theoretical_gbps, 3), 0.375,
                   cmp="close", tol=0.01,
                   detail="paper: Kepler reaches only 37.5% of W_SM"),
        ]
    else:
        metrics.append(Metric(
            "model_peak_gbps", round(bw, 2),
            round(spec.measured_shared_peak_gbps, 2), cmp="close", tol=0.01,
            unit="GB/s", us=us, detail=detail))
    metrics.append(info("theoretical_w_sm_gbps",
                        round(spec.shared_theoretical_gbps, 2), unit="GB/s"))
    return metrics
