"""Beyond-paper: the three-term TPU roofline for every dry-run cell.

Reads experiments/dryrun/<mesh>/*.json (produced by repro.launch.dryrun)
and prints the per-cell analytic terms; falls back to computing the
analytic model directly when no dry-run artifacts exist yet."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Row
from repro import configs
from repro.configs.shapes import SHAPES, cell_supported
from repro.core import costmodel
from repro.core.costmodel import ParallelismPlan


def _fmt(r: dict) -> str:
    return (f"dom={r['dominant']} compute={r['compute_s']*1e3:.1f}ms "
            f"memory={r['memory_s']*1e3:.1f}ms "
            f"coll={r['collective_s']*1e3:.1f}ms "
            f"roofline={r['roofline_fraction']:.1%} "
            f"useful={r['useful_ratio']:.2f}")


def run() -> list[Row]:
    rows: list[Row] = []
    files = sorted(glob.glob("experiments/dryrun/single/*__*.json"))
    seen = set()
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        if rec.get("tag", "baseline") != "baseline":
            continue
        key = (rec["arch"], rec["shape"])
        seen.add(key)
        rows.append((f"roofline/{rec['arch']}/{rec['shape']}", 0.0,
                     _fmt(rec["roofline"]) +
                     f" compiled={rec['compile_s']}s"))
    # analytic fallback for any cell the dry-run hasn't produced yet
    plan = ParallelismPlan(dp=16, tp=16)
    for arch in configs.list_archs():
        cfg = configs.get_config(arch)
        for shape in SHAPES.values():
            if not cell_supported(cfg, shape)[0]:
                continue
            if (arch, shape.name) in seen:
                continue
            c = costmodel.cell_cost(cfg, shape, plan)
            rows.append((f"roofline/{arch}/{shape.name}", 0.0,
                         _fmt(c.to_json()) + " (analytic-only)"))
    return rows
