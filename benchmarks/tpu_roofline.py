"""Beyond-paper: the three-term TPU roofline for every dry-run cell.

Reads experiments/dryrun/<mesh>/*.json (produced by repro.launch.dryrun)
and reports the per-cell analytic terms; falls back to computing the
analytic model directly when no dry-run artifacts exist yet."""

from __future__ import annotations

import glob
import json

from repro.bench import Context, Metric, experiment, info


def _fmt(r: dict) -> str:
    return (f"dom={r['dominant']} compute={r['compute_s']*1e3:.1f}ms "
            f"memory={r['memory_s']*1e3:.1f}ms "
            f"coll={r['collective_s']*1e3:.1f}ms "
            f"roofline={r['roofline_fraction']:.1%} "
            f"useful={r['useful_ratio']:.2f}")


def _cells(quick: bool):
    """(label, roofline dict, analytic?) for every supported cell."""
    # lazy: these pull in jax; importing them at module scope would make
    # every registry.discover() (all CLI paths, every pool worker) pay the
    # full jax import even when no TPU record is scheduled
    from repro import configs
    from repro.configs.shapes import SHAPES, cell_supported
    from repro.core import costmodel
    from repro.core.costmodel import ParallelismPlan

    out = []
    seen = set()
    for f in sorted(glob.glob("experiments/dryrun/single/*__*.json")):
        with open(f) as fh:
            rec = json.load(fh)
        if rec.get("tag", "baseline") != "baseline":
            continue
        seen.add((rec["arch"], rec["shape"]))
        out.append((f"{rec['arch']}/{rec['shape']}", rec["roofline"], False))
    plan = ParallelismPlan(dp=16, tp=16)
    archs = configs.list_archs()
    if quick:
        archs = archs[:2]
    for arch in archs:
        cfg = configs.get_config(arch)
        for shape in SHAPES.values():
            if not cell_supported(cfg, shape)[0]:
                continue
            if (arch, shape.name) in seen:
                continue
            c = costmodel.cell_cost(cfg, shape, plan)
            out.append((f"{arch}/{shape.name}", c.to_json(), True))
    return out


@experiment(
    title="Three-term roofline for every model x workload cell",
    section="beyond-paper",
    artifact="roofline",
    devices=("tpu_v5e",),
    tags=("tpu", "roofline", "costmodel"),
    expected={})
def run(ctx: Context) -> list[Metric]:
    cells = _cells(ctx.quick)
    metrics: list[Metric] = [
        info(f"cell/{label}", _fmt(r),
             detail="analytic-only" if analytic else "dry-run")
        for label, r, analytic in cells
    ]
    fracs = [r["roofline_fraction"] for _, r, _ in cells]
    metrics += [
        Metric("num_cells", len(cells), 1, cmp="ge",
               detail="supported model x workload cells"),
        Metric("max_roofline_fraction", round(max(fracs), 3), 1.0, cmp="le",
               tol=0.0, detail="no cell can beat the hardware roofline"),
        Metric("terms_nonnegative",
               all(min(r["compute_s"], r["memory_s"], r["collective_s"]) >= 0
                   for _, r, _ in cells), True, cmp="eq"),
    ]
    return metrics
