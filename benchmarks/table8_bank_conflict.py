"""Paper Table 8: shared-memory latency under k-way bank conflict + the
TPU strided-gather analogue (model + Pallas kernel correctness)."""

from __future__ import annotations

from benchmarks.common import timed
from repro.bench import Context, Metric, experiment
from repro.core import bankconflict
from repro.core.devices import BANK_CONFLICT_LATENCY

# Slopes of the linear fits to Table 8 (cycles per extra conflict way):
# Maxwell's flat ~2 cyc/way is the paper's headline hardware fix.
EXPECTED_SLOPE = {"GTX560Ti": 37.4, "GTX780": 14.1, "GTX980": 2.0}
TPU_STRIDES = (1, 2, 4, 8, 64, 128)


@experiment(
    title="Bank-conflict latency scaling and the Maxwell fix",
    section="§6.2",
    artifact="Table 8",
    devices=("GTX560Ti", "GTX780", "GTX980", "tpu_v5e"),
    tags=("shared", "bank-conflict", "tpu"),
    expected={
        "GTX560Ti 32-way latency": "1209 cycles (slope ~37 cyc/way)",
        "GTX780 32-way latency": "484 cycles (slope ~14 cyc/way)",
        "GTX980 32-way latency": "90 cycles (slope ~2 cyc/way — "
                                 "bank conflicts de-fanged)",
        "Maxwell headline": "32-way conflict costs less than 1.1x its "
                            "global L1 hit (82 cyc)",
    })
def run(ctx: Context) -> list[Metric]:
    if ctx.device.kind == "tpu":
        return _tpu_metrics(ctx)
    dev = ctx.device.name
    table = BANK_CONFLICT_LATENCY[dev]
    base, slope = bankconflict.linear_fit(dev)
    metrics = [
        Metric("latency_32way_cycles", bankconflict.latency_for_ways(dev, 32),
               table[32], cmp="close", tol=0.01, unit="cyc"),
        Metric("slope_cycles_per_way", round(slope, 1), EXPECTED_SLOPE[dev],
               cmp="close", tol=0.1,
               detail=f"base={base:.1f}cyc; "
                      f"lat(2..32way)={[table[w] for w in (2, 4, 8, 16, 32)]}"),
    ]
    if dev == "GTX980":
        metrics.append(Metric(
            "maxwell_32way_vs_l1_hit", table[32] / 82, 1.1, cmp="close",
            tol=0.05,
            detail="32-way conflict ~= a global L1 hit: the paper's "
                   "headline Maxwell finding"))
    return metrics


def _tpu_metrics(ctx: Context) -> list[Metric]:
    degrees = [bankconflict.tpu_conflict_degree(s) for s in TPU_STRIDES]
    metrics = [
        Metric("strided_conflict_degrees", str(degrees),
               str([1, 2, 4, 8, 64, 128]), cmp="eq",
               detail="rows the busiest lane serves, strides "
                      f"{list(TPU_STRIDES)}"),
    ]
    if not ctx.quick:
        import jax.numpy as jnp
        import numpy as np

        from repro.kernels import ops, ref

        def kernel_matches():
            x = jnp.arange(128 * 8, dtype=jnp.float32).reshape(128, 8)
            return all(
                np.array_equal(np.asarray(ops.strided_gather(x, s)),
                               np.asarray(ref.strided_ref(x, s)))
                for s in TPU_STRIDES)

        ok, us = timed(kernel_matches)
        metrics.append(Metric("strided_kernel_matches_oracle", ok, True,
                              cmp="eq", us=us,
                              detail="Pallas strided-gather vs jnp oracle"))
    return metrics
