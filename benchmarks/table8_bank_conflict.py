"""Paper Table 8: shared-memory latency under k-way bank conflict + the
TPU strided-gather analogue (model + Pallas kernel correctness)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timed
from repro.core import bankconflict
from repro.kernels import ops, ref


def run() -> list[Row]:
    rows: list[Row] = []
    for dev in ("GTX560Ti", "GTX780", "GTX980"):
        vals = {w: bankconflict.latency_for_ways(dev, w)
                for w in (2, 4, 8, 16, 32)}
        base, slope = bankconflict.linear_fit(dev)
        rows.append((
            f"table8/{dev}", 0.0,
            f"lat(2..32way)={list(vals.values())} slope={slope:.1f}cyc/way"
            .replace(",", ";")))
    rows.append(("table8/maxwell_flat", 0.0,
                 "maxwell 32-way=90cyc < its global L1-hit(82)+margin — "
                 "bank conflicts de-fanged (paper headline)"))

    # TPU analogue: conflict degree model + kernel check across strides
    def tpu_sweep():
        out = []
        x = jnp.arange(128 * 8, dtype=jnp.float32).reshape(128, 8)
        for s in (1, 2, 4, 8, 64, 128):
            y = ops.strided_gather(x, s)
            assert np.array_equal(np.asarray(y),
                                  np.asarray(ref.strided_ref(x, s)))
            out.append((s, bankconflict.tpu_conflict_degree(s)))
        return out

    degs, us = timed(tpu_sweep)
    rows.append(("table8/tpu_strided_degree", us,
                 " ".join(f"s{s}->{d}rows" for s, d in degs)))
    return rows
