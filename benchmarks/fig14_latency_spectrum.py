"""Paper Fig 14: the P1–P6 global-memory latency spectrum per device, from
one non-uniform-stride fine-grained chase (Fig 13b)."""

from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import devices, spectrum


def run() -> list[Row]:
    rows: list[Row] = []
    for dev in ("GTX560Ti", "GTX780", "GTX980"):
        for l1 in (True, False):
            sp, us = timed(spectrum.measure_spectrum,
                           lambda d=dev, e=l1: devices.make_hierarchy(
                               d, l1_enabled=e))
            label = "L1on" if l1 else "L1off"
            spec = " ".join(f"{k}={sp[k]:.0f}" for k in sorted(sp))
            rows.append((f"fig14/{dev}_{label}", us, spec))
    # the paper's cross-device claims
    k = spectrum.measure_spectrum(lambda: devices.make_hierarchy("GTX780"))
    m = spectrum.measure_spectrum(lambda: devices.make_hierarchy("GTX980"))
    rows.append(("fig14/maxwell_cold_miss_ratio", 0.0,
                 f"GTX980 P5 / GTX780 P5 = {m['P5'] / k['P5']:.2f} "
                 "(paper: ~2-3.5x)"))
    return rows
