"""Paper Fig 14: the P1–P6 global-memory latency spectrum per device, from
one non-uniform-stride fine-grained chase (Fig 13b)."""

from __future__ import annotations

from benchmarks.common import timed
from repro.bench import Context, Metric, experiment, info
from repro.core import devices, spectrum

# Paper-anchored spectrum (cycles), additive from the §5.2 calibration
# constants — derived via devices.expected_spectrum (e.g. Fermi P2 = P1 +
# 288, the L1-cached L1TLB-miss penalty; Maxwell's virtually-addressed L1
# makes P1=P2=P3).  tests/test_profile.py pins the derivation against the
# paper's literal numbers.


@experiment(
    title="P1–P6 latency spectrum from one fine-grained chase",
    section="§5.2",
    artifact="Fig 14",
    devices=("GTX560Ti", "GTX780", "GTX980"),
    tags=("latency", "spectrum", "pchase"),
    expected={
        "GTX560Ti P1..P5": "96 / 384 / 812 / 564 / 1280 cycles",
        "GTX780 P1..P6": "188 / 215 / 552 / 301 / 665 / 2665 cycles",
        "GTX980 P1..P6": "82 / 82 / 82 / 1052 / 1412 / 6412 cycles "
                         "(L1 on; virtually addressed)",
        "Maxwell cold miss": "GTX980 P5 is ~2-3.5x Kepler's",
    })
def run(ctx: Context) -> list[Metric]:
    dev = ctx.device.name
    sp, us = timed(spectrum.measure_spectrum,
                   lambda: devices.make_hierarchy(dev))
    expected = devices.expected_spectrum(dev)
    metrics = [
        Metric(f"{p}_cycles", round(sp[p]), exp_cyc, cmp="close", tol=0.02,
               unit="cyc", us=us if p == "P1" else 0.0)
        for p, exp_cyc in sorted(expected.items())
    ]
    if not ctx.quick:
        sp_off, us = timed(spectrum.measure_spectrum,
                           lambda: devices.make_hierarchy(dev,
                                                          l1_enabled=False))
        metrics.append(info(
            "spectrum_L1off",
            " ".join(f"{k}={sp_off[k]:.0f}" for k in sorted(sp_off)),
            unit="cyc", us=us))
    if dev == "GTX980" and not ctx.quick:
        k = spectrum.measure_spectrum(lambda: devices.make_hierarchy("GTX780"))
        metrics.append(Metric("cold_miss_ratio_vs_kepler",
                              round(sp["P5"] / k["P5"], 2), [2.0, 3.5],
                              cmp="range",
                              detail="paper: Maxwell's cold TLB miss is "
                                     "~2-3.5x Kepler's"))
    return metrics
