"""Beyond-paper: the fleet's chaos tier under a verified fault campaign.

``repro.serve.faults`` injects seeded faults — replica death mid-decode,
page-table corruption, latency-spike profile degradation — into the
deterministic fleet loop, and the fleet heals through its own machinery:
evacuation + ``_migrate`` re-homing for death, invariant-sweep detection
→ quarantine → readmit for corruption, ``decode_cell_cost`` re-pricing
for degradation.  Every verdict is deterministic accounting (the fleet
consumes no wall clock and exactly one seeded RNG stream):

* **stream integrity**: greedy outputs are schedule-independent, so every
  request that finishes — untouched, migrated, or re-queued — must stream
  byte-identically to the fault-free oracle run;
* **zero leaked pages** after replica death (evacuation is copy-free and
  closed: asserted at kill time, audited again after drain);
* **coverage**: the scripted schedule exercises ≥1 kill and ≥1
  corruption→quarantine→readmit cycle, and outside the campaign no
  invariant ever trips;
* **replay**: an identical seeded campaign replays bit-identically —
  merged decision+fault log, outcome classification, and streams;
* **classification**: every submitted uid ends in exactly one outcome
  class (completed / migrated / requeued / lost / cancelled) — nothing
  is silently dropped.
"""

from __future__ import annotations

import time

from repro.bench import Context, Metric, experiment, info


@experiment(
    title="Fleet chaos tier: seeded faults, replay-verified failover",
    section="§5.1+§6.2 applied",
    artifact="beyond-paper",
    devices=("tpu_v5e",),
    tags=("serve", "fleet", "faults", "chaos", "replay", "tpu"),
    expected={
        "Stream integrity": "every finished request streams byte-identically "
                            "to the fault-free oracle, through death and "
                            "quarantine",
        "Leak-free death": "replica death evacuates copy-free; zero pages "
                           "leaked fleet-wide after drain",
        "Coverage": "the campaign exercises >=1 kill and >=1 "
                    "corruption->quarantine->readmit cycle",
        "Replay": "an identical seeded campaign replays bit-identically "
                  "(log, outcomes, streams)",
        "Classification": "every submitted uid lands in exactly one "
                          "outcome class",
    })
def run(ctx: Context) -> list[Metric]:
    # lazy: keep registry.discover() jax-free (see tpu_roofline)
    import jax
    import numpy as np

    from repro import configs
    from repro.models import transformer as T
    from repro.models.config import ModelConfig
    from repro.serve.faults import (Fault, FaultInjector, OUTCOME_CLASSES,
                                    run_campaign)
    from repro.serve.fleet import FleetEngine

    if ctx.quick:
        cfg = ModelConfig(name="micro", family="dense", num_layers=2,
                          d_model=32, d_ff=64, vocab_size=64, num_heads=2,
                          num_kv_heads=2, dtype="float32",
                          param_dtype="float32")
        n_req, max_slots, max_len = 8, 3, 48
    else:
        cfg = configs.get_smoke_config("granite-8b")
        n_req, max_slots, max_len = 10, 3, 48
    params = T.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(ctx.seed)
    work = []
    for _ in range(n_req):
        plen = int(rng.integers(4, max_len // 4))
        n_new = int(rng.integers(4, max_len // 4))
        work.append((rng.integers(cfg.vocab_size, size=plen)
                     .astype(np.int32), n_new))

    def mk_fleet():
        return FleetEngine(cfg, params, max_slots=max_slots,
                           max_len=max_len, replicas=2, page_len=8,
                           prefill_chunk=16)

    # the fault-free oracle run (same fleet, same workload, no injector)
    t0 = time.perf_counter()
    base = run_campaign(mk_fleet(), work)
    dt_base = time.perf_counter() - t0

    # scripted campaign with guaranteed coverage: degrade early, corrupt
    # a loaded replica (every variant cycles through seeds via ctx.seed),
    # kill the most-loaded replica mid-flight, then recover
    sched = (Fault(2, "degrade", factor=4.0),
             Fault(4, "corrupt", variant=ctx.seed % 3),
             Fault(7, "kill"),
             Fault(10, "recover"))
    t0 = time.perf_counter()
    r1 = run_campaign(mk_fleet(), work, FaultInjector(sched))
    dt_fault = time.perf_counter() - t0
    r2 = run_campaign(mk_fleet(), work, FaultInjector(sched))

    # seeded campaign on top: replay is a pure function of the seed
    seeded = lambda: FaultInjector.campaign(                   # noqa: E731
        ctx.seed + 1, rate=0.10, horizon=80)
    c1 = run_campaign(mk_fleet(), work, seeded())
    c2 = run_campaign(mk_fleet(), work, seeded())

    finished = {u for u, c in r1.outcomes.items()
                if c in ("completed", "migrated", "requeued")}
    streams_ok = all(r1.streams[u] == base.streams[u] for u in finished)
    classified = (sorted(r1.outcomes) == list(range(n_req))
                  and all(c in OUTCOME_CLASSES
                          for c in r1.outcomes.values()))
    ev = r1.event_counts
    metrics = [
        Metric("finished_streams_identical_to_oracle", streams_ok, True,
               cmp="eq",
               detail=f"{len(finished)}/{n_req} finished through "
                      f"kill+corrupt+degrade, byte-for-byte"),
        Metric("pages_leaked_after_replica_death",
               r1.stats["pages_leaked"], 0, cmp="eq",
               detail=f"{r1.stats['deaths']} death(s), audited after "
                      "full drain"),
        Metric("campaign_exercised_kill_and_quarantine",
               ev.get("kill", 0) >= 1 and ev.get("quarantine", 0) >= 1
               and ev.get("readmit", 0) >= 1, True, cmp="eq",
               detail=f"events: {dict(sorted(ev.items()))}"),
        Metric("scripted_replay_bit_identical",
               r1.log == r2.log and r1.outcomes == r2.outcomes
               and r1.streams == r2.streams, True, cmp="eq",
               detail=f"{len(r1.log)} merged decision+fault log entries"),
        Metric("seeded_replay_bit_identical",
               c1.log == c2.log and c1.outcomes == c2.outcomes
               and c1.streams == c2.streams, True, cmp="eq",
               detail=f"seed {ctx.seed + 1}, rate 0.10, "
                      f"events {dict(sorted(c1.event_counts.items()))}"),
        Metric("every_uid_classified", classified, True, cmp="eq",
               detail=f"outcomes: {dict(sorted(r1.outcome_counts().items()))}"),
        Metric("router_margin_violations_under_faults",
               r1.stats["margin_violations"] + c1.stats["margin_violations"],
               0, cmp="eq",
               detail="the margin audit holds under any fault schedule"),
        # fault-campaign behavior: info only
        info("campaign_outcomes",
             " ".join(f"{k}={v}" for k, v in
                      sorted(r1.outcome_counts().items()))),
        info("campaign_fault_events",
             " ".join(f"{k}={v}" for k, v in sorted(ev.items()))),
        info("seeded_campaign_outcomes",
             " ".join(f"{k}={v}" for k, v in
                      sorted(c1.outcome_counts().items()))),
        info("ticks_fault_free", base.stats["ticks"], unit="ticks"),
        info("ticks_under_faults", r1.stats["ticks"], unit="ticks",
             detail="extra ticks = re-homed work re-earning its prefix"),
        info("campaign_wall_ms", round(dt_fault * 1e3, 1), unit="ms",
             us=dt_fault * 1e6,
             detail=f"fault-free run: {dt_base*1e3:.1f} ms; "
                    "CPU interpret-mode"),
    ]
    return metrics
