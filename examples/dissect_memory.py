"""The paper, end to end: dissect three GPU memory hierarchies with
fine-grained P-chase and print the recovered structures vs published truth.

  PYTHONPATH=src python examples/dissect_memory.py
"""

import sys

sys.path.insert(0, "src")

from repro.core import devices, inference, spectrum  # noqa: E402
from repro.core.pchase import cache_backend  # noqa: E402

MB = 1 << 20


def main():
    print("=" * 72)
    print("Fine-grained P-chase dissection (paper Table 5, Figs 7-11, 14)")
    print("=" * 72)

    cases = [
        ("Fermi GTX560Ti L1 data cache", devices.fermi_l1_data, 64 << 10),
        ("Kepler GTX780 texture L1", devices.kepler_texture_l1, 64 << 10),
        ("Kepler GTX780 read-only cache", devices.kepler_readonly, 64 << 10),
        ("Maxwell GTX980 unified L1", devices.maxwell_unified_l1, 128 << 10),
    ]
    for name, mk, nmax in cases:
        p = inference.dissect(cache_backend(mk), n_max=nmax, max_line=4096)
        print(f"\n{name}\n  -> {p.summary()}")

    print("\nL2 TLB (unequal sets, Fig 9):")
    be = cache_backend(devices.l2_tlb)
    c = inference.find_cache_size(be, n_max=512 * MB, n_min=8 * MB,
                                  stride_bytes=2 * MB, granularity=2 * MB)
    page = inference.find_line_size(be, c, stride_bytes=2 * MB,
                                    granularity=256 << 10, max_line=8 * MB)
    st = inference.recover_set_structure(be, c, 2 * MB, max_steps=80)
    print(f"  reach={c // MB}MB page={page // MB}MB ways={st.way_counts}")

    print("\nFermi L1 replacement probabilities (Fig 11):")
    rep = inference.detect_replacement(cache_backend(devices.fermi_l1_data),
                                       16 << 10, 128, passes=800)
    print(f"  LRU={rep.is_lru} probs(sorted)="
          f"{sorted(round(p, 3) for p in rep.way_probs)}"
          f"  (paper: 1/6, 1/2, 1/6, 1/6)")

    print("\nGlobal-memory latency spectrum (Fig 14):")
    for dev in ("GTX560Ti", "GTX780", "GTX980"):
        sp = spectrum.measure_spectrum(lambda d=dev: devices.make_hierarchy(d))
        line = "  ".join(f"{k}={sp[k]:.0f}" for k in sorted(sp))
        print(f"  {dev:9s} {line}")
    print("\n(GTX980 P1=P2=P3: Maxwell's virtually-addressed L1 bypasses "
          "the TLB — paper §5.2 finding 2)")


if __name__ == "__main__":
    main()
