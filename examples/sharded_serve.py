"""Mesh-sharded paged serving: one replica = one device slice.

Runs the SAME workload through an unsharded paged engine and a
mesh-sharded one, then proves the tokens and the tick schedule are
bit-identical — the width-invariance oracle, live.  The paged KV pool's
heads axis is laid out over the mesh's "model" axis; scatter/gather run
under shard_map with the cache donated in place; the allocator and page
tables never leave the host.

Run under a forced host-device mesh to see real sharding:

  XLA_FLAGS=--xla_force_host_platform_device_count=2 \\
      PYTHONPATH=src python examples/sharded_serve.py --quick
  PYTHONPATH=src python examples/sharded_serve.py      # 1-device mesh
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

import jax  # noqa: E402

from repro.launch.mesh import make_serve_mesh  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.serve import paging  # noqa: E402
from repro.serve.engine import (  # noqa: E402
    PagedServeEngine, Request,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny workload (the CI smoke)")
    ap.add_argument("--requests", type=int, default=None)
    args = ap.parse_args()

    # KV heads sized to divide any small mesh width
    cfg = ModelConfig(name="micro4", family="dense", num_layers=2,
                      d_model=32, d_ff=64, vocab_size=64, num_heads=4,
                      num_kv_heads=4, dtype="float32",
                      param_dtype="float32")
    n_req = args.requests or (4 if args.quick else 6)
    params = T.init_params(cfg, jax.random.key(0))

    mesh = make_serve_mesh()        # every visible device on ("model",)
    width = mesh.shape["model"]
    print(f"serve mesh: {dict(mesh.shape)} "
          f"({jax.device_count()} visible devices)")

    def drive(m):
        rng = np.random.default_rng(3)
        eng = PagedServeEngine(cfg, params, max_slots=3, max_len=32,
                               page_len=8, mesh=m)
        for uid in range(n_req):
            plen = int(rng.integers(3, 12))
            n_new = int(rng.integers(3, 9))
            eng.submit(Request(uid, rng.integers(cfg.vocab_size, size=plen)
                               .astype(np.int32), n_new))
        t0 = time.time()
        fin = eng.run_to_completion()
        dt = time.time() - t0
        eng.check_invariants()
        assert eng.alloc.allocated_pages == 0
        return {r.uid: tuple(r.generated) for r in fin}, eng, dt

    base, eng_u, dt_u = drive(None)
    got, eng_m, dt_m = drive(mesh)

    shards = eng_m.shards
    print(f"gather shards: {shards} "
          f"({'pool heads sharded over model' if shards > 1 else 'replicated'})"
          f"; page_len priced per shard -> "
          f"{paging.choose_page_len(cfg, expected_tokens=32, shards=shards)}")
    toks = sum(len(v) for v in base.values())
    print(f"unsharded: {toks} tokens, {eng_u.steps} ticks ({dt_u:.1f}s)")
    print(f"{width}-way mesh: {sum(len(v) for v in got.values())} tokens, "
          f"{eng_m.steps} ticks ({dt_m:.1f}s)")

    assert got == base, "sharded tokens diverged from unsharded"
    assert eng_m.steps == eng_u.steps, "tick schedule changed"
    print(f"ok: {width}-way mesh bit-identical to unsharded "
          f"({toks} tokens, {eng_u.steps} ticks), zero leaks")


if __name__ == "__main__":
    main()
