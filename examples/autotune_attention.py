"""Memory-model-driven flash-attention tuning: the paper's thesis (measure
the hierarchy, then optimize against the model) applied to our own kernel.

Picks (block_q, block_k) from the calibrated VMEM/HBM model, prints the
predicted HBM traffic per choice, and verifies the chosen kernel
configuration against the jnp oracle in interpret mode.

  PYTHONPATH=src python examples/autotune_attention.py
"""

import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.autotune import flash_attention_blocks  # noqa: E402
from repro.core.devices import TPU_V5E  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402


def main():
    print(f"target: {TPU_V5E.name}  VMEM={TPU_V5E.vmem_bytes >> 20}MiB  "
          f"HBM={TPU_V5E.hbm_bytes_per_s / 1e9:.0f}GB/s")
    print(f"{'seq':>8} {'d':>5} {'bq':>6} {'bk':>6} {'VMEM':>10} "
          f"{'HBM traffic':>14} note")
    for seq in (4096, 32768, 131072):
        for d in (64, 128):
            p = flash_attention_blocks(seq, seq, d)
            print(f"{seq:>8} {d:>5} {p.block_q:>6} {p.block_k:>6} "
                  f"{p.vmem_bytes >> 10:>9}K {p.hbm_bytes / 1e6:>12.1f}MB "
                  f"{p.note}")

    # verify the tuned configuration numerically (scaled-down seq on CPU)
    plan = flash_attention_blocks(32768, 32768, 64)
    bq = min(plan.block_q, 256)
    bk = min(plan.block_k, 256)
    q = jax.random.normal(jax.random.key(0), (4, 512, 64))
    out = ops.flash_attention(q, q, q, num_q_heads=4, num_kv_heads=4,
                              block_q=bq, block_k=bk)
    exp = ref.attention_ref(q, q, q, num_q_heads=4, num_kv_heads=4)
    err = float(jnp.abs(out - exp).max())
    print(f"\ntuned kernel vs oracle (bq={bq}, bk={bk}): max|err|={err:.2e}")
    assert err < 1e-4


if __name__ == "__main__":
    main()
