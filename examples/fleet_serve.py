"""Heterogeneous serving fleet with streamed tokens.

Two paged replicas — one priced by the committed tpu_v5e profile, one by
the *measured* TeslaV100 profile (Jia et al.'s Volta numbers, recovered
blind by this repo's pipeline) — behind the cost-model router, with
per-token streaming callbacks from the deterministic front end.  Note
the replicas derive DIFFERENT page lengths from their own profiles: the
dissect→deploy loop, per replica.

  PYTHONPATH=src python examples/fleet_serve.py            # granite smoke
  PYTHONPATH=src python examples/fleet_serve.py --quick    # micro (CI)
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

import jax  # noqa: E402

from repro import configs  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.serve.fleet import FleetEngine  # noqa: E402
from repro.serve.frontend import FleetFrontend  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="micro model + tiny workload (the CI smoke)")
    ap.add_argument("--requests", type=int, default=None)
    args = ap.parse_args()

    if args.quick:
        cfg = ModelConfig(name="micro", family="dense", num_layers=2,
                          d_model=32, d_ff=64, vocab_size=64, num_heads=2,
                          num_kv_heads=2, dtype="float32",
                          param_dtype="float32")
        n_req, slots, max_len = args.requests or 5, 2, 24
    else:
        cfg = configs.get_smoke_config("granite-8b")
        n_req, slots, max_len = args.requests or 8, 3, 48
    params = T.init_params(cfg, jax.random.key(0))

    fleet = FleetEngine(cfg, params, max_slots=slots, max_len=max_len,
                        profiles=["tpu_v5e", "TeslaV100"])
    for r in fleet.replicas:
        print(f"replica {r.name}: page_len={r.engine.page_len} "
              f"(derived from its own profile), "
              f"pool={r.engine.alloc.num_pages} pages, "
              f"Little's-law inflight bound={r.inflight_bound}")

    front = FleetFrontend(fleet)
    streams: dict[int, list[int]] = {}

    def on_token(uid, tok):
        streams.setdefault(uid, []).append(tok)
        if len(streams[uid]) <= 3:      # show the stream coming alive
            print(f"    uid {uid} token #{len(streams[uid])}: {tok}")

    rng = np.random.default_rng(0)
    t0 = time.time()
    for uid in range(n_req):
        plen = int(rng.integers(3, max_len // 3))
        n_new = int(rng.integers(3, max_len // 3))
        prompt = rng.integers(cfg.vocab_size, size=plen).astype(np.int32)
        # submit_blocking rides out Backpressure by ticking the loop
        front.submit_blocking(prompt, n_new, uid=uid, on_token=on_token)
    handles = front.run()
    dt = time.time() - t0

    fleet.check_invariants()
    s = fleet.stats()
    toks = sum(len(h.tokens) for h in handles)
    print(f"\nstreamed {toks} tokens from {s['finished']} requests in "
          f"{s['ticks']} fleet ticks ({dt:.1f}s)")
    print(f"router: {s['decisions']} decisions, {s['migrations']} "
          f"migrations, {s['preemptions']} preemptions; "
          f"pages leaked: {s['pages_leaked']}")
    for p in s["per_replica"]:
        print(f"  {p['replica']}: finished={p['finished']} "
              f"peak_pages={p['peak_pages']}")
    assert len(handles) == n_req and all(h.done for h in handles)
    assert s["pages_leaked"] == 0
    assert not fleet.margin_violations()
    print("ok: all streams complete, router honored its margin, "
          "zero leaks")


if __name__ == "__main__":
    main()
