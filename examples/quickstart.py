"""End-to-end training driver (deliverable b).

Default: a CPU-feasible ~10M-param dense LM trained a few hundred steps on
the synthetic bigram corpus — loss drops well below ln(V).  ``--preset
100m`` selects the ~100M-parameter config the assignment names (sized for
real accelerators; runs on CPU too, just slowly).

  PYTHONPATH=src python examples/quickstart.py
  PYTHONPATH=src python examples/quickstart.py --preset 100m --steps 300
"""

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro import configs  # noqa: E402
from repro.data.pipeline import SyntheticLM  # noqa: E402
from repro.optim import AdamWConfig, cosine_schedule  # noqa: E402
from repro.train.fault import StepWatchdog, run_training  # noqa: E402
from repro.train.loop import init_state, make_train_step  # noqa: E402

PRESETS = {
    # ~10M params: runs a few hundred steps in minutes on CPU
    "10m": dict(num_layers=4, d_model=256, d_ff=1024, vocab_size=2048,
                num_heads=8, num_kv_heads=4, head_dim=32),
    # ~100M params: the assignment's end-to-end scale
    "100m": dict(num_layers=12, d_model=768, d_ff=3072, vocab_size=8192,
                 num_heads=12, num_kv_heads=4, head_dim=64),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="10m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    base = configs.get_config("granite-8b")   # llama-style block layout
    cfg = dataclasses.replace(base, dtype="float32", param_dtype="float32",
                              **PRESETS[args.preset])
    opt = AdamWConfig(lr=args.lr)
    state = init_state(cfg, opt, jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"preset={args.preset}: {n/1e6:.1f}M params, "
          f"{args.steps} steps × {args.batch}×{args.seq} tokens")

    lr_fn = cosine_schedule(args.lr, warmup=args.steps // 10,
                            total=args.steps)
    step = jax.jit(make_train_step(cfg, opt, lr_fn=lr_fn), donate_argnums=0)
    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=1)

    def data_fn(s):
        b = data.batch(s)
        return {"tokens": jnp.asarray(b["tokens"]),
                "labels": jnp.asarray(b["labels"])}

    wd = StepWatchdog()
    hist = []

    def log(s, m):
        hist.append(float(m["ce"]))
        if (s + 1) % 20 == 0:
            print(f"  step {s+1:4d}  ce={hist[-1]:.4f}  "
                  f"({args.batch*args.seq/max(wd.last_duration,1e-9):,.0f} tok/s)")

    t0 = time.time()
    run_training(state, step, data_fn, num_steps=args.steps, watchdog=wd,
                 on_metrics=log)
    import math
    print(f"done in {time.time()-t0:.0f}s: ce {hist[0]:.3f} -> {hist[-1]:.3f} "
          f"(uniform would be {math.log(cfg.vocab_size):.3f})")
    assert hist[-1] < hist[0] * 0.8, "loss should drop"


if __name__ == "__main__":
    main()
