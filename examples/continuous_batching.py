"""Continuous-batching serving: 8 mixed-length requests through 3 cache
slots — slots recycle as requests finish, every decode tick is batched.

  PYTHONPATH=src python examples/continuous_batching.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import configs  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.serve.engine import Request, ServeEngine  # noqa: E402


def main():
    cfg = configs.get_smoke_config("granite-8b")
    params = T.init_params(cfg, jax.random.key(0))
    engine = ServeEngine(cfg, params, max_slots=3, max_len=64)

    rng = np.random.default_rng(0)
    for uid in range(8):
        plen = int(rng.integers(4, 20))
        n_new = int(rng.integers(3, 12))
        engine.submit(Request(
            uid, rng.integers(cfg.vocab_size, size=plen).astype(np.int32),
            n_new))
    print(f"8 requests queued into {engine.max_slots} slots "
          f"(prompt 4-19, gen 3-11 tokens)")

    t0 = time.time()
    finished = engine.run_to_completion()
    dt = time.time() - t0
    s = engine.stats()
    print(f"finished {s['finished']} requests in {s['steps']} engine ticks "
          f"({dt:.1f}s): {s['decoded_tokens']} tokens, "
          f"slot occupancy {s['avg_batch_occupancy']:.0%}")
    for r in finished[:3]:
        print(f"  req {r.uid}: prompt[{len(r.prompt)}] -> {r.generated}")
    assert len(finished) == 8


if __name__ == "__main__":
    main()
