"""Batched serving demo: prefill a request batch, decode with a KV cache,
report prefill/decode throughput (deliverable b, serving flavor).

  PYTHONPATH=src python examples/serve_batch.py --arch granite-8b
  PYTHONPATH=src python examples/serve_batch.py --arch mamba2-1.3b --gen 64
"""

import sys

sys.path.insert(0, "src")

from repro.launch import serve  # noqa: E402

if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--arch" not in argv:
        argv = ["--arch", "granite-8b"] + argv
    if "--smoke" not in argv:
        argv.append("--smoke")
    serve.main(argv)
