"""Dissect-on-start: blind-profile a GPU, then serve priced by it.

The whole dissect→deploy loop in one process: the batched jax engine
recovers GTX980's cache structures from scratch (no published numbers,
no committed artifact — the trace cache is bypassed to prove it), the
fresh in-memory profile binds a fleet replica through the
``resolve_spec()`` seam, and the replica derives its page length from
the structures it just measured.  Startup dissection is sub-second
warm, which is the point: profiling is cheap enough to run every boot.

  PYTHONPATH=src python examples/dissect_serve.py            # granite smoke
  PYTHONPATH=src python examples/dissect_serve.py --quick    # micro (CI)
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

import jax  # noqa: E402

from repro import configs  # noqa: E402
from repro.core import tracecache  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.profile.pipeline import dissect_device  # noqa: E402
from repro.serve.fleet import FleetEngine  # noqa: E402
from repro.serve.frontend import FleetFrontend  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="micro model + tiny workload (the CI smoke)")
    ap.add_argument("--device", default="GTX980")
    args = ap.parse_args()

    t0 = time.time()
    with tracecache.disabled():      # force real simulation, not replay
        prof = dissect_device(args.device, engine="jax")
    dt = time.time() - t0
    measured = sorted(n for n, c in prof.caches.items()
                      if c.provenance == "measured")
    print(f"dissected {prof.device} in {dt:.2f}s wall "
          f"(engine={prof.engine}, stage total "
          f"{prof.timings['total']:.3f}s)")
    for name in measured:
        c = prof.caches[name]
        print(f"  {name}: C={c.size_bytes}B b={c.line_bytes}B "
              f"sets={c.num_sets} assoc={c.assoc:g} "
              f"[{prof.timings.get(name, 0.0):.3f}s]")
    assert measured, "blind search recovered no structures"

    if args.quick:
        cfg = ModelConfig(name="micro", family="dense", num_layers=2,
                          d_model=32, d_ff=64, vocab_size=64, num_heads=2,
                          num_kv_heads=2, dtype="float32",
                          param_dtype="float32")
        n_req, slots, max_len = 4, 2, 24
    else:
        cfg = configs.get_smoke_config("granite-8b")
        n_req, slots, max_len = 6, 3, 48
    params = T.init_params(cfg, jax.random.key(0))

    # the DeviceProfile object itself binds the replica — no artifact on
    # disk, no registry lookup; resolve_spec() prices from what was just
    # measured
    fleet = FleetEngine(cfg, params, max_slots=slots, max_len=max_len,
                        profiles=[prof])
    r = fleet.replicas[0]
    print(f"replica {r.name}: page_len={r.engine.page_len} "
          f"(derived from the fresh profile), "
          f"pool={r.engine.alloc.num_pages} pages")

    front = FleetFrontend(fleet)
    rng = np.random.default_rng(0)
    for uid in range(n_req):
        plen = int(rng.integers(3, max_len // 3))
        n_new = int(rng.integers(3, max_len // 3))
        prompt = rng.integers(cfg.vocab_size, size=plen).astype(np.int32)
        front.submit_blocking(prompt, n_new, uid=uid)
    handles = front.run()

    fleet.check_invariants()
    s = fleet.stats()
    toks = sum(len(h.tokens) for h in handles)
    print(f"served {toks} tokens from {s['finished']} requests on the "
          f"freshly-dissected replica; pages leaked: {s['pages_leaked']}")
    assert len(handles) == n_req and all(h.done for h in handles)
    assert s["pages_leaked"] == 0
    print("ok: dissect-on-start bound the fleet to measured structures")


if __name__ == "__main__":
    main()
