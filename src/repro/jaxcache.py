"""Persistent JAX/XLA compilation cache, shared by tests, CI and the bench
CLI.

Most of the tier-1 suite's wall time is XLA compiling the same model
graphs over and over; with a persistent cache a warm run skips nearly all
of it.  Enabling is semantics-free — only compile time changes — and
opt-out via ``REPRO_NO_JAX_CACHE=1``.  The default cache directory is
repo-local (``.cache/jax`` next to this package's repo root, overridable
with ``JAX_COMPILATION_CACHE_DIR``) so nothing outside the workspace is
touched and a container rebuild starts cold.
"""

from __future__ import annotations

import os


def workspace_cache_dir() -> str:
    """Repo-local root for all persistent accelerator caches."""
    repo_root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    return os.path.join(repo_root, ".cache")


def default_dir() -> str:
    env = os.environ.get("JAX_COMPILATION_CACHE_DIR")
    if env:
        return env
    return os.path.join(workspace_cache_dir(), "jax")


def enable_env(cache_dir: str | None = None) -> str | None:
    """Arrange the cache via ``JAX_*`` environment variables only.

    Unlike :func:`enable` this never imports jax itself — callers on paths
    where jax may not be needed at all (the bench CLI, pool workers) use
    this so the cache is active if and when jax loads lazily.
    """
    if os.environ.get("REPRO_NO_JAX_CACHE"):
        return None
    cache_dir = cache_dir or default_dir()
    os.environ.setdefault("JAX_COMPILATION_CACHE_DIR", cache_dir)
    os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0")
    os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_ENTRY_SIZE_BYTES", "-1")
    os.environ.setdefault("JAX_PERSISTENT_CACHE_ENABLE_XLA_CACHES", "all")
    return cache_dir


def enable(cache_dir: str | None = None) -> str | None:
    """Point JAX's persistent compilation cache at ``cache_dir``.

    Returns the directory in use, or None when disabled/unavailable.
    """
    if os.environ.get("REPRO_NO_JAX_CACHE"):
        return None
    import jax
    cache_dir = cache_dir or default_dir()
    try:
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        # cache every computation: on CPU even small compiles add up across
        # a 140-test suite, and the cache is size-bounded by the workspace
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        jax.config.update("jax_persistent_cache_enable_xla_caches", "all")
    except Exception:
        return None                      # older jax: silently run uncached
    return cache_dir
