"""CLI for the dissection harness.

  python -m repro.bench list   [--device D] [--tag T] [--section S]
  python -m repro.bench run    [filters] [--quick] [--strict] [--out F]
                               [--report F] [--no-csv]
  python -m repro.bench report [ARTIFACT] [-o F]
  python -m repro.bench docs   [-o docs/experiments.md] [--check]

Run from the repo root (the ``benchmarks`` package must be importable);
``benchmarks/run.py`` remains as a thin legacy wrapper around ``run``.
"""

from __future__ import annotations

import argparse
import os
import sys

from repro.bench import registry, report, result, runner
from repro.core import tracecache

DEFAULT_ARTIFACT = "experiments/bench/latest.json"
DEFAULT_DOC = "docs/experiments.md"
DEFAULT_JOBS = max(1, min(os.cpu_count() or 1, 8))


def _add_filters(p: argparse.ArgumentParser) -> None:
    p.add_argument("--device", help="only this registered device")
    p.add_argument("--tag", help="only experiments carrying this tag")
    p.add_argument("--section", help="substring of the paper section, e.g. 4.4")
    p.add_argument("--only", action="append", default=[],
                   metavar="NAME", help="experiment name (repeatable)")


def cmd_list(args: argparse.Namespace) -> int:
    exps = registry.select(device=args.device, tag=args.tag,
                           section=args.section, names=args.only or None)
    print(f"{len(exps)} experiments "
          f"({len(registry.REGISTRY)} registered):")
    for e in exps:
        print(f"  {e.name:28s} {e.artifact:12s} {e.section:10s} "
              f"devices={','.join(e.devices)} tags={','.join(e.tags) or '-'}")
    return 0


def cmd_run(args: argparse.Namespace) -> int:
    cache_root = None if args.no_trace_cache else args.trace_cache
    tracecache.configure(cache_root)
    opts = runner.RunOptions(device=args.device, tag=args.tag,
                             section=args.section, names=tuple(args.only),
                             quick=args.quick, seed=args.seed,
                             jobs=max(1, args.jobs),
                             trace_cache_root=cache_root)
    records = runner.run_experiments(
        opts, progress=lambda s: print(f"# running {s}", file=sys.stderr))
    if not records:
        print("no experiments matched the filters", file=sys.stderr)
        return 2
    if not args.no_csv:
        print("name,us_per_call,derived")
        for name, us, derived in runner.records_to_rows(records):
            print(f"{name},{us:.1f},{derived}")
    payload = result.write_artifact(
        records, args.out,
        extra={"quick": args.quick, "filters": {
            "device": args.device, "tag": args.tag,
            "section": args.section, "only": args.only}})
    if args.report:
        with open(args.report, "w") as fh:
            fh.write(report.render_report(records))
        print(f"# report -> {args.report}", file=sys.stderr)
    s = payload["summary"]
    print(f"# artifact -> {args.out}: {s['PASS']} PASS, "
          f"{s['DEVIATION']} DEVIATION, {s['ERROR']} ERROR, "
          f"{s['INFO']} info-only", file=sys.stderr)
    bad = s["DEVIATION"] + s["ERROR"]
    if bad and args.strict:
        for r in records:
            if r.verdict in (result.DEVIATION, result.ERROR):
                why = (r.error.strip().splitlines()[-1] if r.error else
                       "; ".join(f"{m.name}={m.measured} vs {m.expected}"
                                 for m in r.deviations))
                print(f"# {r.verdict}: {r.experiment} × {r.device}: {why}",
                      file=sys.stderr)
        return 1
    return 0


def cmd_report(args: argparse.Namespace) -> int:
    records = result.load_artifact(args.artifact)
    text = report.render_report(records)
    if args.output:
        with open(args.output, "w") as fh:
            fh.write(text)
        print(f"wrote {args.output}", file=sys.stderr)
    else:
        print(text, end="")
    return 0


def cmd_docs(args: argparse.Namespace) -> int:
    text = report.experiments_doc()
    if args.check:
        try:
            with open(args.output) as fh:
                on_disk = fh.read()
        except FileNotFoundError:
            on_disk = ""
        if on_disk != text:
            print(f"{args.output} is stale; regenerate with "
                  "`python -m repro.bench docs`", file=sys.stderr)
            return 1
        print(f"{args.output} is up to date", file=sys.stderr)
        return 0
    import os
    os.makedirs(os.path.dirname(args.output) or ".", exist_ok=True)
    with open(args.output, "w") as fh:
        fh.write(text)
    print(f"wrote {args.output}", file=sys.stderr)
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.bench",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("list", help="list registered experiments")
    _add_filters(p)
    p.set_defaults(fn=cmd_list)

    p = sub.add_parser("run", help="run experiments, write JSON artifact")
    _add_filters(p)
    p.add_argument("--quick", action="store_true",
                   help="cheap CI subset of each experiment")
    p.add_argument("--strict", action="store_true",
                   help="exit 1 on any DEVIATION/ERROR verdict")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", default=DEFAULT_ARTIFACT,
                   help=f"JSON artifact path (default {DEFAULT_ARTIFACT})")
    p.add_argument("--report", metavar="FILE",
                   help="also write the Markdown verdict report")
    p.add_argument("--no-csv", action="store_true",
                   help="suppress the legacy CSV rows on stdout")
    p.add_argument("--jobs", type=int, default=DEFAULT_JOBS, metavar="N",
                   help="experiment×device records run across N processes "
                        f"(default {DEFAULT_JOBS} on this host; 1 = serial)")
    p.add_argument("--trace-cache", default=tracecache.DEFAULT_ROOT,
                   metavar="DIR",
                   help="simulated-trace cache root (default "
                        f"{tracecache.DEFAULT_ROOT})")
    p.add_argument("--no-trace-cache", action="store_true",
                   help="always re-simulate; neither read nor write traces")
    p.set_defaults(fn=cmd_run)

    p = sub.add_parser("report", help="render Markdown from a JSON artifact")
    p.add_argument("artifact", nargs="?", default=DEFAULT_ARTIFACT)
    p.add_argument("-o", "--output", help="write to file instead of stdout")
    p.set_defaults(fn=cmd_report)

    p = sub.add_parser("docs", help="(re)generate docs/experiments.md")
    p.add_argument("-o", "--output", default=DEFAULT_DOC)
    p.add_argument("--check", action="store_true",
                   help="exit 1 if the file on disk is stale")
    p.set_defaults(fn=cmd_docs)

    args = ap.parse_args(argv)
    try:
        from repro import jaxcache
        jaxcache.enable_env()    # compile-once across runs for TPU records
        registry.discover()
        return args.fn(args)
    except (KeyError, FileNotFoundError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
