"""CLI for the dissection harness.

  python -m repro.bench list   [--device D] [--tag T] [--section S]
  python -m repro.bench run    [filters] [--quick] [--strict] [--out F]
                               [--report F] [--no-csv]
  python -m repro.bench report [ARTIFACT] [-o F]
  python -m repro.bench docs   [--check] [--only TARGET] [-o FILE]
  python -m repro.bench profile dissect DEVICE [--quick] [--engine E] [--out F]
  python -m repro.bench profile show     DEVICE|PATH
  python -m repro.bench profile diff     DEVICE|PATH [--fresh]
  python -m repro.bench profile validate [PATH] [--root DIR]

``docs`` (re)generates every generated documentation file —
``docs/experiments.md`` from the experiment registry, ``docs/serving.md``
from the serving layer's own constants, ``docs/profiles.md`` from the
committed profile artifacts, and ``docs/cli.md`` from the argparse
definitions themselves — and ``--check`` fails if any is stale (the
ci.sh docs-freshness stage).

Run from the repo root (the ``benchmarks`` package must be importable);
``benchmarks/run.py`` remains as a thin legacy wrapper around ``run``.
"""

from __future__ import annotations

import argparse
import os
import sys

from repro.bench import registry, report, result, runner
from repro.core import tracecache

DEFAULT_ARTIFACT = "experiments/bench/latest.json"
DEFAULT_DOC = "docs/experiments.md"
DEFAULT_JOBS = max(1, min(os.cpu_count() or 1, 8))


def _add_filters(p: argparse.ArgumentParser) -> None:
    p.add_argument("--device", help="only this registered device")
    p.add_argument("--tag", help="only experiments carrying this tag")
    p.add_argument("--section", help="substring of the paper section, e.g. 4.4")
    p.add_argument("--only", action="append", default=[],
                   metavar="NAME", help="experiment name (repeatable)")


def cmd_list(args: argparse.Namespace) -> int:
    exps = registry.select(device=args.device, tag=args.tag,
                           section=args.section, names=args.only or None)
    print(f"{len(exps)} experiments "
          f"({len(registry.REGISTRY)} registered):")
    for e in exps:
        print(f"  {e.name:28s} {e.artifact:12s} {e.section:10s} "
              f"devices={','.join(e.devices)} tags={','.join(e.tags) or '-'}")
    return 0


def cmd_run(args: argparse.Namespace) -> int:
    cache_root = None if args.no_trace_cache else args.trace_cache
    tracecache.configure(cache_root)
    opts = runner.RunOptions(device=args.device, tag=args.tag,
                             section=args.section, names=tuple(args.only),
                             quick=args.quick, seed=args.seed,
                             jobs=max(1, args.jobs),
                             trace_cache_root=cache_root)
    records = runner.run_experiments(
        opts, progress=lambda s: print(f"# running {s}", file=sys.stderr))
    if not records:
        print("no experiments matched the filters", file=sys.stderr)
        return 2
    if not args.no_csv:
        print("name,us_per_call,derived")
        for name, us, derived in runner.records_to_rows(records):
            print(f"{name},{us:.1f},{derived}")
    payload = result.write_artifact(
        records, args.out,
        extra={"quick": args.quick, "filters": {
            "device": args.device, "tag": args.tag,
            "section": args.section, "only": args.only}})
    if args.report:
        with open(args.report, "w") as fh:
            fh.write(report.render_report(records))
        print(f"# report -> {args.report}", file=sys.stderr)
    s = payload["summary"]
    print(f"# artifact -> {args.out}: {s['PASS']} PASS, "
          f"{s['DEVIATION']} DEVIATION, {s['ERROR']} ERROR, "
          f"{s['INFO']} info-only", file=sys.stderr)
    bad = s["DEVIATION"] + s["ERROR"]
    if bad and args.strict:
        for r in records:
            if r.verdict in (result.DEVIATION, result.ERROR):
                why = (r.error.strip().splitlines()[-1] if r.error else
                       "; ".join(f"{m.name}={m.measured} vs {m.expected}"
                                 for m in r.deviations))
                print(f"# {r.verdict}: {r.experiment} × {r.device}: {why}",
                      file=sys.stderr)
        return 1
    return 0


def cmd_report(args: argparse.Namespace) -> int:
    records = result.load_artifact(args.artifact)
    text = report.render_report(records)
    if args.output:
        with open(args.output, "w") as fh:
            fh.write(text)
        print(f"wrote {args.output}", file=sys.stderr)
    else:
        print(text, end="")
    return 0


def _load_or_dissect(target: str, fresh: bool, quick: bool, seed: int):
    """Resolve a device name / artifact path into a DeviceProfile."""
    from repro.profile import dissect_device, load_profile, path_for
    if target.endswith(".json"):
        if not fresh:
            return load_profile(target)
        # --fresh on a path: re-dissect the device the artifact names
        target = load_profile(target).device
    if not fresh and os.path.exists(path_for(target)):
        return load_profile(target)
    return dissect_device(target, quick=quick, seed=seed)


def cmd_profile(args: argparse.Namespace) -> int:
    from repro import profile as P
    if args.action != "validate" and not args.target:
        raise ValueError(f"profile {args.action} requires a DEVICE or PATH")
    if args.action == "dissect":
        tracecache.configure(tracecache.DEFAULT_ROOT)
        prof = P.dissect_device(args.target, quick=args.quick,
                                seed=args.seed, engine=args.engine)
        path = P.save_profile(prof, args.out)
        print(f"# profile -> {path}", file=sys.stderr)
        print(prof.summary())
        return 0
    if args.action == "show":
        tracecache.configure(tracecache.DEFAULT_ROOT)
        prof = _load_or_dissect(args.target, False, args.quick, args.seed)
        print(prof.summary())
        for name in sorted(prof.caches):
            print(f"  {name:22s} {prof.caches[name].summary()}")
        for cls in sorted(prof.latency):
            prov = prof.latency_provenance.get(cls, "?")
            print(f"  latency/{cls:14s} {prof.latency[cls]:8.0f} cyc "
                  f"[{prov}]")
        for k in sorted(prof.bandwidth):
            prov = prof.bandwidth_provenance.get(k, "?")
            print(f"  bandwidth/{k:12s} {prof.bandwidth[k]:8.2f} GB/s "
                  f"[{prov}]")
        if prof.bank_conflict:
            bc = prof.bank_conflict
            print(f"  bank_conflict         base={bc.get('base_cycles')} "
                  f"slope={bc.get('slope_cycles_per_way')} cyc/way "
                  f"[{bc.get('provenance', '?')}]")
        for k in sorted(prof.spec):
            print(f"  spec/{k:17s} {prof.spec[k]:.6g} "
                  f"[{prof.spec_provenance.get(k, '?')}]")
        stale = prof.is_stale()
        if stale:
            print(f"  STALE: {'; '.join(stale)}")
        return 0
    if args.action == "diff":
        tracecache.configure(tracecache.DEFAULT_ROOT)
        prof = _load_or_dissect(args.target, args.fresh, args.quick,
                                args.seed)
        stale = prof.is_stale()
        if stale:
            # a stale artifact's measured numbers cannot be reproduced, so
            # a verdict against the CURRENT published tables is meaningless
            print(f"STALE profile {args.target}:", file=sys.stderr)
            for s in stale:
                print(f"  - {s}", file=sys.stderr)
            print("re-dissect (profile dissect DEVICE, or diff --fresh)",
                  file=sys.stderr)
            return 1
        pub = P.published_profile(prof.device)
        rows = P.diff_profiles(prof, pub)
        print(P.render_diff(rows, title=f"Profile diff: {prof.device}"),
              end="")
        bad = [r for r in rows if not r.ok]
        return 1 if bad else 0
    if args.action == "validate":
        if args.target:
            problems = {args.target: P.validate_file(args.target)}
        else:
            problems = P.validate_all(args.root)
        if not problems:
            # an empty root means the CI gate would verify NOTHING — that
            # is a failure, not a pass (a rename/typo must not go green)
            print(f"no profile artifacts under "
                  f"{args.root or P.DEFAULT_ROOT}", file=sys.stderr)
            return 1
        bad = 0
        for path, probs in problems.items():
            if probs:
                bad += 1
                print(f"INVALID {path}:")
                for p in probs:
                    print(f"  - {p}")
            else:
                print(f"ok      {path}")
        return 1 if bad else 0
    raise ValueError(f"unknown profile action {args.action!r}")


def _doc_targets() -> dict[str, tuple[str, "callable"]]:
    """Every generated doc: name -> (default path, renderer).  Renderers
    import lazily — ``cli`` pulls the launchers (and therefore jax)."""
    from repro.bench import docsgen
    return {
        "experiments": (DEFAULT_DOC, report.experiments_doc),
        "serving": ("docs/serving.md", docsgen.serving_doc),
        "profiles": ("docs/profiles.md", docsgen.profiles_doc),
        "cli": ("docs/cli.md", docsgen.cli_doc),
    }


def cmd_docs(args: argparse.Namespace) -> int:
    targets = _doc_targets()
    if args.output and not args.only:
        # historical single-file form: -o PATH acts on experiments.md
        args.only = "experiments"
    names = [args.only] if args.only else list(targets)
    stale = []
    for name in names:
        default_path, render = targets[name]
        path = args.output if (args.only and args.output) else default_path
        text = render()
        if args.check:
            try:
                with open(path) as fh:
                    on_disk = fh.read()
            except FileNotFoundError:
                on_disk = ""
            if on_disk != text:
                stale.append(path)
            else:
                print(f"{path} is up to date", file=sys.stderr)
            continue
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as fh:
            fh.write(text)
        print(f"wrote {path}", file=sys.stderr)
    if stale:
        for path in stale:
            print(f"{path} is stale; regenerate with "
                  "`python -m repro.bench docs`", file=sys.stderr)
        return 1
    return 0


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="python -m repro.bench",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("list", help="list registered experiments")
    _add_filters(p)
    p.set_defaults(fn=cmd_list)

    p = sub.add_parser("run", help="run experiments, write JSON artifact")
    _add_filters(p)
    p.add_argument("--quick", action="store_true",
                   help="cheap CI subset of each experiment")
    p.add_argument("--strict", action="store_true",
                   help="exit 1 on any DEVIATION/ERROR verdict")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", default=DEFAULT_ARTIFACT,
                   help=f"JSON artifact path (default {DEFAULT_ARTIFACT})")
    p.add_argument("--report", metavar="FILE",
                   help="also write the Markdown verdict report")
    p.add_argument("--no-csv", action="store_true",
                   help="suppress the legacy CSV rows on stdout")
    p.add_argument("--jobs", type=int, default=DEFAULT_JOBS, metavar="N",
                   help="experiment×device records run across N processes "
                        "(default min(cores, 8); 1 = serial)")
    p.add_argument("--trace-cache", default=tracecache.DEFAULT_ROOT,
                   metavar="DIR",
                   help="simulated-trace cache root (default "
                        f"{tracecache.DEFAULT_ROOT})")
    p.add_argument("--no-trace-cache", action="store_true",
                   help="always re-simulate; neither read nor write traces")
    p.set_defaults(fn=cmd_run)

    p = sub.add_parser("report", help="render Markdown from a JSON artifact")
    p.add_argument("artifact", nargs="?", default=DEFAULT_ARTIFACT)
    p.add_argument("-o", "--output", help="write to file instead of stdout")
    p.set_defaults(fn=cmd_report)

    p = sub.add_parser("profile",
                       help="dissect/show/diff/validate device profiles")
    p.add_argument("action",
                   choices=("dissect", "show", "diff", "validate"))
    p.add_argument("target", nargs="?", default=None,
                   help="device name or artifact path (validate: optional "
                        "single artifact instead of the whole root)")
    p.add_argument("--quick", action="store_true",
                   help="dissect: record the quick-mode contract in the "
                        "artifact (the batched engine measures every "
                        "structure either way)")
    p.add_argument("--engine", choices=("auto", "jax", "vector",
                                        "reference"), default="auto",
                   help="dissect: trace-simulation core (auto picks the "
                        "batched jax engine when importable)")
    p.add_argument("--fresh", action="store_true",
                   help="diff: re-dissect even if an artifact exists")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", default=None,
                   help="dissect: artifact path (default "
                        "experiments/profiles/<device>.json)")
    p.add_argument("--root", default=None,
                   help="validate: profile root (default "
                        "experiments/profiles)")
    p.set_defaults(fn=cmd_profile)

    p = sub.add_parser("docs",
                       help="(re)generate every generated doc: "
                            "experiments, serving, profiles, cli")
    p.add_argument("-o", "--output", default=None,
                   help="write a single target to this path (with "
                        "--only; bare -o keeps the historical "
                        "experiments.md behavior)")
    p.add_argument("--only", choices=("experiments", "serving",
                                      "profiles", "cli"),
                   help="restrict to one generated doc")
    p.add_argument("--check", action="store_true",
                   help="exit 1 if any file on disk is stale")
    p.set_defaults(fn=cmd_docs)
    return ap


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    try:
        from repro import jaxcache
        jaxcache.enable_env()    # compile-once across runs for TPU records
        registry.discover()
        return args.fn(args)
    except (KeyError, FileNotFoundError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
