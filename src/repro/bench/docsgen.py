"""Generated documentation: serving guide, profile tables, CLI reference.

Three more docs join ``docs/experiments.md`` under the same contract —
**rendered from the code (or committed artifacts), never written by
hand** — so ``python -m repro.bench docs --check`` (a ci.sh stage) fails
the build whenever any of them drifts from its source:

* :func:`serving_doc` → ``docs/serving.md``: the serving-layer guide.
  Prose is templated here, but every number in it (page-length rationale
  scores, router margin, scratch-page constant, preemption rules,
  workload scenario tables, a live capacity-plan example) is pulled
  live from ``repro.serve`` so the guide cannot mis-state the code's
  behavior.
* :func:`profiles_doc` → ``docs/profiles.md``: the measured-vs-published
  verdict table for every committed ``experiments/profiles/*.json``,
  rendered through :mod:`repro.profile.diffing` — re-dissecting a device
  regenerates this page or fails the freshness check.
* :func:`cli_doc` → ``docs/cli.md``: every CLI surface (``repro.bench``
  and the four launchers), walked out of the argparse definitions
  themselves, so flags are documented by their own ``help=`` strings.
"""

from __future__ import annotations

import argparse
import os

GENERATED_BANNER = """\
<!-- GENERATED FILE — do not edit by hand.
     Regenerate with: PYTHONPATH=src python -m repro.bench docs -->
"""


def _md_escape(v: object) -> str:
    return str(v).replace("|", "\\|").replace("\n", " ")


# ---------------------------------------------------------------------------
# docs/serving.md
# ---------------------------------------------------------------------------


def serving_doc() -> str:
    from repro import configs, profile as P
    from repro.serve import engine, faults, fleet, paging, planner, slo, \
        tiers, workload

    cfg = configs.get_config("granite-8b")
    terms = paging.page_len_rationale(cfg, expected_tokens=256)
    chosen = paging.choose_page_len(cfg, expected_tokens=256)
    sharded_rules = sorted(k for k, v in engine.MESH_SERVE_RULES.items()
                           if v is not None)

    lines = [
        "# Serving layer guide",
        "",
        GENERATED_BANNER,
        "The serving stack is a consumer of the paper's dissection laws: "
        "every geometry below (page length, admission bounds, routing "
        "scores) is derived from measured memory-hierarchy parameters, "
        "never hard-coded. This page is generated from the code that "
        "implements it.",
        "",
        "## The four engines",
        "",
        "| Engine | Module | What it is | Use it for |",
        "|---|---|---|---|",
        "| `loop` | `launch/serve.py` | fixed-batch prefill + decode, no "
        "scheduling | kernel-level throughput measurement |",
        "| `dense` | `serve/engine.py::ServeEngine` | continuous batching "
        "over dense `max_slots x max_len` cache slots | the differential "
        "ORACLE: trusted, occupancy-blind |",
        "| `paged` | `serve/engine.py::PagedServeEngine` | continuous "
        "batching over the paged KV cache (`serve/paging.py`) | the real "
        "serving path: HBM tracks generated tokens |",
        "| `fleet` | `serve/fleet.py::FleetEngine` | N paged replicas, "
        "each on its own device profile, behind the cost-model router "
        "with the streaming front end (`serve/frontend.py`) | "
        "multi-replica, heterogeneous serving |",
        "",
        "Each layer is pinned to the previous one by a differential "
        "test: paged reproduces dense token-for-token "
        "(`tests/test_serve_paged_equiv.py`), and an N=1 fleet reproduces "
        "the single paged engine request-for-request on the same tick "
        "schedule (`tests/test_serve_fleet.py`, `serve_fleet` "
        "experiment).",
        "",
        "## Page sizing: the laws, priced",
        "",
        "`paging.choose_page_len` scores every candidate with the "
        "dissection models — the Little's-law gather setup term "
        f"(`GATHER_OUTSTANDING = {paging.GATHER_OUTSTANDING}` outstanding "
        "DMAs), half-page fragmentation, page-table overhead, and the "
        "§6.2 bank-conflict row model (sub-lane-row pages are penalized "
        "by their predicted serialization degree). For `granite-8b` at "
        "256 expected tokens on the active profile:",
        "",
        "| page_len | row bytes | gather | frag | table | conflict "
        "degree | score |",
        "|---:|---:|---:|---:|---:|---:|---:|",
    ]
    for t in terms:
        mark = " **<-- chosen**" if t.page_len == chosen else ""
        lines.append(
            f"| {t.page_len} | {t.row_bytes} | {t.gather_frac} "
            f"| {t.frag_frac} | {t.table_frac} | {t.conflict_degree} "
            f"| {t.score}{mark} |")
    lines += [
        "",
        "A replica constructed with a different device profile re-derives "
        "this table from that profile's measured bandwidth, latency and "
        "lane geometry — the launcher prints the rationale under "
        "`--engine paged`.",
        "",
        "## Mesh-sharded replicas: one replica = one device slice",
        "",
        "`PagedServeEngine(mesh=...)` (and `FleetEngine(mesh=...)`, "
        "`--mesh-shape` on the launcher) lays the paged KV pool out over "
        "a device mesh from `launch/mesh.py::make_serve_mesh`. The split "
        "is deliberately narrow: of the whole rule table, only "
        f"`{sharded_rules}` maps onto a mesh axis "
        f"(`engine.MESH_SERVE_RULES`, heads on `\"model\"` with the GQA "
        "non-divisible fallback); pages, activations and everything else "
        "stay replicated, and the allocator plus page tables never leave "
        "the host. The paged scatter/gather runs under `shard_map`, and "
        "the gather result is constrained back to replicated before any "
        "matmul touches it — so every downstream operand is "
        "width-invariant BY CONSTRUCTION and no cross-width float "
        "reassociation can creep in.",
        "",
        "**Donation contract:** the step functions are jitted with "
        "`donate_argnums` on the cache operand and, under a mesh, "
        "`out_shardings` pinned to the input cache's exact layout, so "
        "XLA aliases every pool shard in place (copy-free update; "
        "`tests/test_serve_donation.py` pins buffers-consumed, a flat "
        "live-buffer count, and the absence of XLA's donation warning).",
        "",
        "**The oracle chain**, each link a differential test:",
        "",
        "```",
        "dense ServeEngine  ==  unsharded paged  ==  1-device mesh  ==  "
        "2/4/8-way mesh",
        "  (trusted)            (paged_equiv)       (serve_sharded)     "
        "(XLA_FLAGS host mesh)",
        "```",
        "",
        "token-for-token on the same tick schedule at every link "
        "(`tests/test_serve_sharded.py`, `serve_sharded` experiment). "
        "Per-shard page pricing: each shard gathers `1/shards` of a row "
        "against its own partition's full bandwidth and latency "
        "(per-partition, not aggregate — arXiv:1804.06826), so "
        "`choose_page_len(shards=N)` re-prices the table above with "
        "thinner rows. For `granite-8b` at 256 expected tokens:",
        "",
        "| shards | chosen page_len | row bytes/shard | gather frac |",
        "|---:|---:|---:|---:|",
    ] + [
        (lambda b: f"| {s} | {b.page_len} | {b.row_bytes} "
                   f"| {b.gather_frac} |")(
            min(paging.page_len_rationale(cfg, expected_tokens=256,
                                          shards=s),
                key=lambda t: (t.score, t.page_len)))
        for s in (1, 2, 4, 8)
    ] + [
        "",
        "## Preemption and seniority",
        "",
        f"* physical pages below `SCRATCH_PAGES = {paging.SCRATCH_PAGES}` "
        "are reserved scratch: inactive batch rows write their garbage "
        "K/V there and can never corrupt live pages;",
        "* when the free list runs dry, the engine preempts the youngest "
        "STRICTLY-younger live request (pages released copy-free, the "
        "request re-queued for a deterministic greedy re-run);",
        "* seniority (`admit_seq`) is assigned once and survives "
        "preemption, so the oldest live request is never a victim and "
        "always makes progress — no livelock, no starvation;",
        "* a preempted request stranded behind a page-dry replica is "
        "MIGRATED by the fleet router to a replica with headroom; it "
        "re-enters that replica's admission order at the back (seniority "
        "is engine-local).",
        "",
        "## Fleet routing policy",
        "",
        "The router scores every replica that can accept the head-of-line "
        "request (`PagedServeEngine.can_accept`: a free slot net of "
        "queued work, plus a first chunk's worth of free pages):",
        "",
        "1. **step cost** — a fresh `decode_cell_cost(...).step_s(spec)` "
        "per (replica, decision), priced against that replica's OWN "
        "profile. One CellCost per decision keeps pricing scoped: a "
        "mixed fleet must never emit `SpecMixWarning`.",
        f"2. **margin filter** — replicas within `ROUTER_MARGIN = "
        f"{fleet.ROUTER_MARGIN:.0%}` of the best predicted step cost are "
        "cost-equivalent; the router NEVER picks outside this band (the "
        "`serve_fleet` experiment audits every decision from the log).",
        "3. **Little's-law inflight bound** — `required_inflight_bytes / "
        "gather_row_bytes` sequences saturate the replica's HBM pipe; "
        "admission past the bound is penalized first.",
        "4. **free-page headroom**, then lowest replica index — the "
        "deterministic tie-break that makes runs replay bit-identically.",
        "",
        "GPU-profile replicas price through "
        "`DeviceProfile.serving_spec()`: measured global bandwidth "
        "(Table 6 / occupancy sweep), the measured P4 DRAM latency as "
        "the Little's-law anchor, and the shared-memory bank count as "
        "the row-tiling lane geometry.",
        "",
        "## Disaggregated prefill/decode tiers",
        "",
        "`--fleet-tiers` (`serve/tiers.py`) splits the fleet into "
        "prefill specialists and decode specialists: prefill is "
        "bandwidth/FLOP-bound (one chunked pass over the prompt), "
        "decode is latency/Little's-law-bound (the whole live cache "
        "re-read every tick), so heterogeneous replicas play to type. "
        "Routing becomes two-stage, both stages on the SAME fleet-global "
        "decision sequence so the merged log still replays "
        "bit-identically:",
        "",
        "1. **stage 1 (admit/migrate)** — prefill-tier candidates, "
        "priced with `prefill_cell_cost` over the whole prompt: "
        "load-independent, memory-bound, so the bandwidth-rich replica "
        "wins the phase it is good at;",
        "2. **KV handoff** — when a prefill specialist finishes a "
        "prompt, its WHOLE pages move: `handoff_bytes = pages × "
        "page_len × kv_bytes_per_token`, priced at `min(src, dst)` "
        "measured global-memory bandwidth plus one worst-endpoint DRAM "
        "round trip (`handoff_seconds`), then quantized against the "
        "destination's decode step (`handoff_ticks`, never 0) — the "
        "first sampled token is withheld in transit, so handoff "
        "latency lands in TTFT, never vanishes between tiers;",
        "3. **stage 2 (handoff placement)** — decode-tier candidates "
        "with import capacity, priced with `decode_cell_cost` at live "
        "load PLUS the per-candidate transfer term, under the same "
        f"`ROUTER_MARGIN = {fleet.ROUTER_MARGIN:.0%}` audit as stage 1.",
        "",
        "`--fleet-tiers auto` ranks replicas by measured profile — "
        "normalized global bandwidth minus normalized P4 DRAM latency "
        "(`tiers.auto_tiers`); the top half prefills. For the committed "
        "profiles:",
        "",
        "| device | global BW (GB/s) | DRAM latency (µs) | auto tier |",
        "|---|---:|---:|---|",
    ] + (lambda specs, plan: [
        f"| {s.name} | {s.hbm_bytes_per_s / 1e9:.0f} "
        f"| {s.hbm_latency_s * 1e6:.3g} "
        f"| {'prefill' if i in plan.prefill else 'decode'} |"
        for i, s in enumerate(specs)
    ])(*(lambda specs: (specs, tiers.auto_tiers(specs)))(
        [P.published_profile(d).serving_spec()
         for d in ("GTX980", "TeslaV100", "tpu_v5e")])) + [
        "",
        "A single-tier plan (every replica in both tiers) degenerates "
        "to the symmetric router bit-for-bit — tokens, tick schedule "
        "and decision log — extending the oracle chain to "
        "dense → paged → fleet → tiered fleet "
        "(`tests/test_serve_tiers.py`, `serve_tiers` experiment). "
        "`export_pages`/`import_pages` move the cache token-major, so "
        "tiers may disagree about `page_len`; allocator invariants run "
        "on both ends and no stream is ever resident in two tiers' "
        "page tables at once. Killing a replica mid-handoff aborts the "
        "transfer deterministically: the request re-enters the prefill "
        "tier and classifies `requeued`/`migrated`, never lost "
        "silently. `planner.plan_tiers` answers the sizing question "
        "per tier — how many prefill vs decode replicas of which "
        "profile — with the handoff folded into predicted TTFT.",
        "",
        "## Streaming front end",
        "",
        "`serve/frontend.py::FleetFrontend` drives one deterministic "
        "event loop (no wall clock, no RNG): each tick dispatches, ticks "
        "every replica in index order, migrates stranded rollbacks, then "
        "drains new tokens to per-request callbacks in uid order. "
        "Preempted requests re-earn their already-streamed prefix "
        "silently (greedy re-runs are identical), so subscribers see one "
        "continuous stream. `submit` raises `Backpressure` when the "
        "bounded queue is full — which only happens when every replica "
        "is page-saturated.",
        "",
        "## Chaos tier: faults, quarantine, replay",
        "",
        "`serve/faults.py::FaultInjector` runs seeded or scripted fault "
        "campaigns against the fleet; every transition is a `FaultEvent` "
        "on the SAME fleet-global sequence as routing decisions, so "
        "`FleetEngine.decision_log()` replays bit-identically under any "
        "fault schedule (`serve_faults` experiment, "
        "`tests/test_serve_faults.py`).",
        "",
        "Injectable fault kinds "
        f"(`faults.FAULT_KINDS = {faults.FAULT_KINDS}`):",
        "",
        "| Kind | What happens | How the fleet heals |",
        "|---|---|---|",
        "| `kill` | replica death mid-prefill/mid-decode: copy-free "
        "evacuation, zero leaked pages (asserted) | stranded rollbacks "
        "re-home through the ordinary `_migrate` machinery; work no "
        "surviving replica can serve is reaped as `lost`, loudly |",
        "| `corrupt` | page-table/allocator bookkeeping broken "
        f"({faults.CORRUPT_VARIANTS} variants: stale owner map, aliased "
        "free page, page-table tail) | the per-tick integrity poll "
        "(`PagedServeEngine.check_invariants`) catches it BEFORE "
        "dispatch/decode; the replica is quarantined, its paging books "
        "rebuilt from scratch (`reset_paging`), and readmitted after "
        f"`QUARANTINE_TICKS = {fleet.QUARANTINE_TICKS}` ticks |",
        "| `degrade` | latency spike: FLOPs and bandwidth divided by a "
        f"factor (default {faults.DEGRADE_FACTOR:.0f}x), HBM latency "
        "multiplied — PRICING only, tokens untouched | the router "
        "re-prices through `decode_cell_cost` and organically drains "
        "load; `recover` restores the base spec |",
        "| `recover` | undo a `degrade` | — |",
        "",
        "Recorded-only event kinds: `quarantine`, `readmit`, `lost`, and "
        "`skip` (a scheduled fault with no eligible target — e.g. a kill "
        "beyond `max_kills`, which defaults to fleet size − 1 so a "
        "campaign can never lose the last replica).",
        "",
        "Replica lifecycle states: "
        f"`{fleet.HEALTHY}` / `{fleet.DEGRADED}` (serving, re-priced) / "
        f"`{fleet.QUARANTINED}` (timed, healing) / `{fleet.DEAD}` "
        "(permanent). Only healthy and degraded replicas receive "
        "dispatches; `FleetEngine.check_invariants()` asserts a "
        "quarantined or dead replica holds zero live requests and zero "
        "pages, and that no uid is owned by two replicas.",
        "",
        "Every submitted request ends in exactly one outcome class "
        f"(`fleet.OUTCOME_CLASSES = {fleet.OUTCOME_CLASSES}`): "
        "`completed` (never touched by a fault), `migrated` (finished "
        "on a different replica than it started), `requeued` (finished "
        "on its home after a fault rollback), `lost` (capacity died; "
        "the stream handle is flagged, never left hanging), `cancelled`. "
        "Greedy decoding is schedule-independent, so every finished "
        "request — migrated or not — streams byte-identically to the "
        "fault-free run.",
        "",
        "## Traffic realism: workloads, SLOs, capacity planning",
        "",
        "`serve/workload.py` generates seeded request traces — one "
        "`np.random.default_rng(seed)` stream consumed strictly in tick "
        "order, so a trace is a pure function of its `WorkloadSpec` "
        "(bit-identical fingerprints, and a shorter horizon is a strict "
        "prefix of a longer one). Lengths are "
        "`Gamma(shape, mean/shape)` draws as fractions of `max_len`, "
        "clipped to fit the engine:",
        "",
        "| scenario | prompt mean (frac·shape) | output mean | "
        "turns/arrival | character |",
        "|---|---|---|---|---|",
    ] + [
        (f"| `{s.name}` | {s.prompt_frac:.2f}·max_len "
         f"(shape {s.prompt_shape:g}) | {s.output_frac:.2f}·max_len "
         f"(shape {s.output_shape:g}) | {s.turns_mean:g} "
         f"| {s.description} |")
        for s in (workload.SCENARIOS[k] for k in sorted(workload.SCENARIOS))
    ] + [
        "",
        f"Arrival processes (`ARRIVALS = {workload.ARRIVALS}`): "
        "homogeneous Poisson; **bursty** — a two-state modulated Poisson "
        f"(ON multiplies the rate by {workload.BURST_FACTOR:g}x, "
        f"entered w.p. {workload.BURST_ON_P:g}/tick, left w.p. "
        f"{workload.BURST_OFF_P:g}/tick); **diurnal** — a sinusoidal "
        f"rate with period {workload.DIURNAL_PERIOD} ticks and "
        f"amplitude {workload.DIURNAL_AMPLITUDE:g}. Agent sessions "
        "spread their turns over gaps of up to "
        f"{workload.TURN_GAP_MAX - 1} ticks.",
        "",
        "`serve/slo.py::SLOTracker` hangs off the front end "
        "(`FleetFrontend.slo`): every submission/token/settlement is "
        "stamped in fleet ticks, and `report()` folds them into "
        "deterministic nearest-rank percentiles "
        f"(`PERCENTILES = {slo.PERCENTILES}`) of TTFT (submit → first "
        "token), TPOT (mean inter-token gap) and residence — tick units "
        "throughout; `SLOReport.to_seconds(step_s)` converts with a "
        "profile-priced `decode_cell_cost(...).step_s`. Backpressured "
        "resubmissions pass `arrival_tick=` so TTFT counts from the "
        "ORIGINAL arrival, and `mean_concurrency = Σresidence/makespan "
        "= λ·W` holds exactly (Little's law as an accounting identity).",
        "",
        "`serve/planner.py` inverts the accounting: "
        "`plan_capacity(cfg, arrival_per_tick=λ, ...)` characterizes one "
        "replica — concurrency `C = min(slots, page capacity, "
        "Little's-law inflight bound)`, the same "
        "`required_inflight_bytes / gather_row_bytes` quantum the "
        "router uses — then walks the replica count up to the smallest "
        "`N` whose utilization and predicted p99 TTFT meet the "
        f"`SLOTarget` (defaults: ttft_p99 ≤ "
        f"{planner.SLOTarget().ttft_p99_ticks:g} ticks, ρ ≤ "
        f"{planner.SLOTarget().max_utilization:g}; `MAX_REPLICAS = "
        f"{planner.MAX_REPLICAS}` caps the search, infeasible is "
        "REPORTED, never raised). For `granite-8b` chat traffic at "
        "λ=0.5/tick on the active profile:",
        "",
    ] + (lambda p: [
        "```",
        *p.lines(),
        "```",
    ])(planner.plan_capacity(
        cfg, arrival_per_tick=0.5,
        mean_prompt=workload.SCENARIOS["chat"].mean_prompt(48),
        mean_new=workload.SCENARIOS["chat"].mean_output(48),
        max_slots=3, max_len=48)) + [
        "",
        "`plan_for_trace` reads λ and the length means off a generated "
        "trace's measured stats; `rank_profiles` runs the same plan "
        "across a list of device profiles and sorts by (feasible, "
        "replicas, step_s) — \"how many replicas of WHICH profile\". "
        "The `serve_workload` experiment holds the planner to a "
        "falsifiable claim: a fleet built with exactly the planned "
        "replica count must measure a mean residence within a stated "
        "bound of the predicted `W`, and its measured p99 TTFT must "
        "meet the SLO the plan promised — all deterministic accounting, "
        "no wall-clock verdicts.",
        "",
        "## Try it",
        "",
        "```bash",
        "PYTHONPATH=src python -m repro.launch.serve --arch granite-8b "
        "--smoke \\",
        "    --engine fleet --fleet-profiles tpu_v5e,TeslaV100 \\",
        "    --requests 8 --slots 3 --max-len 48",
        "PYTHONPATH=src python examples/fleet_serve.py",
        "PYTHONPATH=src python -m repro.bench run --only serve_fleet "
        "--quick",
        "# seeded fault campaign, replay-verified (exits 1 on "
        "divergence)",
        "PYTHONPATH=src python -m repro.launch.serve --arch granite-8b "
        "--smoke \\",
        "    --engine fleet --replicas 2 --requests 12 --faults 1",
        "# seeded chat workload with SLO report, replay-verified "
        "(exits 1 on divergence)",
        "PYTHONPATH=src python -m repro.launch.serve --arch granite-8b "
        "--smoke \\",
        "    --engine fleet --replicas 2 --workload chat --rate 0.5 \\",
        "    --horizon 24 --workload-replay",
        "# capacity planner: replicas-per-profile for a rag workload "
        "(no jax, pure accounting)",
        "PYTHONPATH=src python -m repro.launch.serve --arch granite-8b "
        "--smoke \\",
        "    --engine fleet --fleet-profiles tpu_v5e,TeslaV100 \\",
        "    --workload rag --rate 0.8 --plan",
        "PYTHONPATH=src python -m repro.bench run --only serve_workload "
        "--quick",
        "# disaggregated tiers: auto-assigned from the measured "
        "profiles, replay-verified",
        "PYTHONPATH=src python -m repro.launch.serve --arch granite-8b "
        "--smoke \\",
        "    --engine fleet --replicas 2 --fleet-tiers auto \\",
        "    --workload chat --rate 0.5 --horizon 24 --workload-replay",
        "PYTHONPATH=src python -m repro.bench run --only serve_tiers "
        "--quick",
        "# mesh-sharded paged replica on a forced 2-device host mesh",
        "XLA_FLAGS=--xla_force_host_platform_device_count=2 \\",
        "  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b "
        "--smoke \\",
        "    --engine paged --mesh-shape 2 --requests 8",
        "PYTHONPATH=src python examples/sharded_serve.py --quick",
        "```",
    ]
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# docs/profiles.md
# ---------------------------------------------------------------------------


def profiles_doc(root: str | None = None) -> str:
    from repro import profile as P

    root = root or P.DEFAULT_ROOT
    lines = [
        "# Device profiles: measured vs published",
        "",
        GENERATED_BANNER,
        "One section per committed `repro.profile/v1` artifact under "
        f"`{root}/`, diffed against the published tables through "
        "`repro/profile/diffing.py` (structural fields exact, latencies "
        "within 2%, sustained bandwidths at or below the published "
        "peak). Re-dissecting a device (`python -m repro.bench profile "
        "dissect <device>`) regenerates this page; a stale page fails "
        "the ci.sh docs-freshness stage.",
        "",
    ]
    names = ([] if not os.path.isdir(root) else
             sorted(n for n in os.listdir(root) if n.endswith(".json")))
    for name in names:
        prof = P.load_profile(os.path.join(root, name))
        pc = prof.provenance_counts()
        lines += [
            f"## {prof.device} ({prof.kind}/{prof.generation})",
            "",
            f"`{root}/{name}` — {len(prof.caches)} structures, "
            f"{len(prof.latency)} latency classes; "
            f"**{pc['measured']} measured / {pc['published']} published** "
            f"fields (engine `{prof.engine}`/`{prof.engine_version}`, "
            f"registry `{prof.registry_hash}`).",
            "",
        ]
        if prof.timings:
            total = prof.timings.get("total", 0.0)
            lines += [
                f"Dissection wall time: **{total:.3f} s** total.",
                "",
                "| Stage | Seconds |",
                "|---|---:|",
            ]
            for stage in sorted(prof.timings,
                                key=lambda s: -prof.timings[s]):
                if stage == "total":
                    continue
                lines.append(f"| {stage} | {prof.timings[stage]:.4f} |")
            lines.append("")
        stale = prof.is_stale()
        if stale:
            lines += ["**STALE:** " + "; ".join(stale), ""]
            continue
        if prof.kind == "tpu":
            lines += [
                "Published spec end to end (no on-hardware dissection on "
                "this host); consumers price against these fields:",
                "",
                "| Field | Value | Provenance |",
                "|---|---:|---|",
            ]
            for k in sorted(prof.spec):
                lines.append(
                    f"| {k} | {prof.spec[k]:.6g} "
                    f"| {prof.spec_provenance.get(k, '?')} |")
            lines.append("")
            continue
        rows = P.diff_profiles(prof, P.published_profile(prof.device))
        bad = [r for r in rows if not r.ok]
        lines += [
            f"**{len(rows) - len(bad)} ok · {len(bad)} mismatched** "
            f"({len(rows)} diffed fields)",
            "",
            "| Field | Measured | Published | Rule | Verdict | Note |",
            "|---|---|---|---|---|---|",
        ]
        for r in rows:
            lines.append(
                f"| {_md_escape(r.field)} | {_md_escape(r.measured)} "
                f"| {_md_escape(r.published)} | {r.rule} "
                f"| {'ok' if r.ok else 'MISMATCH'} "
                f"| {_md_escape(r.note)} |")
        lines.append("")
    if not names:
        lines += ["(no committed profile artifacts)", ""]
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# docs/cli.md — rendered from the argparse definitions themselves
# ---------------------------------------------------------------------------

#: defaults that depend on the host (core counts) — documented by their
#: formula, not the value this machine happened to compute
_HOST_DEPENDENT_DEFAULTS = {
    ("python -m repro.bench run", "--jobs"): "min(cores, 8)",
}


def _flag_rows(prog: str, parser: argparse.ArgumentParser) -> list[str]:
    rows = []
    for a in parser._actions:
        if isinstance(a, (argparse._HelpAction,
                          argparse._SubParsersAction)):
            continue
        if a.option_strings:
            name = ", ".join(a.option_strings)
            if a.metavar:
                name += f" {a.metavar}"
            elif a.choices:
                name += " {" + ",".join(str(c) for c in a.choices) + "}"
            elif not isinstance(a, (argparse._StoreTrueAction,
                                    argparse._StoreFalseAction)):
                name += f" {a.dest.upper()}"
        else:
            name = a.metavar or a.dest
            if a.choices:
                name += " {" + ",".join(str(c) for c in a.choices) + "}"
        key = (prog, a.option_strings[0] if a.option_strings else a.dest)
        if key in _HOST_DEPENDENT_DEFAULTS:
            default = _HOST_DEPENDENT_DEFAULTS[key]
        elif a.default in (None, argparse.SUPPRESS):
            default = "—"
        elif a.default is False:
            default = "off"
        else:
            default = f"`{a.default}`"
        rows.append(f"| `{_md_escape(name)}` | {default} "
                    f"| {_md_escape(a.help or '')} |")
    return rows


def _render_parser(title: str, prog: str,
                   parser: argparse.ArgumentParser) -> list[str]:
    lines = [f"## {title}", ""]
    desc = (parser.description or "").strip()
    if desc:
        first = desc.splitlines()[0].strip()
        if first:
            lines += [first, ""]
    subactions = [a for a in parser._actions
                  if isinstance(a, argparse._SubParsersAction)]
    top = _flag_rows(prog, parser)
    if top:
        lines += [f"`{prog}`", "",
                  "| Flag | Default | Description |", "|---|---|---|"]
        lines += top + [""]
    for sub in subactions:
        for cmd, sp in sub.choices.items():
            sub_prog = f"{prog} {cmd}"
            lines += [f"### `{sub_prog}`", ""]
            help_text = next(
                (c.help for c in sub._choices_actions if c.dest == cmd), "")
            if help_text:
                lines += [_md_escape(help_text), ""]
            rows = _flag_rows(sub_prog, sp)
            if rows:
                lines += ["| Flag | Default | Description |",
                          "|---|---|---|"] + rows
            lines.append("")
    return lines


def cli_doc() -> str:
    # imports are local: the launchers pull jax (and set XLA_FLAGS), which
    # registry discovery must not pay for
    from repro.bench import __main__ as bench_main
    from repro.launch import dryrun, perf, serve, train

    lines = [
        "# CLI reference",
        "",
        GENERATED_BANNER,
        "Every table below is walked out of the argparse definition the "
        "command actually parses with (`build_parser()` on each module), "
        "so flags are documented by their own `help=` strings and can "
        "never drift from the code.",
        "",
    ]
    lines += _render_parser("Dissection harness (`repro.bench`)",
                            "python -m repro.bench",
                            bench_main.build_parser())
    lines += _render_parser("Serving launcher", "python -m repro.launch.serve",
                            serve.build_parser())
    lines += _render_parser("Perf hillclimbing driver",
                            "python -m repro.launch.perf",
                            perf.build_parser())
    lines += _render_parser("Training launcher",
                            "python -m repro.launch.train",
                            train.build_parser())
    lines += _render_parser("Compile dry-run driver",
                            "python -m repro.launch.dryrun",
                            dryrun.build_parser())
    return "\n".join(lines) + "\n"
