"""Experiment registry for the dissection harness.

``benchmarks/*.py`` modules register one experiment each via the
:func:`experiment` decorator; :func:`discover` imports every module in the
``benchmarks`` package so nothing is hand-listed anywhere.  The decorated
function has signature ``fn(ctx: Context) -> list[Metric]`` and is called
once per applicable device.
"""

from __future__ import annotations

import dataclasses
import importlib
import pkgutil
from typing import Callable, Iterable, Mapping

from repro.bench.result import Metric
from repro.core import devices as device_registry
from repro.core.devices import DeviceEntry


@dataclasses.dataclass(frozen=True)
class Context:
    """Per-call execution context handed to every experiment function."""

    device: DeviceEntry
    quick: bool = False
    seed: int = 0


ExperimentFn = Callable[[Context], "list[Metric]"]


@dataclasses.dataclass(frozen=True)
class Experiment:
    """A registered experiment and its paper provenance."""

    name: str
    fn: ExperimentFn
    title: str
    section: str                       # paper section, e.g. "§4.4"
    artifact: str                      # "Table 5", "Fig 8", "beyond-paper"
    devices: tuple[str, ...]
    tags: tuple[str, ...] = ()
    expected: Mapping[str, str] = dataclasses.field(default_factory=dict)
    # ^ human-readable paper-published values, keyed by claim — this is the
    #   metadata docs/experiments.md is generated from.

    def applicable(self, device: str) -> bool:
        return device in self.devices

    def run(self, ctx: Context) -> list[Metric]:
        return self.fn(ctx)


REGISTRY: dict[str, Experiment] = {}


def experiment(*, name: str | None = None, title: str, section: str,
               artifact: str, devices: Iterable[str],
               tags: Iterable[str] = (),
               expected: Mapping[str, str] | None = None):
    """Decorator: register ``fn(ctx) -> list[Metric]`` as an experiment.

    ``name`` defaults to the defining module's basename (so
    ``benchmarks/fig8_tlb.py`` registers ``fig8_tlb``).  Devices must
    already exist in :data:`repro.core.devices.DEVICE_REGISTRY`.
    """

    def deco(fn: ExperimentFn) -> ExperimentFn:
        exp_name = name or fn.__module__.rsplit(".", 1)[-1]
        devs = tuple(devices)
        for d in devs:
            device_registry.get_device(d)      # fail fast on typos
        exp = Experiment(name=exp_name, fn=fn, title=title, section=section,
                         artifact=artifact, devices=devs, tags=tuple(tags),
                         expected=dict(expected or {}))
        prev = REGISTRY.get(exp_name)
        if prev is not None:
            # Tolerate re-imports of the same module (e.g. `benchmarks.x`
            # imported twice under one name); reject true collisions.
            if (prev.fn.__module__, prev.fn.__qualname__) != (
                    fn.__module__, fn.__qualname__):
                raise ValueError(
                    f"experiment {exp_name!r} already registered by "
                    f"{prev.fn.__module__}.{prev.fn.__qualname__}")
        REGISTRY[exp_name] = exp
        fn.experiment = exp            # backref for direct calls in tests
        return fn

    return deco


def discover(package: str = "benchmarks") -> list[str]:
    """Import every module in ``package`` so decorators run.

    Returns the imported module basenames.  Helper modules that register
    nothing (``common``, ``run``) are skipped by name; anything else that
    fails to import is a hard error — silently dropping an experiment is
    exactly the failure mode the registry exists to prevent.
    """
    pkg = importlib.import_module(package)
    names = []
    for info in pkgutil.iter_modules(pkg.__path__):
        base = info.name
        if base.startswith("_") or base in ("common", "run"):
            continue
        importlib.import_module(f"{package}.{base}")
        names.append(base)
    return names


def get(name: str) -> Experiment:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown experiment {name!r}; "
                       f"registered: {sorted(REGISTRY)}") from None


def all_experiments() -> list[Experiment]:
    return [REGISTRY[k] for k in sorted(REGISTRY)]


def select(device: str | None = None, tag: str | None = None,
           section: str | None = None,
           names: Iterable[str] | None = None) -> list[Experiment]:
    """Filter registered experiments; substring match for section."""
    exps = all_experiments()
    if names:
        wanted = set(names)
        unknown = wanted - set(REGISTRY)
        if unknown:
            raise KeyError(f"unknown experiments: {sorted(unknown)}")
        exps = [e for e in exps if e.name in wanted]
    if device:
        exps = [e for e in exps if e.applicable(device)]
    if tag:
        exps = [e for e in exps if tag in e.tags]
    if section:
        exps = [e for e in exps if section in e.section]
    return exps
