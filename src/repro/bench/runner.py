"""Execute registered experiments across registered devices.

One :class:`~repro.bench.result.ExperimentRecord` per experiment × device.
The runner never imports individual benchmark modules — it only sees the
registry — so adding an experiment is one decorated function in
``benchmarks/`` and nothing else.

With ``jobs > 1`` the experiment × device records fan out over a process
pool.  Scheduling is invisible in the output: records come back in the
same deterministic order as the serial path, each record's seed is a
stable hash of ``(base seed, experiment, device)`` rather than anything
execution-order-dependent, and ``elapsed_s`` is still measured around the
experiment body inside the worker, so the artifact schema and its timing
semantics are unchanged.  Workers rebuild the registry via
``registry.discover()`` and attach the same trace cache as the parent, so
pooled and serial runs share cached traces.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
import traceback
from concurrent.futures import ProcessPoolExecutor
from typing import Callable, Iterable

from repro.bench import registry
from repro.bench.registry import Context, Experiment
from repro.bench.result import ExperimentRecord, Metric
from repro.core import devices as device_registry
from repro.core import tracecache

Row = tuple[str, float, str]     # legacy CSV row: name, us_per_call, derived


@dataclasses.dataclass(frozen=True)
class RunOptions:
    device: str | None = None          # restrict to one device
    tag: str | None = None
    section: str | None = None
    names: tuple[str, ...] = ()
    quick: bool = False
    seed: int = 0
    jobs: int = 1                      # >1: experiment×device process pool
    trace_cache_root: str | None = None  # propagated to pool workers


def record_seed(base: int, experiment: str, device: str) -> int:
    """Deterministic per-record seed: independent of pool scheduling, run
    order, and jobs count — a record reruns identically in any context."""
    h = hashlib.sha256(f"{base}:{experiment}:{device}".encode()).digest()
    return int.from_bytes(h[:4], "little")


def run_experiments(opts: RunOptions = RunOptions(),
                    progress: Callable[[str], None] | None = None,
                    ) -> list[ExperimentRecord]:
    """Run the selected experiments on every applicable device."""
    exps = registry.select(device=opts.device, tag=opts.tag,
                           section=opts.section, names=opts.names or None)
    tasks: list[tuple[Experiment, str]] = [
        (exp, dev) for exp in exps for dev in exp.devices
        if not (opts.device and dev != opts.device)]
    if opts.jobs > 1 and len(tasks) > 1:
        return _run_pooled(tasks, opts, progress)
    records: list[ExperimentRecord] = []
    for exp, dev_name in tasks:
        if progress:
            progress(f"{exp.name} × {dev_name}")
        records.append(run_one(exp, dev_name, quick=opts.quick,
                               seed=record_seed(opts.seed, exp.name,
                                                dev_name)))
    return records


def run_one(exp: Experiment, device: str, quick: bool = False,
            seed: int = 0) -> ExperimentRecord:
    ctx = Context(device=device_registry.get_device(device), quick=quick,
                  seed=seed)
    t0 = time.perf_counter()
    metrics: list[Metric] = []
    error = None
    try:
        metrics = list(exp.run(ctx))
    except Exception:
        error = traceback.format_exc(limit=8)
    return ExperimentRecord(
        experiment=exp.name, device=device, section=exp.section,
        artifact=exp.artifact, metrics=metrics,
        elapsed_s=time.perf_counter() - t0, error=error)


# ---------------------------------------------------------------------------
# process-pool fan-out
# ---------------------------------------------------------------------------


def _worker_init(trace_cache_root: str | None) -> None:
    from repro import jaxcache
    jaxcache.enable_env()        # env-only: jax stays lazy until needed
    registry.discover()
    if trace_cache_root:
        tracecache.configure(trace_cache_root)


#: artifact consulted for longest-first pool scheduling (best effort)
HINT_ARTIFACT = os.path.join("experiments", "bench", "latest.json")


def _historical_costs(path: str = HINT_ARTIFACT) -> dict[tuple[str, str], float]:
    """(experiment, device) -> elapsed_s from the committed baseline, for
    makespan-friendly submission order.  Purely a scheduling hint: results
    and their order are identical whether or not the file exists."""
    try:
        with open(path) as fh:
            payload = json.load(fh)
        return {(r["experiment"], r["device"]): float(r.get("elapsed_s", 0))
                for r in payload.get("records", [])}
    except (OSError, ValueError, KeyError):
        return {}


def _worker_run_batch(items: list[tuple[str, str, int]],
                      quick: bool) -> list[ExperimentRecord]:
    return [run_one(registry.get(name), device, quick=quick, seed=seed)
            for name, device, seed in items]


def _run_pooled(tasks: list[tuple[Experiment, str]], opts: RunOptions,
                progress: Callable[[str], None] | None,
                ) -> list[ExperimentRecord]:
    jobs = min(opts.jobs, len(tasks))
    costs = _historical_costs()

    def cost(i: int) -> float:
        return costs.get((tasks[i][0].name, tasks[i][1]), float("inf"))

    # TPU records run as ONE sequential batch on one worker: they share a
    # single jax import + XLA warmup instead of paying it per worker, and
    # they overlap the simulator records on the other workers.
    tpu_idx = [i for i, (_, dev) in enumerate(tasks)
               if device_registry.get_device(dev).kind == "tpu"]
    solo_idx = [i for i in range(len(tasks)) if i not in set(tpu_idx)]
    # longest-first submission; unknown records first (assume heavy)
    solo_idx.sort(key=lambda i: -cost(i))
    results: list = [None] * len(tasks)
    with ProcessPoolExecutor(
            max_workers=jobs, initializer=_worker_init,
            initargs=(opts.trace_cache_root,)) as pool:
        futures = []
        if len(tpu_idx) > 1:
            for i in tpu_idx:
                if progress:
                    progress(f"{tasks[i][0].name} × {tasks[i][1]}")
            batch = [(tasks[i][0].name, tasks[i][1],
                      record_seed(opts.seed, tasks[i][0].name, tasks[i][1]))
                     for i in tpu_idx]
            futures.append((tpu_idx, pool.submit(
                _worker_run_batch, batch, opts.quick)))
        else:
            solo_idx = sorted(solo_idx + tpu_idx, key=lambda i: -cost(i))
        for i in solo_idx:
            exp, dev = tasks[i]
            if progress:
                progress(f"{exp.name} × {dev}")
            futures.append(([i], pool.submit(
                _worker_run_batch,
                [(exp.name, dev, record_seed(opts.seed, exp.name, dev))],
                opts.quick)))
        for idxs, fut in futures:
            for i, rec in zip(idxs, fut.result()):
                results[i] = rec
    # original task order, not completion or submission order
    return results


def records_to_rows(records: Iterable[ExperimentRecord]) -> list[Row]:
    """Flatten records into the legacy ``name,us_per_call,derived`` rows."""
    rows: list[Row] = []
    for rec in records:
        for m in rec.metrics:
            derived = f"{m.measured}"
            if m.unit:
                derived += f"{m.unit}"
            if m.cmp != "info":
                derived += f" [expect {m.expected} -> {m.verdict}]"
            if m.detail:
                derived += f" ({m.detail})"
            rows.append((f"{rec.experiment}/{rec.device}/{m.name}", m.us,
                         derived.replace(",", ";")))
        if rec.error:
            rows.append((f"{rec.experiment}/{rec.device}/ERROR", 0.0,
                         rec.error.strip().splitlines()[-1].replace(",", ";")))
    return rows
