"""Execute registered experiments across registered devices.

One :class:`~repro.bench.result.ExperimentRecord` per experiment × device.
The runner never imports individual benchmark modules — it only sees the
registry — so adding an experiment is one decorated function in
``benchmarks/`` and nothing else.
"""

from __future__ import annotations

import dataclasses
import time
import traceback
from typing import Callable, Iterable

from repro.bench import registry
from repro.bench.registry import Context, Experiment
from repro.bench.result import ExperimentRecord, Metric
from repro.core import devices as device_registry

Row = tuple[str, float, str]     # legacy CSV row: name, us_per_call, derived


@dataclasses.dataclass(frozen=True)
class RunOptions:
    device: str | None = None          # restrict to one device
    tag: str | None = None
    section: str | None = None
    names: tuple[str, ...] = ()
    quick: bool = False
    seed: int = 0


def run_experiments(opts: RunOptions = RunOptions(),
                    progress: Callable[[str], None] | None = None,
                    ) -> list[ExperimentRecord]:
    """Run the selected experiments on every applicable device."""
    exps = registry.select(device=opts.device, tag=opts.tag,
                           section=opts.section, names=opts.names or None)
    records: list[ExperimentRecord] = []
    for exp in exps:
        for dev_name in exp.devices:
            if opts.device and dev_name != opts.device:
                continue
            if progress:
                progress(f"{exp.name} × {dev_name}")
            records.append(run_one(exp, dev_name, quick=opts.quick,
                                   seed=opts.seed))
    return records


def run_one(exp: Experiment, device: str, quick: bool = False,
            seed: int = 0) -> ExperimentRecord:
    ctx = Context(device=device_registry.get_device(device), quick=quick,
                  seed=seed)
    t0 = time.perf_counter()
    metrics: list[Metric] = []
    error = None
    try:
        metrics = list(exp.run(ctx))
    except Exception:
        error = traceback.format_exc(limit=8)
    return ExperimentRecord(
        experiment=exp.name, device=device, section=exp.section,
        artifact=exp.artifact, metrics=metrics,
        elapsed_s=time.perf_counter() - t0, error=error)


def records_to_rows(records: Iterable[ExperimentRecord]) -> list[Row]:
    """Flatten records into the legacy ``name,us_per_call,derived`` rows."""
    rows: list[Row] = []
    for rec in records:
        for m in rec.metrics:
            derived = f"{m.measured}"
            if m.unit:
                derived += f"{m.unit}"
            if m.cmp != "info":
                derived += f" [expect {m.expected} -> {m.verdict}]"
            if m.detail:
                derived += f" ({m.detail})"
            rows.append((f"{rec.experiment}/{rec.device}/{m.name}", m.us,
                         derived.replace(",", ";")))
        if rec.error:
            rows.append((f"{rec.experiment}/{rec.device}/ERROR", 0.0,
                         rec.error.strip().splitlines()[-1].replace(",", ";")))
    return rows
