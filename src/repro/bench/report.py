"""Markdown rendering: the verdict report and the generated experiment docs.

``render_report`` turns a list of records (or a JSON artifact) into the
human-readable verdict table each PR diffs against its baseline;
``experiments_doc`` renders ``docs/experiments.md`` purely from registry
metadata so the docs cannot drift from the code.
"""

from __future__ import annotations

from repro.bench import registry
from repro.bench.result import DEVIATION, ERROR, ExperimentRecord, summarize
from repro.core import devices as device_registry


def _md_escape(v: object) -> str:
    return str(v).replace("|", "\\|").replace("\n", " ")


def render_report(records: list[ExperimentRecord], title: str = "Dissection report") -> str:
    """The per-run verdict report (experiment × device)."""
    s = summarize(records)
    lines = [
        f"# {title}",
        "",
        f"**{s['PASS']} PASS · {s['DEVIATION']} DEVIATION · "
        f"{s['ERROR']} ERROR · {s['INFO']} info-only** "
        f"({len(records)} experiment×device records)",
        "",
        "| Experiment | Device | Paper artifact | Verdict | Time (s) | Deviations |",
        "|---|---|---|---|---:|---|",
    ]
    for r in records:
        devs = "; ".join(
            f"{m.name}: {m.measured} vs {m.expected}" for m in r.deviations)
        if r.error:
            devs = r.error.strip().splitlines()[-1]
        lines.append(
            f"| {r.experiment} | {r.device} | {r.artifact} ({r.section}) "
            f"| {r.verdict} | {r.elapsed_s:.2f} | {_md_escape(devs)} |")
    # harness-speed ledger: stable experiment×device order so successive
    # reports diff cleanly when a record regresses
    total = sum(r.elapsed_s for r in records)
    lines += ["", "## Harness wall time", "",
              f"**total {total:.2f} s across {len(records)} records**", "",
              "| Experiment | Device | elapsed_s |", "|---|---|---:|"]
    for r in sorted(records, key=lambda r: (r.experiment, r.device)):
        lines.append(f"| {r.experiment} | {r.device} | {r.elapsed_s:.2f} |")
    # per-record metric detail
    for r in records:
        lines += ["", f"## {r.experiment} × {r.device} — {r.verdict}", ""]
        if r.error:
            lines += ["```", r.error.strip(), "```"]
            continue
        lines += [
            "| Metric | Measured | Expected | Rule | Verdict |",
            "|---|---|---|---|---|",
        ]
        for m in r.metrics:
            exp = "—" if m.cmp == "info" else _md_escape(m.expected)
            rule = m.cmp if m.cmp in ("eq", "info", "range") else (
                f"{m.cmp} ±{m.tol:g}")
            meas = _md_escape(m.measured)
            if m.unit:
                meas += f" {m.unit}"
            lines.append(f"| {m.name} | {meas} | {exp} | {rule} "
                         f"| {m.verdict} |")
    return "\n".join(lines) + "\n"


DOC_HEADER = """\
# Experiment catalogue

<!-- GENERATED FILE — do not edit by hand.
     Regenerate with: PYTHONPATH=src python -m repro.bench docs -->

Every experiment below registers itself with `repro.bench` via the
`@experiment` decorator in its `benchmarks/<name>.py` module; this table is
rendered from that registry metadata (`python -m repro.bench docs`), so it
cannot drift from the code.  Run any subset with
`python -m repro.bench run --only <name>` and render verdicts with
`python -m repro.bench report`.
"""


def experiments_doc() -> str:
    """Render docs/experiments.md from the registry (discover() first)."""
    exps = registry.all_experiments()
    lines = [
        DOC_HEADER,
        "| Experiment | Paper artifact | Section | Devices | Tags |",
        "|---|---|---|---|---|",
    ]
    for e in exps:
        lines.append(
            f"| `{e.name}` | {e.artifact} | {e.section} "
            f"| {', '.join(e.devices)} | {', '.join(e.tags) or '—'} |")
    lines += ["", "## Paper-published expected values", ""]
    for e in exps:
        lines += [f"### `{e.name}` — {e.title}", ""]
        if not e.expected:
            lines += ["(beyond-paper experiment: sanity bounds only)", ""]
            continue
        lines += ["| Claim | Paper value |", "|---|---|"]
        for claim, value in e.expected.items():
            lines.append(f"| {_md_escape(claim)} | {_md_escape(value)} |")
        lines.append("")
    lines += ["## Registered devices", "",
              "| Device | Kind | Generation |", "|---|---|---|"]
    for d in device_registry.list_devices():
        lines.append(f"| {d.name} | {d.kind} | {d.generation} |")
    return "\n".join(lines) + "\n"
