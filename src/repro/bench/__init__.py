"""Registry-driven dissection harness (see DESIGN.md §5).

``benchmarks/*.py`` modules self-register experiments with paper
provenance (section, figure/table, expected values); the runner executes
any subset across the registered device models and emits JSON artifacts
with PASS/DEVIATION verdicts plus the legacy CSV rows.

CLI: ``python -m repro.bench {list,run,report,docs}``.
"""

from repro.bench.registry import (Context, Experiment, REGISTRY,
                                  all_experiments, discover, experiment, get,
                                  select)
from repro.bench.result import (DEVIATION, ERROR, INFO, PASS,
                                ExperimentRecord, Metric, info,
                                load_artifact, summarize, write_artifact)
from repro.bench.runner import (RunOptions, records_to_rows, run_experiments,
                                run_one)
from repro.bench.report import experiments_doc, render_report

__all__ = [
    "Context", "Experiment", "REGISTRY", "all_experiments", "discover",
    "experiment", "get", "select",
    "DEVIATION", "ERROR", "INFO", "PASS", "ExperimentRecord", "Metric",
    "info", "load_artifact", "summarize", "write_artifact",
    "RunOptions", "records_to_rows", "run_experiments", "run_one",
    "experiments_doc", "render_report",
]
