"""Structured results for the dissection harness.

Every experiment emits :class:`Metric` values — one measured number (or
label) next to the paper's published expectation and a comparison rule.
The runner folds the metrics of one experiment × device run into an
:class:`ExperimentRecord` carrying a single PASS/DEVIATION verdict, and a
list of records round-trips through the JSON artifact
(``schema = "repro.bench/v1"``) that CI diffs against its baseline.

Comparison rules (``cmp``):

* ``close`` — relative error ``|m - e| <= tol * max(1, |e|)`` (default)
* ``eq``    — exact equality (ints, strings, bools)
* ``le`` / ``ge`` — one-sided bounds, slack ``tol * max(1, |e|)``
* ``range`` — expected is ``[lo, hi]``, inclusive
* ``info``  — no expectation; never affects the verdict
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any

SCHEMA = "repro.bench/v1"

PASS = "PASS"
DEVIATION = "DEVIATION"
INFO = "INFO"
ERROR = "ERROR"

_CMPS = ("close", "eq", "le", "ge", "range", "info")


@dataclasses.dataclass(frozen=True)
class Metric:
    """One measured quantity with its paper-published expectation."""

    name: str
    measured: Any
    expected: Any = None
    cmp: str = "close"
    tol: float = 0.05
    unit: str = ""
    detail: str = ""
    us: float = 0.0          # wall-time of the underlying measurement

    def __post_init__(self) -> None:
        if self.cmp not in _CMPS:
            raise ValueError(f"unknown cmp {self.cmp!r}; one of {_CMPS}")
        if self.cmp != "info" and self.expected is None:
            raise ValueError(f"metric {self.name!r}: cmp={self.cmp!r} "
                             "requires an expected value")
        # numpy scalars would stringify in the JSON artifact and then fail
        # eq comparisons on reload; normalize to native Python types here
        for field in ("measured", "expected"):
            v = getattr(self, field)
            if hasattr(v, "item") and not isinstance(v, (str, bytes)):
                object.__setattr__(self, field, v.item())

    @property
    def verdict(self) -> str:
        if self.cmp == "info":
            return INFO
        m, e = self.measured, self.expected
        if self.cmp == "eq":
            return PASS if m == e else DEVIATION
        try:
            m = float(m)
        except (TypeError, ValueError):
            return DEVIATION
        if self.cmp == "range":
            lo, hi = float(e[0]), float(e[1])
            return PASS if lo <= m <= hi else DEVIATION
        e = float(e)
        slack = self.tol * max(1.0, abs(e))
        if self.cmp == "close":
            return PASS if abs(m - e) <= slack else DEVIATION
        if self.cmp == "le":
            return PASS if m <= e + slack else DEVIATION
        return PASS if m >= e - slack else DEVIATION      # ge

    def to_json(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["verdict"] = self.verdict
        return d

    @classmethod
    def from_json(cls, d: dict[str, Any]) -> "Metric":
        d = {k: v for k, v in d.items() if k != "verdict"}
        return cls(**d)


def info(name: str, measured: Any, *, unit: str = "", detail: str = "",
         us: float = 0.0) -> Metric:
    """Shorthand for a verdict-neutral metric."""
    return Metric(name, measured, cmp="info", unit=unit, detail=detail, us=us)


@dataclasses.dataclass
class ExperimentRecord:
    """One experiment × device run: metrics plus the folded verdict."""

    experiment: str
    device: str
    section: str
    artifact: str
    metrics: list[Metric]
    elapsed_s: float = 0.0
    error: str | None = None

    @property
    def verdict(self) -> str:
        if self.error is not None:
            return ERROR
        vs = [m.verdict for m in self.metrics]
        if DEVIATION in vs:
            return DEVIATION
        return PASS if PASS in vs else INFO

    @property
    def deviations(self) -> list[Metric]:
        return [m for m in self.metrics if m.verdict == DEVIATION]

    def to_json(self) -> dict[str, Any]:
        return {
            "experiment": self.experiment,
            "device": self.device,
            "section": self.section,
            "artifact": self.artifact,
            "verdict": self.verdict,
            "elapsed_s": round(self.elapsed_s, 3),
            "error": self.error,
            "metrics": [m.to_json() for m in self.metrics],
        }

    @classmethod
    def from_json(cls, d: dict[str, Any]) -> "ExperimentRecord":
        return cls(
            experiment=d["experiment"], device=d["device"],
            section=d["section"], artifact=d["artifact"],
            metrics=[Metric.from_json(m) for m in d["metrics"]],
            elapsed_s=d.get("elapsed_s", 0.0), error=d.get("error"))


def write_artifact(records: list[ExperimentRecord], path: str,
                   extra: dict[str, Any] | None = None) -> dict[str, Any]:
    """Write the JSON artifact; returns the serialized payload."""
    # no timestamp: the artifact is committed as a baseline and must not
    # churn when results are identical
    payload = {
        "schema": SCHEMA,
        "summary": summarize(records),
        "records": [r.to_json() for r in records],
    }
    if extra:
        payload.update(extra)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True, default=str)
        fh.write("\n")
    return payload


def load_artifact(path: str) -> list[ExperimentRecord]:
    with open(path) as fh:
        payload = json.load(fh)
    if payload.get("schema") != SCHEMA:
        raise ValueError(f"{path}: unknown schema {payload.get('schema')!r}")
    return [ExperimentRecord.from_json(r) for r in payload["records"]]


def summarize(records: list[ExperimentRecord]) -> dict[str, int]:
    out = {PASS: 0, DEVIATION: 0, INFO: 0, ERROR: 0}
    for r in records:
        out[r.verdict] += 1
    return out
