"""hubert-xlarge [audio] — 48L d_model=1280 16H d_ff=5120 vocab=504 —
encoder-only; the conv feature extractor is a STUB (input_specs supplies
precomputed 512-dim frame embeddings) per the assignment
[arXiv:2106.07447]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    d_ff=5120,
    vocab_size=504,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    is_encoder=True,
    causal=False,
    frontend="audio",
    frontend_dim=512,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, d_ff=128, vocab_size=64,
        num_heads=4, num_kv_heads=4, head_dim=16, frontend_dim=32,
        dtype="float32", param_dtype="float32")
