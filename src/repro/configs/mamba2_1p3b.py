"""mamba2-1.3b [ssm] — 48L d_model=2048 (attn-free) vocab=50280,
ssm_state=128, SSD (state-space duality) [arXiv:2405.21060]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    ssm_groups=1,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, vocab_size=256, ssm_state=16,
        ssm_head_dim=16, ssm_chunk=16, dtype="float32",
        param_dtype="float32")
