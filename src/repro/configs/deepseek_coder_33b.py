"""deepseek-coder-33b [dense] — 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256 — llama-arch [arXiv:2401.14196; hf]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    num_layers=62,
    d_model=7168,
    d_ff=19200,
    vocab_size=32256,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=56, d_ff=128, vocab_size=256,
        num_heads=4, num_kv_heads=2, head_dim=14, dtype="float32",
        param_dtype="float32")
