"""The four assigned input shapes + per-cell applicability + input specs.

Shapes (assignment):
  train_4k     seq 4,096   global_batch 256   (training      -> train_step)
  prefill_32k  seq 32,768  global_batch 32    (inference     -> prefill_step)
  decode_32k   seq 32,768  global_batch 128   (decode        -> serve_step)
  long_500k    seq 524,288 global_batch 1     (long decode   -> serve_step)

Applicability rules (DESIGN.md §6): long_500k needs sub-quadratic mixing
(SSM/hybrid only); encoder-only architectures have no decode step.
``input_specs`` returns weak-type-correct ShapeDtypeStructs — no device
allocation — exactly what the dry-run lowers against.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    if shape.kind == "decode" and cfg.is_encoder:
        return False, "encoder-only architecture has no decode step"
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, ("full quadratic attention at 512K context; "
                       "long_500k requires sub-quadratic mixing (SSM/hybrid)")
    return True, ""


def supported_cells(cfg: ModelConfig) -> list[ShapeSpec]:
    return [s for s in SHAPES.values() if cell_supported(cfg, s)[0]]


def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                scale: int = 1) -> dict[str, jax.ShapeDtypeStruct]:
    """Step-function inputs for the cell (divide batch/seq by `scale` for
    reduced smoke runs)."""
    b = max(1, shape.global_batch // scale)
    s = max(128, shape.seq_len // scale) if scale > 1 else shape.seq_len
    i32 = jnp.int32
    f = cfg.activation_dtype

    if shape.kind in ("train", "prefill"):
        specs: dict[str, jax.ShapeDtypeStruct] = {}
        s_text = s
        if cfg.frontend == "vision":
            patches = min(cfg.num_patches, s // 2)
            s_text = s - patches
            specs["patches"] = jax.ShapeDtypeStruct(
                (b, patches, cfg.frontend_dim), f)
            specs["tokens"] = jax.ShapeDtypeStruct((b, s_text), i32)
        elif cfg.frontend == "audio":
            specs["frames"] = jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), f)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        return specs

    # decode: one new token against an S-slot cache (built via eval_shape on
    # init_cache by the caller — the cache is a step *argument*)
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "cache_index": jax.ShapeDtypeStruct((), i32),
    }
