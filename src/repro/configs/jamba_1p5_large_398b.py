"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2 — Mamba+attention 1:7 interleave
(one attention layer per 8), MoE every 2nd layer [arXiv:2403.19887; hf].

Note (DESIGN.md §7): Jamba's Mamba-1 block is realized with the SSD
(mamba2) block at the same state size/expansion — the duality-equivalent
formulation this framework implements.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    d_ff=24576,
    vocab_size=65536,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    num_experts=16,
    top_k=2,
    d_ff_expert=24576,
    attn_period=8,
    moe_period=2,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    ssm_groups=1,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=8, d_model=64, d_ff=128, vocab_size=256,
        num_heads=4, num_kv_heads=2, head_dim=16, num_experts=4, top_k=2,
        d_ff_expert=64, ssm_state=8, ssm_head_dim=16, ssm_chunk=16,
        dtype="float32", param_dtype="float32")
