"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16e top-2 [hf:microsoft/Phi-3.5-MoE-instruct; hf]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    d_ff=6400,
    vocab_size=32064,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    num_experts=16,
    top_k=2,
    d_ff_expert=6400,
    rope_theta=1e4,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, d_ff=128, vocab_size=256,
        num_heads=4, num_kv_heads=2, head_dim=16, num_experts=4, top_k=2,
        d_ff_expert=64, dtype="float32", param_dtype="float32")
