"""mistral-large-123b [dense] — 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768 [hf:mistralai/Mistral-Large-Instruct-2407]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12288,
    d_ff=28672,
    vocab_size=32768,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    rope_theta=1e6,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, d_ff=128, vocab_size=256,
        num_heads=8, num_kv_heads=2, head_dim=8, dtype="float32",
        param_dtype="float32")
