"""minitron-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000 — pruned nemotron [arXiv:2407.14679; hf]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    d_ff=16384,
    vocab_size=256000,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, d_ff=128, vocab_size=512,
        num_heads=4, num_kv_heads=2, head_dim=16, dtype="float32",
        param_dtype="float32")
