"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff=1408 vocab=102400,
MoE 64e top-6, MLA kv_lora=512, 2 shared experts [arXiv:2405.04434; hf]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    d_ff=1408,
    vocab_size=102400,
    num_heads=16,
    num_kv_heads=16,
    use_mla=True,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    head_dim=128,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    d_ff_expert=1408,
    rope_theta=1e4,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, d_ff=96, vocab_size=256,
        num_heads=4, num_kv_heads=4, kv_lora_rank=32, qk_nope_dim=16,
        qk_rope_dim=8, v_head_dim=16, head_dim=16, num_experts=8, top_k=2,
        num_shared_experts=1, d_ff_expert=48, dtype="float32",
        param_dtype="float32")
