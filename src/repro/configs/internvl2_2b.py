"""internvl2-2b [vlm] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553 — InternLM2 backbone; the InternViT frontend is a STUB
(input_specs supplies precomputed 1024-dim patch embeddings, 256 patches
prepended to the text sequence) [arXiv:2404.16821; hf]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    d_ff=8192,
    vocab_size=92553,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    frontend="vision",
    frontend_dim=1024,
    num_patches=256,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, d_ff=128, vocab_size=256,
        num_heads=4, num_kv_heads=2, head_dim=16, frontend_dim=48,
        num_patches=8, dtype="float32", param_dtype="float32")
