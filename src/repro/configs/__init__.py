"""Architecture registry: ``--arch <id>`` resolves here."""

from repro.configs import (
    deepseek_coder_33b,
    deepseek_v2_lite_16b,
    granite_8b,
    hubert_xlarge,
    internvl2_2b,
    jamba_1p5_large_398b,
    mamba2_1p3b,
    minitron_8b,
    mistral_large_123b,
    phi35_moe_42b,
)
from repro.configs.shapes import (  # noqa: F401
    SHAPES, ShapeSpec, cell_supported, input_specs, supported_cells,
)

_MODULES = {
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b,
    "phi3.5-moe-42b-a6.6b": phi35_moe_42b,
    "mamba2-1.3b": mamba2_1p3b,
    "mistral-large-123b": mistral_large_123b,
    "minitron-8b": minitron_8b,
    "granite-8b": granite_8b,
    "deepseek-coder-33b": deepseek_coder_33b,
    "hubert-xlarge": hubert_xlarge,
    "internvl2-2b": internvl2_2b,
    "jamba-1.5-large-398b": jamba_1p5_large_398b,
}


def list_archs() -> list[str]:
    return list(_MODULES)


def get_config(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {list_archs()}")
    return _MODULES[arch].CONFIG


def get_smoke_config(arch: str):
    return _MODULES[arch].smoke_config()
