"""Step functions: training (with grad accumulation + optional gradient
compression), prefill and decode/serve.  These are exactly the functions the
multi-pod dry-run lowers and the roofline analyzes."""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.parallel import compression
from repro.parallel.sharding import constrain


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: dict
    step: jax.Array
    ef_state: Any = None          # error-feedback residuals (compression)

    def tree_flatten(self):
        return ((self.params, self.opt_state, self.step, self.ef_state), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState, TrainState.tree_flatten, TrainState.tree_unflatten)


def init_state(cfg: ModelConfig, opt: AdamWConfig, key,
               compress: bool = False) -> TrainState:
    params = T.init_params(cfg, key)
    ef = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
          if compress else None)
    return TrainState(params, adamw_init(params, opt),
                      jnp.zeros((), jnp.int32), ef)


def loss_fn(params, cfg: ModelConfig, batch) -> tuple[jax.Array, dict]:
    logits, aux = T.forward(params, cfg, batch)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:   # vision prefix already included
        raise ValueError("labels must cover the full (patch+text) sequence")
    valid = labels >= 0
    lab = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(valid.sum(), 1)
    ce = jnp.where(valid, nll, 0.0).sum() / denom
    total = ce + aux
    return total, {"loss": total, "ce": ce, "aux": aux,
                   "tokens": denom.astype(jnp.float32)}


def make_train_step(cfg: ModelConfig, opt: AdamWConfig, *,
                    lr_fn: Callable | None = None, microbatches: int = 1,
                    compress_grads: bool = False):
    """Returns step(state, batch) -> (state, metrics).

    microbatches > 1 splits the batch and accumulates grads with lax.scan
    (activation memory / step-time trade — a §Perf knob).
    """

    def grads_of(params, batch):
        return jax.value_and_grad(lambda p: loss_fn(p, cfg, batch),
                                  has_aux=False)(params)

    def value_and_grads(params, batch):
        (tot, metrics), g = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
        return tot, metrics, g

    def step(state: TrainState, batch) -> tuple[TrainState, dict]:
        params = state.params
        if microbatches > 1:
            def mb(carry, mbatch):
                acc = carry
                _, metrics, g = value_and_grads(params, mbatch)
                return jax.tree.map(jnp.add, acc, g), metrics
            split = jax.tree.map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                    *x.shape[1:]), batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            gsum, metrics = jax.lax.scan(mb, zero, split)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            _, metrics, grads = value_and_grads(params, batch)

        ef = state.ef_state
        if compress_grads:
            grads, ef = compression.compress_tree(grads, ef)

        lr = lr_fn(state.step) if lr_fn else opt.lr
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt_state, params, opt, lr)
        metrics.update(opt_metrics)
        metrics["lr"] = jnp.asarray(lr, jnp.float32)
        return TrainState(new_params, new_opt, state.step + 1, ef), metrics

    return step


def make_prefill_step(cfg: ModelConfig, *, max_len: int):
    def prefill_step(params, batch):
        return T.prefill(params, cfg, batch, max_len=max_len)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One batched decode step: (params, cache, tokens, index) -> (logits,
    cache).  This is what decode_32k / long_500k lower."""

    def serve_step(params, cache, tokens, cache_index):
        return T.decode(params, cfg, cache, tokens, cache_index)

    return serve_step
