"""Fault tolerance: watchdog, preemption-safe training, elastic resharding.

Pieces:
* :class:`StepWatchdog` — per-step timing EMA; flags stragglers (steps
  slower than ``factor``×EMA) and exposes counters a cluster agent would
  alarm on.  On real pods this wraps the per-host step; here it is unit
  tested directly.
* :func:`run_training` — checkpoint/restart loop: saves every
  ``ckpt_every`` steps, auto-resumes from the latest checkpoint, and
  optionally raises a simulated preemption.  The integration test kills a
  run mid-flight, restarts it, and asserts bit-identical final params vs an
  uninterrupted run (deterministic data pipeline + stateless step make this
  exact).
* Elasticity = checkpoint + ``restore(shardings=...)`` onto a different
  mesh (see tests/test_distributed.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class StepWatchdog:
    straggler_factor: float = 3.0
    ema_decay: float = 0.9
    ema: float | None = None
    stragglers: int = 0
    steps: int = 0
    last_duration: float = 0.0

    def record(self, duration_s: float) -> bool:
        """Returns True if this step is a straggler."""
        self.steps += 1
        self.last_duration = duration_s
        is_straggler = (self.ema is not None and
                        duration_s > self.straggler_factor * self.ema)
        if is_straggler:
            self.stragglers += 1
            # do not fold outliers into the EMA: keeps the threshold stable
            return True
        self.ema = (duration_s if self.ema is None else
                    self.ema_decay * self.ema +
                    (1 - self.ema_decay) * duration_s)
        return False


class SimulatedPreemption(RuntimeError):
    pass


def run_training(state, step_fn: Callable, data_iter_fn: Callable[[int], Any],
                 *, num_steps: int, ckpt_dir: str | None = None,
                 ckpt_every: int = 50, preempt_at: int | None = None,
                 watchdog: StepWatchdog | None = None,
                 on_metrics: Callable | None = None):
    """Checkpoint/restart training driver.

    ``data_iter_fn(step)`` must return the batch for that *global* step —
    the determinism contract that makes restarts exact.
    """
    start = 0
    if ckpt_dir is not None and ckpt.latest_step(ckpt_dir) is not None:
        state, start = ckpt.restore(ckpt_dir, state)

    metrics = None
    for step in range(start, num_steps):
        if preempt_at is not None and step == preempt_at:
            raise SimulatedPreemption(f"preempted at step {step}")
        t0 = time.perf_counter()
        state, metrics = step_fn(state, data_iter_fn(step))
        jax.block_until_ready(metrics)
        if watchdog is not None:
            watchdog.record(time.perf_counter() - t0)
        if on_metrics is not None:
            on_metrics(step, jax.tree.map(np.asarray, metrics))
        if ckpt_dir is not None and (step + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, step + 1, state)
    if ckpt_dir is not None:
        ckpt.save(ckpt_dir, num_steps, state)
    return state, metrics
