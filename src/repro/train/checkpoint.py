"""Mesh-agnostic checkpointing: sharded save, resharding restore.

Leaves are saved by flattened keypath into one ``.npz`` per checkpoint step
plus a JSON manifest (step, shapes, dtypes).  Restore takes an optional
``shardings`` pytree and ``device_put``s each leaf onto it — which is the
elasticity path: a checkpoint written on a 512-chip mesh restores onto
whatever mesh is alive (the fault-tolerance tests exercise 1-host
shrink/grow).  Writes are atomic (tmp + rename) and a retention policy
keeps the newest k steps.
"""

from __future__ import annotations

import json
import os
import re

import jax
import numpy as np

_SEP = "//"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_into(template, flat: dict[str, np.ndarray], shardings=None):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    leaves = []
    for (path, leaf), shd in zip(paths, shard_leaves):
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        arr = flat[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        leaves.append(jax.device_put(arr, shd) if shd is not None else arr)
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    tmp = os.path.join(ckpt_dir, f".tmp-{step}.npz")
    final = os.path.join(ckpt_dir, f"step-{step:08d}.npz")
    np.savez(tmp, **flat)
    os.replace(tmp, final)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
    }
    mtmp = os.path.join(ckpt_dir, f".tmp-{step}.json")
    with open(mtmp, "w") as f:
        json.dump(manifest, f)
    os.replace(mtmp, os.path.join(ckpt_dir, f"step-{step:08d}.json"))
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep else []:
        for ext in (".npz", ".json"):
            p = os.path.join(ckpt_dir, f"step-{s:08d}{ext}")
            if os.path.exists(p):
                os.remove(p)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step-(\d+)\.npz", name)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, template, *, step: int | None = None,
            shardings=None):
    """Load a checkpoint into the template structure (resharding onto
    `shardings` if given).  Returns (tree, step)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    with np.load(os.path.join(ckpt_dir, f"step-{step:08d}.npz")) as z:
        flat = {k: z[k] for k in z.files}
    return _unflatten_into(template, flat, shardings), step
