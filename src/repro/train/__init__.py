from repro.train.loop import (  # noqa: F401
    TrainState, loss_fn, make_serve_step, make_train_step, make_prefill_step,
)
