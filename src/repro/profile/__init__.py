"""Profile pipeline: blind-dissect a backend into a DeviceProfile.

``pipeline.dissect_device`` runs the full blind-recovery suite against a
registered device; ``store`` persists/validates the versioned JSON
artifacts under ``experiments/profiles/``; ``diffing`` renders the
measured-vs-published verdict table.  The :class:`~repro.core.profile.
DeviceProfile` dataclass itself lives in ``repro.core.profile`` so core
consumers never import this (heavier) pipeline layer.
"""

from repro.core.profile import (            # noqa: F401  (re-exports)
    PROFILE_SCHEMA, CacheProfile, DeviceProfile, SpecMixWarning,
    registry_fingerprint, resolve_spec, set_default_profile, use_profile,
)
from repro.profile.diffing import DiffRow, diff_profiles, render_diff  # noqa: F401
from repro.profile.pipeline import dissect_device, published_profile   # noqa: F401
from repro.profile.store import (           # noqa: F401
    DEFAULT_ROOT, install_profile, load_profile, path_for, save_profile,
    validate_all, validate_file,
)
