"""Persist / load / validate ``repro.profile/v1`` artifacts.

One JSON file per device under ``experiments/profiles/``; writes are
atomic (tmp + rename) like the rest of the repo's artifact stores.  The
validator is what CI runs: schema shape, provenance legality, and
staleness — a committed profile dissected under an older trace-engine
version or a different device registry must fail the build, because its
``measured`` numbers can no longer be reproduced.
"""

from __future__ import annotations

import json
import os

from repro.core.profile import (
    MEASURED, PROFILE_SCHEMA, PUBLISHED, DeviceProfile,
)

DEFAULT_ROOT = os.path.join("experiments", "profiles")


def path_for(device: str, root: str | None = None) -> str:
    return os.path.join(root or DEFAULT_ROOT, f"{device}.json")


def save_profile(prof: DeviceProfile, path: str | None = None) -> str:
    path = path or path_for(prof.device)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(prof.to_json(), fh, indent=2, sort_keys=True)
        fh.write("\n")
    os.replace(tmp, path)
    return path


def load_profile(device_or_path: str, root: str | None = None) -> DeviceProfile:
    """Load by artifact path, or by device name from the profile root."""
    path = (device_or_path if device_or_path.endswith(".json")
            else path_for(device_or_path, root))
    with open(path) as fh:
        return DeviceProfile.from_json(json.load(fh))


def install_profile(device_or_path: str, *,
                    require_kind: str = "tpu") -> DeviceProfile:
    """Launcher entry point: load, vet, and activate a profile.

    One contract for every ``--profile`` flag (launch.serve, launch.perf):
    wrong-kind and stale artifacts fail *here*, at startup, with an
    actionable message — not minutes later inside a consumer.  Raises
    ``SystemExit``; returns the installed profile.
    """
    from repro.core.profile import set_default_profile
    prof = load_profile(device_or_path)
    if require_kind and prof.kind != require_kind:
        raise SystemExit(
            f"profile {device_or_path} is kind={prof.kind!r} "
            f"({prof.device}); these consumers need a {require_kind}-family "
            f"profile (e.g. {path_for('tpu_v5e')})")
    stale = prof.is_stale()
    if stale:
        raise SystemExit(
            f"profile {device_or_path} is stale: {stale}; re-dissect with "
            f"`python -m repro.bench profile dissect {prof.device}`")
    set_default_profile(prof)
    return prof


# ---------------------------------------------------------------------------
# validation (the CI stage)
# ---------------------------------------------------------------------------

_REQUIRED_KEYS = ("schema", "device", "kind", "engine_version",
                  "registry_hash", "caches", "latency",
                  "latency_provenance", "bandwidth", "spec",
                  "spec_provenance")


def validate_file(path: str) -> list[str]:
    """Problems with one committed artifact (empty list = valid + fresh)."""
    problems: list[str] = []
    try:
        with open(path) as fh:
            raw = json.load(fh)
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable: {e}"]
    if raw.get("schema") != PROFILE_SCHEMA:
        return [f"schema {raw.get('schema')!r} != {PROFILE_SCHEMA!r}"]
    for key in _REQUIRED_KEYS:
        if key not in raw:
            problems.append(f"missing required key {key!r}")
    if problems:
        return problems
    try:
        prof = DeviceProfile.from_json(raw)
    except (TypeError, ValueError) as e:
        return [f"malformed: {e}"]
    for sec_name, values, prov in (
            ("latency", prof.latency, prof.latency_provenance),
            ("bandwidth", prof.bandwidth, prof.bandwidth_provenance),
            ("spec", prof.spec, prof.spec_provenance)):
        missing = set(values) - set(prov)
        if missing:
            problems.append(
                f"{sec_name}: fields without provenance: {sorted(missing)}")
        bad = {k: v for k, v in prov.items() if v not in (MEASURED, PUBLISHED)}
        if bad:
            problems.append(f"{sec_name}: illegal provenance {bad}")
    base = os.path.splitext(os.path.basename(path))[0]
    if base != prof.device:
        problems.append(f"filename {base!r} != device {prof.device!r}")
    problems.extend(f"stale: {p}" for p in prof.is_stale())
    return problems


def validate_all(root: str | None = None) -> dict[str, list[str]]:
    """``{path: problems}`` for every ``*.json`` under the profile root."""
    root = root or DEFAULT_ROOT
    out: dict[str, list[str]] = {}
    if not os.path.isdir(root):
        return out
    for name in sorted(os.listdir(root)):
        if name.endswith(".json"):
            path = os.path.join(root, name)
            out[path] = validate_file(path)
    return out
