"""Measured-vs-published profile diff: the per-field verdict table.

Rules follow the repo's bench conventions: structural parameters (size,
line/sector, sets, ways, replacement class, mapping bits) must match
EXACTLY; latency classes are held to a relative tolerance; sustained
bandwidths may sit at or below the published peak (``le``); replacement
probabilities compare sorted (way labels are unobservable, Fig 11).  A
measured ``set_bits`` of ``None`` under stochastic replacement is
reported but not failed — the conflict-stride probe needs deterministic
thrashing, which non-LRU policies deny (the paper recovered Fermi's split
field from miss *addresses*, §4.5).
"""

from __future__ import annotations

import dataclasses

from repro.core.profile import MEASURED, PUBLISHED, DeviceProfile

LATENCY_TOL = 0.02
BANDWIDTH_TOL = 0.05
WAY_PROB_TOL = 0.05


@dataclasses.dataclass(frozen=True)
class DiffRow:
    field: str
    measured: object
    published: object
    rule: str                  # "eq" | "close" | "le" | "probs" | "info"
    ok: bool
    note: str = ""


def _close(m: float, e: float, tol: float) -> bool:
    return abs(float(m) - float(e)) <= tol * max(1.0, abs(float(e)))


def _diff_cache(name: str, m, p) -> list[DiffRow]:
    rows = [
        DiffRow(f"{name}/size_bytes", m.size_bytes, p.size_bytes, "eq",
                m.size_bytes == p.size_bytes),
        DiffRow(f"{name}/line_bytes", m.line_bytes, p.line_bytes, "eq",
                m.line_bytes == p.line_bytes),
        DiffRow(f"{name}/num_sets", m.num_sets, p.num_sets, "eq",
                m.num_sets == p.num_sets),
        DiffRow(f"{name}/way_counts", sorted(m.way_counts),
                sorted(p.way_counts), "eq",
                sorted(m.way_counts) == sorted(p.way_counts)),
        DiffRow(f"{name}/is_lru", m.is_lru, p.is_lru, "eq",
                m.is_lru == p.is_lru),
    ]
    if p.set_bits is not None:
        if m.set_bits is None:
            rows.append(DiffRow(
                f"{name}/set_bits", None, list(p.set_bits), "info", True,
                "not probeable (stochastic replacement denies deterministic "
                "thrashing)" if not m.is_lru else "probe found no conflict "
                "stride"))
        else:
            rows.append(DiffRow(f"{name}/set_bits", list(m.set_bits),
                                list(p.set_bits), "eq",
                                list(m.set_bits) == list(p.set_bits)))
    if p.way_probs:
        if m.way_probs:
            err = max(abs(a - b) for a, b in
                      zip(sorted(m.way_probs), sorted(p.way_probs)))
            rows.append(DiffRow(
                f"{name}/way_probs", [round(x, 3) for x in sorted(m.way_probs)],
                [round(x, 3) for x in sorted(p.way_probs)], "probs",
                err <= WAY_PROB_TOL, f"max |Δp| = {err:.3f}"))
        else:
            rows.append(DiffRow(f"{name}/way_probs", None,
                                [round(x, 3) for x in sorted(p.way_probs)],
                                "probs", False, "not recovered"))
    return rows


def diff_profiles(measured: DeviceProfile,
                  published: DeviceProfile) -> list[DiffRow]:
    """Per-field verdicts; published-fallback fields are info rows (there
    is nothing to verify — they ARE the published value)."""
    rows: list[DiffRow] = []
    for name in sorted(published.caches):
        p = published.caches[name]
        m = measured.caches.get(name)
        if m is None or m.provenance == PUBLISHED:
            rows.append(DiffRow(f"{name}/*", "(published fallback)",
                                p.summary(), "info", True))
            continue
        rows.extend(_diff_cache(name, m, p))
    measured_any_latency = any(v == MEASURED
                               for v in measured.latency_provenance.values())
    for cls in sorted(published.latency):
        pv = published.latency[cls]
        mv = measured.latency.get(cls)
        if mv is None:
            # a profile that measured its spectrum but lost a published
            # class is a regression, not a fallback
            rows.append(DiffRow(f"latency/{cls}", None, pv, "eq",
                                not measured_any_latency,
                                "class not measured"))
        elif measured.latency_provenance.get(cls) == PUBLISHED:
            rows.append(DiffRow(f"latency/{cls}", mv, pv, "info", True))
        else:
            rows.append(DiffRow(f"latency/{cls}", mv, pv, "close",
                                _close(mv, pv, LATENCY_TOL),
                                f"tol {LATENCY_TOL:.0%}"))
    missing = sorted(set(measured.latency) - set(published.latency))
    for cls in missing:
        rows.append(DiffRow(f"latency/{cls}", measured.latency[cls], None,
                            "eq", False, "class not published"))
    for key in sorted(published.spec):
        pv = published.spec[key]
        mv = measured.spec.get(key)
        if measured.spec_provenance.get(key) == MEASURED:
            # an on-hardware measurement legitimately disagrees with the
            # datasheet; show it, don't fail it
            rows.append(DiffRow(f"spec/{key}", mv, pv, "info", True,
                                "measured vs datasheet"))
        else:
            # published-provenance spec fields ARE the datasheet: any
            # drift means the artifact was hand-edited or corrupted
            ok = mv is not None and _close(mv, pv, 1e-9)
            rows.append(DiffRow(f"spec/{key}", mv, pv, "eq", ok))
    bw_m, bw_p = measured.bandwidth, published.bandwidth
    if "global_gbps" in bw_m and "global_gbps" in bw_p:
        rows.append(DiffRow("bandwidth/global_gbps", bw_m["global_gbps"],
                            bw_p["global_gbps"], "close",
                            _close(bw_m["global_gbps"], bw_p["global_gbps"],
                                   BANDWIDTH_TOL), f"tol {BANDWIDTH_TOL:.0%}"))
    if "shared_gbps" in bw_m and "shared_gbps" in bw_p:
        ok = bw_m["shared_gbps"] <= bw_p["shared_gbps"] * (1 + BANDWIDTH_TOL)
        rows.append(DiffRow("bandwidth/shared_gbps", bw_m["shared_gbps"],
                            bw_p["shared_gbps"], "le", ok,
                            "sustained (occupancy model) <= Table-7 peak; "
                            "Kepler sits below it — the paper's Fig 16 point"))
    bc_m, bc_p = measured.bank_conflict, published.bank_conflict
    if bc_m.get("table") and bc_p.get("table"):
        rows.append(DiffRow("bank_conflict/table", bc_m["table"],
                            bc_p["table"], "eq",
                            bc_m["table"] == bc_p["table"]))
        slope = float(bc_m.get("slope_cycles_per_way", 0.0))
        flat = measured.generation in ("maxwell", "volta")
        rows.append(DiffRow(
            "bank_conflict/slope_regime", round(slope, 2),
            "< 5 cyc/way" if flat else ">= 5 cyc/way", "close",
            (slope < 5.0) == flat,
            "Maxwell/Volta keep the flattened-conflict hardware fix"))
    return rows


def render_diff(rows: list[DiffRow], title: str = "Profile diff") -> str:
    bad = [r for r in rows if not r.ok]
    lines = [
        f"# {title}",
        "",
        f"**{len(rows) - len(bad)} ok · {len(bad)} mismatched** "
        f"({len(rows)} fields)",
        "",
        "| Field | Measured | Published | Rule | Verdict | Note |",
        "|---|---|---|---|---|---|",
    ]
    for r in rows:
        verdict = "ok" if r.ok else "MISMATCH"
        lines.append(
            f"| {r.field} | {r.measured} | {r.published} | {r.rule} "
            f"| {verdict} | {r.note} |")
    return "\n".join(lines) + "\n"
