"""The dissect(backend) -> DeviceProfile pipeline.

For a simulated GPU device this runs the whole blind-recovery suite of
``repro.core.inference`` — overflow size search, line/sector recovery,
set-structure staircase, replacement-policy reconstruction, set-bit
probing — against each of the device's registered trace backends, plus
the non-uniform-stride latency-spectrum chase (P1–P6), the Little's-law
occupancy sweep for sustained bandwidths, and the bank-conflict linear
fit.  Everything recovered that way is stamped ``measured``; anything the
suite does not (or, in ``quick`` mode, is told not to) recover falls back
to the published table and is stamped ``published``.

The TPU target has no simulated oracle, so its profile is the published
``TPU_V5E`` spec end to end — the provenance machinery is exactly how a
future on-hardware Pallas dissection upgrades individual fields to
``measured`` without changing any consumer.

Nothing here reads simulator internals: structure recovery consumes only
``(index, latency)`` traces through ``devices.sim_cache_backend``.  The
*published* columns legitimately do read the calibrated geometries — they
are the paper's tables, which is what the blind result is diffed against.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

from repro.core import bankconflict, devices, inference, littles_law, spectrum
from repro.core.profile import (
    MEASURED, PUBLISHED, CacheProfile, DeviceProfile,
)

MB = 1 << 20
KB = 1 << 10


# ---------------------------------------------------------------------------
# per-device dissection plans
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StructureSpec:
    """How to blind-dissect one registered simulated structure."""

    sim_name: str
    n_max: int
    dissect_kw: dict = dataclasses.field(default_factory=dict)
    #: structures whose serial dissection dominates wall time.  Historical:
    #: quick mode used to skip these (published fallback rows); the batched
    #: engine made them cheap enough that every mode measures everything.
    #: The marker survives as documentation and for timing-table emphasis.
    slow: bool = False


_TLB_KW: dict[str, Any] = dict(
    stride_for_size=2 * MB, granularity=2 * MB, line_stride_bytes=2 * MB,
    max_line=8 * MB, structure_max_steps=80)

#: every structure the blind pipeline dissects, per device.  The L2 *data*
#: cache is deliberately absent: its fractional associativity (§4.6) is
#: published-only in this repo, so it exercises the fallback path.
DEVICE_STRUCTURES: dict[str, tuple[StructureSpec, ...]] = {
    "GTX560Ti": (
        StructureSpec("fermi_l1_data", 64 * KB,
                      dict(max_line=4096), slow=True),
        StructureSpec("l1_tlb", 512 * MB, dict(_TLB_KW)),
        StructureSpec("l2_tlb", 512 * MB, dict(_TLB_KW)),
    ),
    "GTX780": (
        StructureSpec("kepler_texture_l1", 64 * KB,
                      dict(max_line=4096), slow=True),
        StructureSpec("kepler_readonly", 64 * KB,
                      dict(max_line=4096), slow=True),
        StructureSpec("l1_tlb", 512 * MB, dict(_TLB_KW)),
        StructureSpec("l2_tlb", 512 * MB, dict(_TLB_KW)),
    ),
    "GTX980": (
        StructureSpec("maxwell_unified_l1", 128 * KB,
                      dict(max_line=4096), slow=True),
        StructureSpec("l1_tlb", 512 * MB, dict(_TLB_KW)),
        StructureSpec("l2_tlb", 512 * MB, dict(_TLB_KW)),
    ),
    "TeslaV100": (
        StructureSpec("volta_l1_data", 512 * KB,
                      dict(max_line=4096), slow=True),
        StructureSpec("l1_tlb", 512 * MB, dict(_TLB_KW)),
        StructureSpec("volta_l2_tlb", 1024 * MB,
                      dict(_TLB_KW, structure_max_steps=40,
                           set_bits_max_log2=26)),
    ),
}

#: paper-published set-index bit fields ([lo, hi) over byte addresses):
#: texture/unified L1 bits 7–8 (Fig 7), Fermi L1's split 9–13 field (§4.5),
#: Volta's page-grain modulo field.
PUBLISHED_SET_BITS: dict[str, tuple[int, int]] = {
    "kepler_texture_l1": (7, 9),
    "kepler_readonly": (7, 9),
    "maxwell_unified_l1": (7, 9),
    "volta_l1_data": (7, 9),
    "fermi_l1_data": (9, 14),
    "volta_l2_tlb": (21, 25),
}



# ---------------------------------------------------------------------------
# published profile (the fallback / diff reference)
# ---------------------------------------------------------------------------


def _published_cache(sim_name: str, role_name: str | None = None) -> CacheProfile:
    cache = devices.SIM_CACHES[sim_name]()
    g = cache.geom
    ways = list(g.way_counts)
    bits = PUBLISHED_SET_BITS.get(sim_name)
    pol = g.replacement
    return CacheProfile(
        name=role_name or sim_name,
        size_bytes=g.size_bytes,
        line_bytes=g.line_bytes,
        num_sets=g.num_sets,
        assoc=g.size_bytes / (g.line_bytes * g.num_sets),
        way_counts=ways,
        uniform_sets=len(set(ways)) <= 1,
        is_lru=pol.kind == "lru",
        way_probs=list(pol.way_probs) if pol.way_probs else None,
        set_bits=list(bits) if bits else None,
        provenance=PUBLISHED,
    )


def _published_l2_data(device: str) -> CacheProfile:
    """The permanent published-fallback row, derived from the calibrated
    hierarchy itself (Table 3 / Jia et al. capacities live in
    ``devices.make_hierarchy``, not re-stated here)."""
    g = devices.make_hierarchy(device).l2.geom
    ways = list(g.way_counts)
    return CacheProfile(
        name="l2_data", size_bytes=g.size_bytes, line_bytes=g.line_bytes,
        num_sets=g.num_sets,
        assoc=g.size_bytes / (g.line_bytes * g.num_sets),
        way_counts=ways, uniform_sets=len(set(ways)) <= 1,
        is_lru=g.replacement.kind == "lru", provenance=PUBLISHED)


def _published_bandwidth(spec: devices.GpuSpec) -> dict[str, float]:
    return {
        "global_gbps": spec.measured_peak_gbps,           # Table 6
        "global_theoretical_gbps": round(spec.theoretical_gbps, 2),
        "shared_gbps": spec.measured_shared_peak_gbps,    # Table 7 W'_SM
        "shared_theoretical_gbps": round(spec.shared_theoretical_gbps, 2),
    }


def _bank_table(device: str) -> dict[str, float]:
    return {str(w): float(c)
            for w, c in sorted(devices.BANK_CONFLICT_LATENCY[device].items())}


def published_profile(device: str) -> DeviceProfile:
    """Everything the paper (or the datasheet) states, provenance
    ``published`` throughout.  This is both the diff reference and the
    fallback the measured pipeline starts from."""
    entry = devices.get_device(device)
    if entry.kind == "tpu":
        spec = entry.spec
        spec_d = dataclasses.asdict(spec)
        spec_d.pop("name")
        return DeviceProfile(
            device=device, kind="tpu", generation=entry.generation,
            spec={k: float(v) for k, v in spec_d.items()},
            spec_provenance={k: PUBLISHED for k in spec_d},
        )
    gspec = entry.spec
    caches = {s.sim_name: _published_cache(s.sim_name)
              for s in DEVICE_STRUCTURES[device]}
    caches["l2_data"] = _published_l2_data(device)
    lat = {k: float(v) for k, v in devices.expected_spectrum(device).items()}
    bw = _published_bandwidth(gspec)
    base, slope = bankconflict.linear_fit(device)
    spec_d = dataclasses.asdict(gspec)
    spec_d.pop("name")
    return DeviceProfile(
        device=device, kind=entry.kind, generation=entry.generation,
        caches=caches,
        latency=lat,
        latency_provenance={k: PUBLISHED for k in lat},
        bandwidth=bw,
        bandwidth_provenance={k: PUBLISHED for k in bw},
        bank_conflict={"generation": gspec.generation,
                       "base_cycles": round(base, 2),
                       "slope_cycles_per_way": round(slope, 2),
                       "table": _bank_table(device),
                       "provenance": PUBLISHED},
        spec={k: float(v) for k, v in spec_d.items()
              if isinstance(v, (int, float))},
        spec_provenance={k: PUBLISHED for k in spec_d
                         if isinstance(spec_d[k], (int, float))},
    )


# ---------------------------------------------------------------------------
# measured pipeline
# ---------------------------------------------------------------------------


def resolve_engine(engine: str = "auto") -> str:
    """Concrete engine name for a dissection request.

    ``"auto"`` picks the batched jax engine when jax imports on this host
    and falls back to the numpy vector engine otherwise — the same
    stub-or-gate posture as the Pallas kernels."""
    if engine in (None, "auto"):
        try:
            import repro.core.cachesim_jax  # noqa: F401
        except Exception:
            return "vector"
        return "jax"
    return engine


def _measured_cache(spec: StructureSpec, *,
                    engine: str = "vector") -> CacheProfile:
    # the registered factories are deterministic (fixed seed) — that is
    # what makes the shared trace_id (= sim_name) valid across runs
    be = devices.sim_cache_backend(spec.sim_name, engine=engine)
    params = inference.dissect(be, n_max=spec.n_max, **spec.dissect_kw)
    way_probs = params.way_probs
    if not params.is_lru:
        # refine the Fig-11 probability estimate: the dissect-default 60
        # passes bound the chain sample too loosely for a 5% diff
        rep = inference.detect_replacement(
            be, params.size_bytes, params.line_bytes, passes=600)
        way_probs = rep.way_probs or way_probs
    return CacheProfile(
        name=spec.sim_name,
        size_bytes=params.size_bytes,
        line_bytes=params.line_bytes,
        num_sets=params.num_sets,
        assoc=params.assoc,
        way_counts=list(params.way_counts),
        uniform_sets=params.uniform_sets,
        is_lru=params.is_lru,
        way_probs=list(way_probs) if way_probs else None,
        set_bits=list(params.set_bits) if params.set_bits else None,
        provenance=MEASURED,
    )


def dissect_structures(device: str, *, engine: str = "auto",
                       ) -> tuple[dict[str, CacheProfile], dict[str, float]]:
    """Blind structure search only: ``(caches, per-stage timings)``.

    The timed unit the dissect-speed benchmark and CI stage race across
    engines; :func:`dissect_device` composes it with the spectrum,
    bandwidth and bank-conflict stages."""
    engine = resolve_engine(engine)
    caches: dict[str, CacheProfile] = {}
    timings: dict[str, float] = {}
    for sspec in DEVICE_STRUCTURES[device]:
        t0 = time.perf_counter()
        caches[sspec.sim_name] = _measured_cache(sspec, engine=engine)
        timings[sspec.sim_name] = round(time.perf_counter() - t0, 4)
    return caches, timings


def dissect_device(device: str, *, quick: bool = False, seed: int = 0,
                   engine: str = "auto") -> DeviceProfile:
    """Run the blind-recovery suite against one registered device.

    Starts from :func:`published_profile` and overwrites every field the
    suite measures, flipping its provenance.  ``engine`` selects the
    trace-simulation core (``"auto"`` → batched jax when available).
    Since the batched engine made the slow data-cache stages cheap,
    ``quick`` mode measures every structure too — the flag survives in
    the artifact as a record of which contract produced it.  Per-stage
    wall time lands in ``profile.timings``.
    """
    entry = devices.get_device(device)
    prof = published_profile(device)
    prof.seed = seed
    prof.quick = quick
    if entry.kind == "tpu":
        # No oracle to dissect blind on this host; the published spec IS
        # the profile until a Pallas on-hardware dissection upgrades it.
        # (prof.engine keeps its "vector" default: no engine ran.)
        return prof

    from repro.core.cachesim import ENGINE_VERSION, JAX_ENGINE_VERSION
    engine = resolve_engine(engine)
    prof.engine = engine
    prof.engine_version = (JAX_ENGINE_VERSION if engine == "jax"
                           else ENGINE_VERSION)

    caches, timings = dissect_structures(device, engine=engine)
    prof.caches.update(caches)

    t0 = time.perf_counter()
    measured_lat = spectrum.measure_spectrum(
        lambda: devices.make_hierarchy(device, seed=seed))
    prof.latency = {k: float(v) for k, v in measured_lat.items()}
    prof.latency_provenance = {k: MEASURED for k in prof.latency}
    timings["spectrum"] = round(time.perf_counter() - t0, 4)

    t0 = time.perf_counter()
    gspec = entry.spec
    _, g_bw = littles_law.best_occupancy(gspec, "global")
    _, s_bw = littles_law.best_occupancy(gspec, "shared")
    prof.bandwidth["global_gbps"] = round(g_bw, 2)
    prof.bandwidth["shared_gbps"] = round(s_bw, 2)
    prof.bandwidth_provenance["global_gbps"] = MEASURED
    prof.bandwidth_provenance["shared_gbps"] = MEASURED
    timings["bandwidth"] = round(time.perf_counter() - t0, 4)

    t0 = time.perf_counter()
    base, slope = bankconflict.linear_fit(device)
    prof.bank_conflict.update({
        "base_cycles": round(base, 2),
        "slope_cycles_per_way": round(slope, 2),
        "table": {str(w): float(bankconflict.latency_for_ways(device, w))
                  for w in (1, 2, 4, 8, 16, 32)},
        "provenance": MEASURED,
    })
    timings["bank_conflict"] = round(time.perf_counter() - t0, 4)

    timings["total"] = round(sum(timings.values()), 4)
    prof.timings = timings
    return prof
