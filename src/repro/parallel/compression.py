"""Gradient compression with error feedback (distributed-optimization trick).

int8 symmetric quantization per leaf: the all-reduce wire traffic drops 4×
(f32) / 2× (bf16).  The quantization residual is carried in an
error-feedback buffer and re-added next step, which provably preserves
SGD/Adam convergence (1-bit Adam / EF-SGD literature); the test suite
checks convergence parity on a toy problem.

Under pjit the quantize→mean→dequantize pattern keeps the all-reduce
operand int8, which the §Roofline collective term credits at 1/4 the
f32 wire bytes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_leaf(g: jax.Array, ef: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
    """Quantize (g + residual); return (dequantized grad, new residual)."""
    g32 = g.astype(jnp.float32) + ef
    q, scale = quantize_int8(g32)
    deq = dequantize_int8(q, scale)
    return deq.astype(g.dtype), g32 - deq


def compress_tree(grads, ef_state):
    out = jax.tree.map(compress_leaf, grads, ef_state)
    new_grads = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_grads, new_ef
