"""Logical-axis sharding: one rule table maps model axes onto mesh axes.

Model code never names mesh axes; it annotates activations/params with
*logical* axes ("batch", "heads", "mlp", "experts", ...) and the active
rule set resolves them onto the ("pod", "data", "model") mesh.  Rules are
swappable per experiment — that is the knob the §Perf hillclimb turns.

Robustness detail: a logical rule is silently dropped for a given tensor
dimension when the dimension size is not divisible by the mesh-axis size
(e.g. 8 KV heads on a 16-way model axis — the standard GQA replication
fallback), so one rule table serves all 10 architectures.
"""

from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axes (a tuple means "shard over both, in order")
DEFAULT_RULES: dict[str, tuple[str, ...] | str | None] = {
    "batch":        ("pod", "data"),   # data parallel
    "seq":          None,              # sequence kept whole by default
    "seq_shard":    "data",            # SP: long-context activations
    "embed":        None,
    "q_features":   "model",           # heads × head_dim, flattened
    "kv_features":  "model",
    "heads":        "model",
    "kv_heads":     "model",
    "head_dim":     None,
    "mlp":          "model",           # TP: FFN hidden
    "vocab":        "model",           # TP: embedding/logits
    "experts":      "model",           # EP
    "capacity":     None,
    "kv_lora":      None,
    "inner":        "model",           # SSM d_inner
    "state":        None,
    "conv":         None,
    "layers":       None,
    "fsdp":         "data",            # parameter sharding (ZeRO-3 style)
    "ssm_heads":    "model",
    # decode caches (serve_step): batch over DP, heads/head_dim over TP;
    # long-context batch-1 cells override cache_seq -> ("data",)
    "cache_batch":  ("pod", "data"),
    "cache_seq":    None,
    "cache_kv_heads": "model",
    "cache_head_dim": "model",
    # paged KV pool (serve.paging): the page axis is replicated by default
    # so every shard can gather any slot's pages locally; override to
    # "data" to spread pool HBM across the data axis (GSPMD handles the
    # cross-shard gather).  Heads reuse cache_kv_heads -> "model" with the
    # same GQA non-divisible fallback as dense caches.
    "cache_pages":  None,
}


class ShardingCtx:
    def __init__(self, mesh: Mesh, rules: dict | None = None,
                 fsdp_params: bool = True):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)
        self.fsdp_params = fsdp_params

    def mesh_axes(self, logical: str | None) -> tuple[str, ...]:
        if logical is None:
            return ()
        r = self.rules.get(logical)
        if r is None:
            return ()
        axes = (r,) if isinstance(r, str) else tuple(r)
        # a rule may name axes the current mesh doesn't have (single-pod
        # meshes have no "pod"): drop them
        return tuple(a for a in axes if a in self.mesh.shape)

    def axis_size(self, axes: Sequence[str]) -> int:
        return math.prod(self.mesh.shape[a] for a in axes)

    def spec(self, logical_axes: Sequence[str | None],
             dims: Sequence[int] | None = None) -> P:
        """Resolve logical axes to a PartitionSpec, dropping indivisible or
        already-used mesh axes."""
        used: set[str] = set()
        parts = []
        for i, name in enumerate(logical_axes):
            axes = tuple(a for a in self.mesh_axes(name) if a not in used)
            if dims is not None and axes:
                if dims[i] % self.axis_size(axes) != 0:
                    # try a prefix that divides (e.g. ("pod","data") -> ("pod",))
                    while axes and dims[i] % self.axis_size(axes) != 0:
                        axes = axes[:-1]
            used.update(axes)
            parts.append(axes if len(axes) != 1 else axes[0])
        return P(*[p if p != () else None for p in parts])

    def named(self, logical_axes: Sequence[str | None],
              dims: Sequence[int] | None = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, dims))


_CTX: contextvars.ContextVar[ShardingCtx | None] = contextvars.ContextVar(
    "sharding_ctx", default=None)


def current() -> ShardingCtx | None:
    return _CTX.get()


@contextlib.contextmanager
def use(ctx: ShardingCtx | None):
    token = _CTX.set(ctx)
    try:
        yield ctx
    finally:
        _CTX.reset(token)


def constrain(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """with_sharding_constraint by logical names; no-op outside a ctx."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"{len(logical_axes)} axes for rank-{x.ndim} tensor")
    return jax.lax.with_sharding_constraint(
        x, ctx.named(logical_axes, x.shape))


# -- parameter logical axes --------------------------------------------------
# Parameters are annotated at init with `logical_axes` metadata (a parallel
# pytree of tuples).  `param_shardings` resolves them, optionally adding
# FSDP sharding of the largest divisible unsharded dimension.


def param_shardings(logical_tree, shapes_tree, ctx: ShardingCtx):
    def one(axes, shape):
        spec = list(ctx.spec(axes, shape.shape))
        while len(spec) < len(shape.shape):
            spec.append(None)
        if ctx.fsdp_params:
            fsdp_axes = ctx.mesh_axes("fsdp")
            used = {a for s in spec for a in ((s,) if isinstance(s, str)
                                              else (s or ()))}
            fsdp_axes = tuple(a for a in fsdp_axes if a not in used)
            if fsdp_axes:
                size = ctx.axis_size(fsdp_axes)
                # shard the largest free dimension divisible by the fsdp axes
                cand = sorted(
                    (i for i, s in enumerate(spec)
                     if s in (None, ()) and shape.shape[i] % size == 0),
                    key=lambda i: -shape.shape[i])
                if cand:
                    spec[cand[0]] = (fsdp_axes if len(fsdp_axes) > 1
                                     else fsdp_axes[0])
        return NamedSharding(ctx.mesh, P(*spec))

    return jax.tree.map(one, logical_tree, shapes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))
