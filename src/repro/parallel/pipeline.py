"""GPipe-style pipeline parallelism over a mesh axis.

At the assigned scale (≤512 chips, ≤398B params) FSDP×TP covers the memory
budget, so the dry-run meshes do not reserve a stage axis (DESIGN.md §5);
this module provides the composable PP primitive for larger deployments
(>2k chips), where a ("stage", "data", "model") mesh re-uses the layer-scan
structure: one scan *unit* stack per stage.

Mechanics: ``shard_map`` over the stage axis; each device holds its stage's
parameters; microbatches stream through with ``lax.ppermute`` between
stages; a ``fori_loop`` runs M + S − 1 ticks (fill + drain).  Differentiable
(jax.grad flows through ppermute), so the same primitive backs training.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_apply(stage_fn, stage_params, x_micro, *, mesh,
                   stage_axis: str = "stage"):
    """Run ``y = stage_S-1(...stage_0(x))`` as a microbatched pipeline.

    stage_params: pytree stacked on a leading stage axis (size S).
    x_micro:      (M, micro_batch, ...) microbatched input.
    Returns       (M, micro_batch, ...) outputs (stage order preserved).
    """
    num_stages = mesh.shape[stage_axis]
    num_micro = x_micro.shape[0]
    ticks = num_micro + num_stages - 1

    def per_stage(params, xs):
        # params: this stage's slice; xs: full microbatch stream (stage 0
        # consumes it; other stages receive activations via ppermute).
        stage_id = jax.lax.axis_index(stage_axis)
        perm = [(i, i + 1) for i in range(num_stages - 1)]

        def tick(t, carry):
            state, outputs = carry
            # stage 0 injects microbatch t (if still filling)
            mb_idx = jnp.clip(t, 0, num_micro - 1)
            inject = jax.lax.dynamic_index_in_dim(xs, mb_idx, 0,
                                                  keepdims=False)
            cur = jnp.where(stage_id == 0, inject, state)
            y = stage_fn(params, cur)
            # collect at the last stage once the pipe is full
            out_idx = jnp.clip(t - (num_stages - 1), 0, num_micro - 1)
            take = jnp.logical_and(stage_id == num_stages - 1,
                                   t >= num_stages - 1)
            outputs = jax.lax.cond(
                take,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, out_idx, 0),
                lambda o: o, outputs)
            # ship activations downstream
            state = jax.lax.ppermute(y, stage_axis, perm)
            return (state, outputs)

        state0 = jnp.zeros_like(xs[0])
        out0 = jnp.zeros_like(xs)
        _, outputs = jax.lax.fori_loop(0, ticks, tick, (state0, out0))
        # only the last stage ever wrote into `outputs` (zeros elsewhere):
        # a psum replicates the result to every stage
        return jax.lax.psum(outputs, stage_axis)

    fn = shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        check_rep=False)
    return fn(stage_params, x_micro)


def stack_stages(unit_params, num_stages: int):
    """Regroup a (units, ...) layer-scan param stack into (stages,
    units/stages, ...) for pipeline placement."""

    def regroup(leaf):
        u = leaf.shape[0]
        assert u % num_stages == 0, f"{u} units across {num_stages} stages"
        return leaf.reshape(num_stages, u // num_stages, *leaf.shape[1:])

    return jax.tree.map(regroup, unit_params)
