"""One config schema for all 10 assigned architectures.

Families: dense / moe / ssm / hybrid / audio / vlm.  Every knob needed by
any of them lives here with a neutral default so a single ``TransformerLM``
assembles the right stack from the config alone (DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int

    # -- attention (0 heads = attention-free) --
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    rope_theta: float = 1e4
    causal: bool = True

    # -- MLA (deepseek-v2) --
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mla_absorbed: bool = False      # absorbed-matmul decode (§Perf): score
                                    # against c_kv directly, no re-expansion

    # -- MoE --
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0            # per-expert hidden dim
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3

    # -- SSM (mamba2 / SSD) --
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_groups: int = 1             # B/C groups (like GQA for SSM)

    # -- hybrid (jamba) --
    attn_period: int = 0            # one attention layer per `attn_period`
    moe_period: int = 1             # MoE every `moe_period` layers (jamba: 2)

    # -- modality frontends (stubs per assignment) --
    is_encoder: bool = False        # hubert: bidirectional, no decode
    frontend: str | None = None     # "audio" | "vision"
    frontend_dim: int = 0           # precomputed frame/patch embedding dim
    num_patches: int = 0            # vision: patches prepended to text

    # -- numerics / execution --
    dtype: str = "bfloat16"         # activation/compute dtype
    param_dtype: str = "bfloat16"
    kv_cache_dtype: str = "bfloat16"   # "int8": quantized KV cache (decode)
    norm_eps: float = 1e-6
    remat: bool = True
    remat_policy: str = "full"      # full | dots (save matmul outputs)
    attention_impl: str = "ref"     # "ref" | "chunked" (XLA) | "flash" (Pallas)
    attention_chunk: int = 1024     # q-block for the chunked impl
    scan_layers: bool = True
    tie_embeddings: bool = False

    # ------------------------------------------------------------------

    def __post_init__(self):
        if self.num_heads and not self.head_dim and not self.use_mla:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def activation_dtype(self):
        return _DTYPES[self.dtype]

    @property
    def parameter_dtype(self):
        return _DTYPES[self.param_dtype]

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.num_heads == 0

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for long_500k: SSM or hybrid (attention is 1/period)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return not self.is_encoder

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    def layer_kinds(self) -> list[str]:
        """Per-layer block kind, e.g. jamba's 1:7 attention:mamba pattern."""
        kinds = []
        for i in range(self.num_layers):
            if self.family == "ssm":
                kinds.append("ssm")
            elif self.family == "hybrid":
                # jamba period of 8: attention at position 4 (1:7 ratio)
                kinds.append("attn" if (i % self.attn_period) ==
                             self.attn_period // 2 else "ssm")
            else:
                kinds.append("attn")
        return kinds

    def ffn_kinds(self) -> list[str]:
        kinds = []
        for i in range(self.num_layers):
            if self.is_moe and (i % self.moe_period) == (self.moe_period - 1):
                kinds.append("moe")
            else:
                kinds.append("dense")
        return kinds

    # -- parameter / FLOP accounting (for roofline + EXPERIMENTS.md) -----

    def param_count(self) -> int:
        """Exact parameter count of the assembled model."""
        from repro.models.transformer import count_params  # lazy: avoid cycle
        return count_params(self)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k + shared experts only)."""
        from repro.models.transformer import count_params
        return count_params(self, active_only=True)

    def model_flops_per_token(self) -> float:
        """6·N_active — the §Roofline MODEL_FLOPS convention."""
        return 6.0 * self.active_param_count()
