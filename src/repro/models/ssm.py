"""Mamba2 / SSD block (state-space duality, arXiv:2405.21060), pure JAX.

Training path: the chunked SSD algorithm — intra-chunk attention-like term
plus an inter-chunk recurrent state carried by ``lax.scan`` — O(S·L) compute
with chunk length L, which is what makes the long_500k cells sub-quadratic.
Decode path: the O(1) per-token recurrence on the (heads, head_dim, state)
SSM state plus a rolling depthwise-conv window.

Layout notes: x/B/C share one input projection and one depthwise conv (as
in the reference implementation); A is scalar-per-head; gated RMSNorm
before the output projection.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rms_norm
from repro.parallel.sharding import constrain


def _dims(cfg: ModelConfig) -> tuple[int, int, int, int, int]:
    d_in = cfg.d_inner
    h = cfg.ssm_heads
    p = cfg.ssm_head_dim
    g = cfg.ssm_groups
    n = cfg.ssm_state
    return d_in, h, p, g, n


def conv_dim(cfg: ModelConfig) -> int:
    d_in, _, _, g, n = _dims(cfg)
    return d_in + 2 * g * n


def init_ssm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in, h, _, g, n = _dims(cfg)
    pd = cfg.parameter_dtype
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * g * n + h          # z, x, B, C, dt
    return {
        "ssm_norm": jnp.ones((d,), pd),
        "in_proj": (jax.random.normal(ks[0], (d, proj_out), jnp.float32)
                    * d ** -0.5).astype(pd),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim(cfg)),
                                     jnp.float32)
                   * cfg.ssm_conv ** -0.5).astype(pd),
        "conv_b": jnp.zeros((conv_dim(cfg),), pd),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "ssm_D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -4.0, jnp.float32),
        "gate_norm": jnp.ones((d_in,), pd),
        "out_proj": (jax.random.normal(ks[2], (d_in, d), jnp.float32)
                     * d_in ** -0.5).astype(pd),
    }


def _split_proj(zxbcdt: jax.Array, cfg: ModelConfig):
    d_in, h, _, g, n = _dims(cfg)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + conv_dim(cfg)]
    dt = zxbcdt[..., -h:]
    return z, xbc, dt


def _split_xbc(xbc: jax.Array, cfg: ModelConfig):
    d_in, h, p, g, n = _dims(cfg)
    x = xbc[..., :d_in]
    bmat = xbc[..., d_in:d_in + g * n]
    cmat = xbc[..., d_in + g * n:]
    return x, bmat, cmat


def ssd_chunked(x, dt, a_log, b, c, d_skip, chunk: int,
                initial_state=None):
    """x: (B,S,H,P); dt: (B,S,H) (post-softplus); b/c: (B,S,G,N).
    Returns y: (B,S,H,P) and the final state (B,H,P,N).

    ``initial_state`` (B,H,N,P) carries the recurrence across chunked
    prefill steps (repro.serve: page-sized prompt chunks); ``None`` is a
    zero state (training / whole-prompt prefill)."""
    s_orig = x.shape[1]
    if s_orig % chunk:
        # pad to a chunk multiple: dt=0 ⇒ decay 1 and zero input, so padded
        # steps are state-neutral
        pad = chunk - s_orig % chunk
        pz = lambda t: jnp.pad(t, [(0, 0), (0, pad)] +
                               [(0, 0)] * (t.ndim - 2))
        x, dt, b, c = pz(x), pz(dt), pz(b), pz(c)
    bs, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    nc = s // chunk
    a = -jnp.exp(a_log)                                    # (H,) negative

    la = dt * a                                            # (B,S,H) log decay
    xb = x * dt[..., None]

    def ch(t):                                             # (B,nc,L,...)
        return t.reshape(bs, nc, chunk, *t.shape[2:])

    xc, lc, bc_, cc = ch(xb), ch(la), ch(b), ch(c)
    lcum = jnp.cumsum(lc, axis=2)                          # (B,nc,L,H)
    ltot = lcum[:, :, -1]                                  # (B,nc,H)

    bh = jnp.repeat(bc_, rep, axis=3) if rep > 1 else bc_  # (B,nc,L,H,N)
    chh = jnp.repeat(cc, rep, axis=3) if rep > 1 else cc

    # intra-chunk (the "attention-like" SSD term)
    sc = jnp.einsum("bclhn,bcmhn->bchlm", chh.astype(jnp.float32),
                    bh.astype(jnp.float32))
    # decay D[l,m] = exp(lcum[l] - lcum[m]) for l >= m
    ll = lcum.transpose(0, 1, 3, 2)                        # (B,nc,H,L)
    dmat = jnp.exp(ll[..., :, None] - ll[..., None, :])    # (B,nc,H,L,M)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    m_ = jnp.where(mask, sc * dmat, 0.0)
    y_diag = jnp.einsum("bchlm,bcmhp->bclhp", m_, xc.astype(jnp.float32))

    # per-chunk state contribution: sum_m exp(ltot - lcum[m]) B_m x_m^T
    wt = jnp.exp(ltot[:, :, None] - lcum)                  # (B,nc,L,H)
    hc = jnp.einsum("bclhn,bclh,bclhp->bchnp", bh.astype(jnp.float32), wt,
                    xc.astype(jnp.float32))                # (B,nc,H,N,P)

    # inter-chunk scan
    def step(hprev, inp):
        hc_c, ltot_c = inp                                 # (B,H,N,P), (B,H)
        hnew = hprev * jnp.exp(ltot_c)[..., None, None] + hc_c
        return hnew, hprev

    h0 = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((bs, h, n, p), jnp.float32))
    hlast, hprevs = jax.lax.scan(
        step, h0, (hc.transpose(1, 0, 2, 3, 4), ltot.transpose(1, 0, 2)))
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)               # (B,nc,H,N,P)

    y_off = jnp.einsum("bclhn,bclh,bchnp->bclhp", chh.astype(jnp.float32),
                       jnp.exp(lcum), hprevs)
    y = (y_diag + y_off).reshape(bs, s, h, p)
    y = y + d_skip[None, None, :, None] * x.astype(jnp.float32)
    return y[:, :s_orig].astype(x.dtype), hlast


def apply_ssm(params: dict, xres: jax.Array, cfg: ModelConfig, *,
              cache: dict | None = None, cache_index: jax.Array | None = None,
              slot_ids: jax.Array | None = None,
              seq_lens: jax.Array | None = None
              ) -> tuple[jax.Array, dict | None]:
    """Full mamba2 block with residual.  cache = {conv (B,W,Cd), state
    (B,H,N,P)} for one-token decode.

    Paged serving (repro.serve): ``slot_ids`` (B,) selects cache rows to
    read/update (the SSM state is slot-resident — O(1) per sequence, so it
    is never paged); a row whose ``cache_index`` is 0 starts fresh (first
    prefill chunk).  With s>1 this is one *chunked-prefill* step: the SSD
    recurrence carries the cached state, and ``seq_lens`` (B,) masks the
    chunk's padded tail (dt=0 ⇒ state-neutral, excluded from the conv
    window)."""
    bs, s, _ = xres.shape
    d_in, h, p, g, n = _dims(cfg)
    xn = rms_norm(xres, params["ssm_norm"], cfg.norm_eps)
    zxbcdt = xn @ params["in_proj"]
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])

    w = params["conv_w"].astype(jnp.float32)               # (W, Cd)
    if cache is None:
        # causal depthwise conv over the sequence
        pad = jnp.pad(xbc.astype(jnp.float32),
                      ((0, 0), (cfg.ssm_conv - 1, 0), (0, 0)))
        xbc_c = sum(pad[:, i:i + s] * w[i] for i in range(cfg.ssm_conv))
        xbc_c = jax.nn.silu(xbc_c + params["conv_b"].astype(jnp.float32))
        x, bmat, cmat = _split_xbc(xbc_c.astype(xres.dtype), cfg)
        x = x.reshape(bs, s, h, p)
        x = constrain(x, "batch", "seq", None, None)
        bmat = bmat.reshape(bs, s, g, n)
        cmat = cmat.reshape(bs, s, g, n)
        y, state = ssd_chunked(x, dt, params["A_log"], bmat, cmat,
                               params["ssm_D"], min(cfg.ssm_chunk, s))
        conv_tail = jnp.pad(xbc, ((0, 0), (cfg.ssm_conv - 1, 0), (0, 0))
                            )[:, -( cfg.ssm_conv - 1):]
        new_cache = {"conv": conv_tail.astype(xres.dtype), "state": state}
    else:
        conv_prev, state_prev = cache["conv"], cache["state"]
        if slot_ids is not None:
            conv_prev = conv_prev[slot_ids]
            state_prev = state_prev[slot_ids]
            # a row starting at position 0 is a fresh request: its slot may
            # hold a previous occupant's state, which must not leak in
            fresh = cache_index == 0
            conv_prev = jnp.where(fresh[:, None, None], 0.0, conv_prev)
            state_prev = jnp.where(fresh[:, None, None, None], 0.0,
                                   state_prev)
        if s == 1:
            # O(1) decode: roll conv window, one recurrence step
            window = jnp.concatenate([conv_prev,
                                      xbc.astype(xres.dtype)], axis=1)
            xbc_c = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w)
            xbc_c = jax.nn.silu(xbc_c + params["conv_b"].astype(jnp.float32))
            x, bmat, cmat = _split_xbc(xbc_c[:, None].astype(xres.dtype), cfg)
            x = x.reshape(bs, 1, h, p)
            bmat = bmat.reshape(bs, 1, g, n)
            cmat = cmat.reshape(bs, 1, g, n)
            a = -jnp.exp(params["A_log"])
            decay = jnp.exp(dt[:, 0] * a)                      # (B,H)
            bh = jnp.repeat(bmat[:, 0], h // g, axis=1)        # (B,H,N)
            chh = jnp.repeat(cmat[:, 0], h // g, axis=1)
            xb = (x[:, 0] * dt[:, 0, :, None]).astype(jnp.float32)   # (B,H,P)
            state = (state_prev * decay[..., None, None] +
                     jnp.einsum("bhn,bhp->bhnp", bh.astype(jnp.float32), xb))
            y = jnp.einsum("bhn,bhnp->bhp", chh.astype(jnp.float32), state)
            y = (y + params["ssm_D"][None, :, None]
                 * x[:, 0].astype(jnp.float32))
            y = y[:, None].astype(xres.dtype)
            new_conv, new_state = window[:, 1:], state
        else:
            # chunked prefill: one multi-token step carrying the cached
            # state; padded chunk-tail tokens are state-neutral (dt=0)
            if seq_lens is None:
                seq_lens = jnp.full((bs,), s, jnp.int32)
            tok_valid = jnp.arange(s)[None, :] < seq_lens[:, None]
            dt = jnp.where(tok_valid[:, :, None], dt, 0.0)
            window_f = jnp.concatenate([conv_prev.astype(jnp.float32),
                                        xbc.astype(jnp.float32)], axis=1)
            xbc_c = sum(window_f[:, i:i + s] * w[i]
                        for i in range(cfg.ssm_conv))
            xbc_c = jax.nn.silu(xbc_c + params["conv_b"].astype(jnp.float32))
            x, bmat, cmat = _split_xbc(xbc_c.astype(xres.dtype), cfg)
            x = x.reshape(bs, s, h, p)
            bmat = bmat.reshape(bs, s, g, n)
            cmat = cmat.reshape(bs, s, g, n)
            y, new_state = ssd_chunked(x, dt, params["A_log"], bmat, cmat,
                                       params["ssm_D"], min(cfg.ssm_chunk, s),
                                       initial_state=state_prev)
            # conv window = last (W-1) inputs ending at the last VALID
            # token, so the padded tail never reaches the next step
            win_src = jnp.concatenate([conv_prev, xbc.astype(xres.dtype)],
                                      axis=1)
            cd = win_src.shape[-1]
            new_conv = jax.vmap(
                lambda wnd, l: jax.lax.dynamic_slice(
                    wnd, (l, 0), (cfg.ssm_conv - 1, cd)))(win_src, seq_lens)
        if slot_ids is not None:
            new_cache = {
                "conv": cache["conv"].at[slot_ids].set(
                    new_conv.astype(cache["conv"].dtype)),
                "state": cache["state"].at[slot_ids].set(new_state)}
        else:
            new_cache = {"conv": new_conv, "state": new_state}

    y = y.reshape(bs, s, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["gate_norm"], cfg.norm_eps)
    y = constrain(y, "batch", "seq", "inner")
    return xres + (y @ params["out_proj"]).astype(xres.dtype), new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    d_in, h, p, g, n = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim(cfg)), dtype),
        "state": jnp.zeros((batch, h, n, p), jnp.float32),
    }
