from repro.models.config import ModelConfig  # noqa: F401
from repro.models.transformer import (  # noqa: F401
    count_params, decode, forward, init_cache, init_params,
    param_logical_axes, prefill,
)
