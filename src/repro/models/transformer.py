"""Model assembly: one TransformerLM covering all 10 architectures.

Layers are stacked and driven by ``lax.scan`` so HLO size is O(1) in depth
(88-layer Mistral-Large compiles as one scanned layer).  Hybrids scan over
the repeating *period* (jamba: 8 layers = 7 mamba + 1 attention, unrolled
inside the scan body), so heterogeneous stacks stay scan-compatible.

Three entry points (what the dry-run lowers):
  forward   — training path (full sequence, no cache)
  prefill   — forward + build a KV/SSM cache padded to ``max_len``
  decode    — one-token step against the cache (serve_step)
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import ModelConfig
from repro.parallel.sharding import constrain

# ---------------------------------------------------------------------------
# layer-stack spec
# ---------------------------------------------------------------------------


def unit_spec(cfg: ModelConfig) -> list[tuple[str, str]]:
    """(block_kind, ffn_kind) for each layer inside one scan unit."""
    period = cfg.attn_period if cfg.family == "hybrid" else 1
    kinds = cfg.layer_kinds()[:period]
    ffns = cfg.ffn_kinds()[:period]
    return list(zip(kinds, ffns))


def num_units(cfg: ModelConfig) -> int:
    period = len(unit_spec(cfg))
    assert cfg.num_layers % period == 0
    return cfg.num_layers // period


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ModelConfig, kind: str, ffn: str) -> dict:
    k1, k2 = jax.random.split(key)
    if kind == "attn":
        p = L.init_mla(k1, cfg) if cfg.use_mla else L.init_attention(k1, cfg)
    else:
        p = S.init_ssm(k1, cfg)
    if kind == "ssm":
        return p                      # mamba block has no separate FFN
    if ffn == "moe":
        p.update(L.init_moe(k2, cfg))
    else:
        p.update(L.init_ffn(k2, cfg))
        p["ffn_norm"] = jnp.ones((cfg.d_model,), cfg.parameter_dtype)
    return p


def _init_unit(key, cfg: ModelConfig) -> dict:
    spec = unit_spec(cfg)
    ks = jax.random.split(key, len(spec))
    out = {}
    for i, ((kind, ffn), k) in enumerate(zip(spec, ks)):
        out[f"b{i}"] = _init_block(k, cfg, kind, ffn)
        # hybrid: ssm layers that carry an FFN (jamba interleaves MLP/MoE
        # after every block)
        if cfg.family == "hybrid" and kind == "ssm":
            k2 = jax.random.fold_in(k, 1)
            ff = (L.init_moe(k2, cfg) if ffn == "moe" else
                  {**L.init_ffn(k2, cfg),
                   "ffn_norm": jnp.ones((cfg.d_model,), cfg.parameter_dtype)})
            out[f"b{i}"].update(ff)
    return out


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    ke, kl, kh, kf = jax.random.split(key, 4)
    pd = cfg.parameter_dtype
    params: dict[str, Any] = {
        "embed": (jax.random.normal(ke, (cfg.vocab_size, cfg.d_model),
                                    jnp.float32)
                  * cfg.d_model ** -0.5).astype(pd),
        "final_norm": jnp.ones((cfg.d_model,), pd),
        "units": jax.vmap(lambda k: _init_unit(k, cfg))(
            jax.random.split(kl, num_units(cfg))),
    }
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(kh, (cfg.d_model, cfg.vocab_size),
                                            jnp.float32)
                          * cfg.d_model ** -0.5).astype(pd)
    if cfg.frontend is not None:
        params["frontend_w1"] = (jax.random.normal(
            kf, (cfg.frontend_dim, cfg.d_model), jnp.float32)
            * cfg.frontend_dim ** -0.5).astype(pd)
        params["frontend_b"] = jnp.zeros((cfg.d_model,), pd)
        if cfg.frontend == "vision":
            params["frontend_w2"] = (jax.random.normal(
                jax.random.fold_in(kf, 1), (cfg.d_model, cfg.d_model),
                jnp.float32) * cfg.d_model ** -0.5).astype(pd)
    return params


def param_logical_axes(params) -> Any:
    """Mirror pytree of logical-axis tuples (stacked 'layers' axis added
    under units/)."""

    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        axes = L.PARAM_AXES.get(name, tuple([None] * leaf.ndim))
        in_units = any(getattr(p, "key", None) == "units" for p in path)
        if in_units:
            axes = ("layers",) + tuple(axes)
        if len(axes) != leaf.ndim:
            axes = tuple([None] * leaf.ndim)
        return tuple(axes)

    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------


def _apply_block(p: dict, x, cfg: ModelConfig, kind: str, ffn: str, *,
                 positions, cache, cache_index, page_table=None,
                 slot_ids=None, seq_lens=None):
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        fn = L.apply_mla if cfg.use_mla else L.apply_attention
        x, new_cache = fn(p, x, cfg, positions=positions, cache=cache,
                          cache_index=cache_index, page_table=page_table)
    else:
        x, new_cache = S.apply_ssm(p, x, cfg, cache=cache,
                                   cache_index=cache_index,
                                   slot_ids=slot_ids, seq_lens=seq_lens)
    has_ffn = kind == "attn" or cfg.family == "hybrid"
    if has_ffn:
        if ffn == "moe":
            x, aux = L.apply_moe_block(p, x, cfg)
        else:
            x = L.apply_dense_block(p, x, cfg)
    return x, new_cache, aux


def _apply_unit(unit_params: dict, x, cfg: ModelConfig, *, positions,
                caches: dict | None, cache_index, page_table=None,
                slot_ids=None, seq_lens=None):
    spec = unit_spec(cfg)
    new_caches = {}
    aux_total = jnp.zeros((), jnp.float32)
    for i, (kind, ffn) in enumerate(spec):
        cache_i = caches[f"b{i}"] if caches is not None else None
        x, nc, aux = _apply_block(unit_params[f"b{i}"], x, cfg, kind, ffn,
                                  positions=positions, cache=cache_i,
                                  cache_index=cache_index,
                                  page_table=page_table, slot_ids=slot_ids,
                                  seq_lens=seq_lens)
        new_caches[f"b{i}"] = nc
        aux_total = aux_total + aux
    return x, new_caches, aux_total


def _embed_inputs(params, cfg: ModelConfig, batch: dict):
    """tokens and/or frontend embeddings -> (B, S, d) activations."""
    parts = []
    if cfg.frontend == "audio" and "frames" in batch:
        h = batch["frames"] @ params["frontend_w1"] + params["frontend_b"]
        parts.append(h.astype(cfg.activation_dtype))
    elif cfg.frontend == "vision" and "patches" in batch:
        h = jax.nn.gelu(batch["patches"] @ params["frontend_w1"]
                        + params["frontend_b"])
        parts.append((h @ params["frontend_w2"]).astype(cfg.activation_dtype))
    if "tokens" in batch:
        emb = jnp.take(params["embed"], batch["tokens"], axis=0)
        parts.append(emb.astype(cfg.activation_dtype))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    return constrain(x, "batch", "seq", "embed")


def forward(params: dict, cfg: ModelConfig, batch: dict
            ) -> tuple[jax.Array, jax.Array]:
    """Training path.  Returns (logits, moe_aux_loss)."""
    x = _embed_inputs(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)

    def unit_fn(carry, unit_params):
        h, aux = carry
        h, _, aux2 = _apply_unit(unit_params, h, cfg, positions=positions,
                                 caches=None, cache_index=None)
        return (h, aux + aux2), None

    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        fn = jax.checkpoint(unit_fn, policy=policy)
    else:
        fn = unit_fn
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)),
                                   params["units"])
    else:
        carry = (x, jnp.zeros((), jnp.float32))
        for i in range(num_units(cfg)):
            carry, _ = fn(carry, jax.tree.map(lambda t: t[i], params["units"]))
        x, aux = carry
    x = rms_final(params, cfg, x)
    logits = head_logits(params, cfg, x)
    return logits, aux


def rms_final(params, cfg, x):
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


def head_logits(params, cfg, x):
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                        w.astype(jnp.float32))
    return constrain(logits, "batch", "seq", "vocab")


# -- caches ------------------------------------------------------------------

#: cache leaves whose axis 2 is the sequence axis (attention K/V family);
#: SSM leaves (conv, state) are sequence-length-independent
_SEQ_CACHE_LEAVES = frozenset({"k", "v", "c_kv", "k_rope",
                               "k_scale", "v_scale"})


def _init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    dt = cfg.activation_dtype
    if kind == "attn":
        if cfg.use_mla:
            return {"c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dt),
                    "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dt)}
        hkv, hd = cfg.num_kv_heads, cfg.head_dim
        if cfg.kv_cache_dtype == "int8":
            return {"k": jnp.zeros((batch, max_len, hkv, hd), jnp.int8),
                    "v": jnp.zeros((batch, max_len, hkv, hd), jnp.int8),
                    "k_scale": jnp.zeros((batch, max_len, hkv), jnp.float32),
                    "v_scale": jnp.zeros((batch, max_len, hkv), jnp.float32)}
        return {"k": jnp.zeros((batch, max_len, hkv, hd), dt),
                "v": jnp.zeros((batch, max_len, hkv, hd), dt)}
    return S.init_ssm_cache(cfg, batch, dt)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    spec = unit_spec(cfg)
    units = num_units(cfg)

    def one_unit(_):
        return {f"b{i}": _init_block_cache(cfg, kind, batch, max_len)
                for i, (kind, _) in enumerate(spec)}

    return jax.vmap(one_unit)(jnp.arange(units))


def _init_block_paged_cache(cfg: ModelConfig, kind: str, num_pages: int,
                            page_len: int, max_slots: int):
    """Attention K/V leaves become a shared (num_pages, page_len, ...) pool;
    SSM leaves stay slot-resident (their state is O(1) per sequence)."""
    dt = cfg.activation_dtype
    if kind == "attn":
        if cfg.kv_cache_dtype == "int8":
            raise NotImplementedError(
                "int8 KV cache is not paged yet; use the dense ServeEngine")
        if cfg.use_mla:
            return {"c_kv": jnp.zeros((num_pages, page_len,
                                       cfg.kv_lora_rank), dt),
                    "k_rope": jnp.zeros((num_pages, page_len,
                                         cfg.qk_rope_dim), dt)}
        hkv, hd = cfg.num_kv_heads, cfg.head_dim
        return {"k": jnp.zeros((num_pages, page_len, hkv, hd), dt),
                "v": jnp.zeros((num_pages, page_len, hkv, hd), dt)}
    return S.init_ssm_cache(cfg, max_slots, dt)


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_len: int,
                     max_slots: int, *, mesh=None, rules=None) -> dict:
    """Paged twin of :func:`init_cache` (same tree structure, paged attn
    leaves).  HBM for attention K/V scales with ``num_pages`` — the pages
    actually in circulation — instead of ``max_slots * max_len``.

    Slot-resident (SSM) leaves get ``max_slots + 1`` rows: row
    ``max_slots`` is a scratch row, the slot-space twin of scratch page 0.
    A decode tick always runs the full batch, so batch rows whose slot is
    empty *or still prefilling* are pointed at the scratch row/page and
    their garbage writes can never touch live state.

    ``mesh`` (a :class:`jax.sharding.Mesh` or a prebuilt
    :class:`~repro.parallel.sharding.ShardingCtx`) lays the pool out with
    :class:`NamedSharding` resolved through ``PAGED_CACHE_AXES`` — KV
    heads on ``"model"``, pages replicated (or on ``"data"`` via
    ``rules``).  The allocator and page tables stay host-side; only the
    dense pool leaves live on the mesh."""
    spec = unit_spec(cfg)
    units = num_units(cfg)

    def one_unit(_):
        return {f"b{i}": _init_block_paged_cache(cfg, kind, num_pages,
                                                 page_len, max_slots + 1)
                for i, (kind, _) in enumerate(spec)}

    cache = jax.vmap(one_unit)(jnp.arange(units))
    if mesh is not None:
        from repro.parallel.sharding import ShardingCtx
        ctx = mesh if isinstance(mesh, ShardingCtx) else ShardingCtx(
            mesh, rules)
        cache = jax.device_put(cache, paged_cache_shardings(cache, ctx))
    return cache


def paged_step(params: dict, cfg: ModelConfig, cache: dict,
               tokens: jax.Array, start: jax.Array, page_tables: jax.Array,
               slot_ids: jax.Array, seq_lens: jax.Array | None = None
               ) -> tuple[jax.Array, dict]:
    """One step against a paged cache: decode (S=1) or a prefill chunk.

    tokens (B,S) at absolute positions ``start[b] + j``; page_tables (B,P)
    maps each slot's logical pages to physical pages (scratch page 0 for
    unallocated/inactive entries); slot_ids (B,) selects the rows of the
    slot-resident (SSM) cache leaves; seq_lens (B,) counts the valid
    tokens of a padded chunk (None = all valid).  Returns logits for every
    chunk position, (B, S, vocab)."""
    x = _embed_inputs(params, cfg, {"tokens": tokens})
    b, s, _ = x.shape
    positions = (start[:, None].astype(jnp.int32)
                 + jnp.arange(s, dtype=jnp.int32)[None, :])

    def unit_fn(h, inp):
        unit_params, unit_cache = inp
        h, new_cache, _ = _apply_unit(unit_params, h, cfg,
                                      positions=positions, caches=unit_cache,
                                      cache_index=start,
                                      page_table=page_tables,
                                      slot_ids=slot_ids, seq_lens=seq_lens)
        return h, new_cache

    x, new_caches = jax.lax.scan(unit_fn, x, (params["units"], cache))
    x = rms_final(params, cfg, x)
    return head_logits(params, cfg, x), new_caches


def prefill(params: dict, cfg: ModelConfig, batch: dict, *,
            max_len: int | None = None) -> tuple[jax.Array, dict]:
    """Forward over the prompt, returning logits and an S_max-padded cache."""
    x = _embed_inputs(params, cfg, batch)
    b, s, _ = x.shape
    max_len = max_len or s
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)

    def unit_fn(h, unit_params):
        h, caches, _ = _apply_unit(unit_params, h, cfg, positions=positions,
                                   caches=None, cache_index=None)
        return h, caches

    x, caches = jax.lax.scan(unit_fn, x, params["units"])

    # pad the SEQUENCE axis of attention leaves to max_len, selected by
    # name: a shape test (leaf.shape[2] == s) misfires when an SSM leaf's
    # head count happens to equal the prompt length
    def pad_to_max(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in _SEQ_CACHE_LEAVES and max_len != s:
            pad = [(0, 0)] * leaf.ndim
            pad[2] = (0, max_len - s)          # (units, batch, seq, ...)
            return jnp.pad(leaf, pad)
        return leaf

    caches = jax.tree_util.tree_map_with_path(pad_to_max, caches)
    x = rms_final(params, cfg, x)
    logits = head_logits(params, cfg, x[:, -1:])
    return logits, caches


def decode(params: dict, cfg: ModelConfig, cache: dict, tokens: jax.Array,
           cache_index: jax.Array) -> tuple[jax.Array, dict]:
    """One decode step: tokens (B, 1) at position ``cache_index``.

    ``cache_index`` may be a scalar (uniform position) or a (B,) vector of
    per-slot positions (continuous batching, repro.serve.engine)."""
    x = _embed_inputs(params, cfg, {"tokens": tokens})
    b = x.shape[0]
    if jnp.ndim(cache_index) == 1:
        positions = cache_index[:, None].astype(jnp.int32)
    else:
        positions = jnp.full((b, 1), cache_index, jnp.int32)

    def unit_fn(h, inp):
        unit_params, unit_cache = inp
        h, new_cache, _ = _apply_unit(unit_params, h, cfg,
                                      positions=positions, caches=unit_cache,
                                      cache_index=cache_index)
        return h, new_cache

    x, new_caches = jax.lax.scan(unit_fn, x, (params["units"], cache))
    x = rms_final(params, cfg, x)
    return head_logits(params, cfg, x), new_caches


# -- cache sharding metadata -------------------------------------------------

CACHE_AXES: dict[str, tuple[str | None, ...]] = {
    "k": ("layers", "cache_batch", "cache_seq", "cache_kv_heads",
          "cache_head_dim"),
    "v": ("layers", "cache_batch", "cache_seq", "cache_kv_heads",
          "cache_head_dim"),
    "c_kv": ("layers", "cache_batch", "cache_seq", "kv_lora"),
    "k_rope": ("layers", "cache_batch", "cache_seq", None),
    "k_scale": ("layers", "cache_batch", "cache_seq", "cache_kv_heads"),
    "v_scale": ("layers", "cache_batch", "cache_seq", "cache_kv_heads"),
    "conv": ("layers", "cache_batch", None, "inner"),
    "state": ("layers", "cache_batch", "ssm_heads", None, None),
}


def cache_logical_axes(cache) -> Any:
    """Mirror pytree of logical axes for an ``init_cache`` structure."""

    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        axes = CACHE_AXES.get(name, tuple([None] * leaf.ndim))
        if len(axes) != leaf.ndim:
            axes = tuple([None] * leaf.ndim)
        return tuple(axes)

    return jax.tree_util.tree_map_with_path(one, cache)


#: paged-pool twin of CACHE_AXES: attention leaves are
#: (units, num_pages, page_len, ...) pools — heads ride the same
#: "cache_kv_heads" rule as dense caches (GQA fallback included), pages
#: ride "cache_pages" (replicated by default, "data" by rule override).
#: The page_len axis is the contiguous gather row and is never sharded.
#: Slot-resident SSM leaves are small O(slots) state; they stay
#: replicated so the scratch-row trick needs no cross-shard reasoning.
PAGED_CACHE_AXES: dict[str, tuple[str | None, ...]] = {
    "k": ("layers", "cache_pages", None, "cache_kv_heads",
          "cache_head_dim"),
    "v": ("layers", "cache_pages", None, "cache_kv_heads",
          "cache_head_dim"),
    "c_kv": ("layers", "cache_pages", None, "kv_lora"),
    "k_rope": ("layers", "cache_pages", None, None),
    "conv": ("layers", None, None, None),
    "state": ("layers", None, None, None, None),
}


def paged_cache_logical_axes(cache) -> Any:
    """Mirror pytree of logical axes for an ``init_paged_cache`` tree."""

    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        axes = PAGED_CACHE_AXES.get(name, tuple([None] * leaf.ndim))
        if len(axes) != leaf.ndim:
            axes = tuple([None] * leaf.ndim)
        return tuple(axes)

    return jax.tree_util.tree_map_with_path(one, cache)


def paged_cache_shardings(cache, ctx) -> Any:
    """Mirror pytree of :class:`NamedSharding` for a paged cache, resolved
    through ``ctx``'s rule table (indivisible axes drop per leaf — the
    GQA replication fallback)."""
    axes = paged_cache_logical_axes(cache)
    return jax.tree.map(
        lambda a, leaf: ctx.named(a, leaf.shape), axes, cache,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------


_param_counts_disk: dict | None = None


def _param_counts_path() -> str:
    from repro.jaxcache import workspace_cache_dir
    return os.path.join(workspace_cache_dir(), "param_counts.json")


@functools.lru_cache(maxsize=None)
def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    # memoized twice: per-process (the cost model calls this for every
    # workload cell of the same arch) and on disk next to the XLA cache
    # (the eval_shape trace costs ~100 ms per arch per process, which
    # dominates cold roofline sweeps).  A pure function of the frozen
    # config, so content-keyed caching is safe.
    if active_only and cfg.is_moe:
        cfg = dataclasses.replace(cfg, num_experts=max(1, cfg.top_k))
    import json
    import math
    global _param_counts_disk
    key = json.dumps(dataclasses.asdict(cfg), sort_keys=True, default=str)
    use_disk = not os.environ.get("REPRO_NO_JAX_CACHE")
    if use_disk and _param_counts_disk is None:
        try:
            with open(_param_counts_path()) as fh:
                _param_counts_disk = json.load(fh)
        except (OSError, ValueError):
            _param_counts_disk = {}
    if use_disk and key in _param_counts_disk:
        return int(_param_counts_disk[key])
    shapes = jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.key(0))
    n = sum(math.prod(l.shape) if l.shape else 1
            for l in jax.tree.leaves(shapes))
    if use_disk:
        _param_counts_disk[key] = n
        try:
            path = _param_counts_path()
            os.makedirs(os.path.dirname(path), exist_ok=True)
            tmp = path + f".{os.getpid()}.tmp"
            with open(tmp, "w") as fh:
                json.dump(_param_counts_disk, fh)
            os.replace(tmp, path)
        except OSError:
            pass
    return n
