"""Building blocks shared by all 10 architectures (pure JAX).

Every apply-function is cache-aware: ``cache=None`` is training/prefill
(full-sequence), a ``(k, v, ...)`` cache plus ``cache_index`` is one decode
step against a preallocated ring of ``S_max`` slots — this is what
``serve_step`` lowers for the decode_32k / long_500k dry-run cells.

Parameter logical axes are registered in ``PARAM_AXES`` (resolved by
``repro.parallel.sharding``); activations carry explicit ``constrain``
annotations so pjit propagates the intended DP/TP/EP/SP layout.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.kernels import ops as kops
from repro.models.config import ModelConfig
from repro.parallel import sharding
from repro.parallel.sharding import constrain

# logical axes by parameter name (stacked layer axis prepended at stack time)
PARAM_AXES: dict[str, tuple[str | None, ...]] = {
    "embed":        ("vocab", "embed"),
    "head":         ("embed", "vocab"),
    "final_norm":   ("embed",),
    "frontend_w1":  (None, "embed"),
    "frontend_w2":  ("embed", "embed"),
    "frontend_b":   ("embed",),
    # attention
    "attn_norm":    ("embed",),
    "wq":           ("embed", "q_features"),
    "wk":           ("embed", "kv_features"),
    "wv":           ("embed", "kv_features"),
    "wo":           ("q_features", "embed"),
    # MLA
    "w_dq":         ("embed", None),
    "w_dkv":        ("embed", "kv_lora"),
    "kv_norm":      ("kv_lora",),
    "w_uk":         ("kv_lora", "q_features"),
    "w_uv":         ("kv_lora", "q_features"),
    # FFN
    "ffn_norm":     ("embed",),
    "w_gate":       ("embed", "mlp"),
    "w_up":         ("embed", "mlp"),
    "w_down":       ("mlp", "embed"),
    # MoE
    "router":       ("embed", "experts"),
    "moe_gate":     ("experts", "embed", "mlp"),
    "moe_up":       ("experts", "embed", "mlp"),
    "moe_down":     ("experts", "mlp", "embed"),
    "shared_gate":  ("embed", "mlp"),
    "shared_up":    ("embed", "mlp"),
    "shared_down":  ("mlp", "embed"),
    # SSM (mamba2)
    "ssm_norm":     ("embed",),
    "in_proj":      ("embed", "inner"),
    "conv_w":       ("conv", "inner"),
    "conv_b":       ("inner",),
    "A_log":        (None,),
    "ssm_D":        (None,),
    "dt_bias":      (None,),
    "gate_norm":    ("inner",),
    "out_proj":     ("inner", "embed"),
}


def _init(key, shape, scale_dim, dtype):
    return (jax.random.normal(key, shape, jnp.float32)
            * (scale_dim ** -0.5)).astype(dtype)


# ---------------------------------------------------------------------------
# norms / rotary
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def rotary(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) with llama-style half rotation; positions: (..., S)."""
    d = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA)
# ---------------------------------------------------------------------------



def _decode_valid(t: int, cache_index) -> jax.Array:
    """(B,t) or (1,t) valid-slot mask; supports per-slot vector indices."""
    ar = jnp.arange(t)[None, :]
    if jnp.ndim(cache_index) == 1:
        return ar <= cache_index[:, None]
    return ar <= cache_index


# -- paged KV cache (repro.serve.paging) -------------------------------------


def _paged_scatter_impl(pages: jax.Array, page_table: jax.Array,
                        positions: jax.Array, vals: jax.Array) -> jax.Array:
    pl = pages.shape[1]
    phys = jnp.take_along_axis(page_table, positions // pl, axis=1)
    return pages.at[phys, positions % pl].set(vals.astype(pages.dtype))


def _paged_gather_impl(pages: jax.Array, page_table: jax.Array) -> jax.Array:
    b, p = page_table.shape
    g = pages[page_table]
    return g.reshape(b, p * pages.shape[1], *pages.shape[2:])


def _paged_shard_axes(pages: jax.Array):
    """(ctx, heads_mesh_axes) when the shard_map fast path applies to this
    pool leaf — an active sharding ctx whose rules put the KV-heads dim on
    present mesh axes (divisibly; the GQA fallback drops it otherwise)
    while pages and head_dim stay whole.  None -> plain impl: unsharded
    engines, MLA's rank-3 compressed leaves, and pages-on-"data" layouts
    (GSPMD handles the cross-shard gather there)."""
    ctx = sharding.current()
    if ctx is None or pages.ndim != 4:
        return None
    spec = tuple(ctx.spec(("cache_pages", None, "cache_kv_heads",
                           "cache_head_dim"), pages.shape))
    pages_ax, _, heads_ax, hd_ax = spec
    if not heads_ax or pages_ax or hd_ax:
        return None
    return ctx, heads_ax


def _paged_scatter(pages: jax.Array, page_table: jax.Array,
                   positions: jax.Array, vals: jax.Array) -> jax.Array:
    """Write per-token values into the shared page pool.

    pages: (num_pages, page_len, ...); page_table: (B, P) physical page of
    each logical page; positions: (B, S) absolute token positions; vals:
    (B, S, ...).  Inactive slots point at the scratch page (0), so their
    garbage writes can never land in a live request's pages.

    Under a serving mesh the heads-sharded pool updates per shard via
    ``shard_map``: each shard scatters only its own heads slice (no
    collectives, no pool copy — with the engine's donated cache operand
    the update is in-place on every shard)."""
    sharded = _paged_shard_axes(pages)
    if sharded is None:
        return _paged_scatter_impl(pages, page_table, positions, vals)
    ctx, ax = sharded
    return shard_map(
        _paged_scatter_impl, mesh=ctx.mesh,
        in_specs=(P(None, None, ax, None), P(None, None), P(None, None),
                  P(None, None, ax, None)),
        out_specs=P(None, None, ax, None))(pages, page_table, positions,
                                           vals)


def _paged_gather(pages: jax.Array, page_table: jax.Array) -> jax.Array:
    """Gather each slot's pages back into a (B, P*page_len, ...) view.

    The sharded path gathers per shard (each shard reads its own heads
    slice at its own partition's bandwidth — the per-partition pricing
    ``choose_page_len(shards=...)`` models), then constrains the result
    back to replicated: one all-gather of data only, so every downstream
    matmul sees width-invariant operands and token streams stay
    bit-identical across mesh widths (the oracle contract; a reassociated
    psum anywhere downstream would break it)."""
    sharded = _paged_shard_axes(pages)
    if sharded is not None:
        ctx, ax = sharded
        g = shard_map(
            _paged_gather_impl, mesh=ctx.mesh,
            in_specs=(P(None, None, ax, None), P(None, None)),
            out_specs=P(None, None, ax, None))(pages, page_table)
    else:
        ctx = sharding.current()
        g = _paged_gather_impl(pages, page_table)
    if ctx is not None:
        g = jax.lax.with_sharding_constraint(
            g, NamedSharding(ctx.mesh, P()))
    return g


def _paged_valid(t: int, positions: jax.Array) -> jax.Array:
    """(B, S, t) causal mask against absolute per-token positions."""
    return jnp.arange(t)[None, None, :] <= positions[:, :, None]


def init_attention(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 5)
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pd = cfg.parameter_dtype
    return {
        "attn_norm": jnp.ones((d,), pd),
        "wq": _init(ks[0], (d, hq * hd), d, pd),
        "wk": _init(ks[1], (d, hkv * hd), d, pd),
        "wv": _init(ks[2], (d, hkv * hd), d, pd),
        "wo": _init(ks[3], (hq * hd, d), hq * hd, pd),
    }


def _sdpa(q, k, v, cfg: ModelConfig, *, causal: bool,
          kv_len_mask: jax.Array | None = None) -> jax.Array:
    """q: (B,S,H,D); k/v: (B,T,Hkv,D).  kv_len_mask: (B,T) valid-slot mask
    (decode against a preallocated cache) or (B,S,T) per-query positional
    mask (paged chunked prefill)."""
    b, s, h, dh = q.shape
    t, hkv = k.shape[1], k.shape[2]
    if cfg.attention_impl == "flash" and kv_len_mask is None and s == t:
        qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
        kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, t, dh)
        vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, t, dh)
        o = kops.flash_attention(qf, kf, vf, num_q_heads=h, num_kv_heads=hkv,
                                 causal=causal)
        return o.reshape(b, h, s, dh).transpose(0, 2, 1, 3)
    if (cfg.attention_impl == "chunked" and s > cfg.attention_chunk
            and s % cfg.attention_chunk == 0
            and (kv_len_mask is None or kv_len_mask.ndim == 2)):
        return _sdpa_chunked(q, k, v, cfg, causal=causal,
                             kv_len_mask=kv_len_mask)
    group = h // hkv
    qg = q.reshape(b, s, hkv, group, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * (dh ** -0.5)
    if causal and s == t:
        mask = jnp.tril(jnp.ones((s, t), bool))
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    if kv_len_mask is not None:
        m = (kv_len_mask[:, None, None, None, :] if kv_len_mask.ndim == 2
             else kv_len_mask[:, None, None, :, :])
        scores = jnp.where(m, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return o.reshape(b, s, h, v.shape[-1]).astype(q.dtype)


def _sdpa_chunked(q, k, v, cfg: ModelConfig, *, causal: bool,
                  kv_len_mask: jax.Array | None = None) -> jax.Array:
    """Pure-XLA flash-style attention: scan over q blocks so the S×S score
    matrix never materializes — the dry-run-safe impl for 32K/500K cells
    (the Pallas kernel is the on-TPU equivalent; same math, same FLOPs)."""
    b, s, h, dh = q.shape
    t, hkv = k.shape[1], k.shape[2]
    group = h // hkv
    bq = cfg.attention_chunk
    nq = s // bq
    qb = (q.reshape(b, nq, bq, hkv, group, dh)
          .transpose(1, 0, 2, 3, 4, 5))                       # (nq,B,bq,K,G,D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def block(carry, inp):
        qi, i = inp
        scores = jnp.einsum("bskgd,btkd->bkgst", qi.astype(jnp.float32),
                            kf) * (dh ** -0.5)
        if causal:
            rows = i * bq + jnp.arange(bq)
            mask = rows[:, None] >= jnp.arange(t)[None, :]
            scores = jnp.where(mask[None, None, None], scores, -1e30)
        if kv_len_mask is not None:
            scores = jnp.where(kv_len_mask[:, None, None, None, :],
                               scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bkgst,btkd->bskgd", p, vf)
        return carry, o.reshape(b, bq, h, v.shape[-1])

    _, ob = jax.lax.scan(block, 0, (qb, jnp.arange(nq)))
    return (ob.transpose(1, 0, 2, 3, 4)
            .reshape(b, s, h, v.shape[-1]).astype(q.dtype))


def apply_attention(p: dict, x: jax.Array, cfg: ModelConfig, *,
                    positions: jax.Array,
                    cache: dict | None = None,
                    cache_index: jax.Array | None = None,
                    page_table: jax.Array | None = None
                    ) -> tuple[jax.Array, dict | None]:
    b, s, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    xn = rms_norm(x, p["attn_norm"], cfg.norm_eps)
    q = (xn @ p["wq"]).reshape(b, s, hq, hd)
    k = (xn @ p["wk"]).reshape(b, s, hkv, hd)
    v = (xn @ p["wv"]).reshape(b, s, hkv, hd)
    q = rotary(q, positions, cfg.rope_theta)
    k = rotary(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")

    new_cache = None
    if cache is None:
        causal = cfg.causal and not cfg.is_encoder
        o = _sdpa(q, k, v, cfg, causal=causal)
        new_cache = {"k": k, "v": v}
    elif page_table is not None:
        # paged cache: scatter this step's K/V into the shared page pool,
        # gather each slot's pages back, mask by absolute position.  Covers
        # both one-token decode (s=1) and chunked prefill (s=chunk).
        ck = _paged_scatter(cache["k"], page_table, positions, k)
        cv = _paged_scatter(cache["v"], page_table, positions, v)
        kg = _paged_gather(ck, page_table)
        vg = _paged_gather(cv, page_table)
        o = _sdpa(q, kg, vg, cfg, causal=False,
                  kv_len_mask=_paged_valid(kg.shape[1], positions))
        new_cache = {"k": ck, "v": cv}
    elif cache_index is not None and jnp.ndim(cache_index) == 1:
        # continuous batching: per-slot cache positions (B,)
        b_idx = jnp.arange(b)
        ck = cache["k"].at[b_idx, cache_index].set(k[:, 0])
        cv = cache["v"].at[b_idx, cache_index].set(v[:, 0])
        t = ck.shape[1]
        valid = jnp.arange(t)[None, :] <= cache_index[:, None]
        o = _sdpa(q, ck, cv, cfg, causal=False, kv_len_mask=valid)
        new_cache = {"k": ck, "v": cv}
    elif cache["k"].dtype == jnp.int8:
        # int8-quantized cache (per token×head symmetric scales): halves the
        # decode HBM traffic — the memory-hierarchy optimization of §Perf
        def quant(x):
            s = jnp.maximum(jnp.abs(x).max(axis=-1), 1e-6) / 127.0
            qx = jnp.clip(jnp.round(x / s[..., None]), -127, 127
                          ).astype(jnp.int8)
            return qx, s.astype(jnp.float32)
        kq, ks = quant(k.astype(jnp.float32))
        vq, vs = quant(v.astype(jnp.float32))
        ck = jax.lax.dynamic_update_slice(cache["k"], kq, (0, cache_index, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], vq, (0, cache_index, 0, 0))
        cks = jax.lax.dynamic_update_slice(cache["k_scale"], ks,
                                           (0, cache_index, 0))
        cvs = jax.lax.dynamic_update_slice(cache["v_scale"], vs,
                                           (0, cache_index, 0))
        kf = (ck.astype(jnp.float32) * cks[..., None]).astype(x.dtype)
        vf = (cv.astype(jnp.float32) * cvs[..., None]).astype(x.dtype)
        t = ck.shape[1]
        valid = _decode_valid(t, cache_index)
        o = _sdpa(q, kf, vf, cfg, causal=False, kv_len_mask=valid)
        new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
    else:
        # one-token decode against a preallocated S_max ring
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, cache_index, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, cache_index, 0, 0))
        t = ck.shape[1]
        valid = _decode_valid(t, cache_index)
        o = _sdpa(q, ck, cv, cfg, causal=False, kv_len_mask=valid)
        new_cache = {"k": ck, "v": cv}
    o = o.reshape(b, s, hq * hd)
    o = constrain(o, "batch", "seq", "q_features")
    return x + (o @ p["wo"]).astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# MLA (deepseek-v2): low-rank KV with decoupled RoPE; cache = (c_kv, k_rope)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 6)
    d, h = cfg.d_model, cfg.num_heads
    nd, rd, vd, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    pd = cfg.parameter_dtype
    return {
        "attn_norm": jnp.ones((d,), pd),
        "wq": _init(ks[0], (d, h * (nd + rd)), d, pd),
        "w_dkv": _init(ks[1], (d, r + rd), d, pd),
        "kv_norm": jnp.ones((r,), pd),
        "w_uk": _init(ks[2], (r, h * nd), r, pd),
        "w_uv": _init(ks[3], (r, h * vd), r, pd),
        "wo": _init(ks[4], (h * vd, d), h * vd, pd),
    }


def apply_mla(p: dict, x: jax.Array, cfg: ModelConfig, *,
              positions: jax.Array, cache: dict | None = None,
              cache_index: jax.Array | None = None,
              page_table: jax.Array | None = None
              ) -> tuple[jax.Array, dict | None]:
    b, s, d = x.shape
    h = cfg.num_heads
    nd, rd, vd, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    xn = rms_norm(x, p["attn_norm"], cfg.norm_eps)
    q = (xn @ p["wq"]).reshape(b, s, h, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = rotary(q_rope, positions, cfg.rope_theta)

    dkv = xn @ p["w_dkv"]                       # (b, s, r + rd)
    c_kv = rms_norm(dkv[..., :r], p["kv_norm"], cfg.norm_eps)
    k_rope = rotary(dkv[..., r:][:, :, None, :], positions,
                    cfg.rope_theta)[:, :, 0]    # (b, s, rd), shared per head

    vector_idx = cache_index is not None and jnp.ndim(cache_index) == 1
    paged = cache is not None and page_table is not None
    valid = None
    if paged:
        # compressed cache lives in the shared page pool (like k/v above)
        ckv_pages = _paged_scatter(cache["c_kv"], page_table, positions, c_kv)
        kr_pages = _paged_scatter(cache["k_rope"], page_table, positions,
                                  k_rope)
        new_cache = {"c_kv": ckv_pages, "k_rope": kr_pages}
        c_kv = _paged_gather(ckv_pages, page_table)
        k_rope = _paged_gather(kr_pages, page_table)
        valid = _paged_valid(c_kv.shape[1], positions)
    elif cache is not None:
        if vector_idx:      # continuous batching: per-slot positions
            b_idx = jnp.arange(b)
            c_kv = cache["c_kv"].at[b_idx, cache_index].set(c_kv[:, 0])
            k_rope = cache["k_rope"].at[b_idx, cache_index].set(k_rope[:, 0])
        else:
            c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv,
                                                (0, cache_index, 0))
            k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope,
                                                  (0, cache_index, 0))
    if not paged:
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
        if cache is not None:
            valid = _decode_valid(c_kv.shape[1], cache_index)
    t = c_kv.shape[1]

    if cache is not None and cfg.mla_absorbed:
        # Absorbed-matmul decode: fold W_uk into the query and W_uv into the
        # output so attention runs against the COMPRESSED cache directly —
        # kills the per-step O(T) re-expansion (exact same math):
        #   qᵀ(c W_uk) = (q W_ukᵀ)ᵀ c      p (c W_uv) = (p c) W_uv
        w_uk = p["w_uk"].reshape(r, h, nd)
        w_uv = p["w_uv"].reshape(r, h, vd)
        q_abs = jnp.einsum("bshd,rhd->bshr", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))
        scale = (nd + rd) ** -0.5
        scores = (jnp.einsum("bshr,btr->bhst", q_abs,
                             c_kv.astype(jnp.float32)) +
                  jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32),
                             k_rope.astype(jnp.float32))) * scale
        vm = (valid[:, None, None, :] if valid.ndim == 2
              else valid[:, None])          # (B,1,S,T) per-query paged mask
        scores = jnp.where(vm, scores, -1e30)
        pr = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhst,btr->bshr", pr, c_kv.astype(jnp.float32))
        o = jnp.einsum("bshr,rhd->bshd", ctx, w_uv.astype(jnp.float32))
        o = o.reshape(b, s, h * vd).astype(x.dtype)
        return x + (o @ p["wo"]).astype(x.dtype), new_cache

    # Expand the compressed cache to per-head K/V and run standard SDPA
    # (naive MLA; the absorbed-matmul decode variant is the §Perf item).
    k_nope = (c_kv @ p["w_uk"]).reshape(b, t, h, nd)
    vfull = (c_kv @ p["w_uv"]).reshape(b, t, h, vd)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, t, h, rd))],
        axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    if cache is None:
        o = _sdpa(q_full, k_full, vfull, cfg, causal=True)
    else:
        o = _sdpa(q_full, k_full, vfull, cfg, causal=False, kv_len_mask=valid)
    o = o.reshape(b, s, h * vd)
    return x + (o @ p["wo"]).astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# dense FFN (SwiGLU)
# ---------------------------------------------------------------------------


def init_ffn(key, cfg: ModelConfig, d_ff: int | None = None,
             prefix: str = "") -> dict:
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    pd = cfg.parameter_dtype
    n = lambda s: (prefix + s) if prefix else s
    out = {
        n("w_gate"): _init(ks[0], (d, f), d, pd),
        n("w_up"): _init(ks[1], (d, f), d, pd),
        n("w_down"): _init(ks[2], (f, d), f, pd),
    }
    if not prefix:
        out["ffn_norm"] = jnp.ones((d,), pd)
    return out


def apply_ffn(p: dict, x: jax.Array, cfg: ModelConfig,
              prefix: str = "") -> jax.Array:
    n = lambda s: (prefix + s) if prefix else s
    h = jax.nn.silu(x @ p[n("w_gate")]) * (x @ p[n("w_up")])
    h = (constrain(h, "batch", "seq", "mlp") if h.ndim == 3
         else constrain(h, "batch", "mlp"))   # shared-expert path: (T, d)
    return (h @ p[n("w_down")]).astype(x.dtype)


def apply_dense_block(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xn = rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    return x + apply_ffn(p, xn, cfg)


# ---------------------------------------------------------------------------
# MoE: top-k token choice, capacity buffers, EP-sharded expert matmuls
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 5)
    d, e, fe = cfg.d_model, cfg.num_experts, cfg.d_ff_expert
    pd = cfg.parameter_dtype
    out = {
        "ffn_norm": jnp.ones((d,), pd),
        "router": _init(ks[0], (d, e), d, jnp.float32),
        "moe_gate": _init(ks[1], (e, d, fe), d, pd),
        "moe_up": _init(ks[2], (e, d, fe), d, pd),
        "moe_down": _init(ks[3], (e, fe, d), fe, pd),
    }
    if cfg.num_shared_experts:
        shared = init_ffn(ks[4], cfg, d_ff=cfg.num_shared_experts * fe,
                          prefix="shared_")
        out.update(shared)
    return out


def moe_capacity(tokens: int, cfg: ModelConfig) -> int:
    cap = math.ceil(tokens * cfg.top_k / cfg.num_experts * cfg.capacity_factor)
    return max(8, -(-cap // 8) * 8)   # round up to 8 for tiling


def apply_moe_block(p: dict, x: jax.Array, cfg: ModelConfig
                    ) -> tuple[jax.Array, jax.Array]:
    """Returns (residual_out, router_aux_loss)."""
    b, s, d = x.shape
    xn = rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    t = b * s
    xt = xn.reshape(t, d)
    e, k = cfg.num_experts, cfg.top_k

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                   # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balance aux (Switch-style) + router z-loss
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce) + cfg.router_z_coef * jnp.mean(
        jax.nn.logsumexp(logits, axis=-1) ** 2)

    # capacity dispatch: rank of each (token, choice) within its expert
    cap = moe_capacity(t, cfg)
    flat_e = top_i.reshape(-1)                               # (T·k,)
    order = jnp.argsort(flat_e, stable=True)
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    ranks_sorted = jnp.arange(t * k, dtype=jnp.int32) - starts[flat_e[order]]
    slot = jnp.zeros((t * k,), jnp.int32).at[order].set(ranks_sorted)
    keep = slot < cap
    tok = jnp.arange(t * k, dtype=jnp.int32) // k

    buf = jnp.zeros((e, cap, d), xt.dtype)
    buf = buf.at[jnp.where(keep, flat_e, e - 1),
                 jnp.where(keep, slot, cap - 1)].add(
        jnp.where(keep[:, None], xt[tok], 0))
    buf = constrain(buf, "experts", "capacity", "embed")

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["moe_gate"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["moe_up"])
    h = constrain(h, "experts", "capacity", "mlp")
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["moe_down"])
    out_buf = constrain(out_buf, "experts", "capacity", "embed")

    gathered = out_buf[flat_e, slot]                         # (T·k, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    y = jnp.zeros((t, d), xt.dtype).at[tok].add(
        gathered * top_p.reshape(-1)[:, None].astype(xt.dtype))

    if cfg.num_shared_experts:
        y = y + apply_ffn(p, xt, cfg, prefix="shared_")
    y = y.reshape(b, s, d)
    y = constrain(y, "batch", "seq", "embed")
    return x + y.astype(x.dtype), aux
