"""Shared-memory bank-conflict model (paper §6.2, Table 8, Figs 17–19).

A warp of 32 threads reads ``sdata[tid * stride]`` (Listing 4).  Words map
to (bank, row) per generation:

* Fermi / Maxwell (4 B banks):   bank = w mod 32,        row = w // 32
* Kepler 4-byte mode (8 B banks): bank = w mod 32,       row = w // 64
  (words w and w+32 share an 8-byte row — stride 2 is conflict-free, Fig 18)
* Kepler 8-byte mode:             bank = (w // 2) mod 32, row = w // 64

The conflict degree is the max number of *distinct rows* any bank must
serve; access latency grows ≈ linearly with it (Table 8), except Maxwell,
whose hardware fix flattens the slope (the paper's headline Maxwell
finding).

The TPU analogue: VMEM is physically (sublanes × lanes)-tiled; a strided
gather makes one lane serve many rows, serializing the VPU the same way.
``tpu_conflict_degree`` reuses the identical row-counting model with
lanes=128, and is validated against the Pallas strided-gather kernel.
"""

from __future__ import annotations

import numpy as np

from repro.core.devices import BANK_CONFLICT_LATENCY

WARP = 32


def _degree(words: np.ndarray, bank_of, row_of) -> int:
    banks = bank_of(words)
    rows = row_of(words)
    degree = 1
    for b in np.unique(banks):
        degree = max(degree, len(np.unique(rows[banks == b])))
    return int(degree)


def conflict_ways(stride: int, generation: str = "fermi",
                  mode_bytes: int = 4) -> int:
    """Conflict degree for ``sdata[tid * stride]`` over one warp."""
    words = np.arange(WARP, dtype=np.int64) * stride
    if generation in ("fermi", "maxwell", "volta"):
        return _degree(words, lambda w: w % 32, lambda w: w // 32)
    if generation == "kepler":
        if mode_bytes == 4:
            return _degree(words, lambda w: w % 32, lambda w: w // 64)
        if mode_bytes == 8:
            return _degree(words, lambda w: (w // 2) % 32, lambda w: w // 64)
    raise ValueError(f"unknown generation/mode {generation}/{mode_bytes}")


def latency_for_ways(device: str, ways: int) -> float:
    """Interpolate Table 8 (measured cycles) for any conflict degree."""
    table = BANK_CONFLICT_LATENCY[device]
    xs = np.array(sorted(table))
    ys = np.array([table[int(x)] for x in xs], dtype=np.float64)
    return float(np.interp(ways, xs, ys))


def latency_for_stride(device: str, stride: int, generation: str,
                       mode_bytes: int = 4) -> float:
    return latency_for_ways(device, conflict_ways(stride, generation, mode_bytes))


def linear_fit(device: str) -> tuple[float, float]:
    """lat ≈ base + slope·(ways−1): the paper's "almost linear" claim.

    Returns (base, slope).  Maxwell's slope is ~2 cycles/way vs Fermi's
    ~37 — the hardware-level optimization the paper reports.
    """
    table = BANK_CONFLICT_LATENCY[device]
    xs = np.array(sorted(table), dtype=np.float64)
    ys = np.array([table[int(x)] for x in xs], dtype=np.float64)
    slope, base = np.polyfit(xs - 1, ys, 1)
    return float(base), float(slope)


# ---------------------------------------------------------------------------
# TPU analogue
# ---------------------------------------------------------------------------


def tpu_conflict_degree(stride: int, lanes: int = 128, sublanes: int = 8,
                        vector_len: int | None = None) -> int:
    """Distinct (sublane-)rows the busiest lane serves for a strided gather.

    A unit-stride vector read touches each lane once (degree 1).  Stride s
    makes lane ``(i·s) mod lanes`` serve ``deg ≈ gcd(s, lanes)``-worth of
    distinct rows — the exact row-counting model above with TPU geometry.
    """
    n = vector_len or lanes
    words = np.arange(n, dtype=np.int64) * stride
    return _degree(words, lambda w: w % lanes, lambda w: w // lanes)
