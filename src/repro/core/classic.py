"""Interpreters for the two *classic* P-chase methods (§4.1).

These implement how Saavedra1992 and Wong2010 read cache parameters off
their average-latency curves — assuming Assumptions 1–3 hold.  Running both
against the Kepler texture-L1 simulator reproduces the paper's Fig 4 vs
Fig 5 contradiction (b=32,T=16 vs b=128,T=4 from the *same* hardware),
which is the motivation for the fine-grained method.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class ClassicParams:
    method: str
    cache_bytes: int | None = None
    line_bytes: int | None = None
    assoc: float | None = None
    num_sets: int | None = None


def interpret_saavedra(curve: dict[int, float], array_bytes: int,
                       cache_bytes: int) -> ClassicParams:
    """tavg–stride reading (Fig 4), N >> C.

    miss rate = s/b while s < b  ⇒  b = first stride at the max plateau;
    misses vanish once the footprint N/s fits one set  ⇒  a = N/s_drop;
    T = C/(a·b).
    """
    strides = sorted(curve)
    tmax = max(curve.values())
    tmin = min(curve.values())
    line = next((s for s in strides if curve[s] >= 0.99 * tmax), None)
    s_drop = next((s for s in strides
                   if s > (line or 0) and curve[s] <= tmin + 0.01 * (tmax - tmin)),
                  None)
    assoc = array_bytes / s_drop if s_drop else None
    num_sets = (int(round(cache_bytes / (assoc * line)))
                if assoc and line else None)
    return ClassicParams("saavedra1992", cache_bytes, line, assoc, num_sets)


def interpret_wong(curve: dict[int, float], cache_bytes: int) -> ClassicParams:
    """tavg–N reading (Fig 5), s ≈ b.

    Plateau count between min and max = number of cache "ways"; plateau
    width = line size.  (Valid only under Assumptions 1–3 — that is the
    point.)
    """
    sizes = sorted(curve)
    vals = [curve[n] for n in sizes]
    # group into plateaus of (approximately) equal tavg; levels drift by a
    # cycle or two within a plateau as N grows, so use a relative tolerance
    tol = 0.06 * (max(vals) - min(vals) or 1.0)
    plateaus: list[tuple[float, int, int]] = []   # (level, start_n, end_n)
    for n, v in zip(sizes, vals):
        if plateaus and abs(v - plateaus[-1][0]) < tol:
            plateaus[-1] = (plateaus[-1][0], plateaus[-1][1], n)
        else:
            plateaus.append((v, n, n))
    # interior plateaus (exclude all-hit floor and all-miss ceiling)
    vmin, vmax = min(vals), max(vals)
    interior = [p for p in plateaus if vmin < p[0] < vmax]
    widths = [p[2] - p[1] for p in interior if p[2] > p[1]]
    line = max(widths) + (sizes[1] - sizes[0]) if widths else None
    nways = len(interior) + 1
    num_sets = nways
    assoc = cache_bytes / (line * num_sets) if line else None
    return ClassicParams("wong2010", cache_bytes, line, assoc, num_sets)
