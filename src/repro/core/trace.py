"""Access-trace containers shared by all P-chase backends.

The paper's fine-grained P-chase (Listing 3) outputs two arrays per run:
``s_index[]`` (the accessed array indices) and ``s_tvalue[]`` (the per-access
latencies).  Every backend in this repo — the pure-python cache simulator,
the Pallas TPU kernel (index trace + differential timing), and the classic
averaged methods — normalizes its output into :class:`PChaseTrace` so that
``core.inference`` can analyze any of them identically.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np


@dataclasses.dataclass(frozen=True)
class PChaseConfig:
    """One (N, s, k) experiment, in *bytes* (paper Table 4 notation)."""

    array_bytes: int          # N
    stride_bytes: int         # s
    iterations: int           # k
    elem_bytes: int = 4       # basic unit of (N, s): one array element
    warmup_passes: int = 1    # passes before timing, to drain cold misses

    @property
    def num_elems(self) -> int:
        return self.array_bytes // self.elem_bytes

    @property
    def stride_elems(self) -> int:
        return max(1, self.stride_bytes // self.elem_bytes)


@dataclasses.dataclass
class PChaseTrace:
    """Fine-grained output: one latency + one index per access.

    ``indices`` are *element* indices into the chase array (the paper's
    ``s_index``); ``latencies`` are model cycles (simulator backend) or
    nanoseconds (hardware backend).  ``meta`` carries backend-specific
    extras (e.g. per-level hit/miss masks from the simulator, used only by
    tests — the analyzer never looks at them).
    """

    config: PChaseConfig
    indices: np.ndarray        # int64[k]
    latencies: np.ndarray      # float64[k]
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        self.indices = np.asarray(self.indices, dtype=np.int64)
        self.latencies = np.asarray(self.latencies, dtype=np.float64)
        if self.indices.shape != self.latencies.shape:
            raise ValueError("indices/latencies length mismatch")

    @property
    def tavg(self) -> float:
        """The only statistic classic P-chase ever sees."""
        return float(self.latencies.mean()) if self.latencies.size else 0.0

    def miss_mask(self, threshold: float | None = None) -> np.ndarray:
        """Classify accesses into hit/miss by latency.

        The fine-grained method's first analysis step: per-access latencies
        are bimodal (hit cluster vs miss cluster); anything above
        ``threshold`` is a miss.  With no threshold we split at the midpoint
        of the two extreme clusters, which is exact for simulator traces and
        robust for hardware ones.
        """
        lat = self.latencies
        if threshold is None:
            lo, hi = lat.min(), lat.max()
            if hi - lo < 1e-9:          # all hits (or all misses): no split
                return np.zeros_like(lat, dtype=bool)
            threshold = (lo + hi) / 2.0
        return lat > threshold

    def miss_count(self, threshold: float | None = None) -> int:
        return int(self.miss_mask(threshold).sum())

    def miss_rate(self, threshold: float | None = None) -> float:
        return float(self.miss_mask(threshold).mean()) if self.latencies.size else 0.0

    def missed_addresses(self, threshold: float | None = None) -> np.ndarray:
        """Distinct byte addresses whose accesses ever missed."""
        mask = self.miss_mask(threshold)
        addrs = self.indices[mask] * self.config.elem_bytes
        return np.unique(addrs)

    def is_periodic(self, period: int | None = None) -> bool:
        """Whether the *miss pattern* recurs with the array period.

        Under LRU (paper Assumption 3) sequential chasing is periodic with
        period N/s accesses (Fig 3); aperiodicity ⇒ non-LRU (§4.5).
        """
        mask = self.miss_mask()
        if period is None:
            period = self.config.num_elems // self.config.stride_elems
        if mask.size < 2 * period:
            return True  # not enough data to falsify periodicity
        tail = mask[: (mask.size // period) * period].reshape(-1, period)
        return bool((tail == tail[0]).all())
