"""P-chase microbenchmark engines (classic + fine-grained).

Three methods from the paper:

* ``saavedra1992`` — average latency vs stride, N fixed (Fig 4).
* ``wong2010`` — average latency vs array size, stride fixed (Fig 5).
* ``fine_grained`` — the paper's contribution (§4.2, Listing 3): record the
  latency *and* the index of every single access.

All engines are backend-generic: a backend is any callable
``(PChaseConfig, indices) -> PChaseTrace``.  Backends provided here drive
the cache simulator; ``repro.kernels.pchase`` provides the Pallas TPU
backend with the identical trace contract.

Two layers sit between a backend and the simulator (DESIGN.md §2):

* **engine selection** — ``engine="vector"`` (default) steps whole index
  chunks through :class:`~repro.core.cachesim.VectorCache`;
  ``engine="reference"`` replays the per-access oracle.  Both produce
  bit-identical traces; the differential tests hold them to that.
  ``engine="jax"`` routes through :class:`~repro.core.cachesim_jax.
  BatchCache` and additionally exposes batched entry points on the
  returned backend — ``backend.batch(requests)`` evaluates many probe
  traces in one engine call and ``backend.steady_misses(configs)``
  answers uniform-chase miss counts in closed form without
  materializing traces at all.  The batched drivers in
  :mod:`repro.core.inference` detect these attributes and switch their
  search loops from one-probe-at-a-time to wave evaluation.
* **trace cache** — when a backend is given a ``trace_id`` and a process
  cache is configured (see :mod:`repro.core.tracecache`), simulated traces
  are content-addressed and reused across experiments, sweeps and repeat
  runs instead of being regenerated.
"""

from __future__ import annotations

from typing import Callable, Protocol, Sequence

import numpy as np

from repro.core import tracecache
from repro.core.cachesim import Cache, MemoryHierarchy, VectorCache
from repro.core.trace import PChaseConfig, PChaseTrace


class TraceBackend(Protocol):
    def __call__(self, config: PChaseConfig,
                 indices: np.ndarray | None = None) -> PChaseTrace: ...


# ---------------------------------------------------------------------------
# Index-sequence construction
# ---------------------------------------------------------------------------


def uniform_chase_indices(config: PChaseConfig, passes: float = 1.0) -> np.ndarray:
    """Paper Listing 1: ``A[i] = (i + stride) % N`` chased from j=0.

    The visited sequence is simply ``(t * s) mod N`` in elements.
    """
    n, s = config.num_elems, config.stride_elems
    k = int(np.ceil(passes * n / s)) if passes else config.iterations
    return (np.arange(k, dtype=np.int64) * s) % n


def chase_from_array(array: np.ndarray, iterations: int, start: int = 0) -> np.ndarray:
    """Chase an arbitrarily-initialized array (the non-uniform-stride init
    of Fig 13b used by the latency-spectrum experiment)."""
    out = np.empty(iterations, dtype=np.int64)
    j = start
    for t in range(iterations):
        j = int(array[j])
        out[t] = j
    return out


# ---------------------------------------------------------------------------
# Simulator backends
# ---------------------------------------------------------------------------


def _chase_streams(config: PChaseConfig, indices: np.ndarray | None,
                   ) -> tuple[np.ndarray, np.ndarray]:
    """(warmup, recorded) element-index streams for one config."""
    if indices is not None:
        # custom init (Fig 13b): caller controls warmup via the indices
        return np.empty(0, dtype=np.int64), np.asarray(indices, dtype=np.int64)
    if config.warmup_passes > 0:
        warm = uniform_chase_indices(config, passes=config.warmup_passes)
    else:
        warm = np.empty(0, dtype=np.int64)
    rec = np.resize(uniform_chase_indices(config), config.iterations)
    return warm, rec


def _vector_record_periodic(vec: VectorCache, rec: np.ndarray,
                            config: PChaseConfig,
                            ) -> tuple[np.ndarray, bool]:
    """Record a uniform multi-pass chase, fast-forwarding steady state.

    ``rec`` is periodic by construction (``np.resize`` of one pass), and
    under a deterministic policy the cache state at pass boundaries must
    eventually revisit a canonical signature; from that point the per-pass
    miss pattern tiles exactly.  The signature canonicalizes recency by
    *rank*, so the tiled hit/miss/latency streams are bit-exact with full
    simulation (the differential tests pin this against the reference
    oracle on multi-pass streams); the ``replaced_ways`` debug meta beyond
    the cycle point is exact only up to the unobservable physical-way
    permutation (meta carries ``steady_state_tiled`` when tiling fired).
    Stochastic policies never take this path: their RNG consumption must
    stay sequential.
    """
    eb = config.elem_bytes
    k = len(rec)
    period = max(1, int(np.ceil(config.num_elems / config.stride_elems)))
    if vec.geom.replacement.kind not in ("lru", "fifo") or k < 3 * period:
        return ~vec.access_chunk(rec * eb), False
    addrs = rec * eb
    miss = np.empty(k, dtype=bool)
    needed: set[int] | None = None
    sigs: dict[bytes, int] = {}
    rw_marks = [len(vec.replaced_ways)]
    t = 0
    while t + period <= k:
        miss[t:t + period] = ~vec.access_chunk(addrs[t:t + period])
        t += period
        rw_marks.append(len(vec.replaced_ways))
        if needed is None:
            needed = set((addrs[:period] // vec.geom.line_bytes).tolist())
        if not needed <= vec._ever_seen:
            continue                       # prefetch path still live
        sig = vec.state_signature()
        prev = sigs.get(sig)
        if prev is None:
            sigs[sig] = t // period
            continue
        # passes [prev, current) form a cycle: tile the remainder
        cyc_miss = miss[prev * period:t]
        cyc_rw = vec.replaced_ways[rw_marks[prev]:rw_marks[t // period]]
        while t < k:
            take = min(len(cyc_miss), k - t)
            miss[t:t + take] = cyc_miss[:take]
            n_miss = int(cyc_miss[:take].sum())
            # in a repeating cycle every set is full, so evictions align
            # one-to-one with misses in order
            vec.replaced_ways.extend(cyc_rw[:n_miss])
            vec.misses += n_miss
            vec.hits += take - n_miss
            t += take
        return miss, True
    if t < k:                              # no cycle found: finish directly
        miss[t:] = ~vec.access_chunk(addrs[t:])
    return miss, False


def cache_backend(make_cache: Callable[[], Cache], t_hit: float = 50.0,
                  t_miss_extra: float = 200.0, *, engine: str = "vector",
                  trace_id: str | None = None) -> TraceBackend:
    """Single-cache backend: latency = t_hit (+ t_miss_extra on miss).

    Used to dissect one cache structure in isolation, as the paper does by
    picking the access path (texture fetch, ``__ldg``, global load...).

    ``engine`` picks the stepping core (``"vector"`` chunks, ``"reference"``
    per-access oracle — bit-identical traces either way; ``"jax"`` the
    batched engine, bit-identical for deterministic policies and
    distributionally equivalent for stochastic ones).  ``trace_id``
    opts the backend into the process trace cache; pass one only when
    ``make_cache`` is deterministic (same structure and seed every call),
    which holds for all registered device factories.
    """
    if engine == "jax":
        return _jax_cache_backend(make_cache, t_hit, t_miss_extra,
                                  trace_id=trace_id)
    if engine not in ("vector", "reference"):
        raise ValueError(f"unknown engine {engine!r}")

    def run(config: PChaseConfig, indices: np.ndarray | None = None) -> PChaseTrace:
        warm, rec = _chase_streams(config, indices)
        tc = tracecache.default_cache() if trace_id else None
        key = None
        if tc is not None:
            # engine is part of the key although the engines are bit-exact:
            # engine="reference" exists to NOT trust that claim, so it must
            # never be served a vector-engine trace
            key = tc.key(trace_id, config,
                         extra={"backend": "cache", "engine": engine,
                                "t_hit": t_hit,
                                "t_miss_extra": t_miss_extra},
                         indices=indices)
            cached = tc.get(key, config, rebuild_indices=rec)
            if cached is not None:
                return cached
        cache = make_cache()
        tiled = False
        if engine == "vector":
            vec = VectorCache.from_cache(cache)
            n, s = config.num_elems, config.stride_elems
            period = max(1, -(-n // s))
            if indices is None and n % s == 0 and warm.size % period == 0:
                # warmup is phase-aligned tiles of the same pass, so fold
                # it into the periodic stream — steady-state tiling then
                # fast-forwards the warmup passes too
                full, tiled = _vector_record_periodic(
                    vec, np.concatenate([warm, rec]), config)
                miss = full[warm.size:]
            elif indices is None:
                if warm.size:
                    vec.access_chunk(warm * config.elem_bytes)
                miss, tiled = _vector_record_periodic(vec, rec, config)
            else:
                miss = ~vec.access_chunk(rec * config.elem_bytes)
            replaced = vec.replaced_ways
        else:
            for idx in warm:
                cache.access(int(idx) * config.elem_bytes)
            miss = np.empty(len(rec), dtype=bool)
            for t, idx in enumerate(rec):
                miss[t] = not cache.access(int(idx) * config.elem_bytes)
            replaced = cache.replaced_ways
        lat = np.where(miss, t_hit + t_miss_extra, t_hit)
        meta = {"true_miss": miss,
                "replaced_ways": list(replaced),
                "miss_threshold": t_hit + t_miss_extra / 2}
        if tiled:
            meta["steady_state_tiled"] = True
        trace = PChaseTrace(config, rec, lat, meta=meta)
        if tc is not None and key is not None:
            tc.put(key, trace, omit_indices=indices is None)
        return trace

    return run


def _jax_cache_backend(make_cache: Callable[[], Cache], t_hit: float,
                       t_miss_extra: float, *,
                       trace_id: str | None = None) -> TraceBackend:
    """``engine="jax"`` backend: batched closed-form/scan trace engine.

    Same trace contract as the numpy engines, plus the batched entry
    points the wave drivers in :mod:`repro.core.inference` key on:

    * ``run.batch(requests)`` — ``requests`` is a list of
      ``(config, indices)`` pairs; one engine call per wave.  Candidate
      lanes skip the trace-cache write-back (hundreds of one-shot probes
      would cost more disk I/O than their closed-form simulation), but
      still consult it for reads.
    * ``run.steady_misses(configs)`` — steady misses per pass of uniform
      chases in closed form, no trace materialized.  Entries are None
      where the lean path does not apply (the driver falls back to a
      full trace for those).

    Stochastic-policy traces embed the jax RNG-lane draws, so they are
    keyed under :data:`~repro.core.cachesim.JAX_ENGINE_VERSION` and never
    shared with the numpy engines.  ``replaced_ways`` debug meta is not
    produced (nothing outside the engine differential tests consumes it).
    """
    from repro.core import cachesim_jax  # lazy: numpy-only callers never
    #                                      pay the jax import

    geom = make_cache().geom
    if geom.replacement.kind not in ("lru", "fifo"):
        # Stochastic policies have no closed form, and a vmapped per-access
        # scan is linear in batch size on CPU — no batching win.  The serial
        # vector core is strictly faster here and keeps stochastic streams
        # bit-identical across engine selections (the BatchCache scan path
        # itself remains distributionally validated by the differential
        # tests).  Without the batched attributes the inference drivers
        # fall back to their serial loops.
        return cache_backend(make_cache, t_hit, t_miss_extra,
                             engine="vector", trace_id=trace_id)
    sim = cachesim_jax.BatchCache([geom])
    miss_threshold = t_hit + t_miss_extra / 2

    def _pass_line_addrs(config: PChaseConfig) -> np.ndarray | None:
        """Distinct line addresses one uniform-chase pass visits, each in
        a single consecutive run — or None when the chase does not tile
        (n % s != 0).  Computed from (N, s, line) directly; no per-access
        arrays, which is what makes ``steady_misses`` ~constant-time."""
        n, s = config.num_elems, config.stride_elems
        if n <= 0 or s <= 0 or n % s:
            return None
        eb, line = config.elem_bytes, geom.line_bytes
        s_bytes, n_bytes = s * eb, n * eb
        if s_bytes <= line:
            # contiguous coverage: every line below N is visited
            count = (n_bytes - s_bytes) // line + 1
            return np.arange(count, dtype=np.int64) * line
        addrs = (np.arange(n // s, dtype=np.int64) * s_bytes) // line * line
        return addrs

    def _period(config: PChaseConfig) -> int:
        return max(1, -(-config.num_elems // max(config.stride_elems, 1)))

    def _record(config: PChaseConfig, warm: np.ndarray,
                rec: np.ndarray) -> np.ndarray:
        """Recorded-portion miss mask, lane simulated from cold."""
        if (config.num_elems > 0 and config.stride_elems > 0
                and config.num_elems % config.stride_elems == 0):
            pattern = uniform_chase_indices(config) * config.elem_bytes
            masks = sim.periodic_masks(0, pattern)
            if masks is not None:
                cold, steady = masks
                total = warm.size + rec.size
                p = len(cold)
                miss = np.resize(steady, total)
                m = min(p, total)
                miss[:m] = cold[:m]
                return miss[warm.size:]
        stream = np.concatenate([warm, rec]) * config.elem_bytes
        hits = sim.simulate([stream])[0]
        return ~hits[warm.size:]

    def _run(config: PChaseConfig, indices: np.ndarray | None,
             store: bool) -> PChaseTrace:
        warm, rec = _chase_streams(config, indices)
        tc = tracecache.default_cache() if trace_id else None
        key = None
        if tc is not None:
            key = tc.key(trace_id, config, seed=sim.seed,
                         extra={"backend": "cache", "engine": "jax",
                                "t_hit": t_hit,
                                "t_miss_extra": t_miss_extra},
                         indices=indices,
                         engine_version=cachesim_jax.JAX_ENGINE_VERSION)
            cached = tc.get(key, config, rebuild_indices=rec)
            if cached is not None:
                return cached
        if indices is not None:
            miss = ~sim.simulate([rec * config.elem_bytes])[0]
        else:
            miss = _record(config, warm, rec)
        lat = np.where(miss, t_hit + t_miss_extra, t_hit)
        trace = PChaseTrace(config, rec, lat,
                            meta={"true_miss": miss,
                                  "miss_threshold": miss_threshold})
        if store and tc is not None and key is not None:
            tc.put(key, trace, omit_indices=indices is None)
        return trace

    def run(config: PChaseConfig,
            indices: np.ndarray | None = None) -> PChaseTrace:
        return _run(config, indices, store=True)

    def batch(requests: Sequence[tuple[PChaseConfig, np.ndarray | None]],
              ) -> list[PChaseTrace]:
        return [_run(cfg, idx, store=False) for cfg, idx in requests]

    def steady_misses(configs: Sequence[PChaseConfig],
                      ) -> list[float | None]:
        out: list[float | None] = []
        for cfg in configs:
            val = None
            # exact iff the recorded stream is entirely steady state:
            # at least one warm pass and at least one full recorded pass
            if cfg.warmup_passes >= 1 and cfg.iterations >= _period(cfg):
                la = _pass_line_addrs(cfg)
                if la is not None:
                    val = sim.steady_miss_count(0, la)
            out.append(val)
        return out

    run.engine = "jax"            # type: ignore[attr-defined]
    run.batch = batch             # type: ignore[attr-defined]
    run.steady_misses = steady_misses  # type: ignore[attr-defined]
    return run


def hierarchy_backend(make_hierarchy: Callable[[], MemoryHierarchy],
                      warmup: bool = True,
                      trace_id: str | None = None) -> TraceBackend:
    """Full-hierarchy backend (data caches + TLBs + page table).

    The hierarchy interleaves per-access control flow across four caches
    and a page-table window, so it steps through the reference oracle; the
    trace cache (``trace_id``) still removes repeat simulation across
    sweeps.
    """

    def run(config: PChaseConfig, indices: np.ndarray | None = None) -> PChaseTrace:
        if indices is None:
            rec = np.resize(uniform_chase_indices(config), config.iterations)
        else:
            rec = np.asarray(indices, dtype=np.int64)
        tc = tracecache.default_cache() if trace_id else None
        key = None
        if tc is not None:
            key = tc.key(trace_id, config,
                         extra={"backend": "hierarchy", "warmup": warmup},
                         indices=indices)
            cached = tc.get(key, config, rebuild_indices=rec)
            if cached is not None:
                return cached
        h = make_hierarchy()
        h.reset()
        if warmup:
            warm = uniform_chase_indices(
                config, passes=max(1, config.warmup_passes))
            for idx in warm:
                h.access(int(idx) * config.elem_bytes)
        lats, infos = h.run_chase(rec, elem_bytes=config.elem_bytes)
        trace = PChaseTrace(config, rec, lats,
                            meta={"patterns": [i.get("pattern") for i in infos]})
        if tc is not None and key is not None:
            tc.put(key, trace, omit_indices=indices is None)
        return trace

    return run


# ---------------------------------------------------------------------------
# The three measurement methods
# ---------------------------------------------------------------------------


def fine_grained(backend: TraceBackend, array_bytes: int, stride_bytes: int,
                 iterations: int | None = None, elem_bytes: int = 4,
                 warmup_passes: int = 2, passes: float = 2.0) -> PChaseTrace:
    """The paper's method: full (index, latency) trace for one (N, s)."""
    cfg = PChaseConfig(array_bytes, stride_bytes, 0, elem_bytes, warmup_passes)
    if iterations is None:
        iterations = int(np.ceil(passes * cfg.num_elems / cfg.stride_elems))
    cfg = PChaseConfig(array_bytes, stride_bytes, iterations, elem_bytes,
                       warmup_passes)
    return backend(cfg)


def saavedra1992(backend: TraceBackend, array_bytes: int,
                 stride_list: Sequence[int], elem_bytes: int = 4,
                 passes: float = 4.0) -> dict[int, float]:
    """Classic method 1: tavg vs stride at fixed N (only averages kept)."""
    out = {}
    for s in stride_list:
        tr = fine_grained(backend, array_bytes, s, elem_bytes=elem_bytes,
                          passes=passes)
        out[s] = tr.tavg
    return out


def wong2010(backend: TraceBackend, array_bytes_list: Sequence[int],
             stride_bytes: int, elem_bytes: int = 4,
             passes: float = 4.0) -> dict[int, float]:
    """Classic method 2: tavg vs array size at fixed stride ≈ line size."""
    out = {}
    for n in array_bytes_list:
        tr = fine_grained(backend, n, stride_bytes, elem_bytes=elem_bytes,
                          passes=passes)
        out[n] = tr.tavg
    return out
