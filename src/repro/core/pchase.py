"""P-chase microbenchmark engines (classic + fine-grained).

Three methods from the paper:

* ``saavedra1992`` — average latency vs stride, N fixed (Fig 4).
* ``wong2010`` — average latency vs array size, stride fixed (Fig 5).
* ``fine_grained`` — the paper's contribution (§4.2, Listing 3): record the
  latency *and* the index of every single access.

All engines are backend-generic: a backend is any callable
``(PChaseConfig, indices) -> PChaseTrace``.  Backends provided here drive
the cache simulator; ``repro.kernels.pchase`` provides the Pallas TPU
backend with the identical trace contract.
"""

from __future__ import annotations

from typing import Callable, Protocol, Sequence

import numpy as np

from repro.core.cachesim import Cache, MemoryHierarchy
from repro.core.trace import PChaseConfig, PChaseTrace


class TraceBackend(Protocol):
    def __call__(self, config: PChaseConfig,
                 indices: np.ndarray | None = None) -> PChaseTrace: ...


# ---------------------------------------------------------------------------
# Index-sequence construction
# ---------------------------------------------------------------------------


def uniform_chase_indices(config: PChaseConfig, passes: float = 1.0) -> np.ndarray:
    """Paper Listing 1: ``A[i] = (i + stride) % N`` chased from j=0.

    The visited sequence is simply ``(t * s) mod N`` in elements.
    """
    n, s = config.num_elems, config.stride_elems
    k = int(np.ceil(passes * n / s)) if passes else config.iterations
    return (np.arange(k, dtype=np.int64) * s) % n


def chase_from_array(array: np.ndarray, iterations: int, start: int = 0) -> np.ndarray:
    """Chase an arbitrarily-initialized array (the non-uniform-stride init
    of Fig 13b used by the latency-spectrum experiment)."""
    out = np.empty(iterations, dtype=np.int64)
    j = start
    for t in range(iterations):
        j = int(array[j])
        out[t] = j
    return out


# ---------------------------------------------------------------------------
# Simulator backends
# ---------------------------------------------------------------------------


def cache_backend(make_cache: Callable[[], Cache], t_hit: float = 50.0,
                  t_miss_extra: float = 200.0) -> TraceBackend:
    """Single-cache backend: latency = t_hit (+ t_miss_extra on miss).

    Used to dissect one cache structure in isolation, as the paper does by
    picking the access path (texture fetch, ``__ldg``, global load...).
    """

    def run(config: PChaseConfig, indices: np.ndarray | None = None) -> PChaseTrace:
        cache = make_cache()
        if indices is None:
            if config.warmup_passes > 0:
                warm = uniform_chase_indices(config, passes=config.warmup_passes)
            else:
                warm = np.empty(0, dtype=np.int64)
            rec = uniform_chase_indices(config)
            rec = np.resize(rec, config.iterations)
        else:  # custom init (Fig 13b): caller controls warmup via the indices
            warm = np.empty(0, dtype=np.int64)
            rec = np.asarray(indices, dtype=np.int64)
        miss = np.empty(len(rec), dtype=bool)
        for idx in warm:
            cache.access(int(idx) * config.elem_bytes)
        for t, idx in enumerate(rec):
            miss[t] = not cache.access(int(idx) * config.elem_bytes)
        lat = np.where(miss, t_hit + t_miss_extra, t_hit)
        return PChaseTrace(config, rec, lat,
                           meta={"true_miss": miss,
                                 "replaced_ways": list(cache.replaced_ways),
                                 "miss_threshold": t_hit + t_miss_extra / 2})

    return run


def hierarchy_backend(make_hierarchy: Callable[[], MemoryHierarchy],
                      warmup: bool = True) -> TraceBackend:
    """Full-hierarchy backend (data caches + TLBs + page table)."""

    def run(config: PChaseConfig, indices: np.ndarray | None = None) -> PChaseTrace:
        h = make_hierarchy()
        h.reset()
        if indices is None:
            rec = uniform_chase_indices(config)
            rec = np.resize(rec, config.iterations)
        else:
            rec = np.asarray(indices, dtype=np.int64)
        if warmup:
            wpasses = max(1, config.warmup_passes)
            warm = uniform_chase_indices(config, passes=wpasses)
            for idx in warm:
                h.access(int(idx) * config.elem_bytes)
        lats, infos = h.run_chase(rec, elem_bytes=config.elem_bytes)
        return PChaseTrace(config, rec, lats,
                           meta={"patterns": [i.get("pattern") for i in infos]})

    return run


# ---------------------------------------------------------------------------
# The three measurement methods
# ---------------------------------------------------------------------------


def fine_grained(backend: TraceBackend, array_bytes: int, stride_bytes: int,
                 iterations: int | None = None, elem_bytes: int = 4,
                 warmup_passes: int = 2, passes: float = 2.0) -> PChaseTrace:
    """The paper's method: full (index, latency) trace for one (N, s)."""
    cfg = PChaseConfig(array_bytes, stride_bytes, 0, elem_bytes, warmup_passes)
    if iterations is None:
        iterations = int(np.ceil(passes * cfg.num_elems / cfg.stride_elems))
    cfg = PChaseConfig(array_bytes, stride_bytes, iterations, elem_bytes,
                       warmup_passes)
    return backend(cfg)


def saavedra1992(backend: TraceBackend, array_bytes: int,
                 stride_list: Sequence[int], elem_bytes: int = 4,
                 passes: float = 4.0) -> dict[int, float]:
    """Classic method 1: tavg vs stride at fixed N (only averages kept)."""
    out = {}
    for s in stride_list:
        tr = fine_grained(backend, array_bytes, s, elem_bytes=elem_bytes,
                          passes=passes)
        out[s] = tr.tavg
    return out


def wong2010(backend: TraceBackend, array_bytes_list: Sequence[int],
             stride_bytes: int, elem_bytes: int = 4,
             passes: float = 4.0) -> dict[int, float]:
    """Classic method 2: tavg vs array size at fixed stride ≈ line size."""
    out = {}
    for n in array_bytes_list:
        tr = fine_grained(backend, n, stride_bytes, elem_bytes=elem_bytes,
                          passes=passes)
        out[n] = tr.tavg
    return out
