"""Calibrated device profiles: the dissect→deploy seam.

The paper's thesis is that software optimization should consume *measured*
memory-hierarchy parameters, not datasheet numbers.  This module is where
that lands in code: a :class:`DeviceProfile` holds every parameter the
dissection suite recovers — cache/TLB geometries, the P1–P6 latency
spectrum, bandwidths, the bank-conflict model, and the TPU spec the
kernel/serving consumers price against — and every field carries
**provenance**: ``"measured"`` when the blind pipeline
(:mod:`repro.profile.pipeline`) derived it from traces, ``"published"``
when it fell back to the datasheet / paper table.

Consumers (``costmodel``, ``core.autotune``, ``core.littles_law``,
``core.roofline``, ``serve.paging``) no longer each default to the
module-level ``TPU_V5E`` constant independently; they resolve through
:func:`resolve_spec`, which honors one process-wide active profile (see
:func:`set_default_profile` / :func:`use_profile`) and warns — once per
plan — when a single plan is priced against two different profiles.

Profiles serialize to the versioned ``repro.profile/v1`` JSON artifact
(persisted under ``experiments/profiles/`` by :mod:`repro.profile.store`)
stamped with the trace-engine version and a fingerprint of the device
registry, so CI can fail on stale artifacts.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import hashlib
import json
import warnings
from typing import Any

from repro.core.cachesim import ENGINE_VERSION, JAX_ENGINE_VERSION
from repro.core import devices as _devices
from repro.core.devices import TPU_V5E, TpuSpec

PROFILE_SCHEMA = "repro.profile/v1"

MEASURED = "measured"
PUBLISHED = "published"
_PROVENANCES = (MEASURED, PUBLISHED)


class SpecMixWarning(UserWarning):
    """A single plan was priced against two different device profiles."""


#: fp32 CUDA cores per SM by generation — the only datasheet number the
#: GPU serving-spec view needs that the dissection suite cannot measure
#: (FLOP peaks are not a memory-hierarchy observable)
_GPU_CORES_PER_SM = {"fermi": 32, "kepler": 192, "maxwell": 128,
                     "volta": 64}


# ---------------------------------------------------------------------------
# dataclasses
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CacheProfile:
    """One dissected (or published) cache/TLB structure."""

    name: str
    size_bytes: int
    line_bytes: int
    num_sets: int
    assoc: float
    way_counts: list[int]
    uniform_sets: bool
    is_lru: bool
    way_probs: list[float] | None = None
    set_bits: list[int] | None = None        # [lo, hi) address-bit field
    provenance: str = PUBLISHED

    def __post_init__(self) -> None:
        if self.provenance not in _PROVENANCES:
            raise ValueError(f"bad provenance {self.provenance!r}")

    def summary(self) -> str:
        pol = "LRU" if self.is_lru else "non-LRU"
        bits = (f" bits[{self.set_bits[0]},{self.set_bits[1]})"
                if self.set_bits else "")
        return (f"C={self.size_bytes}B b={self.line_bytes}B "
                f"T={self.num_sets} a={self.assoc:g}{bits} {pol} "
                f"[{self.provenance}]")


@dataclasses.dataclass
class DeviceProfile:
    """Everything the dissection suite knows about one device.

    ``caches`` is keyed by the canonical simulated-structure name (the
    ``SIM_CACHES`` key / trace id) or a published-only role name like
    ``"l2_data"``.  ``latency`` maps the paper's P1–P6 pattern classes to
    cycles; ``spec`` carries the TPU-shaped consumer numbers (peak FLOP/s,
    HBM bandwidth/latency, VMEM geometry).  Every section has a sibling
    ``*_provenance`` map with one entry per field.
    """

    device: str
    kind: str                                   # "gpu-sim" | "tpu"
    generation: str = ""
    engine: str = "vector"                      # engine that dissected it
    engine_version: str = ENGINE_VERSION
    registry_hash: str = ""
    seed: int = 0
    quick: bool = False
    #: wall-clock seconds per dissection stage (optional; empty for
    #: published-only / TPU profiles)
    timings: dict[str, float] = dataclasses.field(default_factory=dict)
    caches: dict[str, CacheProfile] = dataclasses.field(default_factory=dict)
    latency: dict[str, float] = dataclasses.field(default_factory=dict)
    latency_provenance: dict[str, str] = dataclasses.field(default_factory=dict)
    bandwidth: dict[str, float] = dataclasses.field(default_factory=dict)
    bandwidth_provenance: dict[str, str] = dataclasses.field(default_factory=dict)
    bank_conflict: dict[str, Any] = dataclasses.field(default_factory=dict)
    spec: dict[str, float] = dataclasses.field(default_factory=dict)
    spec_provenance: dict[str, str] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        if not self.registry_hash:
            self.registry_hash = registry_fingerprint()

    # -- consumer view -----------------------------------------------------

    def tpu_spec(self) -> TpuSpec:
        """The spec object every consumer prices against.

        Only meaningful for TPU-family profiles; a GPU profile feeds the
        GPU-side models (littles_law occupancy, bankconflict) instead.
        """
        if self.kind != "tpu":
            raise ValueError(
                f"profile {self.device!r} is kind={self.kind!r}; only tpu "
                "profiles provide a TpuSpec consumer view")
        fields = {f.name for f in dataclasses.fields(TpuSpec)} - {"name"}
        kw = {}
        for k, v in self.spec.items():
            if k not in fields:
                continue
            # JSON stores every number as float; restore int-ness (judged
            # by the default instance's value, which is robust to how the
            # field annotation is spelled) so tile arithmetic stays integral
            kw[k] = int(v) if isinstance(getattr(TPU_V5E, k), int) else float(v)
        return TpuSpec(name=self.device, **kw)

    def serving_spec(self) -> TpuSpec:
        """A TpuSpec-shaped *pricing* view for any profile kind.

        The fleet router (``repro.serve.fleet``) prices every replica with
        the same ``CellCost`` machinery, so a GPU profile must present the
        consumer fields a :class:`TpuSpec` carries.  For ``kind="tpu"``
        this is :meth:`tpu_spec`.  For a dissected GPU the fields come
        from the profile's own measurements wherever one exists:

        * ``hbm_bytes_per_s`` — the sustained global bandwidth the
          Little's-law occupancy sweep found (``bandwidth/global_gbps``,
          Table 6 fallback);
        * ``hbm_latency_s`` — the measured P4 (DRAM) latency of the
          spectrum chase, converted from cycles at the core clock: the
          paper's latency × bandwidth product, per device;
        * ``peak_bf16_flops`` — napkin FMA peak, SMs × cores/SM × 2 ×
          f_core (GPUs here have no bf16 units; this is the fp32 peak the
          compute term is priced against);
        * ``lanes`` — the shared-memory bank count, so the bank-conflict
          row model in ``serve.paging`` sizes page rows to whole bank
          rows (32 banks × 4 B = one 128 B coalesced line).

        Remaining fields (VMEM geometry, ICI) keep the TpuSpec defaults;
        the serving consumers never read them for a single-chip plan.
        """
        if self.kind == "tpu":
            return self.tpu_spec()
        # fail CLOSED on anything the pricing needs: a silently defaulted
        # clock or SM count would misprice fleet routing by orders of
        # magnitude, which is worse than refusing the profile
        missing = [k for k in ("f_core_ghz", "sms") if k not in self.spec]
        if "global_gbps" not in self.bandwidth:
            missing.append("bandwidth/global_gbps")
        if not self.latency.get("P4"):
            missing.append("latency/P4")
        if missing:
            raise ValueError(
                f"profile {self.device!r} cannot price serving: missing "
                f"{missing}")
        if self.generation not in _GPU_CORES_PER_SM:
            raise ValueError(
                f"profile {self.device!r}: unknown generation "
                f"{self.generation!r}; extend _GPU_CORES_PER_SM to price "
                "its FLOP peak")
        f_core_hz = float(self.spec["f_core_ghz"]) * 1e9
        cores = _GPU_CORES_PER_SM[self.generation]
        return TpuSpec(
            name=self.device,
            peak_bf16_flops=float(self.spec["sms"]) * cores * 2.0
            * f_core_hz,
            hbm_bytes_per_s=float(self.bandwidth["global_gbps"]) * 1e9,
            hbm_latency_s=float(self.latency["P4"]) / f_core_hz,
            lanes=int(self.spec.get("shared_banks", TPU_V5E.lanes)),
        )

    def provenance_counts(self) -> dict[str, int]:
        counts = {MEASURED: 0, PUBLISHED: 0}
        for c in self.caches.values():
            counts[c.provenance] += 1
        for src in (self.latency_provenance, self.bandwidth_provenance,
                    self.spec_provenance):
            for p in src.values():
                if p in counts:       # illegal values are store.validate's
                    counts[p] += 1    # job; a summary must never raise
        bc = self.bank_conflict.get("provenance")
        if bc in counts:
            counts[bc] += 1
        return counts

    def is_stale(self) -> list[str]:
        """Reasons this profile can no longer be trusted (empty = fresh).

        The expected engine version depends on which engine dissected the
        profile: numpy-engine profiles track ``ENGINE_VERSION``, batched
        profiles ``JAX_ENGINE_VERSION``.  An unknown engine name is itself
        a staleness reason (fail closed)."""
        problems = []
        expected = {"vector": ENGINE_VERSION,
                    "reference": ENGINE_VERSION,
                    "jax": JAX_ENGINE_VERSION}.get(self.engine)
        if expected is None:
            problems.append(f"unknown dissection engine {self.engine!r}")
        elif self.engine_version != expected:
            problems.append(
                f"engine version {self.engine_version!r} != current "
                f"{expected!r} for engine {self.engine!r}")
        current = registry_fingerprint()
        if self.registry_hash != current:
            problems.append(
                f"device-registry hash {self.registry_hash!r} != current "
                f"{current!r}")
        return problems

    # -- serialization -----------------------------------------------------

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["caches"] = {k: dataclasses.asdict(v)
                       for k, v in self.caches.items()}
        d["schema"] = PROFILE_SCHEMA
        return d

    @classmethod
    def from_json(cls, payload: dict) -> "DeviceProfile":
        schema = payload.get("schema")
        if schema != PROFILE_SCHEMA:
            raise ValueError(
                f"not a {PROFILE_SCHEMA} artifact (schema={schema!r})")
        d = {k: v for k, v in payload.items() if k != "schema"}
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown profile fields: {sorted(unknown)}")
        d["caches"] = {k: CacheProfile(**v)
                       for k, v in d.get("caches", {}).items()}
        for sec in ("latency_provenance", "bandwidth_provenance",
                    "spec_provenance"):
            bad = {k: v for k, v in d.get(sec, {}).items()
                   if v not in _PROVENANCES}
            if bad:
                raise ValueError(f"{sec}: illegal provenance {bad}")
        return cls(**d)

    def summary(self) -> str:
        pc = self.provenance_counts()
        return (f"{self.device} [{self.kind}/{self.generation}] "
                f"{len(self.caches)} structures, "
                f"{len(self.latency)} latency classes; "
                f"{pc[MEASURED]} measured / {pc[PUBLISHED]} published fields")


# ---------------------------------------------------------------------------
# registry fingerprint (staleness anchor)
# ---------------------------------------------------------------------------


def _mapping_probe(cache) -> list[int]:
    """Deterministic observable of the (unhashable) set-map closure."""
    m = cache.geom.mapper()
    lb = cache.geom.line_bytes
    return [int(m(i * lb)) for i in range(64)]


def _geom_descriptor(cache) -> dict | None:
    """Stable descriptor of one cache level (None level stays None)."""
    if cache is None:
        return None
    g = cache.geom
    return {
        "line": g.line_bytes,
        "ways": list(g.way_counts),
        "policy": g.replacement.kind,
        "probs": list(g.replacement.way_probs or ()),
        "prefetch": g.prefetch_lines,
        "map": _mapping_probe(cache),
    }


@functools.lru_cache(maxsize=1)
def registry_fingerprint() -> str:
    """Hash of everything a profile is dissected *from*: simulated cache
    geometries (including their set mappings, probed), full per-device
    hierarchy compositions, latency calibrations, GPU/TPU published
    specs, the bank-conflict table and the trace-engine version.  Any
    change here must invalidate committed profile artifacts.  Pure in the
    module constants, so memoized (building four hierarchies plus the
    mapping probes costs ~15 ms per call)."""
    desc: dict[str, Any] = {"engine": ENGINE_VERSION}
    for name in sorted(_devices.SIM_CACHES):
        desc[f"cache/{name}"] = _geom_descriptor(_devices.SIM_CACHES[name]())
    for dev, spec in sorted(_devices.GPU_SPECS.items()):
        desc[f"gpu/{dev}"] = dataclasses.asdict(spec)
        desc[f"spectrum/{dev}"] = _devices.expected_spectrum(dev)
        # the full hierarchy composition — covers the parameterized L2
        # data cache (size/sets/prefetch, absent from SIM_CACHES), page
        # size, L1 addressing mode and the active window, all of which
        # the spectrum measurements depend on
        h = _devices.make_hierarchy(dev)
        desc[f"hierarchy/{dev}"] = {
            "l1": _geom_descriptor(h.l1),
            "l2": _geom_descriptor(h.l2),
            "l1tlb": _geom_descriptor(h.l1tlb),
            "l2tlb": _geom_descriptor(h.l2tlb),
            "page_bytes": h.page_bytes,
            "l1_virtual": h.l1_virtually_addressed,
            "window": h.active_window_bytes,
        }
    desc["tpu"] = dataclasses.asdict(TPU_V5E)
    desc["bank_conflict"] = {
        d: {str(k): v for k, v in t.items()}
        for d, t in sorted(_devices.BANK_CONFLICT_LATENCY.items())}
    blob = json.dumps(desc, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


# ---------------------------------------------------------------------------
# active-profile resolution (the default-spec-trap fix)
# ---------------------------------------------------------------------------

_ACTIVE: DeviceProfile | TpuSpec | None = None


def set_default_profile(profile: DeviceProfile | TpuSpec | None):
    """Install the process-wide default consumers resolve to; returns the
    previous default so callers can restore it."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, profile
    return prev


def get_default_profile() -> DeviceProfile | TpuSpec | None:
    return _ACTIVE


@contextlib.contextmanager
def use_profile(profile: DeviceProfile | TpuSpec | None):
    """Scoped :func:`set_default_profile` (tests, launchers)."""
    prev = set_default_profile(profile)
    try:
        yield profile
    finally:
        set_default_profile(prev)


def resolve_spec(spec: "DeviceProfile | TpuSpec | None" = None) -> TpuSpec:
    """One resolution path for every consumer.

    ``None`` resolves to the active profile (or the published ``TPU_V5E``
    fallback); a :class:`DeviceProfile` resolves to its consumer spec view;
    a :class:`TpuSpec` passes through.  All former ``spec=TPU_V5E``
    defaults route here, so a launcher-installed profile reaches every
    downstream decision without threading a parameter through each call.
    """
    if spec is None:
        spec = _ACTIVE if _ACTIVE is not None else TPU_V5E
    if isinstance(spec, DeviceProfile):
        return spec.tpu_spec()
    return spec


_MIX_WARNED: set[tuple[str, str, str]] = set()


def warn_spec_mix(plan: str, first: TpuSpec, now: TpuSpec) -> None:
    """Warn (once per plan × pair) that one plan mixed two profiles.

    Names the *fields* that differ: in the primary trap the two specs
    share a name (a dissected ``tpu_v5e`` profile vs the built-in
    constant), so the names alone would make the warning unactionable.
    """
    key = (plan, first.name, now.name)
    if key in _MIX_WARNED:
        return
    _MIX_WARNED.add(key)
    diffs = [f"{f.name}: {getattr(first, f.name):g} -> "
             f"{getattr(now, f.name):g}"
             for f in dataclasses.fields(TpuSpec)
             if f.name != "name" and getattr(first, f.name) != getattr(now, f.name)]
    warnings.warn(
        f"plan {plan!r} was priced with profile {first.name!r} but is now "
        f"being evaluated with {now.name!r} ({'; '.join(diffs) or 'same values'}); "
        "mixing profiles across one plan silently invalidates its "
        "predictions",
        SpecMixWarning, stacklevel=3)
