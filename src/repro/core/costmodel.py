"""Analytic per-cell cost model: FLOPs, HBM traffic, ICI traffic.

Why analytic: XLA's ``cost_analysis()`` counts ``while`` bodies ONCE, so any
scanned program (layers-scan, chunked attention, grad accumulation)
under-reports by the trip count (verified empirically — see
tests/test_costmodel.py, which also validates this model against XLA on
scan-free unrolled configs).  The dry-run keeps the compiled artifact for
memory/sharding/collective-schedule evidence; the roofline *terms* come
from here.  This module is also the napkin-math engine for §Perf: every
hillclimb hypothesis is priced against it first.

Conventions: dot = 2mnk FLOPs; causal attention halves score/PV work;
MoE compute follows the capacity actually dispatched (T·k·cf tokens).
Traffic models are first-order (params + major activations + caches;
ring-collective wire bytes).
"""

from __future__ import annotations

import dataclasses
import itertools

from repro.core import profile
from repro.core.devices import TpuSpec
from repro.models.config import ModelConfig


@dataclasses.dataclass
class ParallelismPlan:
    dp: int          # data-parallel ways (pod × data)
    tp: int          # tensor/expert-parallel ways (model)
    fsdp: bool = True
    remat: bool = True
    # serving weight strategy: "gather" re-gathers FSDP-sharded weights each
    # step; "resident" keeps them 2D-TP-sharded (activation collectives only)
    serving_weights: str = "gather"
    kv_cache_bytes: int = 2          # 2 = bf16, 1 = int8-quantized cache

    @property
    def chips(self) -> int:
        return self.dp * self.tp


#: unique spec-mix dedup tags for CellCosts constructed without a name
_ANON_CELLS = itertools.count()


@dataclasses.dataclass
class CellCost:
    name: str
    global_flops: float            # true executed FLOPs (whole step)
    model_flops: float             # 6·N_active·tokens (2· for fwd-only)
    flops_per_chip: float
    hbm_bytes_per_chip: float
    ici_bytes_per_chip: float
    breakdown: dict

    def _resolve(self, spec) -> TpuSpec:
        """One resolution path for every pricing method (the former
        per-method ``spec=TPU_V5E`` defaults silently let one cell be
        priced against two different specs).  The first resolved spec is
        pinned to this cell; pricing it against a different one later
        warns once (``profile.SpecMixWarning``).  Compared by full value
        — every field, name included — not by name alone: a dissected
        ``tpu_v5e`` profile shares the built-in constant's name while
        disagreeing with its numbers, exactly the mix that must not pass
        silently."""
        spec = profile.resolve_spec(spec)
        prior = getattr(self, "_spec_used", None)
        if prior is None:
            self._spec_used = spec
        elif prior != spec:
            # dedup key: the cell's name, or a per-INSTANCE tag for
            # unnamed cells — a shared "cell" fallback would let the
            # first unnamed cell's warning silence every later one's
            key = getattr(self, "_warn_key", None)
            if key is None:
                key = self._warn_key = (self.name
                                        or f"cell#{next(_ANON_CELLS)}")
            profile.warn_spec_mix(key, prior, spec)
        return spec

    def terms(self, spec=None) -> dict:
        spec = self._resolve(spec)
        return {
            "compute_s": self.flops_per_chip / spec.peak_bf16_flops,
            "memory_s": self.hbm_bytes_per_chip / spec.hbm_bytes_per_s,
            "collective_s": self.ici_bytes_per_chip / spec.ici_bytes_per_s,
        }

    def dominant(self, spec=None) -> str:
        t = self.terms(spec)
        return max(t, key=t.get)[: -len("_s")]

    def step_s(self, spec=None) -> float:
        return max(self.terms(spec).values())

    def roofline_fraction(self, spec=None) -> float:
        """Useful-FLOPs time at peak / bound step time (MFU upper bound)."""
        spec = self._resolve(spec)
        chips = self.global_flops / max(self.flops_per_chip, 1e-30)
        ideal = self.model_flops / (chips * spec.peak_bf16_flops)
        return ideal / self.step_s(spec)

    def useful_ratio(self) -> float:
        return self.model_flops / max(self.global_flops, 1e-30)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(self.terms())
        d["dominant"] = self.dominant()
        d["step_s"] = self.step_s()
        d["roofline_fraction"] = self.roofline_fraction()
        d["useful_ratio"] = self.useful_ratio()
        return d


# ---------------------------------------------------------------------------
# per-layer forward FLOPs per token
# ---------------------------------------------------------------------------


def _attn_proj_flops(cfg: ModelConfig) -> float:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return 2 * d * (hq * hd + 2 * hkv * hd) + 2 * hq * hd * d


def _mla_proj_flops(cfg: ModelConfig, kv_len: float) -> float:
    """Per-token projection + per-token cache-expansion FLOPs.

    The naive MLA decode re-expands the whole compressed cache each step:
    expansion costs 2·r·h·(nd+vd) per *cache entry* per step — kv_len=1 for
    train/prefill (amortized), kv_len=T for decode.  (The absorbed-matmul
    variant removes the T factor — a §Perf optimization.)
    """
    d, h = cfg.d_model, cfg.num_heads
    nd, rd, vd, r = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                     cfg.kv_lora_rank)
    proj = (2 * d * h * (nd + rd) + 2 * d * (r + rd) + 2 * h * vd * d)
    if cfg.mla_absorbed and kv_len > 1:
        # absorbed decode: per-token q/out absorption, no cache expansion
        absorb = 2 * h * (nd * r + r * vd)
        return proj + absorb
    expand = 2 * r * h * (nd + vd) * kv_len
    return proj + expand


def _attn_score_flops(cfg: ModelConfig, kv_len: float,
                      causal_factor: float) -> float:
    hq = cfg.num_heads
    if cfg.use_mla:
        if cfg.mla_absorbed and causal_factor == 1.0:
            # decode against the compressed cache: r+rd score dims, r ctx
            qk = cfg.kv_lora_rank + cfg.qk_rope_dim
            vd = cfg.kv_lora_rank
        else:
            qk = cfg.qk_nope_dim + cfg.qk_rope_dim
            vd = cfg.v_head_dim
    else:
        qk = vd = cfg.head_dim
    return 2 * hq * (qk + vd) * kv_len * causal_factor


def _ffn_flops(cfg: ModelConfig, kind: str) -> float:
    d = cfg.d_model
    if kind == "dense":
        return 2 * 3 * d * cfg.d_ff
    routed = 2 * 3 * d * cfg.d_ff_expert * cfg.top_k * cfg.capacity_factor
    shared = 2 * 3 * d * cfg.num_shared_experts * cfg.d_ff_expert
    router = 2 * d * cfg.num_experts
    return routed + shared + router


def _ssm_flops(cfg: ModelConfig, decode: bool) -> float:
    d, di = cfg.d_model, cfg.d_inner
    h, p, g, n = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups,
                  cfg.ssm_state)
    conv_dim = di + 2 * g * n
    proj = 2 * d * (2 * di + 2 * g * n + h) + 2 * di * d
    conv = 2 * cfg.ssm_conv * conv_dim
    if decode:
        ssd = 2 * h * n * p * 2                      # state update + readout
    else:
        L = cfg.ssm_chunk
        # intra-chunk: C·Bᵀ scores (L·n per token) + apply (L·p); causal ½
        intra = (2 * h * n * L + 2 * h * p * L) * 0.5
        # inter-chunk state: B xᵀ outer products + C·h readout
        inter = 2 * h * n * p * 2
        ssd = intra + inter
    return proj + conv + ssd


def forward_flops_per_token(cfg: ModelConfig, *, kv_len: float,
                            causal_factor: float = 0.5,
                            decode: bool = False) -> float:
    total = 0.0
    kinds = cfg.layer_kinds()
    ffns = cfg.ffn_kinds()
    for kind, ffn in zip(kinds, ffns):
        if kind == "attn":
            if cfg.use_mla:
                total += _mla_proj_flops(cfg, kv_len if decode else 1.0)
            else:
                total += _attn_proj_flops(cfg)
            total += _attn_score_flops(cfg, kv_len,
                                       1.0 if decode else causal_factor)
            total += _ffn_flops(cfg, ffn)
        else:
            total += _ssm_flops(cfg, decode)
            if cfg.family == "hybrid":
                total += _ffn_flops(cfg, ffn)
    total += 2 * cfg.d_model * cfg.vocab_size        # head
    return total


# ---------------------------------------------------------------------------
# cell-level accounting
# ---------------------------------------------------------------------------


def _param_bytes(cfg: ModelConfig) -> float:
    return cfg.param_count() * {"float32": 4, "bfloat16": 2}[cfg.param_dtype]


def kv_bytes_per_token_layer(cfg: ModelConfig, dt: int | None = None) -> int:
    """Bytes one token's K/V occupies in ONE attention layer's cache.

    This is the quantum the paged serving allocator deals in
    (``repro.serve.paging``): a page is ``page_len`` of these per layer.
    """
    if dt is None:
        dt = 2 if cfg.dtype == "bfloat16" else 4
    if cfg.use_mla:
        return (cfg.kv_lora_rank + cfg.qk_rope_dim) * dt
    return 2 * cfg.num_kv_heads * cfg.head_dim * dt


def kv_bytes_per_token(cfg: ModelConfig, dt: int | None = None) -> int:
    """Per-token attention-cache bytes across all layers (SSM state is
    O(1) per sequence, so it never scales with generated length)."""
    return kv_bytes_per_token_layer(cfg, dt) * cfg.layer_kinds().count("attn")


def _cache_bytes(cfg: ModelConfig, batch: int, seq: int,
                 dt: int | None = None) -> float:
    by = 0.0
    if dt is None:
        dt = 2 if cfg.dtype == "bfloat16" else 4
    for kind in cfg.layer_kinds():
        if kind == "attn":
            by += batch * seq * kv_bytes_per_token_layer(cfg, dt)
        else:
            by += batch * (cfg.ssm_heads * cfg.ssm_state * cfg.ssm_head_dim
                           * 4 +
                           (cfg.ssm_conv - 1) *
                           (cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state)
                           * dt)
    return by


def train_cell_cost(cfg: ModelConfig, *, global_batch: int, seq: int,
                    plan: ParallelismPlan, name: str = "") -> CellCost:
    tokens = global_batch * seq
    fwd = forward_flops_per_token(cfg, kv_len=seq) * tokens
    if not plan.remat:
        mult = 3.0                         # fwd + 2×bwd
    elif cfg.remat_policy == "dots":
        mult = 3.35                        # matmul outputs saved: only the
                                           # cheap elementwise work recomputes
    else:
        mult = 4.0                         # full remat: +1 forward recompute
    gflops = fwd * mult
    model_flops = 6.0 * cfg.active_param_count() * tokens
    chips = plan.chips

    p_bytes = _param_bytes(cfg)
    # params: fwd read + bwd read (remat re-read) + grad write + adam m/v r/w
    n = cfg.param_count()
    param_traffic = p_bytes * (3 if plan.remat else 2) + n * 4 + n * 2 * 2 * 2
    d = cfg.d_model
    act_dt = 2 if cfg.dtype == "bfloat16" else 4
    units = max(1, cfg.num_layers //
                (cfg.attn_period if cfg.family == "hybrid" else 1))
    # saved scan carries (remat saves one activation per unit) r/w ×2
    act_traffic = 4 * units * tokens * d * act_dt
    logits_traffic = 2 * tokens * cfg.vocab_size * 4 / 1  # fwd write + bwd read
    hbm_per_chip = (param_traffic + act_traffic + logits_traffic) / chips

    # ICI: FSDP param AG (fwd + bwd) + grad reduce-scatter, sharded over dp
    # after tp split; TP activation all-reduces 2/layer fwd + 2 bwd.
    ici = 0.0
    if plan.fsdp and plan.dp > 1:
        ici += 3 * p_bytes / plan.tp          # 2×AG(bf16) + RS(grads bf16)
    if plan.tp > 1:
        per_ar = (tokens / plan.dp) * d * act_dt
        ici += 2 * 4 * cfg.num_layers * per_ar / 1  # ring AR ≈ 2× payload
    if cfg.is_moe:
        ici += 2 * 2 * (tokens / plan.dp) * cfg.top_k * d * act_dt
    ici_per_chip = ici
    return CellCost(name, gflops, model_flops, gflops / chips, hbm_per_chip,
                    ici_per_chip,
                    breakdown={"fwd_flops": fwd, "param_bytes": p_bytes,
                               "param_traffic": param_traffic,
                               "act_traffic": act_traffic,
                               "logits_traffic": logits_traffic})


def prefill_cell_cost(cfg: ModelConfig, *, global_batch: int, seq: int,
                      plan: ParallelismPlan, name: str = "") -> CellCost:
    tokens = global_batch * seq
    gflops = forward_flops_per_token(cfg, kv_len=seq) * tokens
    model_flops = 2.0 * cfg.active_param_count() * tokens
    chips = plan.chips
    p_bytes = _param_bytes(cfg)
    act_dt = 2 if cfg.dtype == "bfloat16" else 4
    act_traffic = 2 * cfg.num_layers * tokens * cfg.d_model * act_dt
    cache_traffic = _cache_bytes(cfg, global_batch, seq)
    hbm_per_chip = (p_bytes + act_traffic + cache_traffic) / chips
    ici = 0.0
    if plan.fsdp and plan.dp > 1:
        ici += p_bytes / plan.tp
    if plan.tp > 1:
        ici += 2 * 2 * cfg.num_layers * (tokens / plan.dp) * cfg.d_model * act_dt
    if cfg.is_moe:
        ici += 2 * 2 * (tokens / plan.dp) * cfg.top_k * cfg.d_model * act_dt
    return CellCost(name, gflops, model_flops, gflops / chips, hbm_per_chip,
                    ici,
                    breakdown={"param_bytes": p_bytes,
                               "cache_bytes": cache_traffic})


def decode_cell_cost(cfg: ModelConfig, *, global_batch: int, seq: int,
                     plan: ParallelismPlan, name: str = "") -> CellCost:
    tokens = global_batch                     # one new token per sequence
    gflops = forward_flops_per_token(cfg, kv_len=seq, decode=True) * tokens
    model_flops = 2.0 * cfg.active_param_count() * tokens
    chips = plan.chips
    p_bytes = _param_bytes(cfg)
    cache = _cache_bytes(cfg, global_batch, seq, dt=plan.kv_cache_bytes)
    # every step reads all params + the whole live cache, writes one slot
    hbm_per_chip = (p_bytes + cache) / chips
    act_dt = 2 if cfg.dtype == "bfloat16" else 4
    ici = 0.0
    if plan.serving_weights == "gather" and plan.fsdp and plan.dp > 1:
        ici += p_bytes / plan.tp              # per-step param AG (serving)
    if plan.tp > 1 or plan.serving_weights == "resident":
        # resident weights: per-layer activation all-reduces instead
        ici += 2 * 2 * cfg.num_layers * (tokens / max(1, plan.dp)) * \
            cfg.d_model * act_dt
    return CellCost(name, gflops, model_flops, gflops / chips, hbm_per_chip,
                    ici,
                    breakdown={"param_bytes": p_bytes, "cache_bytes": cache})


def cell_cost(cfg: ModelConfig, shape, plan: ParallelismPlan) -> CellCost:
    name = f"{cfg.name}__{shape.name}"
    if shape.kind == "train":
        return train_cell_cost(cfg, global_batch=shape.global_batch,
                               seq=shape.seq_len, plan=plan, name=name)
    if shape.kind == "prefill":
        return prefill_cell_cost(cfg, global_batch=shape.global_batch,
                                 seq=shape.seq_len, plan=plan, name=name)
    return decode_cell_cost(cfg, global_batch=shape.global_batch,
                            seq=shape.seq_len, plan=plan, name=name)
