"""Little's-law occupancy→throughput model (paper §5.1, §6.1).

The paper explains every throughput curve (Fig 12, 15, 16) with one law:
sustained bandwidth needs `latency × bandwidth` bytes in flight.  We encode
that as a small analytic model, calibrated per device, and reuse the same
law for the TPU target (how many bytes of DMA must be outstanding to hide
HBM latency — this is what sizes the double-buffered BlockSpecs in
``repro.kernels``).

GPU-side quirks reproduced (and where they come from):

* GTX780's shared-memory throughput *decreases* with ILP while Fermi's and
  Maxwell's increase (Fig 16): Kepler's 8-byte dual-mode banks serialize a
  thread's ILP accesses, so ILP multiplies the *required* warps instead of
  the in-flight bytes (the paper computes 94 required warps vs 64 allowed).
* GTX560Ti "relies on ILP the most" (Fig 12): fewest allowed warps/SM, so
  only ILP can raise in-flight bytes.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.devices import GpuSpec, TpuSpec

WARP = 32
WORD = 4


@dataclasses.dataclass(frozen=True)
class OccupancyPoint:
    num_ctas: int          # total CTAs launched
    cta_size: int          # threads per CTA
    ilp: int               # independent 4-byte loads per thread


def active_warps_per_sm(spec: GpuSpec, pt: OccupancyPoint,
                        max_ctas_per_sm: int = 16) -> float:
    ctas_per_sm = min(max_ctas_per_sm, np.ceil(pt.num_ctas / spec.sms))
    warps = ctas_per_sm * np.ceil(pt.cta_size / WARP)
    return float(min(spec.max_warps_per_sm, warps))


def global_throughput_gbps(spec: GpuSpec, pt: OccupancyPoint,
                           latency_cycles: float = 600.0) -> float:
    """Device-wide global-memory copy throughput (Fig 12 model).

    in-flight bytes/SM = warps × 32 lanes × ILP × 4 B; Little's law then
    caps throughput at in-flight / latency, and the DRAM subsystem caps it
    at the *measured* peak (Table 6 — the theoretical-vs-measured gap is
    DRAM protocol overhead the paper reports as 70–81% efficiency).
    """
    warps = active_warps_per_sm(spec, pt)
    inflight = warps * WARP * pt.ilp * WORD            # bytes per SM
    latency_s = latency_cycles / (spec.f_core_ghz * 1e9)
    bw = spec.sms * inflight / latency_s / 1e9         # GB/s
    return float(min(spec.measured_peak_gbps, bw))


def shared_throughput_gbps(spec: GpuSpec, pt: OccupancyPoint) -> float:
    """Per-SM shared-memory copy throughput (Fig 15/16 model).

    required_warps(ILP=1) = banks × bank_bytes × latency / (32 lanes × 4 B);
    Kepler's serialized dual-mode issue multiplies required warps by ILP,
    everyone else divides (ILP adds in-flight bytes).  The peak is the
    *measured* W'_SM (Table 7).
    """
    warps = active_warps_per_sm(spec, pt)
    latency = spec.shared_base_latency
    required = (spec.shared_banks * spec.bank_bytes * latency) / (WARP * WORD)
    if spec.generation == "kepler":
        occupancy = warps / (required * pt.ilp)
    else:
        occupancy = warps * pt.ilp / required
    return float(spec.measured_shared_peak_gbps * min(1.0, occupancy))


def best_occupancy(spec: GpuSpec, kind: str = "shared") -> tuple[OccupancyPoint, float]:
    """Grid-search the paper's configuration space (§6.1)."""
    best, best_pt = -1.0, None
    for cta in (32, 64, 128, 256, 512, 1024):
        for ctas_per_sm in (1, 2, 3, 4, 5, 6):
            for ilp in (1, 2, 4):
                pt = OccupancyPoint(ctas_per_sm * spec.sms, cta, ilp)
                v = (shared_throughput_gbps(spec, pt) if kind == "shared"
                     else global_throughput_gbps(spec, pt))
                if v > best:
                    best, best_pt = v, pt
    return best_pt, best


# ---------------------------------------------------------------------------
# TPU side: the same law, sizing in-flight DMA for the Pallas kernels
# ---------------------------------------------------------------------------


def tpu_required_inflight_bytes(spec=None,
                                hbm_latency_s: float | None = None) -> int:
    """Bytes of outstanding HBM→VMEM DMA needed to hide HBM latency.

    ``spec`` may be a :class:`TpuSpec`, a dissected
    :class:`~repro.core.profile.DeviceProfile`, or ``None`` (the active
    profile); the latency anchor defaults to the profile's own
    ``hbm_latency_s`` field instead of a constant baked in here."""
    from repro.core import profile       # local: keep gpu-side import light
    spec = profile.resolve_spec(spec)
    if hbm_latency_s is None:
        hbm_latency_s = spec.hbm_latency_s
    return int(spec.hbm_bytes_per_s * hbm_latency_s)


def tpu_min_block_bytes(spec=None, buffers: int = 2,
                        hbm_latency_s: float | None = None) -> int:
    """Minimum BlockSpec tile size for a `buffers`-deep Pallas pipeline to
    keep the required bytes in flight (used by kernels/memcpy autotuning)."""
    from repro.core import profile
    spec = profile.resolve_spec(spec)
    need = tpu_required_inflight_bytes(spec, hbm_latency_s)
    per_buffer = int(np.ceil(need / max(1, buffers - 1)))
    # round up to a whole (sublanes, lanes) f32 tile
    tile = spec.sublanes * spec.lanes * 4
    return int(np.ceil(per_buffer / tile)) * tile
