"""Batched jax cache-simulation engine: many candidate lanes per call.

:class:`BatchCache` is the third engine in the oracle chain
``Cache`` (per-access reference) → ``VectorCache`` (numpy chunk stepping)
→ ``BatchCache`` (this module).  It carries the cache state planes —
resident-line tags, recency stamps, fill counters — as jax arrays with a
**batch leading axis over candidate lanes** (one lane = one geometry +
one address stream), steps address chunks with ``lax.scan`` and is
wrapped in ``vmap`` + ``jit`` so one call evaluates a whole candidate
grid of an inference stage at once.

Two execution paths sit behind one ``simulate()`` contract:

* **cyclic closed form** — every driver probe the blind pipeline issues
  is a tiling of a one-pass pattern that visits each distinct line in a
  single consecutive run (uniform chases, the ``find_set_bits`` probe
  matrix).  Under LRU/FIFO the inclusion property then gives the exact
  hit/miss stream in closed form: the first touch of each line is a
  compulsory miss, and in steady state an access misses iff it is the
  first access of a line whose set holds more distinct lines than ways
  (``d_s > w_s``).  This is the batched analogue of the vector engine's
  steady-state tiling — same answer, no per-access stepping at all.
* **scan** — arbitrary streams and the stochastic policies go through
  the jitted ``lax.scan`` step (vmapped over lanes).  For deterministic
  policies the scan is bit-exact against the reference oracle; the
  differential tests in ``tests/test_engine_equivalence_jax.py`` pin
  both paths to it.

**RNG-lane equivalence policy.**  The numpy oracle draws its
``random``/``prob`` eviction victims from a *serial* generator whose
consumption order is inherently sequential; a batched engine cannot
reproduce that stream bit-for-bit without serializing.  BatchCache
therefore draws per-step uniforms from ``jax.random`` (seeded, folded
per lane) — identical victim *distributions*, different draws.  Traces
from stochastic lanes are validated distributionally (way-probability
estimates within the profile diff tolerance), never by stream equality,
and the trace cache keys jax traces under
:data:`~repro.core.cachesim.JAX_ENGINE_VERSION` so they can never be
served to the numpy engines (or vice versa).

Prefetch geometries are rejected: no driver probes a prefetching
structure through the batched path, and the interval-coalescing
semantics would force the scan carry through a dynamic store.
"""

from __future__ import annotations

import functools
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.cachesim import CacheGeometry, JAX_ENGINE_VERSION  # noqa: F401

__all__ = ["BatchCache", "JAX_ENGINE_VERSION"]

_POLICY_CODE = {"lru": 0, "fifo": 1, "random": 2, "prob": 3}
_INT32_MAX = np.iinfo(np.int32).max


def _bucket(n: int) -> int:
    """Round up to a power of two: every distinct padded (B, T, W, K)
    costs one XLA compile, so shapes are bucketed to keep the kernel
    count O(log) in probe diversity (the persistent compilation cache
    then makes even those one-time costs)."""
    return 1 << max(0, int(n - 1).bit_length())


class BatchCache:
    """Batched cache simulator over candidate lanes.

    ``geoms`` fixes one :class:`CacheGeometry` per lane (heterogeneous
    sizes, set counts, way counts and policies are all allowed; the
    state planes are padded to the widest lane).  Every ``simulate``
    call starts each lane cold — a lane's hit/miss stream is a pure
    function of ``(geometry, stream, seed)``, which is what makes the
    batched traces content-addressable.
    """

    def __init__(self, geoms: Sequence[CacheGeometry] | CacheGeometry, *,
                 seed: int = 0):
        if isinstance(geoms, CacheGeometry):
            geoms = [geoms]
        self.geoms = list(geoms)
        self.seed = seed
        for g in self.geoms:
            if g.prefetch_lines:
                raise ValueError(
                    f"BatchCache does not support prefetch geometries "
                    f"({g.name!r} has prefetch_lines={g.prefetch_lines})")
            if g.replacement.kind not in _POLICY_CODE:
                raise ValueError(
                    f"unknown replacement policy {g.replacement.kind!r}")

    # -- closed form --------------------------------------------------------

    def steady_miss_count(self, lane: int,
                          line_addrs: np.ndarray) -> float | None:
        """Steady-state misses per pass of a cyclic chase, in closed form.

        ``line_addrs`` lists the distinct line addresses one pass visits
        (each exactly once, in consecutive runs).  Under LRU/FIFO the
        steady per-pass miss count is the number of lines living in
        over-subscribed sets: ``sum(d_s for sets with d_s > w_s)``.
        Returns None when the lane's policy has no closed form.
        """
        g = self.geoms[lane]
        if g.replacement.kind not in ("lru", "fifo"):
            return None
        sets = np.asarray(g.vector_mapper()(
            np.asarray(line_addrs, dtype=np.int64)), dtype=np.int64)
        d = np.bincount(sets, minlength=g.num_sets)
        w = np.asarray(g.way_counts, dtype=np.int64)
        thrash = d > w
        return float(d[thrash].sum())

    def periodic_masks(self, lane: int, pass_addrs: np.ndarray,
                       ) -> tuple[np.ndarray, np.ndarray] | None:
        """Positional closed form for one pass of a cyclic chase.

        Returns ``(miss_cold, miss_steady)`` per-access miss masks for
        the first (cold) pass and for any steady pass, or None when the
        closed form does not apply: non-LRU/FIFO policy, or a pass that
        revisits a line in more than one run (the caller falls back to
        the scan path).  The steady mask treats the pass as cyclic, so a
        line run that wraps across the pass boundary stays one run.
        """
        g = self.geoms[lane]
        if g.replacement.kind not in ("lru", "fifo"):
            return None
        addrs = np.asarray(pass_addrs, dtype=np.int64)
        if addrs.size == 0:
            return None
        sets = np.asarray(g.vector_mapper()(addrs), dtype=np.int64)
        tags = addrs // g.line_bytes
        keys = tags * g.num_sets + sets
        first = np.empty(len(keys), dtype=bool)
        first[0] = True
        np.not_equal(keys[1:], keys[:-1], out=first[1:])
        first_cyc = first.copy()
        first_cyc[0] = keys[0] != keys[-1]
        starts = keys[first_cyc]
        if starts.size == 0:                     # the whole pass is one line
            miss_cold = first.copy()
            return miss_cold, np.zeros(len(keys), dtype=bool)
        if np.unique(starts).size != starts.size:
            return None                          # a line split across runs
        d = np.bincount(sets[first_cyc], minlength=g.num_sets)
        w = np.asarray(g.way_counts, dtype=np.int64)
        thrash_set = d > w
        steady = first_cyc & thrash_set[sets]
        return first, steady

    def _try_periodic(self, lane: int,
                      addrs: np.ndarray) -> np.ndarray | None:
        """Hit stream for a stream that tiles a cyclic one-pass pattern."""
        g = self.geoms[lane]
        if g.replacement.kind not in ("lru", "fifo") or addrs.size == 0:
            return None
        occ = np.flatnonzero(addrs == addrs[0])
        periods = [int(p) for p in occ[1:3]] or [len(addrs)]
        for p in periods:
            if not np.array_equal(addrs, np.resize(addrs[:p], len(addrs))):
                continue
            masks = self.periodic_masks(lane, addrs[:p])
            if masks is None:
                return None
            cold, steady = masks
            miss = np.resize(steady, len(addrs))
            m = min(p, len(addrs))
            miss[:m] = cold[:m]
            return ~miss
        return None

    # -- the batched scan engine --------------------------------------------

    def simulate(self, streams: Sequence[np.ndarray], *,
                 force_scan: bool = False) -> list[np.ndarray]:
        """Hit/miss streams for every lane, each simulated from cold.

        ``streams[i]`` is lane *i*'s byte-address stream; the result is a
        bool array of the same length (True = hit).  Cyclic LRU/FIFO
        lanes resolve through the closed form; everything else goes
        through one vmapped ``lax.scan`` call (``force_scan=True`` pins
        the two paths against each other in the differential tests).
        """
        if len(streams) != len(self.geoms):
            raise ValueError(f"{len(streams)} streams for "
                             f"{len(self.geoms)} lanes")
        out: list[np.ndarray | None] = [None] * len(streams)
        scan_lanes: list[tuple[int, np.ndarray]] = []
        for i, addrs in enumerate(streams):
            addrs = np.asarray(addrs, dtype=np.int64)
            if not force_scan:
                hits = self._try_periodic(i, addrs)
                if hits is not None:
                    out[i] = hits
                    continue
            scan_lanes.append((i, addrs))
        if scan_lanes:
            for (i, _), hits in zip(scan_lanes, self._scan(scan_lanes)):
                out[i] = hits
        return out  # type: ignore[return-value]

    def _scan(self, lanes: list[tuple[int, np.ndarray]]) -> list[np.ndarray]:
        geoms = [self.geoms[i] for i, _ in lanes]
        lens = [len(a) for _, a in lanes]
        b = _bucket(len(lanes))
        t = _bucket(max(g.num_sets for g in geoms))
        w = _bucket(max(max(g.way_counts) for g in geoms))
        k = _bucket(max(lens) if max(lens, default=0) else 1)

        ways = np.zeros((b, t), dtype=np.int32)
        policy = np.zeros(b, dtype=np.int32)
        probs = np.zeros((b, w), dtype=np.float32)
        sets = np.zeros((b, k), dtype=np.int32)
        lines = np.zeros((b, k), dtype=np.int32)
        valid = np.zeros((b, k), dtype=bool)
        for j, ((_, addrs), g) in enumerate(zip(lanes, geoms)):
            ways[j, :g.num_sets] = g.way_counts
            policy[j] = _POLICY_CODE[g.replacement.kind]
            if g.replacement.way_probs:
                probs[j, :len(g.replacement.way_probs)] = g.replacement.way_probs
            s = np.asarray(g.vector_mapper()(addrs), dtype=np.int64)
            tag = addrs // g.line_bytes
            # factorize (line, set) pairs to dense int32 ids per lane so
            # the state planes stay int32 without global jax x64
            _, inv = np.unique(tag * g.num_sets + s, return_inverse=True)
            n = len(addrs)
            sets[j, :n] = s
            lines[j, :n] = inv
            valid[j, :n] = True
        # per-step eviction uniforms, drawn once per batch (see the
        # module docstring's RNG-lane equivalence policy)
        u = np.asarray(jax.random.uniform(
            jax.random.PRNGKey(self.seed), (b, k), dtype=jnp.float32))
        hits = np.asarray(_scan_kernel(
            jnp.asarray(ways), jnp.asarray(policy), jnp.asarray(probs),
            jnp.asarray(sets), jnp.asarray(lines), jnp.asarray(valid),
            jnp.asarray(u)))
        return [hits[j, :n] for j, n in enumerate(lens)]


def _lane_scan(ways, policy, probs, sets, lines, valid, u):
    t, = ways.shape
    w, = probs.shape
    wid = jnp.arange(w, dtype=jnp.int32)
    init = (jnp.full((t, w), -1, dtype=jnp.int32),     # resident line ids
            jnp.zeros((t, w), dtype=jnp.int32),        # recency stamps
            jnp.zeros((t,), dtype=jnp.int32),          # cold-fill counters
            jnp.int32(1))                              # access clock

    def step(carry, x):
        tags, stamp, filled, clock = carry
        s, line, v, uu = x
        row_t, row_s = tags[s], stamp[s]
        wl, f = ways[s], filled[s]
        wvalid = wid < wl
        eq = wvalid & (row_t == line)
        hit = eq.any()
        # victim selection per policy; lru/fifo share argmin-stamp (ties
        # impossible once a set is full: every stamp is a distinct clock)
        ev_det = jnp.argmin(jnp.where(wvalid, row_s, _INT32_MAX)
                            ).astype(jnp.int32)
        ev_rand = jnp.minimum((uu * wl).astype(jnp.int32),
                              jnp.maximum(wl - 1, 0))
        cum = jnp.cumsum(jnp.where(wvalid, probs, 0.0))
        ev_prob = jnp.argmax(cum >= uu * cum[w - 1]).astype(jnp.int32)
        evict = jnp.where(policy == 2, ev_rand,
                          jnp.where(policy == 3, ev_prob, ev_det))
        ins = jnp.where(f < wl, f, evict)
        way = jnp.where(hit, jnp.argmax(eq).astype(jnp.int32), ins)
        do_ins = v & ~hit
        sel = wid == way
        # lru restamps on hit and insert; fifo only on insert
        restamp = jnp.where(policy == 0, v,
                            jnp.where(policy == 1, do_ins, False))
        tags = tags.at[s].set(jnp.where(sel & do_ins, line, row_t))
        stamp = stamp.at[s].set(jnp.where(sel & restamp, clock, row_s))
        filled = filled.at[s].add(jnp.where(do_ins & (f < wl), 1, 0)
                                  .astype(jnp.int32))
        return (tags, stamp, filled, clock + v.astype(jnp.int32)), hit & v

    _, hits = lax.scan(step, init, (sets, lines, valid, u), unroll=4)
    return hits


@functools.partial(jax.jit)
def _scan_kernel(ways, policy, probs, sets, lines, valid, u):
    return jax.vmap(_lane_scan)(ways, policy, probs, sets, lines, valid, u)
