"""Calibrated device models.

Two families live here:

1. The paper's three GPUs (GTX560Ti / GTX780 / GTX980) expressed as
   :class:`~repro.core.cachesim.MemoryHierarchy` instances with every
   structure the paper published (Table 3, Table 5, §4–§6).  These are the
   ground truth that the fine-grained analyzer must re-derive blind.
2. The TPU v5e target (per-chip peaks used by the roofline, VMEM geometry
   used by the autotuner and the Pallas kernels).

Cycle constants for the latency spectrum are calibrated to the
relationships the paper states around Fig 14 (see inline notes); the
*structural* parameters are exact per Table 5.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cachesim import (
    Cache,
    CacheGeometry,
    LatencyModel,
    MemoryHierarchy,
    ReplacementPolicy,
    bitfield_map,
    range_cyclic_map,
    split_bitfield_map,
)

MB = 1 << 20
KB = 1 << 10

# ---------------------------------------------------------------------------
# Structural geometries (Table 5 — exact)
# ---------------------------------------------------------------------------


def fermi_l1_data(rng=None) -> Cache:
    """16 KB, 128 B lines, 32 sets — non-LRU with way probs (1/6,1/2,1/6,1/6).

    §4.5: bits 9–11 pick the major set and 12–13 the group; bits 7–8 are
    *not* part of the set index (Assumption-2 violation #2).
    """
    geom = CacheGeometry(
        name="fermi_l1_data",
        line_bytes=128,
        way_counts=(4,) * 32,
        set_map=split_bitfield_map([(9, 3), (12, 2)]),
        replacement=ReplacementPolicy("prob", (1 / 6, 1 / 2, 1 / 6, 1 / 6)),
    )
    return Cache(geom, rng)


def kepler_texture_l1(rng=None) -> Cache:
    """12 KB, 32 B lines, 4 sets × 96 ways, set = address bits 7–8 (Fig 7)."""
    geom = CacheGeometry(
        name="kepler_texture_l1",
        line_bytes=32,
        way_counts=(96,) * 4,
        set_map=bitfield_map(7, 2),
    )
    return Cache(geom, rng)


def kepler_readonly(rng=None) -> Cache:
    """GTX780 read-only data cache: same geometry as texture L1 (§4.3)."""
    geom = CacheGeometry(
        name="kepler_readonly",
        line_bytes=32,
        way_counts=(96,) * 4,
        set_map=bitfield_map(7, 2),
    )
    return Cache(geom, rng)


def maxwell_unified_l1(rng=None) -> Cache:
    """GTX980 unified L1/texture: 24 KB, 32 B lines, 4 sets × 192 ways."""
    geom = CacheGeometry(
        name="maxwell_unified_l1",
        line_bytes=32,
        way_counts=(192,) * 4,
        set_map=bitfield_map(7, 2),
    )
    return Cache(geom, rng)


def volta_l1_data(rng=None) -> Cache:
    """TeslaV100 combined L1/shared data path (Jia et al. 2018, Table 3.1):
    128 KB at 32 B sector granularity, 4 sets × 1024 ways, LRU.

    The load unit is the 32 B *sector* (the 128 B line fills four sectors
    lazily), so the miss granularity the blind analyzer sees is 32 B — same
    observable as the Maxwell unified L1, eight times the capacity.  Set
    selection stays on address bits 7–8.
    """
    geom = CacheGeometry(
        name="volta_l1_data",
        line_bytes=32,
        way_counts=(1024,) * 4,
        set_map=bitfield_map(7, 2),
    )
    return Cache(geom, rng)


def l1_tlb(rng=None) -> Cache:
    """16-way fully-associative, 2 MB pages ⇒ 32 MB reach (§4.4)."""
    geom = CacheGeometry(
        name="l1_tlb",
        line_bytes=2 * MB,
        way_counts=(16,),
    )
    return Cache(geom, rng)


def l2_tlb(rng=None) -> Cache:
    """65 entries in UNEQUAL sets: one 17-way + six 8-way, LRU (Fig 9)."""
    ways = (17, 8, 8, 8, 8, 8, 8)
    geom = CacheGeometry(
        name="l2_tlb",
        line_bytes=2 * MB,
        way_counts=ways,
        set_map=range_cyclic_map(2 * MB, ways),
    )
    return Cache(geom, rng)


def volta_l2_tlb(rng=None) -> Cache:
    """V100 L2 TLB modeled at 128 entries in 16 EQUAL 8-way LRU sets.

    Unlike the 2015 paper's 17+6×8 structure (Fig 9), Volta's L2 TLB shows
    equal sets again (Jia et al. §3) — held-out validation that the blind
    set-structure recovery distinguishes the two regimes instead of
    pattern-matching the staircase it was developed against.
    """
    geom = CacheGeometry(
        name="volta_l2_tlb",
        line_bytes=2 * MB,
        way_counts=(8,) * 16,
    )
    return Cache(geom, rng)


def l2_data(size_bytes: int, rng=None, prefetch: bool = True) -> Cache:
    """L2 data cache (§4.6): 32 B lines, non-LRU (random model), sequential
    prefetch of ~2/3 capacity.  Associativity is 'not an integer' per the
    paper/Meltzer — we model 16 sets with the remainder folded into ways.

    ``prefetch=False`` models Volta, where the sequential DRAM→L2 streamer
    of the 2015 generations is not observable (Jia et al.) — and where a
    2/3-of-6MB reach would anyway swallow whole 2 MB pages, breaking the
    P4 phase placement of the spectrum experiment."""
    num_sets = 16
    lines = size_bytes // 32
    geom = CacheGeometry(
        name="l2_data",
        line_bytes=32,
        way_counts=(lines // num_sets,) * num_sets,
        replacement=ReplacementPolicy("random"),
        prefetch_lines=int((2 / 3) * lines) if prefetch else 0,
    )
    return Cache(geom, rng)


# ---------------------------------------------------------------------------
# Full device models
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GpuSpec:
    """Published per-device constants used by throughput/latency benchmarks."""

    name: str
    generation: str
    sms: int
    f_core_ghz: float                 # Table 7
    f_mem_mhz: float                  # Table 6
    bus_width_bits: int
    ddr_factor: int = 4
    max_warps_per_sm: int = 48
    shared_banks: int = 32
    bank_bytes: int = 4               # Kepler: 8 (dual mode)
    shared_base_latency: float = 50.0 # §6.2 normal latencies
    measured_peak_gbps: float = 0.0   # Table 6 "maximum throughput"
    measured_shared_peak_gbps: float = 0.0  # Table 7 W'_SM

    @property
    def theoretical_gbps(self) -> float:
        return self.f_mem_mhz * 1e6 * (self.bus_width_bits / 8) * self.ddr_factor / 1e9

    @property
    def shared_theoretical_gbps(self) -> float:
        return self.f_core_ghz * self.bank_bytes * self.shared_banks


GTX560TI = GpuSpec("GTX560Ti", "fermi", sms=8, f_core_ghz=0.950, f_mem_mhz=1050,
                   bus_width_bits=256, max_warps_per_sm=48, bank_bytes=4,
                   shared_base_latency=50.0, measured_peak_gbps=109.38,
                   measured_shared_peak_gbps=35.70)
GTX780 = GpuSpec("GTX780", "kepler", sms=12, f_core_ghz=1.006, f_mem_mhz=1502,
                 bus_width_bits=384, max_warps_per_sm=64, bank_bytes=8,
                 shared_base_latency=47.0, measured_peak_gbps=215.92,
                 measured_shared_peak_gbps=96.58)
GTX980 = GpuSpec("GTX980", "maxwell", sms=16, f_core_ghz=1.279, f_mem_mhz=1753,
                 bus_width_bits=256, max_warps_per_sm=64, bank_bytes=4,
                 shared_base_latency=28.0, measured_peak_gbps=156.25,
                 measured_shared_peak_gbps=122.90)
# Held-out Volta generation (Jia et al. 2018): HBM2 — 4096-bit bus at DDR
# factor 2 (898 GB/s theoretical, ~88% protocol efficiency, better than the
# 70–81% the 2015 paper reports for GDDR5).
TESLAV100 = GpuSpec("TeslaV100", "volta", sms=80, f_core_ghz=1.380,
                    f_mem_mhz=877, bus_width_bits=4096, ddr_factor=2,
                    max_warps_per_sm=64, bank_bytes=4,
                    shared_base_latency=19.0, measured_peak_gbps=791.0,
                    measured_shared_peak_gbps=155.40)

GPU_SPECS = {s.name: s for s in (GTX560TI, GTX780, GTX980, TESLAV100)}

# Latency-spectrum constants (cycles).  Calibration anchors from the paper:
#  * 560Ti L1-cached L1TLB-miss penalty = 288 cycles; L2-cached = 27 (§5.2-3)
#  * GTX780 P2–P5 ≈ half the Fermi values (§5.2-4)
#  * GTX980 ≈ GTX780 on P1–P4; P5 ≈ 3.5× Kepler's, ≈ 2× Fermi's (§5.2-4)
#  * P6 exists only on Kepler/Maxwell; Maxwell's is much larger (§5.2-1)
FERMI_LATENCY = LatencyModel(l1_hit=96, l2_hit=371, dram=564,
                             l1tlb_miss=288, pagewalk=716)
KEPLER_LATENCY = LatencyModel(l1_hit=188, l2_hit=188, dram=301,
                              l1tlb_miss=27, pagewalk=364,
                              context_switch=2000)
MAXWELL_LATENCY = LatencyModel(l1_hit=82, l2_hit=214, dram=1052,
                               l1tlb_miss=24, pagewalk=360,
                               context_switch=5000)
# Volta (Jia et al. Table 3.1 anchors): L1 hit 28, L2 hit 193, HBM2 ~375;
# the virtually-addressed L1 makes P1=P2=P3 as on Maxwell; no page-table
# context-switch window is observable (P6 absent, as on Fermi).
VOLTA_LATENCY = LatencyModel(l1_hit=28, l2_hit=193, dram=375,
                             l1tlb_miss=35, pagewalk=400)


def make_hierarchy(device: str, l1_enabled: bool = True,
                   seed: int = 0) -> MemoryHierarchy:
    """Full global-memory hierarchy for one of the paper's devices."""
    rng = np.random.default_rng(seed)
    if device == "GTX560Ti":     # Fermi: L1+L2 data caches, both TLBs
        return MemoryHierarchy(
            name=device, latency=FERMI_LATENCY,
            l1=fermi_l1_data(rng) if l1_enabled else None,
            l2=l2_data(512 * KB, rng),
            l1tlb=l1_tlb(rng), l2tlb=l2_tlb(rng))
    if device == "GTX780":       # Kepler: global is L2-cached only (Table 3)
        return MemoryHierarchy(
            name=device, latency=KEPLER_LATENCY,
            l1=None,
            l2=l2_data(1536 * KB, rng),
            l1tlb=l1_tlb(rng), l2tlb=l2_tlb(rng),
            active_window_bytes=512 * MB)
    if device == "GTX980":       # Maxwell: unified L1 is virtually addressed
        return MemoryHierarchy(
            name=device, latency=MAXWELL_LATENCY,
            l1=maxwell_unified_l1(rng) if l1_enabled else None,
            l2=l2_data(2048 * KB, rng),
            l1tlb=l1_tlb(rng), l2tlb=l2_tlb(rng),
            l1_virtually_addressed=True,
            active_window_bytes=512 * MB)
    if device == "TeslaV100":    # Volta (held-out): Jia et al. 2018
        return MemoryHierarchy(
            name=device, latency=VOLTA_LATENCY,
            l1=volta_l1_data(rng) if l1_enabled else None,
            l2=l2_data(6 * MB, rng, prefetch=False),
            l1tlb=l1_tlb(rng), l2tlb=volta_l2_tlb(rng),
            l1_virtually_addressed=True)
    raise ValueError(f"unknown device {device!r}")


def expected_spectrum(device: str) -> dict[str, float]:
    """Published Fig-14 P1–P6 latencies, additive from the calibration
    constants (§5.2): this is the table the blind spectrum measurement is
    diffed against, derived from the latency model instead of hand-copied
    per device so a new hierarchy (Volta) gets its expectation for free."""
    h = make_hierarchy(device)
    lat = h.latency
    base = lat.l1_hit if h.l1 is not None else lat.l2_hit
    virt = h.l1 is not None and h.l1_virtually_addressed
    out = {
        "P1": base,
        "P2": base if virt else base + lat.l1tlb_miss,
        "P3": base if virt else base + lat.pagewalk,
        "P4": lat.dram,
        "P5": lat.dram + lat.pagewalk,
    }
    if h.active_window_bytes is not None:
        out["P6"] = out["P5"] + lat.context_switch
    return out


# Shared-memory bank-conflict latency (Table 8 — exact measured cycles;
# TeslaV100 row per Jia et al.: Volta keeps Maxwell's flattened slope).
BANK_CONFLICT_LATENCY = {
    # ways:        1    2    4    8    16    32
    "GTX980":   {1: 28, 2: 30, 4: 34, 8: 42, 16: 58, 32: 90},
    "GTX780":   {1: 47, 2: 82, 4: 96, 8: 158, 16: 257, 32: 484},
    "GTX560Ti": {1: 50, 2: 87, 4: 162, 8: 311, 16: 611, 32: 1209},
    "TeslaV100": {1: 19, 2: 21, 4: 25, 8: 33, 16: 49, 32: 81},
}

# ---------------------------------------------------------------------------
# TPU v5e target (roofline constants + VMEM geometry)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TpuSpec:
    name: str = "tpu_v5e"
    peak_bf16_flops: float = 197e12        # per chip
    hbm_bytes_per_s: float = 819e9         # per chip
    hbm_bytes: int = 16 * (1 << 30)        # 16 GiB per chip
    ici_bytes_per_s_per_link: float = 50e9 # ~50 GB/s/link
    ici_links: int = 4                     # 2D torus: 4 links/chip
    vmem_bytes: int = 128 * (1 << 20)      # per core
    sublanes: int = 8                      # native tile (8, 128)
    lanes: int = 128
    mxu_dim: int = 128
    hbm_latency_s: float = 1.0e-6          # Little's-law latency anchor

    @property
    def ici_bytes_per_s(self) -> float:
        return self.ici_bytes_per_s_per_link * self.ici_links


TPU_V5E = TpuSpec()

# ---------------------------------------------------------------------------
# Device registry (the hook `repro.bench` parameterizes experiments over)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeviceEntry:
    """One runnable measurement target.

    ``kind`` is ``"gpu-sim"`` for the paper's three GPUs (backed by the
    calibrated :mod:`repro.core.cachesim` models) or ``"tpu"`` for the real
    host target.  ``has_hierarchy`` marks devices accepted by
    :func:`make_hierarchy`.
    """

    name: str
    kind: str
    generation: str = ""
    spec: GpuSpec | TpuSpec | None = None
    has_hierarchy: bool = False


DEVICE_REGISTRY: dict[str, DeviceEntry] = {}


def register_device(entry: DeviceEntry) -> DeviceEntry:
    """Register a measurement target; duplicate names are an error."""
    if entry.name in DEVICE_REGISTRY:
        raise ValueError(f"device {entry.name!r} already registered")
    DEVICE_REGISTRY[entry.name] = entry
    return entry


def get_device(name: str) -> DeviceEntry:
    try:
        return DEVICE_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown device {name!r}; registered: {sorted(DEVICE_REGISTRY)}"
        ) from None


def list_devices(kind: str | None = None) -> list[DeviceEntry]:
    entries = DEVICE_REGISTRY.values()
    return [e for e in entries if kind is None or e.kind == kind]


for _spec in (GTX560TI, GTX780, GTX980, TESLAV100):
    register_device(DeviceEntry(_spec.name, "gpu-sim", _spec.generation,
                                _spec, has_hierarchy=True))
register_device(DeviceEntry(TPU_V5E.name, "tpu", "v5e", TPU_V5E))

# ---------------------------------------------------------------------------
# Simulated-cache registry (trace identities for the trace cache)
# ---------------------------------------------------------------------------

#: every fixed-geometry simulated structure, by its canonical name.  The
#: name doubles as the structure's ``trace_id`` in the content-addressed
#: trace cache and as the case label in benchmarks and differential tests.
SIM_CACHES = {
    "fermi_l1_data": fermi_l1_data,
    "kepler_texture_l1": kepler_texture_l1,
    "kepler_readonly": kepler_readonly,
    "maxwell_unified_l1": maxwell_unified_l1,
    "volta_l1_data": volta_l1_data,
    "l1_tlb": l1_tlb,
    "l2_tlb": l2_tlb,
    "volta_l2_tlb": volta_l2_tlb,
}


def sim_cache_backend(name: str, *, engine: str = "vector", **kw):
    """Trace backend for a registered simulated cache, wired into the trace
    cache under the structure's canonical name (the factories are
    deterministic, which is what makes the trace_id valid)."""
    from repro.core.pchase import cache_backend   # local: keep layering flat
    try:
        factory = SIM_CACHES[name]
    except KeyError:
        raise KeyError(f"unknown simulated cache {name!r}; "
                       f"registered: {sorted(SIM_CACHES)}") from None
    return cache_backend(factory, engine=engine, trace_id=name, **kw)
