"""Core: the paper's contribution — fine-grained P-chase memory-hierarchy
dissection — plus the TPU-side roofline machinery built on it."""

from repro.core.cachesim import (  # noqa: F401
    Cache, CacheGeometry, LatencyModel, MemoryHierarchy, ReplacementPolicy,
    VectorCache, bitfield_map, modulo_map, range_cyclic_map,
    split_bitfield_map,
)
from repro.core.inference import (  # noqa: F401
    CacheParams, dissect, detect_replacement, find_cache_size,
    find_line_size, find_set_bits, recover_set_structure,
)
from repro.core.pchase import (  # noqa: F401
    cache_backend, fine_grained, hierarchy_backend, saavedra1992, wong2010,
)
from repro.core.trace import PChaseConfig, PChaseTrace  # noqa: F401
