"""Parameterized cache / TLB / memory-hierarchy simulator.

This is the CPU-side measurement substrate (see DESIGN.md §2): a
ground-truth oracle that can be configured with every structure the paper
discovered —

* classical equal-set set-associative caches (paper Assumptions 1–3),
* **unequal cache sets** (the L2 TLB's 17+6×8 structure, Fig 9),
* **non-bits-defined and non-adjacent set mappings** (texture L1 selects the
  set with address bits 7–8 instead of 5–6, Fig 7; Fermi L1 uses bits 9–11
  and 12–13, §4.5),
* **non-LRU replacement** (Fermi L1's way probabilities (1/6, 1/2, 1/6, 1/6),
  Fig 11; random replacement for the L2),
* **sequential DRAM→L2 prefetch** of ~2/3 the cache capacity (§4.6),
* multi-level composition with TLBs, page-table walks and the Kepler/Maxwell
  512 MB page-table context-switch window (P6, §5.2).

The fine-grained P-chase analyzer (``core.inference``) must recover all of
these *blind* — it only ever sees (index, latency) traces, never the
simulator internals.  ``meta`` fields carry internals for unit tests only.
"""

from __future__ import annotations

import bisect
import dataclasses
import heapq
from typing import Callable, Sequence

import numpy as np

# Bumped whenever the observable trace semantics of either engine change;
# part of every trace-cache key (see core.tracecache) so stale cached
# traces can never leak across engine revisions.
ENGINE_VERSION = "trace-engine/2"

# Version of the batched jax engine (core.cachesim_jax).  Defined here —
# not in cachesim_jax — so the trace cache and profile staleness checks
# can name it without importing jax.  Bumped independently of
# ENGINE_VERSION: the jax engine's hit/miss streams are bit-identical to
# the oracle for deterministic policies but its stochastic-policy RNG
# lanes are only distributionally equivalent, so its traces must never be
# served to (or taken from) the numpy engines.
JAX_ENGINE_VERSION = "trace-engine-jax/1"

# ---------------------------------------------------------------------------
# Set-mapping functions: line address (bytes) -> set index
#
# Each factory attaches a ``vectorized`` attribute to the scalar closure —
# the same mapping applied to a whole int64 address chunk at once — which
# the vectorized engine uses to translate an entire chunk per call.
# ---------------------------------------------------------------------------


def modulo_map(line_bytes: int, num_sets: int) -> Callable[[int], int]:
    """Classic adjacent-bits mapping (paper Assumption 2)."""

    def _map(addr: int) -> int:
        return (addr // line_bytes) % num_sets

    _map.vectorized = lambda addrs: (addrs // line_bytes) % num_sets
    return _map


def bitfield_map(lo_bit: int, num_bits: int) -> Callable[[int], int]:
    """Set selected by address bits [lo_bit, lo_bit+num_bits).

    The texture L1 uses ``bitfield_map(7, 2)`` — bits 7–8 — rather than the
    traditional bits 5–6, which is exactly what breaks Wong2010 (Fig 4/5).
    """
    mask = (1 << num_bits) - 1

    def _map(addr: int) -> int:
        return (addr >> lo_bit) & mask

    _map.vectorized = lambda addrs: (addrs >> lo_bit) & mask
    return _map


def split_bitfield_map(fields: Sequence[tuple[int, int]]) -> Callable[[int], int]:
    """Set index concatenated from non-adjacent bit ranges.

    Models the Fermi L1 data cache's mapping (§4.5): bits 9–11 select the
    "major set" and bits 12–13 the group — ``[(9, 3), (12, 2)]`` — leaving
    bits 7–8 *unused*, which violates Assumption 2 in a second way.
    """
    fields = tuple((int(lo), int(nbits)) for lo, nbits in fields)

    def _map(addr: int) -> int:
        out, shift = 0, 0
        for lo, nbits in fields:
            out |= ((addr >> lo) & ((1 << nbits) - 1)) << shift
            shift += nbits
        return out

    def _vec(addrs: np.ndarray) -> np.ndarray:
        out = np.zeros_like(addrs)
        shift = 0
        for lo, nbits in fields:
            out |= ((addrs >> lo) & ((1 << nbits) - 1)) << shift
            shift += nbits
        return out

    _map.vectorized = _vec
    return _map


def range_cyclic_map(line_bytes: int, way_counts: Sequence[int]) -> Callable[[int], int]:
    """Unequal sets filled in contiguous ranges, wrapping at total capacity.

    Used for the L2 TLB (1×17 + 6×8 entries).  The paper under-determines
    the page→set function; this choice reproduces the observable it reports
    (overflowing by one page thrashes exactly the large set first, then the
    small sets one by one as N grows — Fig 8's piecewise-linear miss rate).
    """
    bounds = np.cumsum(np.asarray(way_counts, dtype=np.int64))
    total = int(bounds[-1])

    def _map(addr: int) -> int:
        q = (addr // line_bytes) % total
        return int(np.searchsorted(bounds, q, side="right"))

    _map.vectorized = lambda addrs: np.searchsorted(
        bounds, (addrs // line_bytes) % total, side="right").astype(np.int64)
    return _map


# ---------------------------------------------------------------------------
# Sorted, coalesced [lo, hi) interval sets (prefetch windows)
# ---------------------------------------------------------------------------


def _interval_add(los: list[int], his: list[int], lo: int, hi: int) -> None:
    """Insert [lo, hi) into a sorted disjoint interval list, coalescing any
    overlapping or adjacent intervals, so membership stays a binary search
    no matter how long the trace runs."""
    i = bisect.bisect_left(los, lo)
    if i > 0 and his[i - 1] >= lo:      # overlaps/abuts predecessor
        i -= 1
        lo = los[i]
        hi = max(hi, his[i])
    j = i
    while j < len(los) and los[j] <= hi:   # absorb successors
        hi = max(hi, his[j])
        j += 1
    los[i:j] = [lo]
    his[i:j] = [hi]


def _interval_contains(los: list[int], his: list[int], x: int) -> bool:
    i = bisect.bisect_right(los, x) - 1
    return i >= 0 and x < his[i]


# ---------------------------------------------------------------------------
# Single cache level
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReplacementPolicy:
    """``lru`` | ``fifo`` | ``random`` | ``prob``.

    ``prob`` replaces way *i* of a full set with probability
    ``way_probs[i]`` — the Fermi L1's measured behaviour is
    ``(1/6, 1/2, 1/6, 1/6)`` (§4.5, Fig 11).
    """

    kind: str = "lru"
    way_probs: tuple[float, ...] | None = None

    def __post_init__(self) -> None:
        if self.kind not in ("lru", "fifo", "random", "prob"):
            raise ValueError(f"unknown replacement policy {self.kind!r}")
        if self.kind == "prob":
            if not self.way_probs:
                raise ValueError("prob policy needs way_probs")
            if abs(sum(self.way_probs) - 1.0) > 1e-9:
                raise ValueError("way_probs must sum to 1")


LRU = ReplacementPolicy("lru")


@dataclasses.dataclass(frozen=True)
class CacheGeometry:
    """Full structural description of one cache level."""

    name: str
    line_bytes: int
    way_counts: tuple[int, ...]                   # per-set ways; unequal allowed
    set_map: Callable[[int], int] | None = None   # default: modulo_map
    replacement: ReplacementPolicy = LRU
    prefetch_lines: int = 0                       # sequential prefetch on compulsory miss

    @property
    def num_sets(self) -> int:
        return len(self.way_counts)

    @property
    def size_bytes(self) -> int:
        return self.line_bytes * sum(self.way_counts)

    @property
    def uniform_ways(self) -> int | None:
        ways = set(self.way_counts)
        return ways.pop() if len(ways) == 1 else None

    def mapper(self) -> Callable[[int], int]:
        return self.set_map or modulo_map(self.line_bytes, self.num_sets)

    def vector_mapper(self) -> Callable[[np.ndarray], np.ndarray]:
        """Chunk-at-a-time set mapping for the vectorized engine.

        Uses the factory-provided ``vectorized`` twin when present; custom
        scalar-only mappings fall back to an element loop (correct, slow).
        """
        m = self.set_map
        if m is None:
            lb, ns = self.line_bytes, self.num_sets
            return lambda addrs: (addrs // lb) % ns
        vec = getattr(m, "vectorized", None)
        if vec is not None:
            return vec
        return lambda addrs: np.fromiter(
            (m(int(a)) for a in addrs), dtype=np.int64, count=len(addrs))

    @staticmethod
    def uniform(name: str, size_bytes: int, line_bytes: int, num_sets: int,
                **kw) -> "CacheGeometry":
        ways, rem = divmod(size_bytes, line_bytes * num_sets)
        if rem:
            raise ValueError("size not divisible by line*sets")
        return CacheGeometry(name, line_bytes, (ways,) * num_sets, **kw)


class Cache:
    """One level.  ``access`` returns True on hit and updates state."""

    def __init__(self, geom: CacheGeometry, rng: np.random.Generator | None = None):
        self.geom = geom
        self._map = geom.mapper()
        self._rng = rng or np.random.default_rng(0)
        self.reset()

    def reset(self) -> None:
        # Per set: fixed physical way slots (tag or None) — way identity must
        # be stable or per-way replacement probabilities are meaningless —
        # plus a recency list of way indices (LRU order, oldest first).
        self._ways: list[list[int | None]] = [
            [None] * w for w in self.geom.way_counts]
        self._order: list[list[int]] = [[] for _ in self.geom.way_counts]
        self._ever_seen: set[int] = set()       # for compulsory-miss prefetch
        # Prefetched-but-not-yet-touched tag intervals [start, end); touching
        # one counts as a hit and promotes the line into the cache proper.
        # Kept sorted and coalesced so membership is O(log n) — long TLB
        # traces used to degrade quadratically on the old linear scan.
        self._pf_lo: list[int] = []
        self._pf_hi: list[int] = []
        self.hits = 0
        self.misses = 0
        self.replaced_ways: list[tuple[int, int]] = []  # (set_idx, way_idx) per eviction

    # -- internals ----------------------------------------------------------

    def _insert(self, set_idx: int, tag: int) -> None:
        slots = self._ways[set_idx]
        order = self._order[set_idx]
        if None in slots:                     # cold fill: first free slot
            way = slots.index(None)
            slots[way] = tag
            order.append(way)
            return
        pol = self.geom.replacement
        if pol.kind in ("lru", "fifo"):
            way = order[0]                    # oldest (FIFO never reorders)
        elif pol.kind == "random":
            way = int(self._rng.integers(len(slots)))
        else:                                 # prob: fixed per-way probabilities
            way = int(self._rng.choice(len(slots), p=np.asarray(pol.way_probs)))
        self.replaced_ways.append((set_idx, way))
        order.remove(way)
        order.append(way)
        slots[way] = tag

    # -- public -------------------------------------------------------------

    def probe(self, addr: int) -> bool:
        """Hit test with no state change (used by tests only)."""
        tag = addr // self.geom.line_bytes
        return tag in self._ways[self._map(addr)]

    @property
    def _prefetched(self) -> list[tuple[int, int]]:
        """Coalesced prefetch windows as (start, end) tag pairs."""
        return list(zip(self._pf_lo, self._pf_hi))

    def _in_prefetch(self, tag: int) -> bool:
        return _interval_contains(self._pf_lo, self._pf_hi, tag)

    def access(self, addr: int) -> bool:
        tag = addr // self.geom.line_bytes
        set_idx = self._map(addr)
        slots = self._ways[set_idx]
        if tag in slots:
            self.hits += 1
            if self.geom.replacement.kind == "lru":
                way = slots.index(tag)
                order = self._order[set_idx]
                order.remove(way)
                order.append(way)             # move to MRU
            return True
        if tag not in self._ever_seen and self._in_prefetch(tag):
            # Prefetched line: its first-ever touch is a hit; promote it.
            self.hits += 1
            self._ever_seen.add(tag)
            self._insert(set_idx, tag)
            return True
        self.misses += 1
        compulsory = tag not in self._ever_seen
        self._ever_seen.add(tag)
        self._insert(set_idx, tag)
        if compulsory and self.geom.prefetch_lines:
            # Sequential DRAM->L2 prefetch (§4.6): the next ~2/3-capacity of
            # lines stream in behind a compulsory miss, so arrays below the
            # prefetch window show no cold-miss pattern.
            _interval_add(self._pf_lo, self._pf_hi,
                          tag + 1, tag + 1 + self.geom.prefetch_lines)
        return False


# ---------------------------------------------------------------------------
# Vectorized stepping engine
# ---------------------------------------------------------------------------


def _group_positions(keys: np.ndarray) -> dict:
    """line key -> ascending positions within the chunk (lazy eviction
    re-candidacy index for the event loop)."""
    if keys.size == 0:
        return {}
    order = np.argsort(keys, kind="stable")   # stable: positions stay sorted
    kk = keys[order]
    brk = np.flatnonzero(np.diff(kk) != 0) + 1
    out: dict = {}
    start = 0
    for end in list(brk) + [order.size]:
        out[int(kk[start])] = order[start:end]
        start = end
    return out


class VectorCache:
    """Chunk-stepping twin of :class:`Cache` — same observable behaviour,
    advanced a whole index chunk per call.

    State lives in numpy arrays: per-set tag rows (``-1`` = empty slot) and
    a per-way timestamp plane that doubles as LRU recency (``lru``) or
    insertion time (``fifo``); prefetch windows are sorted coalesced
    interval arrays.  A chunk is processed event-driven: membership of the
    whole chunk is tested vectorized (binary search of ``tag·T + set`` keys
    against the sorted resident-key snapshot — no per-way gather), runs of
    hits are committed in bulk (LRU recency deduped to one write per
    distinct line), and only the *events* (misses and prefetch promotions —
    the points where state actually changes) run through the exact
    per-access reference semantics, consuming the RNG in the same order as
    :class:`Cache` so ``random``/``prob`` replacement streams are
    bit-identical.  An eviction re-candidates the evicted tag's next chunk
    position, so correctness never depends on the initial snapshot.

    ``Cache`` remains the ground-truth oracle; the differential test suite
    asserts bit-exact hit/miss/latency streams between the two engines.
    """

    #: block size for one event-loop pass; bounds snapshot staleness costs
    _BLOCK = 1 << 16

    def __init__(self, geom: CacheGeometry, rng: np.random.Generator | None = None):
        self.geom = geom
        self._ns = geom.num_sets
        self._vmap = geom.vector_mapper()
        self._rng = rng or np.random.default_rng(0)
        pol = geom.replacement
        self._pol = pol.kind
        self._probs = (np.asarray(pol.way_probs, dtype=np.float64)
                       if pol.way_probs else None)
        self.reset()

    @classmethod
    def from_cache(cls, cache: Cache) -> "VectorCache":
        """Twin a freshly-built reference cache (shares its RNG instance, so
        the stochastic replacement stream stays bit-identical)."""
        return cls(cache.geom, cache._rng)

    def reset(self) -> None:
        g = self.geom
        self._wl = np.asarray(g.way_counts, dtype=np.int64)
        w = int(self._wl.max())
        t = g.num_sets
        self._tags = np.full((t, w), -1, dtype=np.int64)
        self._stamp = np.full((t, w), -1, dtype=np.int64)
        self._filled = np.zeros(t, dtype=np.int64)
        self._way_of: dict[int, int] = {}     # resident key -> way index
        self._clock = 0
        self._ever_seen: set[int] = set()
        self._pf_lo: list[int] = []
        self._pf_hi: list[int] = []
        self.hits = 0
        self.misses = 0
        self.replaced_ways: list[tuple[int, int]] = []

    # A resident line is keyed ``tag * num_sets + set`` — one int64 per
    # line, totally ordered, so a whole chunk's membership is one
    # searchsorted against the sorted resident-key snapshot.
    def _key(self, s: int, tag: int) -> int:
        return tag * self._ns + s

    # -- scalar compatibility ------------------------------------------------

    def probe(self, addr: int) -> bool:
        tag = addr // self.geom.line_bytes
        s = int(self._vmap(np.asarray([addr], dtype=np.int64))[0])
        return self._key(s, tag) in self._way_of

    def access(self, addr: int) -> bool:
        return bool(self.access_chunk(np.asarray([addr], dtype=np.int64))[0])

    # -- chunk stepping ------------------------------------------------------

    def access_chunk(self, addrs: np.ndarray) -> np.ndarray:
        """Advance the cache over a whole address chunk; returns the per-
        access hit mask (True = hit), identical to mapping ``Cache.access``
        over the chunk."""
        addrs = np.ascontiguousarray(addrs, dtype=np.int64)
        k = addrs.size
        if k == 0:
            return np.zeros(0, dtype=bool)
        if k <= self._BLOCK:
            return self._step_block(addrs)
        return np.concatenate([self._step_block(addrs[i:i + self._BLOCK])
                               for i in range(0, k, self._BLOCK)])

    def _step_block(self, addrs: np.ndarray) -> np.ndarray:
        k = addrs.size
        ns = self._ns
        tags = addrs // self.geom.line_bytes
        sets = np.ascontiguousarray(self._vmap(addrs), dtype=np.int64)
        keys = tags * ns + sets
        t0 = self._clock
        self._clock += k

        # membership snapshot: binary search against sorted resident keys
        if self._way_of:
            resident = np.sort(np.fromiter(
                self._way_of.keys(), dtype=np.int64, count=len(self._way_of)))
            pos = np.searchsorted(resident, keys)
            np.clip(pos, 0, resident.size - 1, out=pos)
            hit = resident[pos] == keys
        else:
            hit = np.zeros(k, dtype=bool)
        # Initial event candidates: the FIRST snapshot-miss of each distinct
        # line only — an event always (re)inserts its line, so later uses
        # are hits until an eviction re-candidates them.  Commit runs mark
        # the skipped positions as hits.
        miss_at = np.flatnonzero(~hit)
        if miss_at.size:
            _, first = np.unique(keys[miss_at], return_index=True)
            heap = miss_at[np.sort(first)].tolist()   # ascending => heap
        else:
            heap = []
        groups: dict | None = None
        way_of = self._way_of
        ptr = 0
        while heap:
            i = heapq.heappop(heap)
            if i < ptr:                            # already handled
                continue
            key = int(keys[i])
            if key in way_of:                      # re-inserted since: a hit
                continue
            self._commit_hits(keys, hit, ptr, i, t0)
            s, tag = int(sets[i]), int(tags[i])
            hit[i] = self._event(s, tag, t0 + i)
            evicted = self._evicted_key
            if evicted is not None:
                # Re-candidate only the evicted line's NEXT use: a miss
                # there re-inserts it, and any later eviction re-pushes — so
                # one position per eviction keeps the heap O(events).
                if groups is None:
                    groups = _group_positions(keys)
                arr = groups.get(evicted)
                if arr is not None:
                    j = int(np.searchsorted(arr, i, side="right"))
                    if j < arr.size:
                        heapq.heappush(heap, int(arr[j]))
            ptr = i + 1
        self._commit_hits(keys, hit, ptr, k, t0)
        return hit

    def _commit_hits(self, keys: np.ndarray, hit: np.ndarray,
                     lo: int, hi: int, t0: int) -> None:
        """Fold a run of pure hits [lo, hi) into counters (and, for LRU,
        recency stamps — one write per distinct line, last touch wins).
        Valid because cache state is piecewise-constant between events."""
        if lo >= hi:
            return
        hit[lo:hi] = True
        self.hits += hi - lo
        if self._pol != "lru":
            return
        ns, stamp, way_of = self._ns, self._stamp, self._way_of
        if hi - lo == 1:                        # dominant case in thrash
            key = int(keys[lo])
            stamp[key % ns, way_of[key]] = t0 + lo
            return
        if hi - lo <= 24:                       # tiny run: skip np.unique
            seen = set()
            for j in range(hi - 1, lo - 1, -1):
                key = int(keys[j])
                if key not in seen:
                    seen.add(key)
                    stamp[key % ns, way_of[key]] = t0 + j
            return
        # first occurrence in the reversed segment == last touch
        uniq, ridx = np.unique(keys[hi - 1:lo - 1 if lo else None:-1],
                               return_index=True)
        for key, r in zip(uniq.tolist(), ridx.tolist()):
            stamp[key % ns, way_of[key]] = t0 + hi - 1 - r

    def _event(self, s: int, tag: int, t: int) -> bool:
        """One state-changing access, exactly mirroring ``Cache.access``'s
        non-hit path (including RNG draw order).  Returns hit/miss."""
        self._evicted_key = None
        if tag not in self._ever_seen and \
                _interval_contains(self._pf_lo, self._pf_hi, tag):
            self.hits += 1
            self._ever_seen.add(tag)
            self._insert(s, tag, t)
            return True
        self.misses += 1
        compulsory = tag not in self._ever_seen
        self._ever_seen.add(tag)
        self._insert(s, tag, t)
        if compulsory and self.geom.prefetch_lines:
            _interval_add(self._pf_lo, self._pf_hi,
                          tag + 1, tag + 1 + self.geom.prefetch_lines)
        return False

    def state_signature(self) -> bytes:
        """Canonical state for deterministic-policy cycle detection:
        resident tags in timestamp-rank order per set, plus fill counts.
        Two states with equal signatures evolve identically under lru/fifo
        on equal future chunks — provided every chunk tag is already in
        ``_ever_seen`` (so the prefetch path is dead); callers must check
        that before comparing signatures.
        """
        order = np.argsort(self._stamp, axis=1, kind="stable")
        canon = np.take_along_axis(self._tags, order, axis=1)
        return canon.tobytes() + self._filled.tobytes()

    def _insert(self, s: int, tag: int, t: int) -> None:
        wl = int(self._wl[s])
        f = int(self._filled[s])
        if f < wl:                                 # cold fill: first free way
            w = f
            self._filled[s] = f + 1
        else:
            if self._pol in ("lru", "fifo"):
                w = int(self._stamp[s, :wl].argmin())
            elif self._pol == "random":
                w = int(self._rng.integers(wl))
            else:                                  # prob
                w = int(self._rng.choice(wl, p=self._probs))
            evicted = int(self._tags[s, w])
            self._evicted_key = self._key(s, evicted)
            del self._way_of[self._evicted_key]
            self.replaced_ways.append((s, w))
        self._tags[s, w] = tag
        self._stamp[s, w] = t
        self._way_of[self._key(s, tag)] = w


# ---------------------------------------------------------------------------
# Hierarchy: L1/L2 data caches + L1/L2 TLB + page table
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    """Cycle constants for one device (calibrated in core/devices.py)."""

    l1_hit: float
    l2_hit: float
    dram: float
    l1tlb_miss: float          # extra cycles when L1 TLB misses, L2 TLB hits
    pagewalk: float            # extra cycles when both TLBs miss
    context_switch: float = 0  # P6: page-table context switch (Kepler/Maxwell)


@dataclasses.dataclass
class MemoryHierarchy:
    """Composable device model.  Any level may be None (e.g. no L1)."""

    name: str
    latency: LatencyModel
    l1: Cache | None = None
    l2: Cache | None = None
    l1tlb: Cache | None = None
    l2tlb: Cache | None = None
    page_bytes: int = 2 << 20
    # Maxwell: "L1 data cache addressing does not go through the TLBs" (§5.2-2)
    l1_virtually_addressed: bool = False
    # Kepler/Maxwell: only a 512 MB window of page entries is active (P6)
    active_window_bytes: int | None = None
    _window_start: int = dataclasses.field(default=0, init=False)

    def reset(self) -> None:
        for c in (self.l1, self.l2, self.l1tlb, self.l2tlb):
            if c is not None:
                c.reset()
        self._window_start = 0

    def access(self, addr: int) -> tuple[float, dict]:
        """One load.  Returns (cycles, info) with per-level hit booleans."""
        lat = self.latency
        info: dict[str, bool | str] = {}

        # Virtually-addressed L1 short-circuits translation entirely.
        if self.l1 is not None and self.l1_virtually_addressed:
            if self.l1.access(addr):
                info["l1"] = True
                info["pattern"] = "P1"
                return lat.l1_hit, info
            info["l1"] = False

        cycles = 0.0
        # -- translation --
        tlb_state = "hit"
        if self.l1tlb is not None:
            page_addr = (addr // self.page_bytes) * self.page_bytes
            if self.l1tlb.access(page_addr):
                info["l1tlb"] = True
            else:
                info["l1tlb"] = False
                if self.l2tlb is not None and self.l2tlb.access(page_addr):
                    info["l2tlb"] = True
                    cycles += lat.l1tlb_miss
                    tlb_state = "l1tlb_miss"
                else:
                    info["l2tlb"] = False
                    cycles += lat.pagewalk
                    tlb_state = "pagewalk"
                    if self.active_window_bytes is not None:
                        win = self.active_window_bytes
                        if not (self._window_start <= addr < self._window_start + win):
                            cycles += lat.context_switch
                            self._window_start = (addr // win) * win
                            tlb_state = "context_switch"

        # -- data --
        if self.l1 is not None and not self.l1_virtually_addressed:
            if self.l1.access(addr):
                info["l1"] = True
                info["pattern"] = _classify(True, None, tlb_state)
                return cycles + lat.l1_hit, info
            info["l1"] = False
        if self.l2 is not None and self.l2.access(addr):
            info["l2"] = True
            info["pattern"] = _classify(False, True, tlb_state)
            return cycles + lat.l2_hit, info
        if self.l2 is not None:
            info["l2"] = False
        info["pattern"] = _classify(False, False, tlb_state)
        return cycles + lat.dram, info

    def run_chase(self, indices: np.ndarray, elem_bytes: int = 4,
                  base_addr: int = 0) -> tuple[np.ndarray, list[dict]]:
        """Drive the hierarchy with a pointer-chase index sequence."""
        lats = np.empty(len(indices), dtype=np.float64)
        infos: list[dict] = []
        for i, idx in enumerate(indices):
            cyc, info = self.access(base_addr + int(idx) * elem_bytes)
            lats[i] = cyc
            infos.append(info)
        return lats, infos


def _classify(l1_hit: bool, l2_hit: bool | None, tlb: str) -> str:
    """Label with the paper's Fig 14 pattern names (simulator meta only)."""
    if tlb == "context_switch":
        return "P6"
    cached = l1_hit or bool(l2_hit)
    if cached:
        return {"hit": "P1", "l1tlb_miss": "P2", "pagewalk": "P3"}[tlb]
    return {"hit": "P4", "l1tlb_miss": "P5", "pagewalk": "P5"}[tlb]
