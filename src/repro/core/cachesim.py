"""Parameterized cache / TLB / memory-hierarchy simulator.

This is the CPU-side measurement substrate (see DESIGN.md §2): a
ground-truth oracle that can be configured with every structure the paper
discovered —

* classical equal-set set-associative caches (paper Assumptions 1–3),
* **unequal cache sets** (the L2 TLB's 17+6×8 structure, Fig 9),
* **non-bits-defined and non-adjacent set mappings** (texture L1 selects the
  set with address bits 7–8 instead of 5–6, Fig 7; Fermi L1 uses bits 9–11
  and 12–13, §4.5),
* **non-LRU replacement** (Fermi L1's way probabilities (1/6, 1/2, 1/6, 1/6),
  Fig 11; random replacement for the L2),
* **sequential DRAM→L2 prefetch** of ~2/3 the cache capacity (§4.6),
* multi-level composition with TLBs, page-table walks and the Kepler/Maxwell
  512 MB page-table context-switch window (P6, §5.2).

The fine-grained P-chase analyzer (``core.inference``) must recover all of
these *blind* — it only ever sees (index, latency) traces, never the
simulator internals.  ``meta`` fields carry internals for unit tests only.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Set-mapping functions: line address (bytes) -> set index
# ---------------------------------------------------------------------------


def modulo_map(line_bytes: int, num_sets: int) -> Callable[[int], int]:
    """Classic adjacent-bits mapping (paper Assumption 2)."""

    def _map(addr: int) -> int:
        return (addr // line_bytes) % num_sets

    return _map


def bitfield_map(lo_bit: int, num_bits: int) -> Callable[[int], int]:
    """Set selected by address bits [lo_bit, lo_bit+num_bits).

    The texture L1 uses ``bitfield_map(7, 2)`` — bits 7–8 — rather than the
    traditional bits 5–6, which is exactly what breaks Wong2010 (Fig 4/5).
    """

    def _map(addr: int) -> int:
        return (addr >> lo_bit) & ((1 << num_bits) - 1)

    return _map


def split_bitfield_map(fields: Sequence[tuple[int, int]]) -> Callable[[int], int]:
    """Set index concatenated from non-adjacent bit ranges.

    Models the Fermi L1 data cache's mapping (§4.5): bits 9–11 select the
    "major set" and bits 12–13 the group — ``[(9, 3), (12, 2)]`` — leaving
    bits 7–8 *unused*, which violates Assumption 2 in a second way.
    """

    def _map(addr: int) -> int:
        out, shift = 0, 0
        for lo, nbits in fields:
            out |= ((addr >> lo) & ((1 << nbits) - 1)) << shift
            shift += nbits
        return out

    return _map


def range_cyclic_map(line_bytes: int, way_counts: Sequence[int]) -> Callable[[int], int]:
    """Unequal sets filled in contiguous ranges, wrapping at total capacity.

    Used for the L2 TLB (1×17 + 6×8 entries).  The paper under-determines
    the page→set function; this choice reproduces the observable it reports
    (overflowing by one page thrashes exactly the large set first, then the
    small sets one by one as N grows — Fig 8's piecewise-linear miss rate).
    """
    bounds = np.cumsum(np.asarray(way_counts, dtype=np.int64))
    total = int(bounds[-1])

    def _map(addr: int) -> int:
        q = (addr // line_bytes) % total
        return int(np.searchsorted(bounds, q, side="right"))

    return _map


# ---------------------------------------------------------------------------
# Single cache level
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReplacementPolicy:
    """``lru`` | ``fifo`` | ``random`` | ``prob``.

    ``prob`` replaces way *i* of a full set with probability
    ``way_probs[i]`` — the Fermi L1's measured behaviour is
    ``(1/6, 1/2, 1/6, 1/6)`` (§4.5, Fig 11).
    """

    kind: str = "lru"
    way_probs: tuple[float, ...] | None = None

    def __post_init__(self) -> None:
        if self.kind not in ("lru", "fifo", "random", "prob"):
            raise ValueError(f"unknown replacement policy {self.kind!r}")
        if self.kind == "prob":
            if not self.way_probs:
                raise ValueError("prob policy needs way_probs")
            if abs(sum(self.way_probs) - 1.0) > 1e-9:
                raise ValueError("way_probs must sum to 1")


LRU = ReplacementPolicy("lru")


@dataclasses.dataclass(frozen=True)
class CacheGeometry:
    """Full structural description of one cache level."""

    name: str
    line_bytes: int
    way_counts: tuple[int, ...]                   # per-set ways; unequal allowed
    set_map: Callable[[int], int] | None = None   # default: modulo_map
    replacement: ReplacementPolicy = LRU
    prefetch_lines: int = 0                       # sequential prefetch on compulsory miss

    @property
    def num_sets(self) -> int:
        return len(self.way_counts)

    @property
    def size_bytes(self) -> int:
        return self.line_bytes * sum(self.way_counts)

    @property
    def uniform_ways(self) -> int | None:
        ways = set(self.way_counts)
        return ways.pop() if len(ways) == 1 else None

    def mapper(self) -> Callable[[int], int]:
        return self.set_map or modulo_map(self.line_bytes, self.num_sets)

    @staticmethod
    def uniform(name: str, size_bytes: int, line_bytes: int, num_sets: int,
                **kw) -> "CacheGeometry":
        ways, rem = divmod(size_bytes, line_bytes * num_sets)
        if rem:
            raise ValueError("size not divisible by line*sets")
        return CacheGeometry(name, line_bytes, (ways,) * num_sets, **kw)


class Cache:
    """One level.  ``access`` returns True on hit and updates state."""

    def __init__(self, geom: CacheGeometry, rng: np.random.Generator | None = None):
        self.geom = geom
        self._map = geom.mapper()
        self._rng = rng or np.random.default_rng(0)
        self.reset()

    def reset(self) -> None:
        # Per set: fixed physical way slots (tag or None) — way identity must
        # be stable or per-way replacement probabilities are meaningless —
        # plus a recency list of way indices (LRU order, oldest first).
        self._ways: list[list[int | None]] = [
            [None] * w for w in self.geom.way_counts]
        self._order: list[list[int]] = [[] for _ in self.geom.way_counts]
        self._ever_seen: set[int] = set()       # for compulsory-miss prefetch
        # Prefetched-but-not-yet-touched tag intervals [start, end); touching
        # one counts as a hit and promotes the line into the cache proper.
        self._prefetched: list[tuple[int, int]] = []
        self.hits = 0
        self.misses = 0
        self.replaced_ways: list[tuple[int, int]] = []  # (set_idx, way_idx) per eviction

    # -- internals ----------------------------------------------------------

    def _insert(self, set_idx: int, tag: int) -> None:
        slots = self._ways[set_idx]
        order = self._order[set_idx]
        if None in slots:                     # cold fill: first free slot
            way = slots.index(None)
            slots[way] = tag
            order.append(way)
            return
        pol = self.geom.replacement
        if pol.kind in ("lru", "fifo"):
            way = order[0]                    # oldest (FIFO never reorders)
        elif pol.kind == "random":
            way = int(self._rng.integers(len(slots)))
        else:                                 # prob: fixed per-way probabilities
            way = int(self._rng.choice(len(slots), p=np.asarray(pol.way_probs)))
        self.replaced_ways.append((set_idx, way))
        order.remove(way)
        order.append(way)
        slots[way] = tag

    # -- public -------------------------------------------------------------

    def probe(self, addr: int) -> bool:
        """Hit test with no state change (used by tests only)."""
        tag = addr // self.geom.line_bytes
        return tag in self._ways[self._map(addr)]

    def _in_prefetch(self, tag: int) -> bool:
        for lo, hi in self._prefetched:
            if lo <= tag < hi:
                return True
        return False

    def access(self, addr: int) -> bool:
        tag = addr // self.geom.line_bytes
        set_idx = self._map(addr)
        slots = self._ways[set_idx]
        if tag in slots:
            self.hits += 1
            if self.geom.replacement.kind == "lru":
                way = slots.index(tag)
                order = self._order[set_idx]
                order.remove(way)
                order.append(way)             # move to MRU
            return True
        if tag not in self._ever_seen and self._in_prefetch(tag):
            # Prefetched line: its first-ever touch is a hit; promote it.
            self.hits += 1
            self._ever_seen.add(tag)
            self._insert(set_idx, tag)
            return True
        self.misses += 1
        compulsory = tag not in self._ever_seen
        self._ever_seen.add(tag)
        self._insert(set_idx, tag)
        if compulsory and self.geom.prefetch_lines:
            # Sequential DRAM->L2 prefetch (§4.6): the next ~2/3-capacity of
            # lines stream in behind a compulsory miss, so arrays below the
            # prefetch window show no cold-miss pattern.
            self._prefetched.append((tag + 1, tag + 1 + self.geom.prefetch_lines))
        return False


# ---------------------------------------------------------------------------
# Hierarchy: L1/L2 data caches + L1/L2 TLB + page table
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    """Cycle constants for one device (calibrated in core/devices.py)."""

    l1_hit: float
    l2_hit: float
    dram: float
    l1tlb_miss: float          # extra cycles when L1 TLB misses, L2 TLB hits
    pagewalk: float            # extra cycles when both TLBs miss
    context_switch: float = 0  # P6: page-table context switch (Kepler/Maxwell)


@dataclasses.dataclass
class MemoryHierarchy:
    """Composable device model.  Any level may be None (e.g. no L1)."""

    name: str
    latency: LatencyModel
    l1: Cache | None = None
    l2: Cache | None = None
    l1tlb: Cache | None = None
    l2tlb: Cache | None = None
    page_bytes: int = 2 << 20
    # Maxwell: "L1 data cache addressing does not go through the TLBs" (§5.2-2)
    l1_virtually_addressed: bool = False
    # Kepler/Maxwell: only a 512 MB window of page entries is active (P6)
    active_window_bytes: int | None = None
    _window_start: int = dataclasses.field(default=0, init=False)

    def reset(self) -> None:
        for c in (self.l1, self.l2, self.l1tlb, self.l2tlb):
            if c is not None:
                c.reset()
        self._window_start = 0

    def access(self, addr: int) -> tuple[float, dict]:
        """One load.  Returns (cycles, info) with per-level hit booleans."""
        lat = self.latency
        info: dict[str, bool | str] = {}

        # Virtually-addressed L1 short-circuits translation entirely.
        if self.l1 is not None and self.l1_virtually_addressed:
            if self.l1.access(addr):
                info["l1"] = True
                info["pattern"] = "P1"
                return lat.l1_hit, info
            info["l1"] = False

        cycles = 0.0
        # -- translation --
        tlb_state = "hit"
        if self.l1tlb is not None:
            page_addr = (addr // self.page_bytes) * self.page_bytes
            if self.l1tlb.access(page_addr):
                info["l1tlb"] = True
            else:
                info["l1tlb"] = False
                if self.l2tlb is not None and self.l2tlb.access(page_addr):
                    info["l2tlb"] = True
                    cycles += lat.l1tlb_miss
                    tlb_state = "l1tlb_miss"
                else:
                    info["l2tlb"] = False
                    cycles += lat.pagewalk
                    tlb_state = "pagewalk"
                    if self.active_window_bytes is not None:
                        win = self.active_window_bytes
                        if not (self._window_start <= addr < self._window_start + win):
                            cycles += lat.context_switch
                            self._window_start = (addr // win) * win
                            tlb_state = "context_switch"

        # -- data --
        if self.l1 is not None and not self.l1_virtually_addressed:
            if self.l1.access(addr):
                info["l1"] = True
                info["pattern"] = _classify(True, None, tlb_state)
                return cycles + lat.l1_hit, info
            info["l1"] = False
        if self.l2 is not None and self.l2.access(addr):
            info["l2"] = True
            info["pattern"] = _classify(False, True, tlb_state)
            return cycles + lat.l2_hit, info
        if self.l2 is not None:
            info["l2"] = False
        info["pattern"] = _classify(False, False, tlb_state)
        return cycles + lat.dram, info

    def run_chase(self, indices: np.ndarray, elem_bytes: int = 4,
                  base_addr: int = 0) -> tuple[np.ndarray, list[dict]]:
        """Drive the hierarchy with a pointer-chase index sequence."""
        lats = np.empty(len(indices), dtype=np.float64)
        infos: list[dict] = []
        for i, idx in enumerate(indices):
            cyc, info = self.access(base_addr + int(idx) * elem_bytes)
            lats[i] = cyc
            infos.append(info)
        return lats, infos


def _classify(l1_hit: bool, l2_hit: bool | None, tlb: str) -> str:
    """Label with the paper's Fig 14 pattern names (simulator meta only)."""
    if tlb == "context_switch":
        return "P6"
    cached = l1_hit or bool(l2_hit)
    if cached:
        return {"hit": "P1", "l1tlb_miss": "P2", "pagewalk": "P3"}[tlb]
    return {"hit": "P4", "l1tlb_miss": "P5", "pagewalk": "P5"}[tlb]
