"""Three-term roofline from a compiled XLA artifact.

The TPU-side measurement loop (DESIGN.md §5): where the paper reads per-
access latencies out of shared memory, at pod scale we read the compiled
HLO.  For every (architecture × shape × mesh) cell the dry-run produces

  compute term    = HLO_FLOPs  / (chips × peak_FLOP/s)
  memory term     = HLO_bytes  / (chips × HBM_bw)
  collective term = wire_bytes / (chips × ICI_bw)

``cost_analysis()`` supplies FLOPs / bytes-accessed; collective bytes are
not in cost_analysis, so we parse the (optimized) HLO text and sum the
shapes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, converting each to estimated wire bytes (ring
algorithms: an all-reduce moves ≈ 2× its payload).
"""

from __future__ import annotations

import dataclasses
import json
import re

from repro.core import profile
from repro.core.devices import TPU_V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# `%x = (bf16[8,128]{1,0}, ...) all-gather-start(' — capture result type blob
_INSTR_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|\S+)\s+(?P<op>[\w-]+)\(")
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def shape_bytes(type_blob: str) -> int:
    """Total bytes of all array shapes inside a type string (tuples ok)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_blob):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Payload bytes per collective kind, from result shapes.

    Async pairs (`-start`/`-done`) are counted once, on `-start`.
    """
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        if op.endswith("-done"):
            continue
        base = op[:-6] if op.endswith("-start") else op
        if base not in _COLLECTIVES:
            continue
        out[base] = out.get(base, 0) + shape_bytes(m.group("shape"))
    return out


def wire_bytes(coll: dict[str, int]) -> float:
    """Estimated ICI traffic.  Ring all-reduce ≈ 2× payload
    (reduce-scatter + all-gather phases); everything else ≈ 1×."""
    total = 0.0
    for kind, nbytes in coll.items():
        total += nbytes * (2.0 if kind == "all-reduce" else 1.0)
    return total


@dataclasses.dataclass
class RooflineReport:
    name: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_payload: dict[str, int]
    wire_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float | None = None      # 6·N·D (or 6·N_active·D for MoE)
    # peak of the spec the report was priced against — the fraction below
    # must use the SAME roof as the terms, not a module-level constant
    peak_bf16_flops: float = 0.0
    spec_name: str = ""

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Lower-bound step time: the max term (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful compute / ideal step budget: how close the *useful* work
        runs to the hardware roof if the dominant term is fully utilized."""
        if not self.model_flops:
            return 0.0
        peak = self.peak_bf16_flops or TPU_V5E.peak_bf16_flops
        ideal = self.model_flops / (self.chips * peak)
        return ideal / self.step_s if self.step_s else 0.0

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/redundancy waste."""
        if not self.model_flops or not self.hlo_flops:
            return 0.0
        return self.model_flops / self.hlo_flops

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["dominant"] = self.dominant
        d["step_s"] = self.step_s
        d["roofline_fraction"] = self.roofline_fraction
        d["useful_flops_ratio"] = self.useful_flops_ratio
        return d

    def summary(self) -> str:
        mf = (f" useful={self.useful_flops_ratio:.2f}"
              if self.model_flops else "")
        rf = (f" roofline={self.roofline_fraction:.1%}"
              if self.model_flops else "")
        return (f"{self.name}: compute={self.compute_s*1e3:.2f}ms "
                f"memory={self.memory_s*1e3:.2f}ms "
                f"collective={self.collective_s*1e3:.2f}ms "
                f"dominant={self.dominant}{mf}{rf}")


def analyze(name: str, *, cost: dict, hlo_text: str, chips: int,
            spec=None, model_flops: float | None = None,
            per_device_module: bool = True) -> RooflineReport:
    """Build the report from ``compiled.cost_analysis()`` + HLO text.

    ``per_device_module=True`` (the SPMD dry-run case): cost_analysis and
    the HLO text describe ONE device's program, so flops/bytes/collective
    payloads are already per-chip; stored ``hlo_flops``/``hlo_bytes`` are
    normalized to global (×chips).  ``model_flops`` is always global.
    """
    spec = profile.resolve_spec(spec)
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    if per_device_module:
        flops_per_chip, bytes_per_chip = flops, nbytes
        flops_global, bytes_global = flops * chips, nbytes * chips
    else:
        flops_per_chip, bytes_per_chip = flops / chips, nbytes / chips
        flops_global, bytes_global = flops, nbytes
    coll = collective_bytes(hlo_text)
    wb = wire_bytes(coll)          # per-device wire traffic (ring estimate)
    if not per_device_module:
        wb = wb / chips
    return RooflineReport(
        name=name, chips=chips,
        hlo_flops=flops_global, hlo_bytes=bytes_global,
        coll_payload=coll, wire_bytes=wb,
        compute_s=flops_per_chip / spec.peak_bf16_flops,
        memory_s=bytes_per_chip / spec.hbm_bytes_per_s,
        collective_s=wb / spec.ici_bytes_per_s,
        model_flops=model_flops,
        peak_bf16_flops=spec.peak_bf16_flops,
        spec_name=spec.name,
    )


def dump(reports: list[RooflineReport], path: str) -> None:
    with open(path, "w") as f:
        json.dump([r.to_json() for r in reports], f, indent=2)
