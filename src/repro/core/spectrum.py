"""Global-memory latency spectrum (paper §5.2, Fig 13b/14).

The paper's trick: instead of one uniform stride, the chase array is
initialized with **non-uniform strides** so a single fine-grained run walks
through every access-pattern class P1–P6:

  P1  data-cache hit
  P2  data-cache hit, L1 TLB miss, L2 TLB hit
  P3  data-cache hit, L2 TLB miss (page-table walk)
  P4  data-cache miss, TLB hit
  P5  data-cache miss, TLB miss (cold)
  P6  page-table context switch (Kepler/Maxwell only: touching a page
      entry outside the 512 MB active window)

We build the phase program explicitly (addresses below), chase it through a
:class:`~repro.core.cachesim.MemoryHierarchy`, and recover one latency per
pattern from the phase-median of the recorded trace.  Phase boundaries are
part of the *experiment design* (as in the paper), not leaked simulator
state.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.cachesim import MemoryHierarchy
from repro.core.trace import PChaseConfig, PChaseTrace

MB = 1 << 20


@dataclasses.dataclass
class SpectrumPhase:
    pattern: str
    addrs: np.ndarray          # byte addresses, in chase order
    steady_from: int = 0       # ignore this many leading accesses (setup)


def build_phases(page_bytes: int = 2 * MB, line_bytes: int = 32,
                 l1tlb_entries: int = 16, l2tlb_entries: int = 65,
                 prefetch_reach_bytes: int = 3 * MB // 2,
                 active_window_bytes: int = 512 * MB,
                 has_window: bool = True,
                 spread_bytes: int = 1536) -> list[SpectrumPhase]:
    """The non-uniform-stride program, one phase per pattern.

    Mirrors the paper's recipe: big strides (s1 = 32 MB) build TLB+cache
    misses, strides inside a mapped page build cache-miss/TLB-hit, revisits
    of cached lines with big strides build cache-hit/TLB-miss, and an
    intra-line crawl builds pure hits.  Two experiment-design details the
    fine-grained view forces:

    * the P4 offset is pushed past the L2 prefetch reach so the prefetcher
      (§4.6) cannot convert it into a hit;
    * ring elements carry a per-element ``spread_bytes`` offset (still
      inside their page) so that caches with non-adjacent set-index bits
      (Fermi L1, §4.5) don't alias the whole ring into one set; 1536 = 3·512
      walks bits 9–13 coprime to Fermi's split set field.
    """
    phases: list[SpectrumPhase] = []
    s1 = 32 * MB

    def spread(i: np.ndarray) -> np.ndarray:
        return (i * spread_bytes) % (page_bytes // 2)

    # P5: fresh pages, stride 32 MB, inside the first active window.
    k5 = np.arange(8, dtype=np.int64)
    p5 = k5 * s1
    phases.append(SpectrumPhase("P5", p5, steady_from=0))

    # P6: fresh pages beyond the active window boundary (one per window).
    if has_window:
        p6 = active_window_bytes + np.arange(4, dtype=np.int64) * active_window_bytes
        phases.append(SpectrumPhase("P6", p6, steady_from=0))

    # P4: new lines inside already-mapped pages (TLB hit, cache miss);
    # offset > prefetch reach keeps them out of the prefetcher's shadow.
    p4 = p5 + prefetch_reach_bytes + 64 * line_bytes
    phases.append(SpectrumPhase("P4", p4, steady_from=0))

    # P2: cycle > l1tlb_entries cached lines spaced ~32 MB: pass 2+ hits the
    # data cache but misses the L1 TLB (L2 TLB still covers them).
    n2 = l1tlb_entries + 4
    k2 = np.arange(n2, dtype=np.int64)
    ring2 = k2 * s1 + spread(k2)
    p2 = np.concatenate([ring2, ring2, ring2])
    phases.append(SpectrumPhase("P2", p2, steady_from=n2))

    # P3: cycle enough cached pages that EVERY L2 TLB set is over-subscribed
    # (2·entries+1 covers unequal sets too): pass 2+ hits the data cache but
    # walks the page table.
    n3 = 2 * l2tlb_entries + 1
    k3 = np.arange(n3, dtype=np.int64)
    ring3 = k3 * page_bytes + spread(k3)
    p3 = np.concatenate([ring3, ring3, ring3])
    phases.append(SpectrumPhase("P3", p3, steady_from=n3))

    # P1: crawl one cached line (after a priming touch).
    base = p5[0]
    p1 = base + (np.arange(line_bytes // 4 * 3, dtype=np.int64) * 4) % line_bytes
    phases.append(SpectrumPhase("P1", p1, steady_from=1))
    return phases


def _tlb_entries(h: MemoryHierarchy) -> tuple[int, int]:
    """Entry counts the phase program must over-subscribe.  Derived from
    the hierarchy under test (not the paper's 16/65 defaults) so a larger
    TLB — Volta's 128-entry L2 TLB — still gets every set thrashed by the
    P2/P3 rings.  Experiment design, not leaked state: the sizes are part
    of the published device description."""
    l1 = sum(h.l1tlb.geom.way_counts) if h.l1tlb is not None else 16
    l2 = sum(h.l2tlb.geom.way_counts) if h.l2tlb is not None else 65
    return l1, l2


def measure_spectrum(make_hierarchy: Callable[[], MemoryHierarchy],
                     elem_bytes: int = 4) -> dict[str, float]:
    """Run the whole program on a fresh hierarchy; phase-median latencies."""
    h = make_hierarchy()
    h.reset()
    has_window = h.active_window_bytes is not None
    line = h.l1.geom.line_bytes if h.l1 is not None else (
        h.l2.geom.line_bytes if h.l2 is not None else 32)
    prefetch_reach = 0
    if h.l2 is not None:
        prefetch_reach = h.l2.geom.prefetch_lines * h.l2.geom.line_bytes
    l1e, l2e = _tlb_entries(h)
    phases = build_phases(page_bytes=h.page_bytes, line_bytes=line,
                          l1tlb_entries=l1e, l2tlb_entries=l2e,
                          prefetch_reach_bytes=prefetch_reach + line,
                          active_window_bytes=h.active_window_bytes or 0,
                          has_window=has_window)
    out: dict[str, float] = {}
    for ph in phases:
        idx = ph.addrs // elem_bytes
        lats, _ = h.run_chase(idx, elem_bytes=elem_bytes)
        steady = lats[ph.steady_from:]
        out[ph.pattern] = float(np.median(steady))
    return out


def spectrum_trace(make_hierarchy: Callable[[], MemoryHierarchy],
                   elem_bytes: int = 4) -> PChaseTrace:
    """Single concatenated trace (useful for plotting / cluster tests)."""
    h = make_hierarchy()
    h.reset()
    has_window = h.active_window_bytes is not None
    prefetch_reach = 0
    if h.l2 is not None:
        prefetch_reach = h.l2.geom.prefetch_lines * h.l2.geom.line_bytes
    l1e, l2e = _tlb_entries(h)
    phases = build_phases(page_bytes=h.page_bytes,
                          l1tlb_entries=l1e, l2tlb_entries=l2e,
                          prefetch_reach_bytes=prefetch_reach + 32,
                          active_window_bytes=h.active_window_bytes or 0,
                          has_window=has_window)
    addrs = np.concatenate([p.addrs for p in phases])
    idx = addrs // elem_bytes
    lats, infos = h.run_chase(idx, elem_bytes=elem_bytes)
    labels = [i.get("pattern") for i in infos]
    cfg = PChaseConfig(int(addrs.max()) + elem_bytes, 0, len(idx), elem_bytes, 0)
    return PChaseTrace(cfg, idx, lats, meta={"patterns": labels})
