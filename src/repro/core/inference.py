"""Blind cache-parameter recovery from fine-grained P-chase traces.

Implements the paper's two-stage procedure (Fig 6) plus the extra analyses
the fine-grained trace makes possible:

* cache size ``C``           — overflow search (stage 0)
* line size ``b``            — overflow-by-one, miss-count jump (stage 1)
* set structure ``T``/ways   — overflow line-by-line; *unequal* sets are
                               recovered from miss-count breakpoints (§4.4)
* replacement policy         — periodicity test; if non-LRU, reconstruct the
                               eviction chain and estimate per-way
                               replacement probabilities (Fig 11)
* set-mapping address bits   — conflict-stride probe (recovers e.g. the
                               texture L1's bits-7–8 mapping, Fig 7)

Everything here consumes only ``(index, latency)`` traces through a
:class:`~repro.core.pchase.TraceBackend`; simulator internals are never
read.  The same code analyzes Pallas-kernel traces on real hardware.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.pchase import TraceBackend, fine_grained
from repro.core.trace import PChaseConfig, PChaseTrace


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _miss_mask(trace: PChaseTrace) -> np.ndarray:
    thr = trace.meta.get("miss_threshold")
    return trace.miss_mask(thr)


def _accesses_per_pass(cfg: PChaseConfig) -> int:
    return max(1, math.ceil(cfg.num_elems / cfg.stride_elems))


def _per_pass_misses(tr: PChaseTrace) -> float:
    """Average steady-state miss count per full traversal, from a trace."""
    per_pass = _accesses_per_pass(tr.config)
    n_pass = len(tr.indices) // per_pass
    if n_pass == 0:
        return float(_miss_mask(tr).sum())
    mask = _miss_mask(tr)[: n_pass * per_pass].reshape(n_pass, per_pass)
    return float(mask.sum(axis=1).mean())


def misses_per_pass(backend: TraceBackend, array_bytes: int, stride_bytes: int,
                    passes: int = 4, elem_bytes: int = 4,
                    warmup_passes: int = 2) -> float:
    """Average steady-state miss count per full traversal of the array."""
    tr = fine_grained(backend, array_bytes, stride_bytes,
                      elem_bytes=elem_bytes, warmup_passes=warmup_passes,
                      passes=passes)
    return _per_pass_misses(tr)


# ---------------------------------------------------------------------------
# Wave evaluation (batched engines)
# ---------------------------------------------------------------------------

#: probes evaluated per engine call by the batched search drivers
_WAVE = 16


def _is_batched(backend: TraceBackend) -> bool:
    """Does the backend expose the batched entry points (engine="jax")?"""
    return getattr(backend, "steady_misses", None) is not None


def _probe_cfg(array_bytes: int, stride_bytes: int, passes: float,
               elem_bytes: int, warmup_passes: int = 2) -> PChaseConfig:
    """The config ``fine_grained`` would build for the same probe."""
    cfg = PChaseConfig(array_bytes, stride_bytes, 0, elem_bytes,
                       warmup_passes)
    iters = int(np.ceil(passes * cfg.num_elems / cfg.stride_elems))
    return PChaseConfig(array_bytes, stride_bytes, iters, elem_bytes,
                        warmup_passes)


def _misses_per_pass_many(backend: TraceBackend,
                          probes: list[tuple[int, int, float, int]],
                          ) -> list[float]:
    """Steady misses-per-pass for many ``(N, stride, passes, elem_bytes)``
    probes — through the backend's lean closed-form path where it has one,
    serial full traces otherwise (including lean-path gaps: non-tiling
    chases and stochastic policies)."""
    cfgs = [_probe_cfg(*p) for p in probes]
    lean = getattr(backend, "steady_misses", None)
    vals = lean(cfgs) if lean is not None else [None] * len(cfgs)
    return [(_per_pass_misses(backend(cfg)) if v is None else float(v))
            for cfg, v in zip(cfgs, vals)]


def _wave_grid(lo: int, hi: int, granularity: int,
               wave: int = _WAVE) -> list[int]:
    """≤``wave`` granularity-aligned interior points of ``(lo, hi)``."""
    pts = {((lo + (hi - lo) * i // (wave + 1)) // granularity) * granularity
           for i in range(1, wave + 1)}
    return sorted(p for p in pts if lo < p < hi)


# ---------------------------------------------------------------------------
# Stage 0: cache size
# ---------------------------------------------------------------------------


def find_cache_size(backend: TraceBackend, *, n_max: int, n_min: int = 0,
                    stride_bytes: int = 4, granularity: int = 4,
                    elem_bytes: int = 4) -> int:
    """Largest N with zero steady-state misses (paper step 1).

    All-hit is monotone in N (N ≤ C never evicts), so we binary-search
    instead of the paper's linear sweep — same measurement, fewer runs.
    Batched backends evaluate the whole doubling ladder, then a grid of
    midpoints per bisection wave, in single engine calls; endpoints stay
    granularity-aligned, so wave and serial search return the same N.
    """

    def all_hit(n: int) -> bool:
        tr = fine_grained(backend, n, stride_bytes, elem_bytes=elem_bytes,
                          warmup_passes=2, passes=2.0)
        return _miss_mask(tr).sum() == 0

    if n_min <= 0:
        n_min = granularity
    if _is_batched(backend):
        return _find_cache_size_batched(
            backend, n_max=n_max, n_min=n_min, stride_bytes=stride_bytes,
            granularity=granularity, elem_bytes=elem_bytes)
    # grow until first miss
    hi = n_min
    while hi <= n_max and all_hit(hi):
        hi *= 2
    if hi > n_max:
        raise ValueError(f"no miss up to n_max={n_max}; cache larger than probe range")
    lo = hi // 2  # all-hit
    while hi - lo > granularity:
        mid = ((lo + hi) // 2) // granularity * granularity
        if mid <= lo:
            break
        if all_hit(mid):
            lo = mid
        else:
            hi = mid
    return lo


def _find_cache_size_batched(backend: TraceBackend, *, n_max: int,
                             n_min: int, stride_bytes: int,
                             granularity: int, elem_bytes: int) -> int:
    def all_hit(ns: list[int]) -> dict[int, bool]:
        vals = _misses_per_pass_many(
            backend, [(n, stride_bytes, 2.0, elem_bytes) for n in ns])
        return {n: v == 0.0 for n, v in zip(ns, vals)}

    ladder = []
    n = n_min
    while n <= n_max:
        ladder.append(n)
        n *= 2
    hit = all_hit(ladder)
    fails = [n for n in ladder if not hit[n]]
    if not fails:
        raise ValueError(f"no miss up to n_max={n_max}; "
                         "cache larger than probe range")
    hi = fails[0]
    lo = hi // 2
    while hi - lo > granularity:
        mids = _wave_grid(lo, hi, granularity)
        if not mids:
            break
        res = all_hit(mids)
        bad = [m for m in mids if not res[m]]
        if bad:
            hi = min(bad)
        lo = max([m for m in mids if res[m] and m < hi], default=lo)
    return lo


# ---------------------------------------------------------------------------
# Stage 1: line size (+ LRU hint)
# ---------------------------------------------------------------------------


def find_line_size(backend: TraceBackend, cache_bytes: int, *,
                   elem_bytes: int = 4, stride_bytes: int | None = None,
                   max_line: int = 1 << 16, granularity: int | None = None,
                   passes: int = 8, jump_ratio: float = 1.6) -> int:
    """Line size from an overflow-by-one-element trace (paper step 2).

    Two signals, take the smaller (each is exact in its regime):

    * **fine-grained** — at N = C + 1 element the steady-state missed
      addresses are exactly the over-subscribed set's line starts; when the
      mapping puts *adjacent* lines in one set (texture bits-7–8, Fermi L1
      bits-9–13, the TLBs) their minimum gap IS the line size.  This is the
      case classic P-chase gets wrong (Fig 4/5).
    * **classic jump** — for adjacent-bits mappings (Assumption 2 holds)
      consecutive lines land in different sets, so the min-gap is T·b, but
      misses/pass jumps ×2 once δ crosses b + 1 element; binary-search the
      jump.
    """
    g = granularity or elem_bytes
    s = stride_bytes or elem_bytes
    candidates: list[int] = []

    tr = fine_grained(backend, cache_bytes + g, s, elem_bytes=elem_bytes,
                      warmup_passes=2, passes=passes)
    addrs = np.sort(np.unique(tr.indices[_miss_mask(tr)])) * elem_bytes
    if len(addrs) >= 2:
        candidates.append(int(np.diff(addrs).min()))

    try:
        # the jump search's baseline is exactly the trace above — reuse it
        # instead of regenerating the overflow-by-one stream
        candidates.append(_line_size_by_jump(
            backend, cache_bytes, stride_bytes=s, elem_bytes=elem_bytes,
            granularity=g, max_line=max_line, passes=passes,
            jump_ratio=jump_ratio, base=_per_pass_misses(tr)))
    except ValueError:
        pass
    if not candidates:
        raise ValueError("could not determine line size")
    best = min(candidates)
    # Lines (and pages) are powers of two; snap to absorb stochastic noise
    # in the jump location under non-deterministic replacement.
    return 1 << round(math.log2(best))


def _line_size_by_jump(backend: TraceBackend, cache_bytes: int, *,
                       stride_bytes: int, elem_bytes: int, granularity: int,
                       max_line: int, passes: int, jump_ratio: float,
                       base: float | None = None) -> int:
    """The paper's original signal: m(δ) jumps at δ = b + 1 element."""
    if base is None:
        base = misses_per_pass(backend, cache_bytes + granularity,
                               stride_bytes, passes=passes,
                               elem_bytes=elem_bytes)
    if base <= 0:
        raise ValueError("no misses when overflowing by one element")
    if _is_batched(backend):
        return _line_jump_batched(
            backend, cache_bytes, stride_bytes=stride_bytes,
            elem_bytes=elem_bytes, granularity=granularity,
            max_line=max_line, passes=passes, jump_ratio=jump_ratio,
            base=base)

    def jumped(delta: int) -> bool:
        m = misses_per_pass(backend, cache_bytes + delta, stride_bytes,
                            passes=passes, elem_bytes=elem_bytes)
        return m >= jump_ratio * base

    lo, hi = granularity, 2 * granularity
    while hi <= 2 * max_line and not jumped(hi):
        lo, hi = hi, hi * 2
    if hi > 2 * max_line:
        raise ValueError("no miss-count jump found below max_line")
    while hi - lo > granularity:
        mid = ((lo + hi) // 2) // granularity * granularity
        if mid <= lo:
            break
        if jumped(mid):
            hi = mid
        else:
            lo = mid
    return hi - granularity


def _line_jump_batched(backend: TraceBackend, cache_bytes: int, *,
                       stride_bytes: int, elem_bytes: int, granularity: int,
                       max_line: int, passes: int, jump_ratio: float,
                       base: float) -> int:
    def jumped(deltas: list[int]) -> dict[int, bool]:
        vals = _misses_per_pass_many(
            backend, [(cache_bytes + d, stride_bytes, float(passes),
                       elem_bytes) for d in deltas])
        return {d: v >= jump_ratio * base for d, v in zip(deltas, vals)}

    g = granularity
    ladder = []
    d = 2 * g
    while d <= 2 * max_line:
        ladder.append(d)
        d *= 2
    jm = jumped(ladder)
    firsts = [d for d in ladder if jm[d]]
    if not firsts:
        raise ValueError("no miss-count jump found below max_line")
    hi = firsts[0]
    lo = hi // 2
    while hi - lo > g:
        mids = _wave_grid(lo, hi, g)
        if not mids:
            break
        res = jumped(mids)
        bad = [m for m in mids if res[m]]
        if bad:
            hi = min(bad)
        lo = max([m for m in mids if not res[m] and m < hi], default=lo)
    return hi - g


# ---------------------------------------------------------------------------
# Stage 2: set structure (equal or unequal)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SetStructure:
    way_counts: list[int]         # per discovered set, discovery order
    uniform: bool
    num_sets: int
    assoc: float                  # C / (b · T) — may be fractional (L2!)


def conflict_set_ways(backend: TraceBackend, cache_bytes: int,
                      line_bytes: int, *, elem_bytes: int = 4,
                      passes: int = 8) -> int:
    """Ways of the set overflowed at N = C + b: the distinct missed lines in
    steady state are exactly that set's lines ⇒ ways = #lines − 1."""
    tr = fine_grained(backend, cache_bytes + line_bytes, line_bytes,
                      elem_bytes=elem_bytes, warmup_passes=2, passes=passes)
    missed = np.unique(tr.indices[_miss_mask(tr)] * elem_bytes // line_bytes)
    return max(0, len(missed) - 1)


def recover_set_structure(backend: TraceBackend, cache_bytes: int,
                          line_bytes: int, *, elem_bytes: int = 4,
                          passes: int = 4, max_steps: int = 512,
                          new_set_threshold: float = 2.0) -> SetStructure:
    """Overflow line by line (paper step 3).

    Each miss-per-pass increment Δm ≥ 2 marks a set beginning to thrash,
    with way count Δm − 1; Δm ≈ 1 extends an already-thrashing set.  The
    sweep ends when every access misses.  Equal-set caches produce identical
    jumps (Assumption 1 holds); the L2 TLB produces the 17-then-8s staircase
    (Assumption 1 violated, Fig 8/9).
    """
    way_counts: list[int] = []
    prev = 0.0
    lines_total = cache_bytes // line_bytes
    # batched backends take the staircase in waves; the early-stop check
    # still runs per step on the host, so at most one wave is overshoot
    wave = _WAVE if _is_batched(backend) else 1
    j, done = 1, False
    while j <= max_steps and not done:
        chunk = list(range(j, min(j + wave - 1, max_steps) + 1))
        ms = _misses_per_pass_many(
            backend, [(cache_bytes + jj * line_bytes, line_bytes,
                       float(passes), elem_bytes) for jj in chunk])
        for jj, m in zip(chunk, ms):
            dm = m - prev
            if dm >= new_set_threshold:
                way_counts.append(int(round(dm)) - 1)
            prev = m
            per_pass = math.ceil(lines_total + jj)
            if m >= 0.999 * per_pass:  # all sets thrash: structure exposed
                done = True
                break
        j = chunk[-1] + 1
    uniform = len(set(way_counts)) <= 1
    t = len(way_counts)
    assoc = cache_bytes / (line_bytes * t) if t else float("nan")
    return SetStructure(way_counts, uniform, t, assoc)


# ---------------------------------------------------------------------------
# Stage 2b: replacement policy (paper step 4 / Fig 11)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ReplacementReport:
    is_lru: bool
    way_probs: list[float] | None   # estimated replacement probabilities
    evictions: int                  # reconstruction sample size


def detect_replacement(backend: TraceBackend, cache_bytes: int,
                       line_bytes: int, *, elem_bytes: int = 4,
                       passes: int = 60) -> ReplacementReport:
    """Periodicity test + eviction-chain reconstruction.

    With N = C + b only one set is over-subscribed, by one line, so exactly
    one of its lines is absent at any instant.  Hence the victim of miss t
    is the line that misses at t+1 — the missed-line sequence IS the
    eviction chain.  Way labels are built lazily from the chain itself
    (each first-seen victim sits in a not-yet-labelled physical way), so no
    cold-fill assumption is needed; counts begin once all labels exist.
    The recovered probabilities equal the true per-way probabilities up to
    the (unobservable) way permutation — the paper's Fig 11 analysis,
    automated.
    """
    tr = fine_grained(backend, cache_bytes + line_bytes, line_bytes,
                      elem_bytes=elem_bytes, warmup_passes=2, passes=passes)
    mask = _miss_mask(tr)
    lines = tr.indices * elem_bytes // line_bytes

    period = _accesses_per_pass(tr.config)
    is_lru = True
    if mask.size >= 2 * period:
        folded = mask[: (mask.size // period) * period].reshape(-1, period)
        is_lru = bool((folded == folded[0]).all())
        # LRU with one-line overflow also implies the conflict set misses on
        # every access; a periodic-but-partial pattern is still non-LRU.
        if is_lru:
            conflict_lines = np.unique(lines[mask])
            for ln in conflict_lines:
                ln_mask = mask[lines == ln]
                if not ln_mask.all():
                    is_lru = False
                    break
    if is_lru:
        return ReplacementReport(True, None, 0)

    # --- eviction-chain reconstruction on the conflict set ---
    missed_lines = lines[mask]
    conflict = np.unique(missed_lines)
    ways = len(conflict) - 1
    if ways <= 0:
        return ReplacementReport(False, None, 0)
    slot_of: dict[int, int] = {}
    next_label = 0
    counts = np.zeros(ways, dtype=np.int64)
    seq = [int(x) for x in missed_lines]
    for t in range(len(seq) - 1):
        victim = seq[t + 1]
        w = slot_of.pop(victim, None)
        if w is None:                   # victim in a way we haven't labelled
            if next_label >= ways:      # chain glitch (shouldn't happen)
                continue
            w = next_label
            next_label += 1
        elif next_label >= ways:        # all ways labelled: count this one
            counts[w] += 1
        slot_of[seq[t]] = w
    total = int(counts.sum())
    probs = (counts / total).tolist() if total else None
    return ReplacementReport(False, probs, total)


# ---------------------------------------------------------------------------
# Set-mapping address bits (conflict-stride probe)
# ---------------------------------------------------------------------------


def find_set_bits(backend: TraceBackend, line_bytes: int, ways: int,
                  num_sets: int, *, elem_bytes: int = 4,
                  max_log2: int = 20, passes: int = 6) -> tuple[int, int]:
    """Recover which address bits select the set.

    Probe: chase ``ways+1`` lines spaced 2^p apart.  If the spacing keeps
    all lines in one set they thrash (all miss); the smallest such p bounds
    the top of the set-index field, and ``log2(num_sets)`` bits below it
    form the field.  Texture L1 ⇒ (7, 9) i.e. bits 7–8 (Fig 7); a classical
    cache of the same shape ⇒ (5, 7).
    """
    n_lines = ways + 1

    def probe(p: int) -> tuple[PChaseConfig, np.ndarray]:
        spacing = 1 << p
        addrs = np.arange(n_lines, dtype=np.int64) * (spacing // elem_bytes)
        idx = np.resize(addrs, n_lines * passes)
        n_bytes = int(addrs[-1] * elem_bytes + line_bytes)
        return PChaseConfig(n_bytes, spacing, len(idx), elem_bytes, 0), idx

    ps = list(range(int(math.log2(line_bytes)), max_log2 + 1))
    run_batch = getattr(backend, "batch", None)
    wave = _WAVE if run_batch is not None else 1
    for i in range(0, len(ps), wave):
        chunk = ps[i:i + wave]
        reqs = [probe(p) for p in chunk]
        if run_batch is not None:
            traces = run_batch(reqs)
        else:
            traces = [backend(cfg, indices=idx) for cfg, idx in reqs]
        for p, tr in zip(chunk, traces):
            steady = _miss_mask(tr)[n_lines:]
            if steady.size and steady.all():
                lo = p - int(round(math.log2(num_sets)))
                return (lo, p)
    raise ValueError("no conflict stride found: cache may be fully associative")


# ---------------------------------------------------------------------------
# Orchestrated dissection (the whole Fig 6 flowchart)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CacheParams:
    size_bytes: int
    line_bytes: int
    num_sets: int
    assoc: float
    way_counts: list[int]
    uniform_sets: bool
    is_lru: bool
    way_probs: list[float] | None = None
    set_bits: tuple[int, int] | None = None

    def summary(self) -> str:
        pol = "LRU" if self.is_lru else (
            f"non-LRU p={['%.3f' % p for p in self.way_probs]}"
            if self.way_probs else "non-LRU")
        bits = (f" set-bits[{self.set_bits[0]},{self.set_bits[1]})"
                if self.set_bits else "")
        return (f"C={self.size_bytes}B b={self.line_bytes}B T={self.num_sets} "
                f"a={self.assoc:g} ways={self.way_counts} {pol}{bits}")


def dissect(backend: TraceBackend, *, n_max: int, elem_bytes: int = 4,
            stride_for_size: int | None = None, granularity: int | None = None,
            max_line: int = 1 << 16, probe_set_bits: bool = True,
            structure_max_steps: int = 128,
            line_stride_bytes: int | None = None,
            set_bits_max_log2: int = 20) -> CacheParams:
    """Run the full two-stage procedure against one cache path.

    ``line_stride_bytes`` sets the chase stride of the line-size stage — a
    TLB dissection strides by the expected page size instead of crawling
    4-byte elements across a 32 MB reach.  ``set_bits_max_log2`` bounds the
    conflict-stride probe (page-grain mappings need spacings past 2^20).
    """
    g = granularity or elem_bytes
    size = find_cache_size(backend, n_max=n_max, granularity=g,
                           stride_bytes=stride_for_size or elem_bytes,
                           elem_bytes=elem_bytes)
    line = find_line_size(backend, size, elem_bytes=elem_bytes,
                          stride_bytes=line_stride_bytes,
                          max_line=max_line, granularity=g)
    ways0 = conflict_set_ways(backend, size, line, elem_bytes=elem_bytes)
    repl = detect_replacement(backend, size, line, elem_bytes=elem_bytes)
    if repl.is_lru:
        struct = recover_set_structure(backend, size, line,
                                       elem_bytes=elem_bytes,
                                       max_steps=structure_max_steps)
        if not struct.way_counts:           # fully associative single set
            struct = SetStructure([ways0], True, 1, size / line)
    else:
        # Miss-count staircases are stochastic under non-LRU replacement;
        # derive T from C = T·a·b with a from the conflict set (paper §4.5).
        t = int(round(size / (line * max(1, ways0))))
        struct = SetStructure([ways0] * t, True, t, float(ways0))
    num_sets = struct.num_sets
    set_bits = None
    if probe_set_bits and num_sets > 1 and struct.uniform:
        try:
            set_bits = find_set_bits(backend, line, struct.way_counts[0],
                                     num_sets, elem_bytes=elem_bytes,
                                     max_log2=set_bits_max_log2)
        except ValueError:
            set_bits = None
    return CacheParams(
        size_bytes=size, line_bytes=line, num_sets=num_sets,
        assoc=struct.assoc if struct.way_counts else float(ways0),
        way_counts=struct.way_counts, uniform_sets=struct.uniform,
        is_lru=repl.is_lru, way_probs=repl.way_probs, set_bits=set_bits)
