"""Memory-model-driven kernel tuning (the paper's payoff, §1: measured
hierarchy parameters → software optimization).

Given the resolved device profile (VMEM capacity, HBM bandwidth/latency
via Little's law — ``repro.core.profile.resolve_spec``, so a dissected
profile installed by a launcher reaches here without parameter plumbing),
choose BlockSpec tiles analytically:

* flash attention: maximize the q-tile (each q-block re-streams all of K/V,
  so HBM traffic ≈ S_kv·d·2·(S_q/bq)) subject to the working set fitting a
  VMEM fraction and tiles being (8,128)-aligned;
* memcpy: smallest block that keeps latency×bandwidth bytes in flight with
  double buffering.

Every choice returns its predicted traffic so the perf loop can check
hypotheses against measurements.
"""

from __future__ import annotations

import dataclasses

from repro.core import profile
from repro.core.littles_law import tpu_min_block_bytes


@dataclasses.dataclass
class FlashPlan:
    block_q: int
    block_k: int
    vmem_bytes: int
    hbm_bytes: float          # predicted traffic for one (head, S×S) tile
    note: str
    spec_name: str = ""       # profile the plan was priced against


def flash_attention_blocks(seq_q: int, seq_k: int, head_dim: int, *,
                           dtype_bytes: int = 2, spec=None,
                           vmem_fraction: float = 0.5) -> FlashPlan:
    spec = profile.resolve_spec(spec)
    budget = int(spec.vmem_bytes * vmem_fraction)
    best: FlashPlan | None = None
    for bq in (128, 256, 512, 1024, 2048):
        if bq > seq_q:
            break
        for bk in (128, 256, 512, 1024, 2048):
            if bk > seq_k:
                break
            # resident: q, k, v tiles (double-buffered), acc f32, scores f32
            vmem = (bq * head_dim * dtype_bytes * 2 +
                    2 * bk * head_dim * dtype_bytes * 2 +
                    bq * head_dim * 4 + bq * bk * 4)
            if vmem > budget:
                continue
            traffic = (seq_q * head_dim * dtype_bytes * 2 +      # q in, o out
                       (seq_q / bq) * seq_k * head_dim * dtype_bytes * 2)
            cand = FlashPlan(bq, bk, vmem, traffic,
                             f"kv re-streamed {seq_q // bq}×", spec.name)
            if best is None or (cand.hbm_bytes, -cand.block_k) < \
                    (best.hbm_bytes, -best.block_k):
                best = cand
    if best is None:
        return FlashPlan(128, 128, 0, float("inf"), "fallback: tiny VMEM",
                         spec.name)
    return best


@dataclasses.dataclass
class MemcpyPlan:
    block_rows: int
    block_bytes: int
    inflight_bytes: int
    note: str
    spec_name: str = ""       # profile the plan was priced against


def memcpy_block(cols: int, *, dtype_bytes: int = 4, spec=None,
                 hbm_latency_s: float | None = None) -> MemcpyPlan:
    spec = profile.resolve_spec(spec)
    need = tpu_min_block_bytes(spec, buffers=2, hbm_latency_s=hbm_latency_s)
    row_bytes = cols * dtype_bytes
    rows = max(spec.sublanes, -(-need // row_bytes))
    rows = -(-rows // spec.sublanes) * spec.sublanes      # (8,·) aligned
    return MemcpyPlan(rows, rows * row_bytes, need,
                      "smallest double-buffered block hiding HBM latency",
                      spec.name)
