"""Content-addressed P-chase trace cache.

Simulated traces are pure functions of (probed structure, chase config,
seed, engine revision) — yet before this cache every sweep re-simulated
identical streams: ``inference.dissect`` replays the same overflow traces
the spectrum/TLB/classic experiments already produced, and every
``repro.bench run`` regenerates all of them from scratch.  This module
gives each backend a consult-before-simulate store:

* **Key** — SHA-256 over the canonical JSON of ``(trace_id, PChaseConfig
  fields, seed, ENGINE_VERSION, backend params, digest of any explicit
  index stream)``.  ``trace_id`` names the probed structure (a registered
  device / cache factory label); callers must only pass one for
  deterministic backends.
* **Layout** — ``<root>/<engine tag>/<hh>/<key>.npz`` (two-level fan-out),
  one npz per trace.  Payloads are stored compactly: hit/miss masks as
  packed bits, two-valued latency streams as (bitmask, lo, hi), and the
  index stream of a uniform chase omitted entirely (the caller rebuilds it
  from the config at load).  Bulky debug-only meta (``replaced_ways``) is
  not persisted — reloaded traces carry the measurement contract, not
  simulator internals.  The engine tag directory means a bumped
  :data:`repro.core.cachesim.ENGINE_VERSION` abandons stale traces
  wholesale.
* **Eviction** — size-capped (``REPRO_TRACE_CACHE_MAX_MB``, default 512):
  on insert, oldest-mtime files are pruned until the root fits under the
  cap.  Reads bump mtime, so the policy is LRU-by-file.
* **Concurrency** — writes go through a temp file + ``os.replace`` so
  parallel bench workers never observe torn traces; a corrupt/unreadable
  entry is treated as a miss and deleted.

The default process-wide cache is configured by :func:`configure` (the
bench CLI does this; ``--no-trace-cache`` turns it off) or the
``REPRO_TRACE_CACHE_DIR`` environment variable.  When unconfigured, every
lookup misses and nothing is written — unit tests stay hermetic.
"""

from __future__ import annotations

import contextlib
import hashlib
import json
import os
import tempfile
from typing import Any

import numpy as np

from repro.core.cachesim import ENGINE_VERSION
from repro.core.trace import PChaseConfig, PChaseTrace

DEFAULT_ROOT = os.path.join("experiments", "traces")
DEFAULT_MAX_MB = 512

# meta fields that round-trip through the npz payload
_BITMASK_META = ("true_miss",)
_SCALAR_META = ("miss_threshold", "steady_state_tiled", "per_access_ns")


def _pack_mask(mask: np.ndarray) -> np.ndarray:
    return np.packbits(np.asarray(mask, dtype=bool))


def _unpack_mask(bits: np.ndarray, n: int) -> np.ndarray:
    return np.unpackbits(bits, count=n).astype(bool)


def _canonical(parts: dict[str, Any]) -> str:
    return json.dumps(parts, sort_keys=True, separators=(",", ":"),
                      default=str)


def indices_digest(indices: np.ndarray) -> str:
    """Stable digest of an explicit index stream (custom-init chases)."""
    arr = np.ascontiguousarray(indices, dtype=np.int64)
    return hashlib.sha256(arr.tobytes()).hexdigest()[:32]


class TraceCache:
    """One cache root.  All operations are best-effort: I/O errors degrade
    to cache misses, never to harness failures."""

    #: bytes written between eviction scans (a full-tree walk per put would
    #: be quadratic in cache size)
    _EVICT_EVERY = 32 << 20

    def __init__(self, root: str, max_bytes: int = DEFAULT_MAX_MB << 20):
        self.root = root
        self.max_bytes = max_bytes
        self.hits = 0
        self.misses = 0
        self._written_since_evict = 0

    # -- keys ---------------------------------------------------------------

    def key(self, trace_id: str, config: PChaseConfig, *, seed: int = 0,
            extra: dict[str, Any] | None = None,
            indices: np.ndarray | None = None,
            engine_version: str | None = None) -> str:
        """Content key for one trace.  ``engine_version`` names the engine
        revision the trace was (or would be) produced under — the numpy
        :data:`~repro.core.cachesim.ENGINE_VERSION` by default, the jax
        :data:`~repro.core.cachesim.JAX_ENGINE_VERSION` for batched
        traces.  The version is hashed into the key AND prefixes the
        storage path, so a jax-produced entry can never be served to the
        numpy engines (whose stochastic-policy streams differ draw for
        draw) or vice versa, and bumping either version abandons that
        engine's tag directory wholesale."""
        ev = engine_version or ENGINE_VERSION
        parts: dict[str, Any] = {
            "trace_id": trace_id,
            "engine": ev,
            "seed": seed,
            "config": [config.array_bytes, config.stride_bytes,
                       config.iterations, config.elem_bytes,
                       config.warmup_passes],
        }
        if extra:
            parts["extra"] = extra
        if indices is not None:
            parts["indices"] = indices_digest(indices)
        digest = hashlib.sha256(_canonical(parts).encode()).hexdigest()
        # composite key: "<engine tag>/<sha256>", e.g. "trace-engine-2/ab..."
        return f"{ev.replace('/', '-')}/{digest}"

    def _path(self, key: str) -> str:
        tag, _, digest = key.rpartition("/")
        tag = tag or ENGINE_VERSION.replace("/", "-")
        return os.path.join(self.root, tag, digest[:2], digest + ".npz")

    # -- get / put ----------------------------------------------------------

    def get(self, key: str, config: PChaseConfig,
            rebuild_indices: np.ndarray | None = None) -> PChaseTrace | None:
        """Load a trace.  ``rebuild_indices`` restores the index stream for
        entries stored without one (uniform chases — the caller rebuilds
        the stream from the config for free)."""
        path = self._path(key)
        try:
            with np.load(path, allow_pickle=False) as z:
                n = int(z["n"])
                if "indices" in z.files:
                    indices = z["indices"].astype(np.int64)
                elif rebuild_indices is not None:
                    indices = np.asarray(rebuild_indices, dtype=np.int64)
                else:
                    raise ValueError("trace stored without indices")
                if "lat_mask" in z.files:   # two-valued latency stream
                    lo, hi = z["lat_values"]
                    latencies = np.where(_unpack_mask(z["lat_mask"], n),
                                         hi, lo).astype(np.float64)
                else:
                    latencies = z["latencies"]
                meta: dict[str, Any] = {}
                for name in _BITMASK_META:
                    if f"{name}_bits" in z.files:
                        meta[name] = _unpack_mask(z[f"{name}_bits"], n)
                if "patterns" in z.files:
                    meta["patterns"] = [p if p != "" else None
                                        for p in z["patterns"].tolist()]
                if "scalar_meta" in z.files:
                    meta.update(json.loads(str(z["scalar_meta"])))
                trace = PChaseTrace(config, indices, latencies, meta=meta)
        except FileNotFoundError:
            self.misses += 1
            return None
        except Exception:                      # torn/stale file: drop it
            self.misses += 1
            try:
                os.unlink(path)
            except OSError:
                pass
            return None
        self.hits += 1
        try:
            os.utime(path)                     # LRU bump
        except OSError:
            pass
        return trace

    def put(self, key: str, trace: PChaseTrace,
            omit_indices: bool = False) -> None:
        """Store a trace.  ``omit_indices`` skips the index stream for
        uniform chases (rebuilt at load from the config)."""
        n = len(trace.latencies)
        payload: dict[str, Any] = {"n": np.int64(n)}
        if not omit_indices:
            idx = trace.indices
            if idx.size and 0 <= idx.min() and idx.max() < 2 ** 31:
                idx = idx.astype(np.int32)
            payload["indices"] = idx
        lat = trace.latencies
        vals = np.unique(lat)
        if vals.size == 2:
            payload["lat_mask"] = _pack_mask(lat == vals[1])
            payload["lat_values"] = vals
        elif vals.size == 1:
            payload["lat_mask"] = _pack_mask(np.zeros(n, dtype=bool))
            payload["lat_values"] = np.array([vals[0], vals[0]])
        else:
            payload["latencies"] = lat
        scalar: dict[str, Any] = {}
        for name, value in trace.meta.items():
            if name in _BITMASK_META:
                payload[f"{name}_bits"] = _pack_mask(value)
            elif name == "patterns":
                payload[name] = np.asarray(
                    [p if p is not None else "" for p in value])
            elif name in _SCALAR_META:
                scalar[name] = float(value)
            # other meta (e.g. replaced_ways — debug internals) is not
            # persisted; the measurement contract round-trips in full
        if scalar:
            payload["scalar_meta"] = np.asarray(json.dumps(scalar))
        try:
            os.makedirs(os.path.dirname(path := self._path(key)),
                        exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                       suffix=".tmp")
            with os.fdopen(fd, "wb") as fh:
                # uncompressed: traces are compact already and zlib costs
                # more than the simulation it would save
                np.savez(fh, **payload)
            os.replace(tmp, path)
            self._written_since_evict += os.path.getsize(path)
        except OSError:
            return
        if self._written_since_evict >= self._EVICT_EVERY:
            self._written_since_evict = 0
            self._evict()

    # -- eviction -----------------------------------------------------------

    def _entries(self) -> list[tuple[float, int, str]]:
        out = []
        for dirpath, _, files in os.walk(self.root):
            for f in files:
                if not f.endswith(".npz"):
                    continue
                p = os.path.join(dirpath, f)
                try:
                    st = os.stat(p)
                except OSError:
                    continue
                out.append((st.st_mtime, st.st_size, p))
        return out

    def _evict(self) -> None:
        entries = self._entries()
        total = sum(size for _, size, _ in entries)
        if total <= self.max_bytes:
            return
        for _, size, path in sorted(entries):          # oldest mtime first
            try:
                os.unlink(path)
            except OSError:
                continue
            total -= size
            if total <= self.max_bytes:
                break


# ---------------------------------------------------------------------------
# Process-wide default (what the backends consult)
# ---------------------------------------------------------------------------

_default: TraceCache | None = None
_configured = False


def configure(root: str | None = DEFAULT_ROOT, *,
              max_mb: int | None = None) -> TraceCache | None:
    """Install (or, with ``root=None``, remove) the process default."""
    global _default, _configured
    _configured = True
    if root is None:
        _default = None
    else:
        if max_mb is None:
            max_mb = int(os.environ.get("REPRO_TRACE_CACHE_MAX_MB",
                                        DEFAULT_MAX_MB))
        _default = TraceCache(root, max_bytes=max_mb << 20)
    return _default


def default_cache() -> TraceCache | None:
    """The process-wide cache, or None when disabled (the default)."""
    global _configured
    if not _configured:
        env = os.environ.get("REPRO_TRACE_CACHE_DIR")
        if env:
            configure(env)
        else:
            _configured = True
    return _default


@contextlib.contextmanager
def disabled():
    """Temporarily turn the process cache off — the dissect-speed
    benchmark uses this so engine timings race raw simulation, not a
    warm trace store."""
    global _default, _configured
    saved = (_default, _configured)
    _default, _configured = None, True
    try:
        yield
    finally:
        _default, _configured = saved
