"""Paged KV-cache allocation: fixed-size pages, free-list, page tables.

The serving engine's cache is no longer a dense ``(max_slots, max_len)``
block: attention K/V live in a shared pool of ``num_pages`` fixed-size
pages and every request holds an ordered list of physical pages covering
exactly the tokens it has actually produced.  The allocator is plain
Python/numpy bookkeeping — the jitted model only ever sees the dense page
pool plus an ``(slots, pages_per_seq)`` int32 page table.

Page length is *derived*, not hard-coded: :func:`choose_page_len` prices
each candidate with the repo's own dissection laws —

* **Little's law** (paper §5.1, ``core.littles_law``): a page is one
  contiguous DMA row of the gather; rows much smaller than the
  latency-hiding in-flight quantum waste bandwidth on transfer setup, so
  the gather-overhead term falls as ``setup/(setup + row_bytes)``.
* **Fragmentation**: a live request wastes half a page on average, so the
  capacity-waste term grows linearly in ``page_len``.
* **Bank-conflict row model** (paper §6.2, ``core.bankconflict``): the
  page row stride must keep the VMEM lane-serialization degree at 1,
  i.e. rows must be whole (sublanes × lanes) tiles; candidates that are
  not are penalized by their predicted serialization degree.

Physical page 0 is a permanently reserved *scratch* page: inactive batch
slots in the jitted decode step write their garbage K/V there, so they can
never corrupt a live request's pages (the paged analogue of the dense
engine's "inactive slots decode garbage" trade).
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.core import bankconflict, littles_law, profile
from repro.core.costmodel import (  # noqa: F401  (re-exported for serve)
    kv_bytes_per_token, kv_bytes_per_token_layer,
)
from repro.models.config import ModelConfig

#: physical page ids below this are never handed out (page 0 = scratch)
SCRATCH_PAGES = 1

#: outstanding DMA descriptors assumed by the gather-overhead term: with D
#: transfers in flight, each must carry required_inflight/D bytes to keep
#: the HBM pipe busy (Little's law applied per-transfer)
GATHER_OUTSTANDING = 16


class OutOfPages(RuntimeError):
    """Raised by ``alloc`` when the free list cannot cover a request."""


class PageAllocator:
    """Free-list page allocator with per-request page lists.

    All-or-nothing ``alloc``; ``release`` is copy-free (pages go straight
    back on the free list).  ``check_invariants`` is cheap enough to call
    every engine tick — the soak test does.
    """

    def __init__(self, num_pages: int, page_len: int):
        if num_pages <= SCRATCH_PAGES:
            raise ValueError(f"need > {SCRATCH_PAGES} pages, got {num_pages}")
        if page_len < 1:
            raise ValueError(f"page_len must be >= 1, got {page_len}")
        self.num_pages = num_pages
        self.page_len = page_len
        self.free: deque[int] = deque(range(SCRATCH_PAGES, num_pages))
        self.pages: dict[int, list[int]] = {}       # uid -> physical pages
        # -2 scratch, -1 free, else owning uid
        self.owner = np.full(num_pages, -1, dtype=np.int64)
        self.owner[:SCRATCH_PAGES] = -2

    # -- capacity ----------------------------------------------------------

    @property
    def capacity(self) -> int:
        """Allocatable pages (total minus scratch)."""
        return self.num_pages - SCRATCH_PAGES

    @property
    def free_pages(self) -> int:
        return len(self.free)

    @property
    def allocated_pages(self) -> int:
        return sum(len(p) for p in self.pages.values())

    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_len)

    # -- alloc / release ---------------------------------------------------

    def alloc(self, uid: int, n: int = 1) -> list[int]:
        """Append ``n`` pages to ``uid``'s page list (all-or-nothing)."""
        if n < 0:
            raise ValueError(f"n must be >= 0, got {n}")
        if n == 0:
            # no phantom bookkeeping: a uid that owns nothing must not
            # appear in `pages` (check_invariants rejects empty lists)
            return []
        if n > len(self.free):
            raise OutOfPages(f"uid {uid}: need {n} pages, {len(self.free)} free")
        got = [self.free.popleft() for _ in range(n)]
        for p in got:
            self.owner[p] = uid
        self.pages.setdefault(uid, []).extend(got)
        return got

    def ensure(self, uid: int, tokens: int) -> int:
        """Grow ``uid``'s page list to cover ``tokens``; returns #new pages."""
        need = self.pages_for(tokens) - len(self.pages.get(uid, ()))
        if need > 0:
            self.alloc(uid, need)
            return need
        return 0

    def release(self, uid: int) -> int:
        """Free every page held by ``uid`` (copy-free). Returns the count."""
        pages = self.pages.pop(uid, [])
        for p in pages:
            self.owner[p] = -1
            self.free.append(p)
        return len(pages)

    # -- invariants --------------------------------------------------------

    def violations(self) -> list[str]:
        """Non-raising :meth:`check_invariants`: the corruption-DETECTION
        hook the fleet's chaos tier polls.  Returns the first violated
        invariant's message (empty list when the books are clean) so a
        fault campaign can quarantine a corrupted replica instead of
        crashing the fleet."""
        try:
            self.check_invariants()
        except AssertionError as e:
            return [str(e) or "allocator invariant violated"]
        return []

    def check_invariants(self) -> None:
        """No leaks, no double ownership, accounting closed."""
        freeset = set(self.free)
        assert len(freeset) == len(self.free), "free list has duplicates"
        owned: set[int] = set()
        for uid, pages in self.pages.items():
            assert pages, f"uid {uid} has an empty page list"
            pset = set(pages)
            assert len(pset) == len(pages), f"uid {uid} holds a page twice"
            assert not (pset & owned), f"uid {uid} shares a page"
            assert not (pset & freeset), f"uid {uid} holds a freed page"
            for p in pages:
                assert self.owner[p] == uid, f"owner map stale for page {p}"
            owned |= pset
        assert all(p >= SCRATCH_PAGES for p in owned | freeset), \
            "scratch page leaked into circulation"
        assert len(owned) + len(freeset) == self.capacity, \
            (f"leak: {len(owned)} owned + {len(freeset)} free "
             f"!= {self.capacity} allocatable")
        assert int((self.owner == -1).sum()) == len(freeset)


# ---------------------------------------------------------------------------
# page-length sizing from the dissection laws
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PageLenTerm:
    """Scoring terms for one candidate page length (all dimensionless)."""

    page_len: int
    row_bytes: int              # contiguous gather row per layer PER SHARD
    gather_frac: float          # bandwidth lost to transfer setup
    frag_frac: float            # capacity lost to the half-page tail
    table_frac: float           # capacity spent on page-table entries
    conflict_degree: int        # VMEM lane-serialization of the row stride
    score: float
    shards: int = 1             # mesh partitions the heads dim splits into


def page_len_rationale(cfg: ModelConfig, *, spec=None,
                       expected_tokens: int = 256,
                       candidates: tuple[int, ...] = (8, 16, 32, 64, 128, 256),
                       shards: int = 1,
                       ) -> list[PageLenTerm]:
    """Price every candidate page length with the paper's laws.

    ``expected_tokens`` is the typical total sequence length served
    (prompt + generation); the fragmentation and page-table terms are
    fractions of that working set.  ``spec`` resolves through
    ``repro.core.profile`` — a dissected profile artifact changes the
    Little's-law setup term and the lane geometry here, not constants.

    ``shards`` is the number of mesh partitions the pool's KV-heads dim
    is split into: each shard gathers only ``1/shards`` of a page row,
    against its OWN partition's full bandwidth and latency (per-partition,
    not aggregate, is the right anchor — arXiv:1804.06826).  Thinner
    per-shard rows leave more of the in-flight quantum uncovered, so wider
    meshes push the argmin toward LONGER pages.  ``shards=1`` is exactly
    the unsharded pricing.
    """
    spec = profile.resolve_spec(spec)
    full_bpt = kv_bytes_per_token_layer(cfg)
    if full_bpt == 0:             # attention-free: paging is table-only
        full_bpt = 1
    bpt = max(1, full_bpt // max(1, shards))
    setup = littles_law.tpu_required_inflight_bytes(spec) / GATHER_OUTSTANDING
    out = []
    for pl in candidates:
        row = pl * bpt
        gather = setup / (setup + row)
        frag = (pl / 2) / expected_tokens
        # one int32 entry per page, priced against the UNSHARDED row: the
        # page table is host-side bookkeeping and is never partitioned,
        # so its overhead must not inflate with the shard count
        table = 4.0 / (pl * full_bpt)
        # bank-conflict row model: a page row that is a whole number of
        # lane rows (lanes x 4 B) gathers as contiguous tiles (degree 1);
        # a sub-tile row makes one vector read straddle pages, i.e. a
        # strided access with stride = row words — the same lane/row
        # counting as the paper's shared-memory model
        if row % (spec.lanes * 4) == 0:
            degree = 1
        else:
            degree = bankconflict.tpu_conflict_degree(max(1, row // 4),
                                                      lanes=spec.lanes,
                                                      sublanes=spec.sublanes)
        penalty = max(0.0, (degree - 1) / spec.sublanes)
        out.append(PageLenTerm(pl, row, round(gather, 4), round(frag, 4),
                               round(table, 6), degree,
                               round(gather + frag + table + penalty, 4),
                               max(1, shards)))
    return out


def choose_page_len(cfg: ModelConfig, *, spec=None,
                    expected_tokens: int = 256, shards: int = 1) -> int:
    """The argmin of :func:`page_len_rationale` (ties -> smaller page)."""
    terms = page_len_rationale(cfg, spec=spec,
                               expected_tokens=expected_tokens,
                               shards=shards)
    best = min(terms, key=lambda t: (t.score, t.page_len))
    return best.page_len


def gather_shards(cfg: ModelConfig, ctx) -> int:
    """Partitions the paged gather actually runs in under ``ctx``: the
    mesh-axis size of the ``cache_kv_heads`` rule when it divides the
    model's KV-head count, else 1 (the GQA replication fallback, and
    MLA's rank-3 compressed leaves which never shard heads)."""
    if ctx is None:
        return 1
    if cfg.use_mla or cfg.num_kv_heads <= 0:
        return 1
    size = ctx.axis_size(ctx.mesh_axes("cache_kv_heads"))
    return size if size > 1 and cfg.num_kv_heads % size == 0 else 1
