"""Profile-aware multi-replica serving fleet.

:class:`FleetEngine` runs N :class:`~repro.serve.engine.PagedServeEngine`
replicas — each bound to its OWN resolved device profile, so mixed
GTX980 / TeslaV100 / tpu_v5e fleets are first-class — behind a router
that prices admission with the same measure-then-deploy machinery the
single-engine path already consumes:

* **step cost** (:meth:`~repro.core.costmodel.CellCost.step_s`): a fresh
  ``decode_cell_cost`` is priced against each candidate replica's spec
  for the load it would carry *after* admitting the request.  One
  CellCost per (replica, decision) keeps the pricing correctly scoped —
  a mixed fleet must never trip ``SpecMixWarning``, which exists to catch
  ONE plan straddling two profiles, not N plans each on their own.
* **free-page headroom**: among cost-equivalent replicas the router
  prefers the one with the most pages left after the request's first
  chunk — the fleet analogue of admission-by-free-pages.
* **Little's-law inflight bound**: a replica whose live sequence count
  already covers its latency-hiding quantum
  (``required_inflight_bytes / gather row``) gains nothing from more
  concurrency, so the router penalizes overage — the paper's occupancy
  law applied to request placement instead of warp placement.

Every decision is appended to a :class:`RouteDecision` log and the whole
scheduler is deterministic (no RNG, no wall clock, index tie-breaks), so
a fleet run REPLAYS bit-identically: the ``serve_fleet`` experiment gates
on it.  The router never chooses a replica whose predicted step cost
exceeds the best candidate's by more than its own ``margin`` — that
invariant is checked from the decision log, not trusted.

With one replica the fleet degenerates exactly to the single paged
engine: dispatch applies the engine's own admission predicate
(:meth:`~repro.serve.engine.PagedServeEngine.can_accept`), so the same
requests are admitted on the same ticks and the token stream is
request-for-request identical — the dense/paged single-engine path stays
the differential oracle.

**Chaos tier** (``repro.serve.faults`` drives it): every replica carries
a lifecycle state — ``healthy``/``degraded``/``quarantined``/``dead`` —
and the router only ever dispatches to *dispatchable* (healthy or
degraded) replicas.  :meth:`FleetEngine.kill` evacuates a replica
copy-free (zero leaked pages, stranded requests re-homed through the
same ``_migrate`` machinery that moves preemption rollbacks);
corruption detected by ``PagedServeEngine.check_invariants`` sends a
replica through the :meth:`quarantine` → heal → :meth:`readmit`
lifecycle; :meth:`degrade` swaps in a latency-spiked spec so
``decode_cell_cost`` re-prices the replica and the router organically
drains load from it.  Every lifecycle transition is recorded as a
:class:`FaultEvent` sharing one fleet-global sequence with the routing
decisions, so :meth:`decision_log` stays bit-identical under replay of
ANY fault schedule — the deterministic event loop's payoff.

**Tiered fleets** (``repro.serve.tiers`` defines the policy): with a
non-symmetric :class:`~repro.serve.tiers.TierPlan` the router splits
into two stages.  Stage 1 places fresh admissions (and re-prefill
migrations) on *prefill-tier* replicas, priced per replica with
``prefill_cell_cost`` — the FLOP + bandwidth cost of the prompt the
request brings (chunking only spreads that work over ticks, so the
whole prompt is the right admission quantum).  A prefill-specialist
replica runs with ``hold_after_prefill``: the tick a prompt completes,
the request parks in the engine's ``ready`` queue instead of decoding.
Stage 2 then routes a **KV handoff**: ``decode_cell_cost`` at the
destination's load *plus* the paged-page transfer priced by
``min(src, dst)`` measured global-memory bandwidth
(:func:`repro.serve.tiers.handoff_seconds`).  The handoff occupies
:func:`~repro.serve.tiers.handoff_ticks` fleet ticks in transit —
during which the stream's tokens are withheld, so the transfer lands in
TTFT instead of vanishing between tiers — and the pages arrive via
``PagedServeEngine.export_pages``/``import_pages`` (copy-free on the
source, allocator-checked on both ends).  Both stage decisions AND the
handoff transfer event ride the same fleet-global sequence, so the
two-stage log still replays bit-for-bit, and ``margin_violations()``
audits both stages with one rule.  A symmetric plan (or ``tiers=None``)
keeps every stage a no-op: the fleet reproduces the single-stage router
token-for-token on the same tick schedule — the tiered link of the
dense→paged→fleet oracle chain.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Sequence

from repro.core import littles_law, profile
from repro.core.costmodel import (ParallelismPlan, decode_cell_cost,
                                  prefill_cell_cost)
from repro.core.devices import TpuSpec
from repro.models.config import ModelConfig
from repro.serve import paging, tiers as tiering
from repro.serve.engine import PagedServeEngine, Request
from repro.serve.tiers import TierPlan

#: default routing margin: a replica within 10% of the cheapest predicted
#: step cost is cost-equivalent and competes on headroom instead
ROUTER_MARGIN = 0.10

#: replica lifecycle states (the chaos tier's vocabulary)
HEALTHY = "healthy"          # serving normally
DEGRADED = "degraded"        # serving, but priced with a spiked spec
QUARANTINED = "quarantined"  # corruption detected: healed, timed readmit
DEAD = "dead"                # replica lost: permanent for the run

#: states the router may dispatch to
DISPATCHABLE_STATES = (HEALTHY, DEGRADED)

#: fleet ticks a quarantined replica sits out before readmission
QUARANTINE_TICKS = 8

#: terminal outcome classes a fault campaign assigns to every request
OUTCOME_CLASSES = ("completed", "migrated", "requeued", "lost", "cancelled")

_SINGLE_CHIP = ParallelismPlan(dp=1, tp=1, fsdp=False)


def resolve_fleet_profile(entry) -> "TpuSpec | None":
    """One replica-profile entry → the TpuSpec it is priced with.

    Accepts ``None`` (the process default), a :class:`TpuSpec`, a
    :class:`~repro.core.profile.DeviceProfile` (any kind — GPU profiles
    price through their measured :meth:`serving_spec` view), or a string:
    an artifact path / device name under ``experiments/profiles/`` if one
    exists, else the published profile for that registered device.
    """
    if entry is None or isinstance(entry, TpuSpec):
        return entry
    if isinstance(entry, profile.DeviceProfile):
        return entry.serving_spec()
    if isinstance(entry, str):
        import os

        from repro.profile import load_profile, path_for, published_profile
        if entry.endswith(".json"):
            return load_profile(entry).serving_spec()
        if os.path.exists(path_for(entry)):
            return load_profile(entry).serving_spec()
        return published_profile(entry).serving_spec()
    raise TypeError(f"cannot resolve fleet profile from {type(entry)!r}")


@dataclasses.dataclass(frozen=True)
class RouteScore:
    """One candidate replica's pricing at one decision point."""

    replica: int
    step_cost_s: float          # total priced cost (incl. handoff_s)
    free_pages_after: int       # page headroom after the first chunk
    inflight_overage: int       # live+1 beyond the Little's-law bound
    within_margin: bool
    handoff_s: float = 0.0      # KV-transfer share ("handoff" stage only)


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """One routing decision, replayable and auditable."""

    seq: int                    # decision counter (fleet-global)
    tick: int
    uid: int
    kind: str                   # "admit" | "migrate" | "handoff"
    scores: tuple[RouteScore, ...]
    chosen: int                 # replica index

    def key(self) -> tuple:
        """Compact identity for bit-identical replay comparison."""
        return (self.seq, self.tick, self.uid, self.kind, self.chosen,
                tuple((s.replica, round(s.step_cost_s, 15),
                       s.free_pages_after, s.inflight_overage,
                       round(s.handoff_s, 15))
                      for s in self.scores))


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One fault or lifecycle transition, recorded in the decision log.

    ``seq`` shares the fleet-global sequence counter with
    :class:`RouteDecision`, so the merged log totally orders faults
    against routing — replay compares the interleaving, not just each
    stream separately.  ``kind`` is one of ``kill``, ``corrupt``,
    ``degrade``, ``recover``, ``quarantine``, ``readmit``, ``lost`` or
    ``skip`` (an injector fault that found no eligible target); the
    tiered fleet adds ``handoff`` (a KV transfer left its source) and
    ``handoff_abort`` (the destination was gone or full at arrival) —
    not faults, but transfers belong in the same total order so the
    two-stage log replays as ONE interleaving.
    """

    seq: int
    tick: int
    kind: str
    replica: int                # -1 for fleet-level events (e.g. "lost")
    detail: tuple = ()

    def key(self) -> tuple:
        """Compact identity for bit-identical replay comparison."""
        return (self.seq, self.tick, f"fault:{self.kind}", self.replica,
                self.detail)


@dataclasses.dataclass
class _Transit:
    """One KV handoff in flight between tiers.

    While in transit the request is resident NOWHERE — the source freed
    its pages at export, the destination allocates at arrival — and its
    token stream (``held``) is withheld from the frontend so the
    transfer's ticks land in TTFT.
    """

    req: Request
    payload: dict
    src: int
    dst: int
    arrive_tick: int
    held: list[int]                    # generated tokens withheld in flight


class FleetReplica:
    """One engine + the spec it is priced and page-sized with."""

    def __init__(self, index: int, cfg: ModelConfig, params, *,
                 spec: TpuSpec | None, max_slots: int, max_len: int,
                 page_len: int | None, num_pages: int | None,
                 prefill_chunk: int | None, sampler,
                 mesh=None, shard_rules: dict | None = None,
                 prefill_tier: bool = True, decode_tier: bool = True):
        self.index = index
        # resolve ONCE: every subsequent pricing of this replica uses the
        # same pinned spec object (never the mutable process default)
        self.spec = profile.resolve_spec(spec)
        # one replica = one device slice: its paged pool is laid out over
        # `mesh` (KV heads on "model"), its page_len priced per shard
        self.mesh = mesh
        # tier membership (symmetric fleets leave both True); a
        # prefill-SPECIALIST parks completed prompts for handoff instead
        # of decoding them — that is the only engine-level difference
        self.prefill_tier = prefill_tier
        self.decode_tier = decode_tier
        self.engine = PagedServeEngine(
            cfg, params, max_slots=max_slots, max_len=max_len,
            page_len=page_len, num_pages=num_pages,
            prefill_chunk=prefill_chunk, sampler=sampler, spec=self.spec,
            mesh=mesh, shard_rules=shard_rules,
            hold_after_prefill=prefill_tier and not decode_tier)
        self.cfg = cfg
        self._row_bytes = (self.engine.page_len
                           * max(1, paging.kv_bytes_per_token_layer(cfg)))
        # Little's law: sequences needed so their gather rows cover the
        # in-flight quantum; past this, concurrency adds latency not BW
        self.inflight_bound = max(1, round(
            littles_law.tpu_required_inflight_bytes(self.spec)
            / self._row_bytes))
        # chaos-tier lifecycle: the spec a degraded replica recovers to,
        # the state the router filters on, and the readmission deadline
        self.base_spec = self.spec
        self.state = HEALTHY
        self.quarantined_until = -1

    @property
    def dispatchable(self) -> bool:
        """May the router place work here?  Healthy or degraded only —
        quarantined and dead replicas never receive dispatches (a fleet
        invariant, asserted by ``check_invariants``)."""
        return self.state in DISPATCHABLE_STATES

    def rebind_spec(self, spec: "TpuSpec") -> None:
        """Re-price this replica (latency-spike degradation/recovery):
        every subsequent routing decision uses the new spec, and the
        Little's-law inflight bound is re-derived from it.  Page
        geometry is NOT re-derived — pages are already handed out."""
        self.spec = spec
        self.inflight_bound = max(1, round(
            littles_law.tpu_required_inflight_bytes(spec)
            / self._row_bytes))

    @property
    def name(self) -> str:
        return f"r{self.index}:{self.spec.name}"

    def score(self, req: Request, kind: str = "admit",
              handoff_s: float = 0.0) -> RouteScore:
        """Price placing ``req`` onto this replica, against its OWN
        spec.  A fresh CellCost per call — pricing is scoped to one
        (replica, decision), which is why a mixed fleet never warns.

        Admission and migration place *prefill* work, so they are priced
        with ``prefill_cell_cost`` over the whole prompt the request
        brings (the FLOP + bandwidth cost chunking merely spreads over
        ticks) — a bandwidth-rich replica wins the prefill-dominated
        phase it is actually good at, instead of being handicapped by a
        decode-shaped estimate.  The ``handoff`` stage places *decode*
        work: ``decode_cell_cost`` at the load this replica would carry,
        plus the caller-computed KV-transfer term ``handoff_s`` (priced
        by ``min(src, dst)`` bandwidth) so a cheap decoder behind an
        expensive transfer does not look free."""
        eng = self.engine
        live = eng.live_count() + len(eng.waiting)
        if kind == "handoff":
            tokens = (eng.live_committed_tokens()
                      + sum(len(r.prompt) + r.max_new_tokens
                            for r in eng.waiting)
                      + len(req.prompt) + req.max_new_tokens)
            seq = max(1, tokens // (live + 1))
            cell = decode_cell_cost(self.cfg, global_batch=live + 1,
                                    seq=seq, plan=_SINGLE_CHIP,
                                    name=f"fleet/{self.name}")
        else:                          # "admit" | "migrate": prefill work
            cell = prefill_cell_cost(self.cfg, global_batch=1,
                                     seq=max(1, len(req.prompt)),
                                     plan=_SINGLE_CHIP,
                                     name=f"fleet/{self.name}")
        chunk_pages = eng.alloc.pages_for(eng.prefill_chunk)
        return RouteScore(
            replica=self.index,
            step_cost_s=cell.step_s(self.spec) + handoff_s,
            free_pages_after=eng.alloc.free_pages - chunk_pages,
            inflight_overage=max(0, live + 1 - self.inflight_bound),
            within_margin=False,       # filled in by the router
            handoff_s=handoff_s)

    @property
    def tier(self) -> str:
        if self.prefill_tier and self.decode_tier:
            return "both"
        return "prefill" if self.prefill_tier else "decode"

    def stats(self) -> dict:
        s = self.engine.stats()
        s["replica"] = self.name
        s["spec"] = self.spec.name
        s["inflight_bound"] = self.inflight_bound
        s["state"] = self.state
        s["tier"] = self.tier
        return s


class FleetEngine:
    """N paged replicas behind the profile-aware router (module doc).

    ``profiles`` gives one entry per replica (see
    :func:`resolve_fleet_profile`); ``replicas`` alone builds a
    homogeneous fleet on the active profile.  ``num_pages`` may be a
    sequence (one pool size per replica) to model unequal HBM headroom.
    ``mesh`` makes every replica a device slice: each engine's paged pool
    is mesh-sharded (``launch.mesh.make_serve_mesh`` builds the shape the
    ``--mesh-shape`` flag names); routing stays host-side and unchanged.
    Requests enter a fleet-level FIFO and are dispatched head-of-line:
    the router either places ``pending[0]`` or leaves it queued until a
    replica frees capacity — FIFO admission is what makes an N=1 fleet
    reproduce the single engine's schedule exactly.
    """

    def __init__(self, cfg: ModelConfig, params, *,
                 max_slots: int, max_len: int,
                 replicas: int | None = None,
                 profiles: Sequence | None = None,
                 page_len: int | None = None,
                 num_pages: "int | Sequence[int] | None" = None,
                 prefill_chunk: int | None = None,
                 sampler: Callable | None = None,
                 margin: float = ROUTER_MARGIN,
                 migration: bool = True,
                 quarantine_ticks: int = QUARANTINE_TICKS,
                 mesh=None, shard_rules: dict | None = None,
                 tiers: "TierPlan | str | None" = None):
        if profiles is None:
            profiles = [None] * (replicas or 1)
        elif replicas is not None and replicas != len(profiles):
            raise ValueError(
                f"replicas={replicas} but {len(profiles)} profiles given")
        if not profiles:
            raise ValueError("a fleet needs at least one replica")
        if isinstance(num_pages, (list, tuple)):
            if len(num_pages) != len(profiles):
                raise ValueError(
                    f"{len(num_pages)} num_pages for {len(profiles)} "
                    "replicas")
            pools = list(num_pages)
        else:
            pools = [num_pages] * len(profiles)
        self.cfg = cfg
        self.margin = margin
        self.migration = migration
        # specs resolve BEFORE replicas exist: the "auto" tier plan ranks
        # them by measured bandwidth/latency (repro.serve.tiers)
        specs = [profile.resolve_spec(resolve_fleet_profile(p))
                 for p in profiles]
        self.tier_plan = tiering.resolve_tiers(tiers, len(profiles), specs)
        self.tiered = self.tier_plan.tiered
        self.replicas = [
            FleetReplica(i, cfg, params,
                         spec=specs[i],
                         max_slots=max_slots, max_len=max_len,
                         page_len=page_len, num_pages=pools[i],
                         prefill_chunk=prefill_chunk, sampler=sampler,
                         mesh=mesh, shard_rules=shard_rules,
                         prefill_tier=i in self.tier_plan.prefill,
                         decode_tier=i in self.tier_plan.decode)
            for i in range(len(profiles))]
        self.pending: deque[Request] = deque()
        self.decisions: list[RouteDecision] = []
        self.events: list[FaultEvent] = []
        self.injector = None           # attach_injector (repro.serve.faults)
        self.quarantine_ticks = quarantine_ticks
        self.lost: dict[int, Request] = {}
        self.ticks = 0
        self.migrations = 0
        self.rejected = 0
        self.handoffs = 0
        self.handoff_aborts = 0
        self._transit: list[_Transit] = []     # KV handoffs in flight
        self.deaths = 0
        self.quarantines = 0
        self.readmits = 0
        self.degrades = 0
        self._seqno = 0                # decisions + events share one order
        self._submitted: set[int] = set()
        self._cancelled: set[int] = set()
        self._homes: dict[int, set[int]] = {}   # uid -> replicas it ran on
        self._fault_hit: set[int] = set()       # uids evacuated by a fault

    # -- event log ----------------------------------------------------------

    def _next_seq(self) -> int:
        self._seqno += 1
        return self._seqno - 1

    def record_event(self, kind: str, replica: int,
                     detail: tuple = ()) -> FaultEvent:
        """Append a :class:`FaultEvent` to the fleet-global log (shared
        sequence with routing decisions, so replay compares the full
        interleaving)."""
        ev = FaultEvent(seq=self._next_seq(), tick=self.ticks, kind=kind,
                        replica=replica, detail=detail)
        self.events.append(ev)
        return ev

    # -- routing ------------------------------------------------------------

    def _route(self, req: Request, kind: str,
               exclude: frozenset[int] = frozenset(),
               src: "FleetReplica | None" = None,
               ) -> FleetReplica | None:
        """Score every dispatchable replica that can take ``req`` now;
        pick within the cost margin by (inflight overage, page headroom,
        index).  Quarantined and dead replicas are never candidates.

        ``kind`` selects the routing stage: ``admit``/``migrate`` place
        prefill work on prefill-tier replicas, ``handoff`` places decode
        work on decode-tier replicas (``src`` is then the exporting
        replica, whose measured bandwidth caps the transfer rate).  In a
        symmetric fleet every replica sits in both tiers and the filter
        is a no-op."""
        if kind == "handoff":
            assert src is not None
            tokens = len(req.prompt)
            n_bytes = tiering.handoff_bytes(
                self.cfg, len(src.engine.alloc.pages.get(req.uid, ())),
                src.engine.page_len)
            candidates = [r for r in self.replicas
                          if r.index not in exclude
                          and r.dispatchable
                          and r.decode_tier
                          and r.engine.can_import(tokens)]
            scores = {r.index: r.score(req, kind,
                                       handoff_s=tiering.handoff_seconds(
                                           n_bytes, src.spec, r.spec))
                      for r in candidates}
        else:
            candidates = [r for r in self.replicas
                          if r.index not in exclude
                          and r.dispatchable
                          and r.prefill_tier
                          and r.engine.can_accept(req)]
            scores = {r.index: r.score(req, kind) for r in candidates}
        if not candidates:
            return None
        best = min(s.step_cost_s for s in scores.values())
        cut = best * (1.0 + self.margin)
        scores = {i: dataclasses.replace(s, within_margin=s.step_cost_s <= cut)
                  for i, s in scores.items()}
        within = [r for r in candidates if scores[r.index].within_margin]
        chosen = min(within, key=lambda r: (scores[r.index].inflight_overage,
                                            -scores[r.index].free_pages_after,
                                            r.index))
        self.decisions.append(RouteDecision(
            seq=self._next_seq(), tick=self.ticks, uid=req.uid,
            kind=kind,
            scores=tuple(scores[i] for i in sorted(scores)),
            chosen=chosen.index))
        return chosen

    def _place(self, req: Request, replica: FleetReplica) -> None:
        self._homes.setdefault(req.uid, set()).add(replica.index)
        replica.engine.submit(req)

    def _dispatch(self) -> None:
        while self.pending:
            replica = self._route(self.pending[0], "admit")
            if replica is None:
                return                 # head-of-line blocks: FIFO fairness
            self._place(self.pending.popleft(), replica)

    def _migrate(self) -> None:
        """Re-route preempted requests stranded behind a saturated
        replica.  A request sitting in a replica's waiting queue after
        its tick is a preemption rollback (fresh dispatches were just
        admitted); if its home replica cannot re-admit it now but
        another can, move it — seniority is engine-local, so the mover
        re-enters the target's admission order at the back.  For a
        non-dispatchable (quarantined/dead) home the re-admission check
        is skipped entirely: failover re-homing rides the SAME machinery
        as preemption migration."""
        for r in self.replicas:
            eng = r.engine
            chunk_pages = eng.alloc.pages_for(eng.prefill_chunk)
            for pos, req in enumerate(list(eng.waiting)):
                if req.admit_seq < 0 and r.dispatchable:
                    continue
                # the home engine re-admits it next tick iff the replica
                # is serving AND a slot is free for its queue position
                # AND a chunk's worth of pages survived the preemption
                # scramble (can_accept would wrongly charge the request
                # against itself here)
                if (r.dispatchable
                        and pos < len(eng.free_slots)
                        and eng.alloc.free_pages >= chunk_pages):
                    continue
                target = self._route(req, "migrate",
                                     exclude=frozenset((r.index,)))
                if target is None:
                    continue
                eng.waiting.remove(req)
                req.admit_seq = -1
                self._place(req, target)
                self.migrations += 1

    # -- KV handoff (the tiered fleet's second routing stage) ---------------

    def _collect_handoffs(self) -> None:
        """Stage 2: route every request whose prefill just completed on a
        prefill-specialist replica to a decode-tier replica, export its
        pages (copy-free on the source) and put the transfer in flight.
        An unroutable request (decode tier saturated or down) simply
        stays ``ready`` — it holds its pages and retries next tick, so
        nothing is dropped and nothing decodes out of tier."""
        for r in self.replicas:
            if not (self.tiered and r.engine.hold_after_prefill
                    and r.dispatchable):
                continue
            for req in list(r.engine.ready):
                target = self._route(req, "handoff", src=r)
                if target is None:
                    continue
                chosen = next(s for s in self.decisions[-1].scores
                              if s.replica == target.index)
                ticks = tiering.handoff_ticks(
                    chosen.handoff_s, chosen.step_cost_s - chosen.handoff_s)
                req, payload = r.engine.export_pages(req.uid)
                # withhold the stream while the pages are in flight: the
                # first token only reaches the frontend after arrival,
                # so the transfer's ticks show up in TTFT
                held, req.generated = req.generated, []
                self._transit.append(_Transit(
                    req=req, payload=payload, src=r.index,
                    dst=target.index, arrive_tick=self.ticks + ticks,
                    held=held))
                self.handoffs += 1
                self.record_event(
                    "handoff", r.index,
                    (req.uid, target.index, payload["pages"], ticks))

    def _abort_handoff(self, t: _Transit, why: str) -> None:
        """Arrival failed (destination died/quarantined or its capacity
        evaporated): roll the request back to the fleet queue for a full
        re-prefill, exactly like a preemption rollback — greedy re-runs
        regenerate the withheld prefix, so the stream stays byte-stable."""
        t.req.generated = []
        t.req.prefill_pos = 0
        t.req.admit_seq = -1           # seniority is engine-local: reset
        self.pending.appendleft(t.req)
        self.handoff_aborts += 1
        self.record_event("handoff_abort", t.dst, (t.req.uid, why))

    def _arrive_handoffs(self) -> None:
        """Land every transfer whose transit time has elapsed: allocate
        on the destination, scatter the pages, release the withheld
        tokens.  A destination that was killed/quarantined mid-flight
        counts as a fault hit (the request classifies requeued/migrated,
        never silently completed)."""
        due = [t for t in self._transit if t.arrive_tick <= self.ticks]
        for t in due:
            self._transit.remove(t)
            dst = self.replicas[t.dst]
            if not dst.dispatchable:
                self._fault_hit.add(t.req.uid)
                self._abort_handoff(t, f"destination {dst.state}")
                continue
            t.req.generated = t.held
            if not dst.engine.import_pages(t.req, t.payload):
                self._abort_handoff(t, "destination out of capacity")

    # -- fault lifecycle (driven by repro.serve.faults, or directly) --------

    def attach_injector(self, injector) -> None:
        """Bind a :class:`repro.serve.faults.FaultInjector`: its due
        faults are applied at the START of every tick, and corruption
        detection runs right after (so corrupt books are quarantined
        before any dispatch or decode consumes them)."""
        self.injector = injector

    def kill(self, index: int, *, reason: str = "fault") -> list[Request]:
        """Replica death: evacuate every live request copy-free (ZERO
        leaked pages — asserted), leave the rollbacks in the dead
        replica's waiting queue for ``_migrate`` to re-home, and mark
        the replica permanently dead for this run."""
        r = self.replicas[index]
        if r.state == DEAD:
            return []
        moved = r.engine.evacuate()
        assert r.engine.alloc.allocated_pages == 0, \
            f"replica {index} leaked pages across death"
        self._fault_hit.update(q.uid for q in moved)
        r.state = DEAD
        self.deaths += 1
        self.record_event("kill", index,
                          (reason, len(moved), len(r.engine.waiting)))
        return moved

    def quarantine(self, index: int, *, ticks: int | None = None,
                   reason: str = "fault") -> list[Request]:
        """Corruption response: evacuate, rebuild the paging books from
        scratch (``reset_paging`` — clean by construction), and sit the
        replica out for ``ticks`` fleet ticks.  Stranded requests either
        migrate away (``_migrate`` skips the home-readmission check for
        a non-dispatchable home) or re-earn their place here after
        :meth:`readmit`."""
        r = self.replicas[index]
        if r.state in (DEAD, QUARANTINED):
            return []
        ticks = self.quarantine_ticks if ticks is None else ticks
        moved = r.engine.evacuate()
        r.engine.reset_paging()
        self._fault_hit.update(q.uid for q in moved)
        r.state = QUARANTINED
        r.quarantined_until = self.ticks + max(1, ticks)
        self.quarantines += 1
        self.record_event("quarantine", index,
                          (reason, len(moved), r.quarantined_until))
        return moved

    def readmit(self, index: int) -> None:
        """Quarantine over: the replica returns healthy, on its base
        spec (a degradation does not survive the heal)."""
        r = self.replicas[index]
        if r.state != QUARANTINED:
            return
        r.state = HEALTHY
        r.quarantined_until = -1
        r.rebind_spec(r.base_spec)
        self.readmits += 1
        self.record_event("readmit", index)

    def degrade(self, index: int, factor: float = 4.0) -> None:
        """Latency-spike a replica's profile: bandwidth and FLOPs divided
        by ``factor``, HBM latency multiplied by it.  Nothing but the
        PRICING changes — the router sees the spike through
        ``decode_cell_cost(...).step_s`` and organically drains load
        from the sick replica; tokens are never touched."""
        r = self.replicas[index]
        if not r.dispatchable:
            return
        spiked = dataclasses.replace(
            r.spec,
            peak_bf16_flops=r.spec.peak_bf16_flops / factor,
            hbm_bytes_per_s=r.spec.hbm_bytes_per_s / factor,
            hbm_latency_s=r.spec.hbm_latency_s * factor)
        r.rebind_spec(spiked)
        if r.state == HEALTHY:
            r.state = DEGRADED
        self.degrades += 1
        self.record_event("degrade", index, (round(factor, 6),))

    def recover(self, index: int) -> None:
        """Undo :meth:`degrade`: back to the base spec and healthy."""
        r = self.replicas[index]
        if r.state != DEGRADED:
            return
        r.rebind_spec(r.base_spec)
        r.state = HEALTHY
        self.record_event("recover", index)

    def _detect(self) -> None:
        """Poll every serving replica's integrity (allocator + page-table
        mirrors); a violation quarantines the replica before dispatch or
        decode can consume the corrupt books.  Only runs under an
        attached injector — outside fault campaigns a violated invariant
        must CRASH (it is a bug, not chaos)."""
        for r in self.replicas:
            if not r.dispatchable:
                continue
            bad = r.engine.integrity_violations()
            if bad:
                self.quarantine(r.index, reason=bad[0][:80])

    def _readmit_due(self) -> None:
        for r in self.replicas:
            if r.state == QUARANTINED and self.ticks >= r.quarantined_until:
                self.readmit(r.index)

    def _reap_lost(self) -> None:
        """Classify as LOST any request no non-dead replica can ever
        serve (capacity died with its replicas).  Quarantined capacity
        counts as coming back, so its work waits instead of dying.  In
        a tiered fleet a queued request needs a PREFILL-tier home, and a
        post-prefill request (ready or in transit) needs a decode-tier
        home — if that whole tier died, its work is reaped, pages
        released, nothing leaks."""
        alive = [r for r in self.replicas if r.state != DEAD]
        prefill_alive = [r for r in alive if r.prefill_tier]
        decode_alive = [r for r in alive if r.decode_tier]

        def doomed(req: Request) -> bool:
            return not any(a.engine.servable(req) for a in prefill_alive)

        for r in self.replicas:
            if r.state != DEAD:
                continue
            for req in [q for q in r.engine.waiting if doomed(q)]:
                r.engine.waiting.remove(req)
                self._lose(req, f"stranded on dead r{r.index}")
        for req in [q for q in self.pending if doomed(q)]:
            self.pending.remove(req)
            self._lose(req, "no capable replica left")
        if self.tiered and not decode_alive:
            for t in list(self._transit):
                self._transit.remove(t)
                self._lose(t.req, "decode tier died in flight")
            for r in self.replicas:
                if not r.dispatchable:
                    continue
                eng = r.engine
                for req in list(eng.ready):
                    eng.alloc.release(req.uid)
                    eng.page_tables[req.slot][:] = 0
                    eng.free_slots.append(req.slot)
                    eng.ready.remove(req)
                    req.slot = None
                    self._lose(req, "decode tier died")

    def _lose(self, req: Request, why: str) -> None:
        self.lost[req.uid] = req
        self.record_event("lost", -1, (req.uid, why))

    # -- public surface ------------------------------------------------------

    def submit(self, req: Request) -> None:
        alive = [r for r in self.replicas if r.state != DEAD]
        ok = any(r.engine.servable(req) for r in alive if r.prefill_tier)
        if ok and self.tiered and req.max_new_tokens > 1:
            # a decoding request also needs a decode-tier home it fits
            ok = any(r.engine.servable(req) for r in alive if r.decode_tier)
        if not ok:
            self.rejected += 1
            raise ValueError(
                f"request {req.uid} (prompt {len(req.prompt)} + "
                f"{req.max_new_tokens} new) fits no replica in the fleet")
        self._submitted.add(req.uid)
        self.pending.append(req)

    def cancel(self, uid: int) -> bool:
        for req in self.pending:
            if req.uid == uid:
                self.pending.remove(req)
                self._cancelled.add(uid)
                return True
        for t in self._transit:        # cancelled mid-handoff: the pages
            if t.req.uid == uid:       # are in flight, resident nowhere
                self._transit.remove(t)
                self._cancelled.add(uid)
                return True
        if any(r.engine.cancel(uid) for r in self.replicas):
            self._cancelled.add(uid)
            return True
        return False

    @property
    def saturated(self) -> bool:
        """Every SERVING replica is page/slot-saturated (non-dispatchable
        replicas count as saturated) — the backpressure signal the
        streaming front end surfaces to submitters."""
        return all(not r.dispatchable or r.engine.saturated
                   for r in self.replicas)

    def live(self) -> int:
        return (len(self.pending) + len(self._transit)
                + sum(r.engine.live_count() + len(r.engine.waiting)
                      for r in self.replicas))

    def step(self) -> int:
        """One fleet tick: inject due faults + detect corruption, lift
        due quarantines, land due KV handoffs, dispatch, tick every
        SERVING replica (index order), export newly-ready prefills to
        the decode tier, migrate stranded rollbacks, reap doomed
        requests.  Returns live requests.  With no injector, no faults
        and a symmetric tier plan every added stage is a no-op, so an
        N=1 or single-tier fleet still reproduces the single paged
        engine tick-for-tick."""
        if self.injector is not None:
            self.injector.on_tick(self)
            self._detect()
        self._readmit_due()
        if self._transit:
            self._arrive_handoffs()
        self._dispatch()
        for r in self.replicas:
            if r.dispatchable:
                r.engine.step()
        if self.tiered:
            self._collect_handoffs()
        if self.migration and len(self.replicas) > 1:
            self._migrate()
        if self.deaths:
            self._reap_lost()
        self.ticks += 1
        return self.live()

    def run_to_completion(self, max_ticks: int = 10_000) -> list[Request]:
        while self.live() and self.ticks < max_ticks:
            self.step()
        return self.finished()

    def finished(self) -> list[Request]:
        out = [q for r in self.replicas for q in r.engine.finished]
        return sorted(out, key=lambda q: q.uid)

    def check_invariants(self) -> None:
        """Fleet-wide invariants, cheap enough for every soak tick:
        every replica's engine/allocator books are clean, no uid is
        owned by two replicas, and no quarantined or dead replica holds
        live work (i.e. ever received a dispatch while down)."""
        owner: dict[int, int] = {}
        for r in self.replicas:
            r.engine.check_invariants()
            for req in list(r.engine.waiting) + r.engine._live():
                prev = owner.setdefault(req.uid, r.index)
                assert prev == r.index, \
                    f"uid {req.uid} owned by replicas r{prev} and r{r.index}"
            if not r.dispatchable:
                assert r.engine.live_count() == 0, \
                    f"{r.state} replica r{r.index} has live work"
                assert r.engine.alloc.allocated_pages == 0, \
                    f"{r.state} replica r{r.index} still holds pages"
        for req in self.pending:
            assert req.uid not in owner, \
                f"uid {req.uid} both pending and placed on r{owner[req.uid]}"
        assert not set(self.lost) & set(owner), "lost uid still owned"
        # tiered invariants: an in-flight handoff is resident NOWHERE (its
        # source freed the pages at export, the destination has not yet
        # allocated — a stream can never sit in two tiers' page tables),
        # and a prefill specialist never decodes
        for t in self._transit:
            assert t.req.uid not in owner, \
                f"in-transit uid {t.req.uid} still owned by a replica"
            holders = [r.index for r in self.replicas
                       if t.req.uid in r.engine.alloc.pages]
            assert not holders, \
                f"in-transit uid {t.req.uid} holds pages on {holders}"
        for r in self.replicas:
            if r.engine.hold_after_prefill:
                assert not r.engine.active, \
                    f"prefill specialist r{r.index} is decoding"

    def classify(self) -> dict[int, str]:
        """Terminal outcome class per submitted uid (``OUTCOME_CLASSES``):

        * ``completed`` — finished, never touched by a fault;
        * ``migrated`` — finished after running on more than one replica
          (failover re-homing or preemption migration);
        * ``requeued`` — finished on its home replica after a fault
          rolled it back (kill/quarantine evacuation);
        * ``cancelled`` — cancelled by the caller;
        * ``lost`` — everything else: reaped as unservable, or still
          unfinished when the campaign was classified.  Every uid ends
          in exactly one class — nothing is silently dropped.
        """
        finished = {q.uid for r in self.replicas for q in r.engine.finished}
        cancelled = self._cancelled | {
            q.uid for r in self.replicas for q in r.engine.cancelled}
        out: dict[int, str] = {}
        for uid in sorted(self._submitted):
            if uid in finished:
                if len(self._homes.get(uid, ())) > 1:
                    out[uid] = "migrated"
                elif uid in self._fault_hit:
                    out[uid] = "requeued"
                else:
                    out[uid] = "completed"
            elif uid in cancelled:
                out[uid] = "cancelled"
            else:
                out[uid] = "lost"
        return out

    def decision_log(self) -> list[tuple]:
        """Routing decisions AND fault events, merged on the shared
        fleet-global sequence — the replay artifact."""
        merged = ([d.key() for d in self.decisions]
                  + [e.key() for e in self.events])
        return sorted(merged, key=lambda k: k[0])

    def stats(self) -> dict:
        per = [r.stats() for r in self.replicas]
        return {
            "ticks": self.ticks,
            "replicas": len(self.replicas),
            "tiers": self.tier_plan.describe(),
            "tiered": self.tiered,
            "decisions": len(self.decisions),
            "migrations": self.migrations,
            "handoffs": self.handoffs,
            "handoff_aborts": self.handoff_aborts,
            "in_transit": len(self._transit),
            "rejected": self.rejected,
            "deaths": self.deaths,
            "quarantines": self.quarantines,
            "readmits": self.readmits,
            "degrades": self.degrades,
            "lost": len(self.lost),
            "fault_events": len(self.events),
            "margin_violations": len(self.margin_violations()),
            "states": tuple(r.state for r in self.replicas),
            "preemptions": sum(s["preemptions"] for s in per),
            "decoded_tokens": sum(s["decoded_tokens"] for s in per),
            "finished": sum(s["finished"] for s in per),
            "max_slack_tokens": max(s["max_slack_tokens"] for s in per),
            "peak_pages": sum(s["peak_pages"] for s in per),
            "pages_leaked": sum(r.engine.alloc.allocated_pages
                                for r in self.replicas),
            "per_replica": per,
        }

    def margin_violations(self) -> list[RouteDecision]:
        """Decisions that picked a replica beyond the margin of the best
        candidate — the router contract, audited from its own log."""
        out = []
        for d in self.decisions:
            best = min(s.step_cost_s for s in d.scores)
            chosen = next(s for s in d.scores if s.replica == d.chosen)
            if chosen.step_cost_s > best * (1.0 + self.margin) * (1 + 1e-12):
                out.append(d)
        return out
