"""Profile-aware multi-replica serving fleet.

:class:`FleetEngine` runs N :class:`~repro.serve.engine.PagedServeEngine`
replicas — each bound to its OWN resolved device profile, so mixed
GTX980 / TeslaV100 / tpu_v5e fleets are first-class — behind a router
that prices admission with the same measure-then-deploy machinery the
single-engine path already consumes:

* **step cost** (:meth:`~repro.core.costmodel.CellCost.step_s`): a fresh
  ``decode_cell_cost`` is priced against each candidate replica's spec
  for the load it would carry *after* admitting the request.  One
  CellCost per (replica, decision) keeps the pricing correctly scoped —
  a mixed fleet must never trip ``SpecMixWarning``, which exists to catch
  ONE plan straddling two profiles, not N plans each on their own.
* **free-page headroom**: among cost-equivalent replicas the router
  prefers the one with the most pages left after the request's first
  chunk — the fleet analogue of admission-by-free-pages.
* **Little's-law inflight bound**: a replica whose live sequence count
  already covers its latency-hiding quantum
  (``required_inflight_bytes / gather row``) gains nothing from more
  concurrency, so the router penalizes overage — the paper's occupancy
  law applied to request placement instead of warp placement.

Every decision is appended to a :class:`RouteDecision` log and the whole
scheduler is deterministic (no RNG, no wall clock, index tie-breaks), so
a fleet run REPLAYS bit-identically: the ``serve_fleet`` experiment gates
on it.  The router never chooses a replica whose predicted step cost
exceeds the best candidate's by more than its own ``margin`` — that
invariant is checked from the decision log, not trusted.

With one replica the fleet degenerates exactly to the single paged
engine: dispatch applies the engine's own admission predicate
(:meth:`~repro.serve.engine.PagedServeEngine.can_accept`), so the same
requests are admitted on the same ticks and the token stream is
request-for-request identical — the dense/paged single-engine path stays
the differential oracle.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Sequence

from repro.core import littles_law, profile
from repro.core.costmodel import ParallelismPlan, decode_cell_cost
from repro.core.devices import TpuSpec
from repro.models.config import ModelConfig
from repro.serve import paging
from repro.serve.engine import PagedServeEngine, Request

#: default routing margin: a replica within 10% of the cheapest predicted
#: step cost is cost-equivalent and competes on headroom instead
ROUTER_MARGIN = 0.10

_SINGLE_CHIP = ParallelismPlan(dp=1, tp=1, fsdp=False)


def resolve_fleet_profile(entry) -> "TpuSpec | None":
    """One replica-profile entry → the TpuSpec it is priced with.

    Accepts ``None`` (the process default), a :class:`TpuSpec`, a
    :class:`~repro.core.profile.DeviceProfile` (any kind — GPU profiles
    price through their measured :meth:`serving_spec` view), or a string:
    an artifact path / device name under ``experiments/profiles/`` if one
    exists, else the published profile for that registered device.
    """
    if entry is None or isinstance(entry, TpuSpec):
        return entry
    if isinstance(entry, profile.DeviceProfile):
        return entry.serving_spec()
    if isinstance(entry, str):
        import os

        from repro.profile import load_profile, path_for, published_profile
        if entry.endswith(".json"):
            return load_profile(entry).serving_spec()
        if os.path.exists(path_for(entry)):
            return load_profile(entry).serving_spec()
        return published_profile(entry).serving_spec()
    raise TypeError(f"cannot resolve fleet profile from {type(entry)!r}")


@dataclasses.dataclass(frozen=True)
class RouteScore:
    """One candidate replica's pricing at one decision point."""

    replica: int
    step_cost_s: float          # CellCost.step_s after admitting
    free_pages_after: int       # page headroom after the first chunk
    inflight_overage: int       # live+1 beyond the Little's-law bound
    within_margin: bool


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """One routing decision, replayable and auditable."""

    seq: int                    # decision counter (fleet-global)
    tick: int
    uid: int
    kind: str                   # "admit" | "migrate"
    scores: tuple[RouteScore, ...]
    chosen: int                 # replica index

    def key(self) -> tuple:
        """Compact identity for bit-identical replay comparison."""
        return (self.seq, self.tick, self.uid, self.kind, self.chosen,
                tuple((s.replica, round(s.step_cost_s, 15),
                       s.free_pages_after, s.inflight_overage)
                      for s in self.scores))


class FleetReplica:
    """One engine + the spec it is priced and page-sized with."""

    def __init__(self, index: int, cfg: ModelConfig, params, *,
                 spec: TpuSpec | None, max_slots: int, max_len: int,
                 page_len: int | None, num_pages: int | None,
                 prefill_chunk: int | None, sampler):
        self.index = index
        # resolve ONCE: every subsequent pricing of this replica uses the
        # same pinned spec object (never the mutable process default)
        self.spec = profile.resolve_spec(spec)
        self.engine = PagedServeEngine(
            cfg, params, max_slots=max_slots, max_len=max_len,
            page_len=page_len, num_pages=num_pages,
            prefill_chunk=prefill_chunk, sampler=sampler, spec=self.spec)
        self.cfg = cfg
        row_bytes = (self.engine.page_len
                     * max(1, paging.kv_bytes_per_token_layer(cfg)))
        # Little's law: sequences needed so their gather rows cover the
        # in-flight quantum; past this, concurrency adds latency not BW
        self.inflight_bound = max(1, round(
            littles_law.tpu_required_inflight_bytes(self.spec) / row_bytes))

    @property
    def name(self) -> str:
        return f"r{self.index}:{self.spec.name}"

    def score(self, req: Request) -> RouteScore:
        """Price admitting ``req`` onto this replica, against its OWN
        spec.  A fresh CellCost per call — pricing is scoped to one
        (replica, decision), which is why a mixed fleet never warns."""
        eng = self.engine
        live = eng.live_count() + len(eng.waiting)
        tokens = (eng.live_committed_tokens()
                  + sum(len(r.prompt) + r.max_new_tokens
                        for r in eng.waiting)
                  + len(req.prompt) + req.max_new_tokens)
        seq = max(1, tokens // (live + 1))
        cell = decode_cell_cost(self.cfg, global_batch=live + 1, seq=seq,
                                plan=_SINGLE_CHIP,
                                name=f"fleet/{self.name}")
        chunk_pages = eng.alloc.pages_for(eng.prefill_chunk)
        return RouteScore(
            replica=self.index,
            step_cost_s=cell.step_s(self.spec),
            free_pages_after=eng.alloc.free_pages - chunk_pages,
            inflight_overage=max(0, live + 1 - self.inflight_bound),
            within_margin=False)       # filled in by the router

    def stats(self) -> dict:
        s = self.engine.stats()
        s["replica"] = self.name
        s["spec"] = self.spec.name
        s["inflight_bound"] = self.inflight_bound
        return s


class FleetEngine:
    """N paged replicas behind the profile-aware router (module doc).

    ``profiles`` gives one entry per replica (see
    :func:`resolve_fleet_profile`); ``replicas`` alone builds a
    homogeneous fleet on the active profile.  ``num_pages`` may be a
    sequence (one pool size per replica) to model unequal HBM headroom.
    Requests enter a fleet-level FIFO and are dispatched head-of-line:
    the router either places ``pending[0]`` or leaves it queued until a
    replica frees capacity — FIFO admission is what makes an N=1 fleet
    reproduce the single engine's schedule exactly.
    """

    def __init__(self, cfg: ModelConfig, params, *,
                 max_slots: int, max_len: int,
                 replicas: int | None = None,
                 profiles: Sequence | None = None,
                 page_len: int | None = None,
                 num_pages: "int | Sequence[int] | None" = None,
                 prefill_chunk: int | None = None,
                 sampler: Callable | None = None,
                 margin: float = ROUTER_MARGIN,
                 migration: bool = True):
        if profiles is None:
            profiles = [None] * (replicas or 1)
        elif replicas is not None and replicas != len(profiles):
            raise ValueError(
                f"replicas={replicas} but {len(profiles)} profiles given")
        if not profiles:
            raise ValueError("a fleet needs at least one replica")
        if isinstance(num_pages, (list, tuple)):
            if len(num_pages) != len(profiles):
                raise ValueError(
                    f"{len(num_pages)} num_pages for {len(profiles)} "
                    "replicas")
            pools = list(num_pages)
        else:
            pools = [num_pages] * len(profiles)
        self.cfg = cfg
        self.margin = margin
        self.migration = migration
        self.replicas = [
            FleetReplica(i, cfg, params,
                         spec=resolve_fleet_profile(p),
                         max_slots=max_slots, max_len=max_len,
                         page_len=page_len, num_pages=pools[i],
                         prefill_chunk=prefill_chunk, sampler=sampler)
            for i, p in enumerate(profiles)]
        self.pending: deque[Request] = deque()
        self.decisions: list[RouteDecision] = []
        self.ticks = 0
        self.migrations = 0
        self.rejected = 0

    # -- routing ------------------------------------------------------------

    def _route(self, req: Request, kind: str,
               exclude: frozenset[int] = frozenset(),
               ) -> FleetReplica | None:
        """Score every replica that can accept ``req`` now; pick within
        the cost margin by (inflight overage, page headroom, index)."""
        candidates = [r for r in self.replicas
                      if r.index not in exclude
                      and r.engine.can_accept(req)]
        if not candidates:
            return None
        scores = {r.index: r.score(req) for r in candidates}
        best = min(s.step_cost_s for s in scores.values())
        cut = best * (1.0 + self.margin)
        scores = {i: dataclasses.replace(s, within_margin=s.step_cost_s <= cut)
                  for i, s in scores.items()}
        within = [r for r in candidates if scores[r.index].within_margin]
        chosen = min(within, key=lambda r: (scores[r.index].inflight_overage,
                                            -scores[r.index].free_pages_after,
                                            r.index))
        self.decisions.append(RouteDecision(
            seq=len(self.decisions), tick=self.ticks, uid=req.uid,
            kind=kind,
            scores=tuple(scores[i] for i in sorted(scores)),
            chosen=chosen.index))
        return chosen

    def _dispatch(self) -> None:
        while self.pending:
            replica = self._route(self.pending[0], "admit")
            if replica is None:
                return                 # head-of-line blocks: FIFO fairness
            replica.engine.submit(self.pending.popleft())

    def _migrate(self) -> None:
        """Re-route preempted requests stranded behind a saturated
        replica.  A request sitting in a replica's waiting queue after
        its tick is a preemption rollback (fresh dispatches were just
        admitted); if its home replica cannot re-admit it now but
        another can, move it — seniority is engine-local, so the mover
        re-enters the target's admission order at the back."""
        for r in self.replicas:
            eng = r.engine
            chunk_pages = eng.alloc.pages_for(eng.prefill_chunk)
            for pos, req in enumerate(list(eng.waiting)):
                if req.admit_seq < 0:
                    continue
                # the home engine re-admits it next tick iff a slot is
                # free for its queue position AND a chunk's worth of
                # pages survived the preemption scramble (can_accept
                # would wrongly charge the request against itself here)
                if (pos < len(eng.free_slots)
                        and eng.alloc.free_pages >= chunk_pages):
                    continue
                target = self._route(req, "migrate",
                                     exclude=frozenset((r.index,)))
                if target is None:
                    continue
                eng.waiting.remove(req)
                req.admit_seq = -1
                target.engine.submit(req)
                self.migrations += 1

    # -- public surface ------------------------------------------------------

    def submit(self, req: Request) -> None:
        if not any(r.engine.servable(req) for r in self.replicas):
            self.rejected += 1
            raise ValueError(
                f"request {req.uid} (prompt {len(req.prompt)} + "
                f"{req.max_new_tokens} new) fits no replica in the fleet")
        self.pending.append(req)

    def cancel(self, uid: int) -> bool:
        for req in self.pending:
            if req.uid == uid:
                self.pending.remove(req)
                return True
        return any(r.engine.cancel(uid) for r in self.replicas)

    @property
    def saturated(self) -> bool:
        """Every replica is page/slot-saturated — the backpressure signal
        the streaming front end surfaces to submitters."""
        return all(r.engine.saturated for r in self.replicas)

    def live(self) -> int:
        return (len(self.pending)
                + sum(r.engine.live_count() + len(r.engine.waiting)
                      for r in self.replicas))

    def step(self) -> int:
        """One fleet tick: dispatch, tick every replica (index order),
        then migrate stranded preemptions.  Returns live requests."""
        self._dispatch()
        for r in self.replicas:
            r.engine.step()
        if self.migration and len(self.replicas) > 1:
            self._migrate()
        self.ticks += 1
        return self.live()

    def run_to_completion(self, max_ticks: int = 10_000) -> list[Request]:
        while self.live() and self.ticks < max_ticks:
            self.step()
        return self.finished()

    def finished(self) -> list[Request]:
        out = [q for r in self.replicas for q in r.engine.finished]
        return sorted(out, key=lambda q: q.uid)

    def check_invariants(self) -> None:
        for r in self.replicas:
            r.engine.alloc.check_invariants()

    def decision_log(self) -> list[tuple]:
        return [d.key() for d in self.decisions]

    def stats(self) -> dict:
        per = [r.stats() for r in self.replicas]
        return {
            "ticks": self.ticks,
            "replicas": len(self.replicas),
            "decisions": len(self.decisions),
            "migrations": self.migrations,
            "rejected": self.rejected,
            "preemptions": sum(s["preemptions"] for s in per),
            "decoded_tokens": sum(s["decoded_tokens"] for s in per),
            "finished": sum(s["finished"] for s in per),
            "max_slack_tokens": max(s["max_slack_tokens"] for s in per),
            "peak_pages": sum(s["peak_pages"] for s in per),
            "pages_leaked": sum(r.engine.alloc.allocated_pages
                                for r in self.replicas),
            "per_replica": per,
        }

    def margin_violations(self) -> list[RouteDecision]:
        """Decisions that picked a replica beyond the margin of the best
        candidate — the router contract, audited from its own log."""
        out = []
        for d in self.decisions:
            best = min(s.step_cost_s for s in d.scores)
            chosen = next(s for s in d.scores if s.replica == d.chosen)
            if chosen.step_cost_s > best * (1.0 + self.margin) * (1 + 1e-12):
                out.append(d)
        return out
