from repro.serve.engine import (  # noqa: F401
    PagedServeEngine, Request, ServeEngine,
)
from repro.serve.paging import (  # noqa: F401
    OutOfPages, PageAllocator, choose_page_len, page_len_rationale,
)
