from repro.serve.engine import (  # noqa: F401
    PagedServeEngine, Request, ServeEngine,
)
from repro.serve.fleet import (  # noqa: F401
    FleetEngine, FleetReplica, RouteDecision, RouteScore,
    resolve_fleet_profile,
)
from repro.serve.frontend import (  # noqa: F401
    Backpressure, FleetFrontend, StreamHandle,
)
from repro.serve.paging import (  # noqa: F401
    OutOfPages, PageAllocator, choose_page_len, page_len_rationale,
)
