from repro.serve.engine import (  # noqa: F401
    PagedServeEngine, Request, ServeEngine,
)
from repro.serve.fleet import (  # noqa: F401
    FleetEngine, FleetReplica, RouteDecision, RouteScore,
    resolve_fleet_profile,
)
from repro.serve.frontend import (  # noqa: F401
    Backpressure, FleetFrontend, StreamHandle,
)
from repro.serve.paging import (  # noqa: F401
    OutOfPages, PageAllocator, choose_page_len, page_len_rationale,
)
from repro.serve.planner import (  # noqa: F401
    CapacityPlan, ReplicaModel, SLOTarget, characterize_replica,
    plan_capacity, plan_for_trace, rank_profiles,
)
from repro.serve.slo import (  # noqa: F401
    SLOReport, SLOTracker, percentile,
)
from repro.serve.workload import (  # noqa: F401
    ARRIVALS, SCENARIOS, Scenario, Trace, TraceRequest, WorkloadSpec,
    generate_trace, replay_trace,
)
