"""Per-request latency accounting in tick units: TTFT, TPOT, percentiles.

The serving stack's event loop is tick-deterministic (no wall clock, no
RNG — the replay guarantee every serve test pins), so latency accounting
must be too: :class:`SLOTracker` stamps request lifecycle events with the
fleet's *tick counter*, and every summary statistic below is an integer
or exact ratio of integers.  Two runs of the same seed produce
bit-identical SLO reports — which is what lets the ``serve_workload``
experiment gate on them.

Definitions (industry-standard, in ticks):

* **TTFT** (time to first token): ticks from :meth:`on_submit` to the
  tick the request's FIRST token was drained to its stream.  Queue wait
  and chunked prefill both land here — a request admitted instantly with
  a one-chunk prompt has TTFT 1 (submitted before the tick, token
  drained after it).
* **TPOT** (time per output token): mean ticks between subsequent
  tokens, ``(finish_tick - first_token_tick) / (tokens - 1)``; defined
  only for requests with ≥ 2 tokens.  In this simulator a request that
  decodes without interruption has TPOT exactly 1.0; preemption
  rollbacks and page stalls push it above 1.

Percentiles use the **nearest-rank** method (``ceil(q/100 · n)``-th of
the sorted values) — a value actually observed, no interpolation, and
therefore stable under replay comparison.

Tick units convert to seconds through the cost model, not a clock: one
decode tick is one batched decode step, so multiply by any replica's
``decode_cell_cost(...).step_s(spec)`` (:meth:`SLOReport.to_seconds`).
The same numbers priced against two different device profiles give the
dissect→deploy answer "what would THIS hardware's p99 look like" without
re-running anything.
"""

from __future__ import annotations

import dataclasses
import math

#: percentiles every summary reports (nearest-rank, deterministic)
PERCENTILES = (50, 99)

#: terminal outcome labels a tracker accepts (mirrors the frontend's
#: StreamHandle terminal states)
OUTCOMES = ("finished", "cancelled", "lost")


def percentile(values, q: float) -> float:
    """Nearest-rank percentile: the ``ceil(q/100 · n)``-th smallest value.

    Deterministic and interpolation-free — the result is always one of
    ``values`` (required for bit-identical replay comparison; numpy's
    default linear interpolation would return synthetic floats).
    """
    if not 0 < q <= 100:
        raise ValueError(f"percentile q must be in (0, 100], got {q}")
    vals = sorted(values)
    if not vals:
        raise ValueError("percentile of an empty sequence")
    rank = math.ceil(q / 100.0 * len(vals))
    return float(vals[rank - 1])


@dataclasses.dataclass
class RequestTiming:
    """One request's lifecycle timestamps, in fleet ticks."""

    uid: int
    submit_tick: int
    first_token_tick: int | None = None
    last_token_tick: int | None = None
    finish_tick: int | None = None
    tokens: int = 0
    outcome: str = "pending"

    @property
    def settled(self) -> bool:
        return self.outcome != "pending"

    @property
    def ttft_ticks(self) -> int | None:
        if self.first_token_tick is None:
            return None
        return self.first_token_tick - self.submit_tick

    @property
    def tpot_ticks(self) -> float | None:
        """Mean inter-token gap; None until a second token exists."""
        if self.tokens < 2 or self.last_token_tick is None:
            return None
        return ((self.last_token_tick - self.first_token_tick)
                / (self.tokens - 1))

    @property
    def residence_ticks(self) -> int | None:
        """Submit→settle span: the W in Little's law L = λ·W."""
        if self.finish_tick is None:
            return None
        return self.finish_tick - self.submit_tick


class SLOTracker:
    """Accumulates :class:`RequestTiming` rows from frontend callbacks.

    The :class:`~repro.serve.frontend.FleetFrontend` owns one and feeds
    it from ``submit``/``_drain_streams``/``cancel``; nothing here ticks
    a clock or draws randomness, so a tracker's summary is a pure
    function of the (seeded) run that produced it.
    """

    def __init__(self):
        self.timings: dict[int, RequestTiming] = {}

    # -- event surface (called by the frontend) -----------------------------

    def on_submit(self, uid: int, tick: int) -> None:
        if uid in self.timings:
            raise ValueError(f"uid {uid} already tracked")
        self.timings[uid] = RequestTiming(uid=uid, submit_tick=tick)

    def on_token(self, uid: int, tick: int) -> None:
        t = self.timings[uid]
        if t.first_token_tick is None:
            t.first_token_tick = tick
        t.last_token_tick = tick
        t.tokens += 1

    def on_finish(self, uid: int, tick: int, outcome: str) -> None:
        if outcome not in OUTCOMES:
            raise ValueError(f"unknown outcome {outcome!r}; "
                             f"expected one of {OUTCOMES}")
        t = self.timings[uid]
        if t.settled:
            raise ValueError(f"uid {uid} already settled ({t.outcome})")
        t.finish_tick = tick
        t.outcome = outcome

    # -- derived series ------------------------------------------------------

    def finished(self) -> list[RequestTiming]:
        return [t for t in self.timings.values() if t.outcome == "finished"]

    def ttfts(self) -> list[int]:
        return [t.ttft_ticks for t in self.finished()
                if t.ttft_ticks is not None]

    def tpots(self) -> list[float]:
        return [t.tpot_ticks for t in self.finished()
                if t.tpot_ticks is not None]

    def residences(self) -> list[int]:
        return [t.residence_ticks for t in self.finished()]

    def report(self) -> "SLOReport":
        """Fold the rows into a deterministic summary (tick units)."""
        counts = {o: 0 for o in OUTCOMES + ("pending",)}
        for t in self.timings.values():
            counts[t.outcome] += 1
        fin = self.finished()
        tokens = sum(t.tokens for t in fin)
        makespan = (max(t.finish_tick for t in fin)
                    - min(t.submit_tick for t in fin)) if fin else 0
        ttfts, tpots, res = self.ttfts(), self.tpots(), self.residences()

        def pcts(vals) -> dict[str, float]:
            if not vals:
                return {f"p{q}": float("nan") for q in PERCENTILES}
            return {f"p{q}": percentile(vals, q) for q in PERCENTILES}

        return SLOReport(
            requests=len(self.timings),
            outcome_counts=counts,
            tokens=tokens,
            makespan_ticks=makespan,
            ttft=pcts(ttfts),
            tpot=pcts(tpots),
            ttft_mean=(sum(ttfts) / len(ttfts)) if ttfts else float("nan"),
            tpot_mean=(sum(tpots) / len(tpots)) if tpots else float("nan"),
            mean_residence_ticks=(sum(res) / len(res)) if res
            else float("nan"),
            # Little's law as an accounting identity: time-averaged live
            # requests over the makespan — λ·W with λ = n/makespan and
            # W = Σ residence / n, so it holds EXACTLY by construction;
            # the planner's claim is predicting W, validated against this
            mean_concurrency=(sum(res) / makespan) if makespan
            else float("nan"),
        )


@dataclasses.dataclass(frozen=True)
class SLOReport:
    """One run's latency summary — every field deterministic, tick units."""

    requests: int
    outcome_counts: dict[str, int]
    tokens: int
    makespan_ticks: int
    ttft: dict[str, float]             # {"p50": ..., "p99": ...}
    tpot: dict[str, float]
    ttft_mean: float
    tpot_mean: float
    mean_residence_ticks: float
    mean_concurrency: float            # Σ residence / makespan (= λ·W)

    def key(self) -> tuple:
        """Compact identity for bit-identical replay comparison (NaNs
        compare unequal, so empty-series fields are stringified)."""
        return (self.requests, tuple(sorted(self.outcome_counts.items())),
                self.tokens, self.makespan_ticks,
                tuple(sorted(self.ttft.items())),
                tuple(sorted(self.tpot.items())),
                repr(self.ttft_mean), repr(self.tpot_mean),
                repr(self.mean_residence_ticks),
                repr(self.mean_concurrency))

    def to_seconds(self, step_s: float) -> dict[str, float]:
        """Price the tick-unit stats on a device: one tick = one batched
        decode step = ``decode_cell_cost(...).step_s(spec)`` seconds."""
        out = {"step_s": step_s,
               "makespan_s": self.makespan_ticks * step_s,
               "ttft_mean_s": self.ttft_mean * step_s,
               "tpot_mean_s": self.tpot_mean * step_s}
        out.update({f"ttft_{k}_s": v * step_s for k, v in self.ttft.items()})
        out.update({f"tpot_{k}_s": v * step_s for k, v in self.tpot.items()})
        if self.makespan_ticks:
            out["tokens_per_s"] = self.tokens / (self.makespan_ticks * step_s)
        return out

    def lines(self) -> list[str]:
        """Human-readable block (the launcher prints it)."""
        c = self.outcome_counts
        return [
            f"requests={self.requests} "
            f"(finished={c['finished']} cancelled={c['cancelled']} "
            f"lost={c['lost']} pending={c['pending']}), "
            f"tokens={self.tokens} over {self.makespan_ticks} ticks",
            f"TTFT ticks: p50={self.ttft['p50']:g} p99={self.ttft['p99']:g} "
            f"mean={self.ttft_mean:.2f}",
            f"TPOT ticks: p50={self.tpot['p50']:g} p99={self.tpot['p99']:g} "
            f"mean={self.tpot_mean:.3f}",
            f"mean residence={self.mean_residence_ticks:.1f} ticks, "
            f"mean concurrency={self.mean_concurrency:.2f} "
            "(= arrival rate x residence; Little's law)",
        ]
