"""Streaming front end for the serving fleet.

One *deterministic* event loop drives everything: each
:meth:`FleetFrontend.tick` runs one fleet step and then drains newly
produced tokens to per-request callbacks in uid order.  Determinism is
the design constraint, not a convenience — the N=1 fleet must reproduce
the single paged engine's token stream request-for-request (the
differential-oracle contract ``tests/test_serve_fleet.py`` pins), and a
wall-clock scheduler (asyncio timers, threads) would make routing and
stream interleaving replay-dependent.  Callers who want asynchrony wrap
``run()`` in their own executor; the loop itself never sleeps, never
polls a clock, and never consumes randomness.

Streaming across preemption: a preempted (or migrated) request is rolled
back and deterministically re-run, so its ``generated`` list is rebuilt
from scratch — the handle therefore only emits tokens *beyond* what it
has already streamed.  Greedy re-runs regenerate an identical prefix, so
the subscriber sees one continuous, replayable stream regardless of how
many times the scheduler rolled the request back.

Backpressure: the frontend bounds its submission queue.  When every
replica is page-saturated the fleet stops draining, the bound is hit and
:meth:`submit` raises :class:`Backpressure` instead of queueing unbounded
work — the caller's signal to shed load or retry after progress.

Latency accounting: the frontend owns an :class:`~repro.serve.slo
.SLOTracker` and stamps every lifecycle event with the fleet's tick
counter — submission at :meth:`submit`, first token and per-token
progress in :meth:`_drain_streams`, terminal outcomes wherever they
settle.  TTFT/TPOT therefore come out in *tick units* (deterministic,
replayable), convertible to seconds with any replica's
``decode_cell_cost(...).step_s`` — see ``repro.serve.slo``.

Failover: streams survive replica death and quarantine with no frontend
machinery of their own — an evacuated request is rolled back exactly
like a preempted one, so the handle silently re-earns its streamed
prefix and continues byte-stably once the request is re-homed.  The one
genuinely new terminal state is **lost**: when the fleet reaps a request
no surviving replica can ever serve, the handle is flagged ``lost``
(``on_finish`` fires, ``done`` stays False) so no submitter waits
forever on capacity that died.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.serve.engine import Request
from repro.serve.fleet import FleetEngine
from repro.serve.slo import SLOTracker


class Backpressure(RuntimeError):
    """The fleet queue is full (every replica page-saturated); retry
    after ticks have freed capacity."""


@dataclasses.dataclass
class StreamHandle:
    """A submitted request plus its streaming state."""

    uid: int
    request: Request
    on_token: Callable[[int, int], None] | None = None   # (uid, token)
    on_finish: Callable[["StreamHandle"], None] | None = None
    tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    cancelled: bool = False
    lost: bool = False                 # reaped by the fleet: capacity died

    @property
    def streamed(self) -> int:
        return len(self.tokens)

    @property
    def settled(self) -> bool:
        """Terminal: finished, cancelled, or lost — no more tokens."""
        return self.done or self.cancelled or self.lost


class FleetFrontend:
    """Deterministic request queue + token streamer over a FleetEngine.

    ``max_pending`` bounds the fleet-level FIFO (default: twice the
    fleet's total slots — enough to keep every replica busy through a
    full drain without ever queueing unbounded work).
    """

    def __init__(self, fleet: FleetEngine, *, max_pending: int | None = None):
        self.fleet = fleet
        total_slots = sum(r.engine.max_slots for r in fleet.replicas)
        if max_pending is None:
            max_pending = 2 * total_slots
        if max_pending <= 0:
            raise ValueError(
                f"max_pending must be positive, got {max_pending}; a "
                "non-positive bound would reject every submission")
        self.max_pending = max_pending
        self.handles: dict[int, StreamHandle] = {}
        self.slo = SLOTracker()
        self._next_uid = 0

    # -- submission ---------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int, *,
               on_token=None, on_finish=None,
               uid: int | None = None,
               arrival_tick: int | None = None) -> StreamHandle:
        """Queue a request; raises :class:`Backpressure` at the bound.

        ``arrival_tick`` backdates the SLO clock for callers (the trace
        driver) who retried through backpressure: TTFT then counts from
        when the request WANTED to arrive, not when the queue finally
        took it.  Defaults to the current fleet tick."""
        if len(self.fleet.pending) >= self.max_pending:
            raise Backpressure(
                f"fleet queue at its bound ({self.max_pending}); "
                f"saturated={self.fleet.saturated}")
        if uid is None:
            uid = self._next_uid
        if uid in self.handles:
            raise ValueError(f"uid {uid} already submitted")
        req = Request(uid, np.asarray(prompt, dtype=np.int32),
                      max_new_tokens)
        self.fleet.submit(req)          # may raise ValueError: unservable
        # bookkeeping only after the fleet accepted the request — a
        # rejected submission must not burn a uid or leave a handle
        self._next_uid = max(self._next_uid, uid) + 1
        handle = StreamHandle(uid, req, on_token, on_finish)
        self.handles[uid] = handle
        self.slo.on_submit(uid, self.fleet.ticks if arrival_tick is None
                           else arrival_tick)
        return handle

    def submit_blocking(self, prompt, max_new_tokens: int, *,
                        max_ticks: int = 10_000,
                        **kw) -> StreamHandle:
        """:meth:`submit`, but ride out backpressure by ticking the loop
        until the queue drains (every submitted request eventually
        finishes, so progress is guaranteed for servable work).  The one
        retry policy shared by the launcher, example and benchmark."""
        for _ in range(max_ticks):
            try:
                return self.submit(prompt, max_new_tokens, **kw)
            except Backpressure:
                self.tick()
        raise Backpressure(
            f"queue did not drain within {max_ticks} ticks")

    def cancel(self, uid: int) -> bool:
        """Abort a request wherever it lives; fires ``on_finish``.

        Guarded on ``settled``, not just done/cancelled: a LOST handle
        already fired its ``on_finish`` and may still be cancellable at
        the fleet level (its request can sit re-queued on a dead
        replica) — re-entering here would double-fire the callback."""
        handle = self.handles.get(uid)
        if handle is None or handle.settled:
            return False
        if not self.fleet.cancel(uid):
            return False
        handle.cancelled = True
        self.slo.on_finish(uid, self.fleet.ticks, "cancelled")
        if handle.on_finish:
            handle.on_finish(handle)
        return True

    @property
    def backpressure(self) -> bool:
        return (len(self.fleet.pending) >= self.max_pending
                or self.fleet.saturated)

    # -- the event loop -----------------------------------------------------

    def _drain_streams(self) -> int:
        """Emit tokens produced since the last drain, in uid order.
        Rolled-back requests re-earn their prefix silently (module doc)."""
        emitted = 0
        finished = {r.uid: r for r in self.fleet.finished()}
        for uid in sorted(self.handles):
            h = self.handles[uid]
            if h.settled:
                continue
            gen = h.request.generated
            while len(gen) > h.streamed:
                tok = gen[h.streamed]
                h.tokens.append(tok)
                emitted += 1
                self.slo.on_token(uid, self.fleet.ticks)
                if h.on_token:
                    h.on_token(uid, tok)
            if uid in finished:
                h.done = True
                self.slo.on_finish(uid, self.fleet.ticks, "finished")
                if h.on_finish:
                    h.on_finish(h)
            elif uid in self.fleet.lost:
                h.lost = True          # capacity died under this request
                self.slo.on_finish(uid, self.fleet.ticks, "lost")
                if h.on_finish:
                    h.on_finish(h)
        return emitted

    def tick(self) -> int:
        """One event-loop turn: fleet step + stream drain.  Returns the
        number of live (unsettled) handles."""
        self.fleet.step()
        self._drain_streams()
        return sum(1 for h in self.handles.values() if not h.settled)

    def run(self, max_ticks: int = 10_000) -> list[StreamHandle]:
        """Drive the loop until every handle finished or was cancelled."""
        while self.tick() and self.fleet.ticks < max_ticks:
            pass
        return [self.handles[uid] for uid in sorted(self.handles)]
