"""Disaggregated prefill/decode tier assignment + KV-handoff pricing.

The paper's P1–P6 latency spectrum and Table-7 bandwidths parameterize
exactly the split modern serving exploits: **prefill** is bandwidth/
FLOP-bound (one long chunked pass over the prompt, activation + cache
*writes* dominating), **decode** is latency/Little's-law-bound (one
token per tick, the whole live cache re-read every step).  A
heterogeneous fleet should therefore play to type — route prefill to
bandwidth-rich replicas, decode to low-latency ones — instead of taking
whole requests symmetrically.

This module is the pure-policy half of that split; ``repro.serve.fleet``
consumes it:

* :class:`TierPlan` — which replica indices may take prefill placements
  and which may take decode placements.  A replica may sit in both
  tiers; when *every* replica does, the plan is *symmetric* and the
  fleet degenerates bit-for-bit to today's single-stage router (the
  oracle-chain link ``tests/test_serve_tiers.py`` pins).
* :func:`parse_tiers` — the ``--fleet-tiers prefill:0,1/decode:2,3``
  CLI grammar.
* :func:`auto_tiers` — rank replicas by their *measured* profile: high
  global-memory bandwidth (the Volta dissection's Table-7 quantity,
  carried as ``serving_spec().hbm_bytes_per_s``) pulls a replica toward
  the prefill tier, low P4 DRAM latency (``hbm_latency_s``) toward the
  decode tier.
* :func:`handoff_bytes` / :func:`handoff_seconds` /
  :func:`handoff_ticks` — the KV handoff between tiers modeled as a
  paged-page transfer: whole source pages move at ``min(src, dst)``
  measured global-memory bandwidth (the slower endpoint gates the
  wire), plus one worst-endpoint DRAM round trip to start the burst.
  The tick cost quantizes that against the destination's own decode
  step so handoff latency lands in the fleet's tick clock — and
  therefore in TTFT — instead of vanishing between tiers.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.serve import paging


@dataclasses.dataclass(frozen=True)
class TierPlan:
    """Replica indices eligible for each routing stage.

    ``prefill`` receives fresh admissions and re-prefill migrations
    (stage 1); ``decode`` receives post-prefill handoffs (stage 2).
    Both tuples are sorted, non-empty, and may overlap — a replica in
    both tiers serves whole requests exactly as the symmetric fleet
    does.
    """

    prefill: tuple[int, ...]
    decode: tuple[int, ...]

    def __post_init__(self):
        object.__setattr__(self, "prefill", tuple(sorted(self.prefill)))
        object.__setattr__(self, "decode", tuple(sorted(self.decode)))
        if not self.prefill or not self.decode:
            raise ValueError(f"both tiers need at least one replica: {self}")

    @property
    def tiered(self) -> bool:
        """True when any replica is specialized — i.e. the plan is NOT
        the symmetric fleet.  A symmetric plan must degenerate to the
        single-stage router bit-for-bit."""
        return set(self.prefill) != set(self.decode)

    def validate(self, n_replicas: int) -> "TierPlan":
        members = set(self.prefill) | set(self.decode)
        bad = [i for i in members if not 0 <= i < n_replicas]
        if bad:
            raise ValueError(
                f"tier plan names replicas {sorted(bad)} but the fleet "
                f"has {n_replicas}")
        orphans = set(range(n_replicas)) - members
        if orphans:
            raise ValueError(
                f"replicas {sorted(orphans)} belong to no tier")
        return self

    def describe(self) -> str:
        return (f"prefill:{','.join(map(str, self.prefill))}"
                f"/decode:{','.join(map(str, self.decode))}")


def symmetric(n_replicas: int) -> TierPlan:
    """Every replica in both tiers — today's fleet, spelled as a plan."""
    allr = tuple(range(n_replicas))
    return TierPlan(prefill=allr, decode=allr)


def parse_tiers(text: str, n_replicas: int) -> TierPlan:
    """Parse ``prefill:0,1/decode:2,3`` (either order; ``auto`` and
    ``none`` are resolved by the caller, not here)."""
    parts: dict[str, tuple[int, ...]] = {}
    for field in text.strip().split("/"):
        if ":" not in field:
            raise ValueError(
                f"bad tier field {field!r} in {text!r} "
                "(want prefill:IDX,.../decode:IDX,...)")
        name, _, idxs = field.partition(":")
        name = name.strip().lower()
        if name not in ("prefill", "decode"):
            raise ValueError(f"unknown tier {name!r} in {text!r}")
        if name in parts:
            raise ValueError(f"tier {name!r} given twice in {text!r}")
        try:
            parts[name] = tuple(int(t) for t in idxs.split(",") if t.strip())
        except ValueError as e:
            raise ValueError(f"bad replica index in {text!r}") from e
    if set(parts) != {"prefill", "decode"}:
        raise ValueError(f"{text!r} must name both tiers")
    return TierPlan(prefill=parts["prefill"],
                    decode=parts["decode"]).validate(n_replicas)


def auto_tiers(specs: Sequence) -> TierPlan:
    """Assign tiers from the measured profile, deterministically.

    Each replica gets a *prefill affinity* (its global-memory bandwidth
    normalized to the fleet's best — Table-7's quantity) and a *decode
    affinity* (the fleet's best P4 DRAM latency normalized to its own).
    Replicas are ranked by ``prefill_affinity - decode_affinity``
    (bandwidth-rich first, ties broken by index) and the top half takes
    the prefill tier.  A one-replica fleet stays symmetric — there is
    nothing to specialize.
    """
    n = len(specs)
    if n < 2:
        return symmetric(n)
    bw = [float(s.hbm_bytes_per_s) for s in specs]
    lat = [float(s.hbm_latency_s) for s in specs]
    best_bw, best_lat = max(bw), min(lat)
    edge = [(bw[i] / best_bw) - (best_lat / lat[i]) for i in range(n)]
    ranked = sorted(range(n), key=lambda i: (-edge[i], i))
    n_prefill = -(-n // 2)                      # ceil: prefill gets the tie
    return TierPlan(prefill=tuple(ranked[:n_prefill]),
                    decode=tuple(ranked[n_prefill:])).validate(n)


def resolve_tiers(tiers, n_replicas: int, specs: Sequence) -> TierPlan:
    """One front door for everything the fleet/CLI accepts: ``None``
    (symmetric), ``"auto"`` (profile-ranked), a grammar string, or a
    prebuilt :class:`TierPlan`."""
    if tiers is None:
        return symmetric(n_replicas)
    if isinstance(tiers, TierPlan):
        return tiers.validate(n_replicas)
    if isinstance(tiers, str):
        text = tiers.strip().lower()
        if text in ("", "none", "symmetric"):
            return symmetric(n_replicas)
        if text == "auto":
            return auto_tiers(specs)
        return parse_tiers(tiers, n_replicas)
    raise TypeError(f"cannot resolve a tier plan from {type(tiers)!r}")


# -- handoff pricing ---------------------------------------------------------


def handoff_bytes(cfg, n_pages: int, page_len: int) -> int:
    """Bytes a KV handoff moves: WHOLE source pages (the transfer unit
    is the page, exactly like the gather row), not just the stored
    tokens — chunk-padding slack rides along."""
    return n_pages * page_len * paging.kv_bytes_per_token(cfg)


def handoff_seconds(n_bytes: int, src_spec, dst_spec) -> float:
    """Paged-page transfer time: the payload at ``min(src, dst)``
    measured global-memory bandwidth (both endpoints touch every byte;
    the slower one gates the wire) plus one worst-endpoint DRAM round
    trip to launch the burst (the paper's P4 quantity)."""
    bw = min(float(src_spec.hbm_bytes_per_s),
             float(dst_spec.hbm_bytes_per_s))
    lat = max(float(src_spec.hbm_latency_s), float(dst_spec.hbm_latency_s))
    return n_bytes / bw + lat


def handoff_ticks(handoff_s: float, dst_step_s: float) -> int:
    """Quantize a handoff against the DESTINATION's decode step: the
    ticks its batch turns over while the pages are in flight.  Never
    zero — a handoff that cost nothing would vanish from TTFT, and the
    whole point of pricing it is that it cannot."""
    return max(1, math.ceil(handoff_s / max(dst_step_s, 1e-12)))
