"""Continuous-batching serving engines: dense slots and paged KV cache.

:class:`ServeEngine` is the original vLLM-style *dense-slot* engine: a
fixed pool of ``max_slots`` cache slots, each reserving ``max_len`` worth
of HBM; requests are admitted into free slots (whole-prompt prefill at
batch 1), every engine tick runs ONE batched decode step for all active
slots at their own positions.  It stays as the differential ORACLE for
the paged engine — token-for-token greedy equality is a tier-1 test.

:class:`PagedServeEngine` replaces the dense block with the paged cache
from ``repro.serve.paging``: attention K/V live in fixed-size pages handed
out on demand, prompts are admitted in page-sized *chunks* interleaved
with decode ticks (no more batch-1 monopoly ticks), admission is gated by
free-page count, and HBM held per request tracks the tokens it has
actually produced to within one page.  Page length is derived from the
paper's laws (Little's law + bank-conflict row model) by
``paging.choose_page_len``, not hard-coded.

Shared design notes
* inactive slots decode garbage that is masked out by the per-slot valid
  mask; their tokens are pinned to 0 — wasted flops are bounded by
  (free/active) ratio, the standard continuous-batching trade.  In the
  paged engine their page-table rows point at the reserved scratch page,
  so garbage writes cannot touch live pages;
* greedy sampling (argmax) keeps the engines deterministic for tests; a
  temperature hook is provided;
* when the free list runs dry mid-decode the paged engine preempts the
  youngest request (pages freed copy-free, request re-queued for a full
  deterministic re-run), so the oldest request always makes progress.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.parallel import sharding
from repro.serve import paging
from repro.serve.paging import OutOfPages, PageAllocator

#: rule overrides for a serving mesh: ONLY the paged pool shards (KV
#: heads on "model"; pages replicated unless a caller overrides
#: "cache_pages" to "data").  Every activation rule is neutralized so
#: all compute runs on width-invariant replicated operands — mesh
#: sharding here buys pool HBM capacity and per-shard gather bandwidth
#: while token streams stay bit-identical across mesh widths (the
#: oracle chain the sharded tests pin).
MESH_SERVE_RULES: dict = {k: None for k in sharding.DEFAULT_RULES}
MESH_SERVE_RULES["cache_kv_heads"] = "model"

#: cache-leaf names that live in the shared (num_pages, page_len, ...)
#: pool; everything else (SSM conv/state) is slot-resident.  The KV
#: handoff (export_pages/import_pages) repacks paged leaves token-major
#: so source and destination may disagree on page_len.
_PAGED_LEAVES = frozenset({"k", "v", "c_kv", "k_rope"})


def _leaf_name(path) -> str:
    entry = path[-1]
    return entry.key if hasattr(entry, "key") else str(entry)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int
    generated: list[int] = dataclasses.field(default_factory=list)
    slot: int | None = None
    prefill_pos: int = 0               # chunked prefill progress (paged)
    admit_seq: int = -1                # admission order (preemption victim)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_slots: int,
                 max_len: int,
                 sampler: Callable[[jax.Array], jax.Array] | None = None):
        if cfg.is_encoder:
            raise ValueError("encoder-only model has no decode path")
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.cache = T.init_cache(cfg, max_slots, max_len)
        self.free: deque[int] = deque(range(max_slots))
        self.active: dict[int, Request] = {}       # slot -> request
        self.waiting: deque[Request] = deque()
        self.finished: list[Request] = []
        # per-slot position of the NEXT token to be written
        self.positions = np.zeros(max_slots, dtype=np.int32)
        self.last_tokens = np.zeros(max_slots, dtype=np.int32)
        self.sampler = sampler or (lambda logits: jnp.argmax(logits, -1))
        self.steps = 0
        self.decoded_tokens = 0

        self._prefill = jax.jit(
            lambda p, toks: T.prefill(p, cfg, {"tokens": toks},
                                      max_len=max_len))
        self._decode = jax.jit(
            lambda p, c, t, idx: T.decode(p, cfg, c, t, idx),
            donate_argnums=1)

    # -- queue management ---------------------------------------------------

    def submit(self, req: Request) -> None:
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError("request exceeds max_len")
        self.waiting.append(req)

    def _admit(self) -> None:
        while self.waiting and self.free:
            req = self.waiting.popleft()
            slot = self.free.popleft()
            req.slot = slot
            logits, pcache = self._prefill(
                self.params, jnp.asarray(req.prompt[None, :], jnp.int32))
            # scatter the prefilled slot into the batched cache (axis 1 is
            # the slot/batch axis for every cache leaf)
            self.cache = jax.tree.map(
                lambda c, p: c.at[:, slot].set(p[:, 0].astype(c.dtype)),
                self.cache, pcache)
            tok = int(np.asarray(self.sampler(logits[0, -1])))
            req.generated.append(tok)
            self.last_tokens[slot] = tok
            self.positions[slot] = len(req.prompt)
            self.active[slot] = req
            self._maybe_finish(slot)

    def _maybe_finish(self, slot: int) -> None:
        req = self.active.get(slot)
        if req is not None and req.done:
            del self.active[slot]
            self.free.append(slot)
            self.finished.append(req)

    # -- the engine tick ------------------------------------------------------

    def step(self) -> int:
        """Admit + one batched decode step.  Returns #active slots."""
        self._admit()
        if not self.active:
            return 0
        toks = jnp.asarray(self.last_tokens[:, None], jnp.int32)
        idx = jnp.asarray(self.positions, jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, toks, idx)
        sampled = np.asarray(self.sampler(logits[:, 0]))
        for slot, req in list(self.active.items()):
            tok = int(sampled[slot])
            req.generated.append(tok)
            self.last_tokens[slot] = tok
            self.positions[slot] += 1
            self.decoded_tokens += 1
            self._maybe_finish(slot)
        self.steps += 1
        return len(self.active)

    def run_to_completion(self, max_steps: int = 10_000) -> list[Request]:
        while (self.waiting or self.active) and self.steps < max_steps:
            self.step()
            if not self.active and self.waiting:
                # all slots drained but work remains: admit next tick
                continue
        return sorted(self.finished, key=lambda r: r.uid)

    def stats(self) -> dict:
        return {"steps": self.steps, "decoded_tokens": self.decoded_tokens,
                "finished": len(self.finished),
                "avg_batch_occupancy":
                    self.decoded_tokens / max(1, self.steps) / self.max_slots}

    def hbm_reserved_bytes(self) -> int:
        """Attention-cache HBM the dense engine reserves, occupancy-blind."""
        return (self.max_slots * self.max_len
                * paging.kv_bytes_per_token(self.cfg))


# ---------------------------------------------------------------------------
# paged engine
# ---------------------------------------------------------------------------


class PagedServeEngine:
    """Continuous batching over a paged KV cache (see module docstring).

    ``page_len`` defaults to ``paging.choose_page_len`` — sized by the
    repo's own cost model, not a magic number.  ``num_pages`` defaults to
    dense-equivalent capacity (every slot can reach ``max_len``); size it
    by the real workload to realize the HBM savings.  ``prefill_chunk``
    (a multiple of ``page_len``; default one page) bounds how much of a
    tick a long prompt can monopolize — and also bounds per-request page
    slack, so keep it one page where admission latency doesn't matter.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_slots: int,
                 max_len: int, page_len: int | None = None,
                 num_pages: int | None = None,
                 prefill_chunk: int | None = None,
                 sampler: Callable[[jax.Array], jax.Array] | None = None,
                 spec=None, mesh=None, shard_rules: dict | None = None,
                 hold_after_prefill: bool = False):
        if cfg.is_encoder:
            raise ValueError("encoder-only model has no decode path")
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        # `mesh` shards the paged pool leaves across devices (heads on
        # "model" via MESH_SERVE_RULES + shard_rules overrides); the
        # allocator and page tables below stay host-side and unchanged
        self.mesh = mesh
        if mesh is not None:
            rules = dict(MESH_SERVE_RULES)
            rules.update(shard_rules or {})
            self._shard_ctx = sharding.ShardingCtx(mesh, rules)
        else:
            self._shard_ctx = None
        self.shards = paging.gather_shards(cfg, self._shard_ctx)
        # `spec` may be a dissected DeviceProfile (launcher --profile) —
        # page sizing then follows measured parameters, not constants;
        # under a mesh the gather term prices each shard's OWN partition
        # bandwidth against its 1/shards-thin rows
        self.page_len = page_len or paging.choose_page_len(
            cfg, spec=spec, expected_tokens=max_len, shards=self.shards)
        self.prefill_chunk = prefill_chunk or self.page_len
        if self.prefill_chunk % self.page_len:
            raise ValueError(
                f"prefill_chunk {self.prefill_chunk} must be a multiple of "
                f"page_len {self.page_len}")
        # page-table rows must cover the CHUNK-PADDED prefill frontier: a
        # prompt of max_len-1 tokens pads its last chunk past max_len when
        # prefill_chunk does not divide max_len
        frontier = -(-max_len // self.prefill_chunk) * self.prefill_chunk
        self.pages_per_seq = -(-frontier // self.page_len)
        if num_pages is None:
            num_pages = max_slots * self.pages_per_seq + paging.SCRATCH_PAGES
        self.alloc = PageAllocator(num_pages, self.page_len)
        self.cache = T.init_paged_cache(cfg, num_pages, self.page_len,
                                        max_slots, mesh=self._shard_ctx)
        self.page_tables = np.zeros((max_slots, self.pages_per_seq),
                                    dtype=np.int32)
        self.free_slots: deque[int] = deque(range(max_slots))
        self.waiting: deque[Request] = deque()
        self.prefilling: deque[Request] = deque()
        self.active: dict[int, Request] = {}       # slot -> decoding request
        # hold_after_prefill parks a request here the tick its prefill
        # completes instead of decoding it — the prefill-specialist mode
        # of a tiered fleet: the fleet drains `ready` through
        # export_pages into a decode replica.  Off (the default) the
        # deque stays empty and nothing changes.
        self.hold_after_prefill = hold_after_prefill
        self.ready: deque[Request] = deque()
        self.finished: list[Request] = []
        self.cancelled: list[Request] = []
        self.positions = np.zeros(max_slots, dtype=np.int32)
        self.last_tokens = np.zeros(max_slots, dtype=np.int32)
        self.sampler = sampler or (lambda logits: jnp.argmax(logits, -1))
        self.steps = 0
        self.decoded_tokens = 0
        self.preemptions = 0
        self.peak_pages = 0
        self.max_slack_tokens = 0
        self.exports = 0               # KV handoffs out (tiered fleet)
        self.imports = 0               # KV handoffs in
        self._admit_counter = 0

        # the ctx must be ACTIVE at trace time (layers' paged scatter /
        # gather pick their shard_map path off it); a None ctx is pinned
        # too, so an ambient test ctx can never leak into engine traces
        ctx = self._shard_ctx

        def chunk_fn(p, c, t, st, tab, sl, sq):
            with sharding.use(ctx):
                return T.paged_step(p, cfg, c, t, st, tab, sl, sq)

        def decode_fn(p, c, t, st, tab, sl):
            with sharding.use(ctx):
                return T.paged_step(p, cfg, c, t, st, tab, sl, None)

        jit_kw: dict = {"donate_argnums": 1}
        if ctx is not None:
            # pin out shardings: logits replicated, new cache EXACTLY the
            # input cache's layout — donation then aliases every pool
            # shard in place (copy-free update, asserted by the donation
            # regression test)
            jit_kw["out_shardings"] = (
                NamedSharding(ctx.mesh, PartitionSpec()),
                T.paged_cache_shardings(self.cache, ctx))
        self._chunk_step = jax.jit(chunk_fn, **jit_kw)
        self._decode_step = jax.jit(decode_fn, **jit_kw)

    # -- bookkeeping --------------------------------------------------------

    def _worst_case_pages(self, req: Request) -> int:
        """Pages a request can ever hold: the chunk-padded prefill frontier
        or the fully-decoded length, whichever is larger."""
        plen = len(req.prompt)
        pad_end = -(-plen // self.prefill_chunk) * self.prefill_chunk
        return self.alloc.pages_for(max(pad_end, plen + req.max_new_tokens))

    def submit(self, req: Request) -> None:
        plen = len(req.prompt)
        if plen + req.max_new_tokens > self.max_len:
            raise ValueError("request exceeds max_len")
        if self._worst_case_pages(req) > self.alloc.capacity:
            raise ValueError(
                f"request {req.uid} can need {self._worst_case_pages(req)} "
                f"pages; pool only has {self.alloc.capacity}")
        self.waiting.append(req)

    def _sync_table(self, req: Request) -> None:
        row = self.page_tables[req.slot]
        row[:] = 0
        pages = self.alloc.pages.get(req.uid, ())
        row[:len(pages)] = pages

    def _live(self) -> list[Request]:
        return (list(self.prefilling) + list(self.ready)
                + list(self.active.values()))

    def _drop_live(self, req: Request) -> None:
        """Remove ``req`` from whichever live structure holds it."""
        if req.slot in self.active and self.active[req.slot] is req:
            del self.active[req.slot]
        elif req in self.ready:
            self.ready.remove(req)
        else:
            self.prefilling.remove(req)

    def _preempt(self, victim: Request) -> None:
        """Copy-free rollback: pages to the free list, request re-queued
        for a full (deterministic, greedy) re-run."""
        self.alloc.release(victim.uid)
        self.page_tables[victim.slot][:] = 0
        self.free_slots.append(victim.slot)
        self._drop_live(victim)
        victim.slot = None
        victim.generated = []
        victim.prefill_pos = 0
        self.waiting.appendleft(victim)
        self.preemptions += 1

    def _ensure_pages(self, req: Request, tokens: int) -> bool:
        """Grow ``req`` to cover ``tokens``, preempting the youngest
        STRICTLY-YOUNGER request while the free list is short.  Seniority
        (``admit_seq``) is assigned once and survives preemption, so a
        request can never evict anything admitted before it — the oldest
        live request is never a victim and always makes progress (no
        livelock, no starvation under a continuous arrival stream)."""
        while True:
            try:
                if self.alloc.ensure(req.uid, tokens):
                    self._sync_table(req)
                    self.peak_pages = max(self.peak_pages,
                                          self.alloc.allocated_pages)
                return True
            except OutOfPages:
                victims = [r for r in self._live()
                           if r is not req and r.admit_seq > req.admit_seq]
                if not victims:
                    return False
                self._preempt(max(victims, key=lambda r: r.admit_seq))

    # -- admission surface (shared with the fleet router) -------------------

    def servable(self, req: Request) -> bool:
        """Can this engine EVER run ``req`` (geometry, not current load)?"""
        return (len(req.prompt) + req.max_new_tokens <= self.max_len
                and self._worst_case_pages(req) <= self.alloc.capacity)

    def can_accept(self, req: Request) -> bool:
        """Would ``req`` be admitted next tick, counting work already
        queued in ``waiting``?  This is the SAME predicate ``_admit``
        applies (free slot + a first chunk's worth of free pages), with
        queued-but-unadmitted requests charged against the slot headroom —
        the fleet router must not over-dispatch onto a replica whose
        slots are already spoken for."""
        return (self.servable(req)
                and len(self.free_slots) > len(self.waiting)
                and self.alloc.free_pages
                >= self.alloc.pages_for(self.prefill_chunk))

    @property
    def saturated(self) -> bool:
        """No slot or page headroom for even a minimal new request — the
        condition the fleet front end surfaces as backpressure."""
        return (len(self.free_slots) <= len(self.waiting)
                or self.alloc.free_pages
                < self.alloc.pages_for(self.prefill_chunk))

    def live_count(self) -> int:
        return len(self.prefilling) + len(self.ready) + len(self.active)

    def live_committed_tokens(self) -> int:
        """Σ (prompt + max_new) over live requests: the sequence lengths
        this engine is committed to serving.  Deterministic and monotone
        within a request's lifetime, which is what admission pricing
        wants (per-tick positions would make route scores depend on
        phase, not load)."""
        return sum(len(r.prompt) + r.max_new_tokens for r in self._live())

    # -- scheduling ---------------------------------------------------------

    def _admit(self) -> None:
        """Admission gated by FREE PAGES (first chunk's worth), not by a
        whole max_len-sized slot."""
        while (self.waiting and self.free_slots
               and self.alloc.free_pages
               >= self.alloc.pages_for(self.prefill_chunk)):
            req = self.waiting.popleft()
            req.slot = self.free_slots.popleft()
            if req.admit_seq < 0:      # preempted requests keep seniority
                req.admit_seq = self._admit_counter
                self._admit_counter += 1
            req.prefill_pos = 0
            req.generated = []
            self.page_tables[req.slot][:] = 0
            self.positions[req.slot] = 0
            self.last_tokens[req.slot] = 0
            self.prefilling.append(req)

    def _prefill_tick(self) -> None:
        """One page-sized chunk of the oldest prefilling request."""
        req = self.prefilling[0]
        plen = len(req.prompt)
        start = req.prefill_pos
        # the chunk's padded tail writes garbage up to the chunk boundary,
        # so pages must cover it (chunk = 1 page by default -> <=1 page of
        # slack, reclaimed as decode writes fill the tail back in)
        if not self._ensure_pages(req, start + self.prefill_chunk):
            return                      # stall; decode ticks will free pages
        s_real = min(self.prefill_chunk, plen - start)
        toks = np.zeros(self.prefill_chunk, dtype=np.int32)
        toks[:s_real] = req.prompt[start:start + s_real]
        logits, self.cache = self._chunk_step(
            self.params, self.cache, jnp.asarray(toks[None]),
            jnp.asarray([start], jnp.int32),
            jnp.asarray(self.page_tables[req.slot][None]),
            jnp.asarray([req.slot], jnp.int32),
            jnp.asarray([s_real], jnp.int32))
        req.prefill_pos += s_real
        if req.prefill_pos == plen:
            tok = int(np.asarray(self.sampler(logits[0, s_real - 1])))
            req.generated.append(tok)
            self.last_tokens[req.slot] = tok
            self.positions[req.slot] = plen
            self.prefilling.popleft()
            if self.hold_after_prefill and not req.done:
                # prefill-specialist mode: park for the fleet's handoff
                # instead of decoding here (a done-after-prefill request
                # has nothing to hand off and retires below as usual)
                self.ready.append(req)
            else:
                self.active[req.slot] = req
                self._maybe_finish(req.slot)

    def _maybe_finish(self, slot: int) -> None:
        req = self.active.get(slot)
        if req is not None and req.done:
            del self.active[slot]
            self.alloc.release(req.uid)
            self.page_tables[slot][:] = 0
            self.free_slots.append(slot)
            self.finished.append(req)

    def _decode_tick(self) -> None:
        # grow every decoding request to cover its next write position; a
        # request that cannot get a page even after preempting younger
        # work rolls itself back
        for slot in sorted(self.active):
            req = self.active.get(slot)
            if req is None:
                continue               # preempted by an earlier slot's grow
            if not self._ensure_pages(req, int(self.positions[slot]) + 1):
                self._preempt(req)
        if not self.active:
            return
        # batch rows without a DECODING request (free slots, but also slots
        # still mid-prefill) are retargeted at the scratch page / scratch
        # slot row so their garbage writes cannot corrupt live state
        mask = np.zeros(self.max_slots, dtype=bool)
        mask[list(self.active)] = True
        tables = np.where(mask[:, None], self.page_tables, 0)
        slot_ids = np.where(mask, np.arange(self.max_slots), self.max_slots)
        toks = jnp.asarray(self.last_tokens[:, None], jnp.int32)
        logits, self.cache = self._decode_step(
            self.params, self.cache, toks,
            jnp.asarray(self.positions, jnp.int32),
            jnp.asarray(tables, jnp.int32),
            jnp.asarray(slot_ids, jnp.int32))
        sampled = np.asarray(self.sampler(logits[:, 0]))
        for slot, req in list(self.active.items()):
            tok = int(sampled[slot])
            req.generated.append(tok)
            self.last_tokens[slot] = tok
            self.positions[slot] += 1
            self.decoded_tokens += 1
            self._maybe_finish(slot)

    def step(self) -> int:
        """Admit + at most one prefill chunk + one batched decode step.
        Returns the number of live (prefilling or decoding) requests."""
        self._admit()
        if self.prefilling:
            self._prefill_tick()
        self._decode_tick()
        self.steps += 1
        self._record_slack()
        return len(self.active) + len(self.prefilling) + len(self.ready)

    def cancel(self, uid: int) -> bool:
        """Abort a request wherever it is; frees its pages copy-free."""
        for q in (self.waiting, self.prefilling, self.ready):
            for r in q:
                if r.uid == uid:
                    q.remove(r)
                    if r.slot is not None:
                        self.alloc.release(uid)
                        self.page_tables[r.slot][:] = 0
                        self.free_slots.append(r.slot)
                        r.slot = None
                    self.cancelled.append(r)
                    return True
        for slot, r in list(self.active.items()):
            if r.uid == uid:
                del self.active[slot]
                self.alloc.release(uid)
                self.page_tables[slot][:] = 0
                self.free_slots.append(slot)
                r.slot = None
                self.cancelled.append(r)
                return True
        return False

    def run_to_completion(self, max_steps: int = 10_000) -> list[Request]:
        while (self.waiting or self.prefilling or self.ready or self.active) \
                and self.steps < max_steps:
            self.step()
        return sorted(self.finished, key=lambda r: r.uid)

    # -- failover surface (consumed by the fleet's chaos tier) --------------

    def evacuate(self) -> list[Request]:
        """Roll back every LIVE request copy-free (pages to the free
        list, generation reset for a deterministic greedy re-run) and
        re-queue the rollbacks at the FRONT of ``waiting`` in admission
        order.  Seniority (``admit_seq``) survives, exactly as under
        preemption — greedy re-runs regenerate identical token prefixes,
        which is what lets streams ride out a replica death or
        quarantine byte-stably.  Returns the rolled-back requests,
        oldest first."""
        victims = sorted(self._live(), key=lambda r: r.admit_seq,
                         reverse=True)
        for req in victims:            # youngest first + appendleft ==
            self.alloc.release(req.uid)  # oldest ends at the queue head
            self.page_tables[req.slot][:] = 0
            self.free_slots.append(req.slot)
            self._drop_live(req)
            req.slot = None
            req.generated = []
            req.prefill_pos = 0
            self.waiting.appendleft(req)
        return victims[::-1]

    def reset_paging(self) -> None:
        """Discard ALL paging bookkeeping: fresh allocator, zeroed page
        tables and positions.  Only sound when no request is live (call
        :meth:`evacuate` first) — this is the quarantine heal, run after
        detected page-table corruption so the replica readmits with
        books that are clean by construction.  Page *contents* are left
        alone: every rolled-back request re-prefills from position 0, so
        stale K/V is always overwritten before it is read."""
        assert not self.active and not self.prefilling and not self.ready, \
            "reset_paging with live requests — evacuate first"
        self.alloc = PageAllocator(self.alloc.num_pages, self.page_len)
        self.page_tables[:] = 0
        self.positions[:] = 0
        self.last_tokens[:] = 0
        self.free_slots = deque(range(self.max_slots))

    # -- KV handoff surface (consumed by the fleet's tiered router) ---------

    def can_import(self, tokens: int) -> bool:
        """Could a handed-off request carrying ``tokens`` of KV land here
        next tick?  Same shape as :meth:`can_accept` — a free slot beyond
        what ``waiting`` has spoken for, plus pages for the WHOLE stored
        prefix (an import is not chunked: the pages arrive together)."""
        return (len(self.free_slots) > len(self.waiting)
                and self.alloc.free_pages
                >= self.alloc.pages_for(max(1, tokens)))

    def export_pages(self, uid: int) -> tuple[Request, dict]:
        """Extract a READY request (prefill complete, held for handoff)
        and its KV as a token-major host payload; the source side is
        copy-free exactly like :meth:`evacuate` — pages go straight back
        to the free list, the slot is freed, and the allocator's books
        are re-checked before returning.  The payload repacks paged
        leaves as ``(units, tokens, ...)`` so a destination with a
        different ``page_len`` can take it; slot-resident (SSM) leaves
        ride along as their single row."""
        req = next((r for r in self.ready if r.uid == uid), None)
        assert req is not None, f"uid {uid} is not ready for export"
        slot = req.slot
        tokens = int(self.positions[slot])
        pages = np.asarray(self.alloc.pages.get(uid, ()), dtype=np.int32)

        def one(path, leaf):
            if _leaf_name(path) in _PAGED_LEAVES:
                rows = np.asarray(leaf[:, pages])  # (units, n, page_len, ..)
                flat = rows.reshape(
                    (rows.shape[0], len(pages) * self.page_len)
                    + rows.shape[3:])
                return flat[:, :tokens].copy()
            return np.asarray(leaf[:, slot]).copy()

        payload = {
            "tokens": tokens,
            "pages": len(pages),
            "page_len": self.page_len,
            "last_token": int(self.last_tokens[slot]),
            "leaves": jax.tree_util.tree_map_with_path(one, self.cache),
        }
        self.alloc.release(uid)
        self.page_tables[slot][:] = 0
        self.free_slots.append(slot)
        self.ready.remove(req)
        req.slot = None
        self.exports += 1
        self.alloc.check_invariants()
        return req, payload

    def import_pages(self, req: Request, payload: dict) -> bool:
        """Land a handed-off request: allocate pages for its stored
        prefix, scatter the payload into this pool's geometry, and put
        it straight into decode.  Seniority is engine-local, so the
        arrival enters this engine's admission order at the back (the
        same rule migration uses).  Returns False — leaving the engine
        untouched — when capacity evaporated since the routing decision;
        the fleet then rolls the request back instead."""
        tokens = payload["tokens"]
        if not self.can_import(tokens):
            return False
        slot = self.free_slots.popleft()
        req.slot = slot
        req.admit_seq = self._admit_counter
        self._admit_counter += 1
        ok = self.alloc.ensure(req.uid, max(1, tokens))
        assert ok, "can_import promised pages the allocator refused"
        self._sync_table(req)
        self.peak_pages = max(self.peak_pages, self.alloc.allocated_pages)
        pages = np.asarray(self.alloc.pages[req.uid], dtype=np.int32)

        def one(path, leaf, row):
            if _leaf_name(path) in _PAGED_LEAVES:
                buf = np.zeros(
                    (row.shape[0], len(pages) * self.page_len)
                    + row.shape[2:], dtype=row.dtype)
                buf[:, :tokens] = row
                buf = buf.reshape(
                    (row.shape[0], len(pages), self.page_len) + row.shape[2:])
                return leaf.at[:, pages].set(jnp.asarray(buf, leaf.dtype))
            return leaf.at[:, slot].set(jnp.asarray(row, leaf.dtype))

        self.cache = jax.tree_util.tree_map_with_path(
            one, self.cache, payload["leaves"])
        self.positions[slot] = tokens
        self.last_tokens[slot] = payload["last_token"]
        req.prefill_pos = tokens
        self.active[slot] = req
        self.imports += 1
        self.alloc.check_invariants()
        return True

    def check_invariants(self) -> None:
        """Allocator invariants plus engine<->allocator cross-consistency
        (page tables mirror the allocator's page lists, pages cover every
        stored token, nothing dead holds pages).  Cheap enough for every
        tick — the soak tests and the fleet's corruption detection both
        call it."""
        self.alloc.check_invariants()
        live = {r.uid: r for r in self._live()}
        # every allocated page belongs to a LIVE request (a just-admitted
        # request may hold zero pages while it waits for its first chunk)
        assert set(self.alloc.pages) <= set(live), \
            (f"pages held by non-live uids "
             f"{sorted(set(self.alloc.pages) - set(live))}")
        for uid, req in live.items():
            pages = self.alloc.pages.get(uid, [])
            row = self.page_tables[req.slot]
            assert list(row[:len(pages)]) == pages, \
                f"uid {uid}: page table row diverges from allocator"
            assert not row[len(pages):].any(), \
                f"uid {uid}: page table row has a nonzero tail"
            assert len(pages) * self.page_len >= self._tokens_stored(req), \
                f"uid {uid}: pages do not cover stored tokens"
        for r in list(self.waiting) + self.finished + self.cancelled:
            assert r.uid not in self.alloc.pages or r.uid in live, \
                f"non-live uid {r.uid} still owns pages"

    def integrity_violations(self) -> list[str]:
        """Non-raising :meth:`check_invariants` — the detection hook the
        fleet polls under fault injection to decide quarantine."""
        try:
            self.check_invariants()
        except AssertionError as e:
            return [str(e) or "engine invariant violated"]
        return []

    # -- accounting ---------------------------------------------------------

    def _tokens_stored(self, req: Request) -> int:
        if req.slot is None:
            return 0
        if req.slot in self.active and self.active[req.slot] is req:
            return int(self.positions[req.slot])
        return req.prefill_pos

    def _record_slack(self) -> None:
        for req in self._live():
            held = len(self.alloc.pages.get(req.uid, ())) * self.page_len
            slack = held - self._tokens_stored(req)
            self.max_slack_tokens = max(self.max_slack_tokens, slack)

    def hbm_reserved_bytes(self) -> int:
        """Attention-cache HBM held RIGHT NOW for live requests (pages in
        circulation), the number that scales with actual output length."""
        return (self.alloc.allocated_pages * self.page_len
                * paging.kv_bytes_per_token(self.cfg))

    def page_table_bytes(self) -> int:
        return self.page_tables.nbytes

    def stats(self) -> dict:
        return {"steps": self.steps, "decoded_tokens": self.decoded_tokens,
                "finished": len(self.finished),
                "cancelled": len(self.cancelled),
                "preemptions": self.preemptions,
                "exports": self.exports,
                "imports": self.imports,
                "page_len": self.page_len,
                "gather_shards": self.shards,
                "num_pages": self.alloc.num_pages,
                "peak_pages": self.peak_pages,
                "max_slack_tokens": self.max_slack_tokens,
                "avg_batch_occupancy":
                    self.decoded_tokens / max(1, self.steps) / self.max_slots}
