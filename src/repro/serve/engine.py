"""Continuous-batching serving engine.

vLLM-style slot management on top of the batched decode path: a fixed pool
of ``max_slots`` cache slots; requests are admitted into free slots
(per-request prefill scattered into the batched cache), every engine tick
runs ONE batched decode step for all active slots at their own positions
(the per-slot ``cache_index`` vector added to ``models.decode``), finished
requests free their slots immediately for waiting work.

Design notes
* admission prefill runs at batch 1 and is written into the slot with a
  ``.at[:, slot]`` scatter per cache leaf — O(cache-slot bytes), no global
  reshuffle;
* inactive slots decode garbage that is masked out by the per-slot valid
  mask; their tokens are pinned to 0 — wasted flops are bounded by
  (free/active) ratio, the standard continuous-batching trade;
* greedy sampling (argmax) keeps the engine deterministic for tests; a
  temperature hook is provided.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int
    generated: list[int] = dataclasses.field(default_factory=list)
    slot: int | None = None

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_slots: int,
                 max_len: int,
                 sampler: Callable[[jax.Array], jax.Array] | None = None):
        if cfg.is_encoder:
            raise ValueError("encoder-only model has no decode path")
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.cache = T.init_cache(cfg, max_slots, max_len)
        self.free: deque[int] = deque(range(max_slots))
        self.active: dict[int, Request] = {}       # slot -> request
        self.waiting: deque[Request] = deque()
        self.finished: list[Request] = []
        # per-slot position of the NEXT token to be written
        self.positions = np.zeros(max_slots, dtype=np.int32)
        self.last_tokens = np.zeros(max_slots, dtype=np.int32)
        self.sampler = sampler or (lambda logits: jnp.argmax(logits, -1))
        self.steps = 0
        self.decoded_tokens = 0

        self._prefill = jax.jit(
            lambda p, toks: T.prefill(p, cfg, {"tokens": toks},
                                      max_len=max_len))
        self._decode = jax.jit(
            lambda p, c, t, idx: T.decode(p, cfg, c, t, idx),
            donate_argnums=1)

    # -- queue management ---------------------------------------------------

    def submit(self, req: Request) -> None:
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError("request exceeds max_len")
        self.waiting.append(req)

    def _admit(self) -> None:
        while self.waiting and self.free:
            req = self.waiting.popleft()
            slot = self.free.popleft()
            req.slot = slot
            logits, pcache = self._prefill(
                self.params, jnp.asarray(req.prompt[None, :], jnp.int32))
            # scatter the prefilled slot into the batched cache (axis 1 is
            # the slot/batch axis for every cache leaf)
            self.cache = jax.tree.map(
                lambda c, p: c.at[:, slot].set(p[:, 0].astype(c.dtype)),
                self.cache, pcache)
            tok = int(np.asarray(self.sampler(logits[0, -1])))
            req.generated.append(tok)
            self.last_tokens[slot] = tok
            self.positions[slot] = len(req.prompt)
            self.active[slot] = req
            self._maybe_finish(slot)

    def _maybe_finish(self, slot: int) -> None:
        req = self.active.get(slot)
        if req is not None and req.done:
            del self.active[slot]
            self.free.append(slot)
            self.finished.append(req)

    # -- the engine tick ------------------------------------------------------

    def step(self) -> int:
        """Admit + one batched decode step.  Returns #active slots."""
        self._admit()
        if not self.active:
            return 0
        toks = jnp.asarray(self.last_tokens[:, None], jnp.int32)
        idx = jnp.asarray(self.positions, jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, toks, idx)
        sampled = np.asarray(self.sampler(logits[:, 0]))
        for slot, req in list(self.active.items()):
            tok = int(sampled[slot])
            req.generated.append(tok)
            self.last_tokens[slot] = tok
            self.positions[slot] += 1
            self.decoded_tokens += 1
            self._maybe_finish(slot)
        self.steps += 1
        return len(self.active)

    def run_to_completion(self, max_steps: int = 10_000) -> list[Request]:
        while (self.waiting or self.active) and self.steps < max_steps:
            self.step()
            if not self.active and self.waiting:
                # all slots drained but work remains: admit next tick
                continue
        return sorted(self.finished, key=lambda r: r.uid)

    def stats(self) -> dict:
        return {"steps": self.steps, "decoded_tokens": self.decoded_tokens,
                "finished": len(self.finished),
                "avg_batch_occupancy":
                    self.decoded_tokens / max(1, self.steps) / self.max_slots}
