"""Seeded workload generation: realistic traffic for the serving tiers.

Every serving oracle so far ran on hand-built request lists; this module
generates the traffic those oracles are pointed at — and keeps the
repo's determinism discipline while doing it.  A :class:`WorkloadSpec`
names a **scenario** (per-request prompt/output length distributions), an
**arrival process** (when requests show up) and a seed; :func:`
generate_trace` turns it into a :class:`Trace` by consuming exactly one
``np.random.default_rng(seed)`` stream *in tick order* — the same
discipline as the chaos tier's seeded campaigns, so a whole trace is a
pure function of its spec and replays bit-identically
(:meth:`Trace.fingerprint` is the comparison artifact).

Scenarios (length distributions are gamma-shaped fractions of the
engine's ``max_len``, so one spec scales from micro test configs to real
pools):

* ``chat``  — short prompts, mid-length replies; the interactive staple.
* ``rag``   — retrieval-augmented: LONG prompts (the stuffed context),
  short grounded answers.  Prefill-heavy: stresses chunked admission.
* ``agent`` — many-turn tool loops: each arrival is a *session* of
  several short correlated requests a few ticks apart.
* ``batch`` — offline summarize: mid prompts, LONG outputs.
  Decode-heavy: stresses page growth and preemption.

Arrival processes (per tick, all seeded):

* ``poisson`` — memoryless baseline, ``k ~ Poisson(rate)``.
* ``bursty``  — two-state on/off modulation (flash crowds): bursts
  multiply the rate by ``BURST_FACTOR`` while they last.
* ``diurnal`` — sinusoidal day curve with ``DIURNAL_PERIOD``-tick days:
  peak traffic ``(1 + DIURNAL_AMPLITUDE)`` × the nominal rate.

:func:`replay_trace` drives a :class:`~repro.serve.frontend
.FleetFrontend` through a trace on its arrival schedule (backpressured
arrivals re-queue FIFO and their TTFT keeps counting from the ORIGINAL
arrival tick — queueing you caused is latency you must report), leaving
per-request TTFT/TPOT in the frontend's :class:`~repro.serve.slo
.SLOTracker`.
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
from collections import deque

import numpy as np

#: burst multiplier while the bursty process is in its ON state
BURST_FACTOR = 6.0
#: per-tick probability of entering / leaving a burst
BURST_ON_P = 0.06
BURST_OFF_P = 0.25

#: one synthetic "day" for the diurnal curve, in ticks
DIURNAL_PERIOD = 48
#: peak-to-nominal rate swing of the diurnal curve
DIURNAL_AMPLITUDE = 0.8

#: widest gap (ticks, exclusive) between an agent session's turns
TURN_GAP_MAX = 4


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Per-request length distributions, as fractions of ``max_len``.

    Lengths are drawn ``round(Gamma(shape, mean/shape))`` — mean
    ``frac × max_len``, coefficient of variation ``1/sqrt(shape)`` — and
    clipped so every request fits the engine (``prompt + output ≤
    max_len``, both ≥ 1).  ``turns_mean > 1`` makes each arrival a
    session of several requests (the agent loop).
    """

    name: str
    prompt_frac: float
    prompt_shape: float
    output_frac: float
    output_shape: float
    turns_mean: float = 1.0
    description: str = ""

    def mean_prompt(self, max_len: int) -> float:
        return max(1.0, self.prompt_frac * max_len)

    def mean_output(self, max_len: int) -> float:
        return max(1.0, self.output_frac * max_len)


SCENARIOS: dict[str, Scenario] = {
    "chat": Scenario("chat", prompt_frac=0.15, prompt_shape=2.0,
                     output_frac=0.30, output_shape=2.0,
                     description="short prompts, mid replies "
                                 "(interactive)"),
    "rag": Scenario("rag", prompt_frac=0.55, prompt_shape=6.0,
                    output_frac=0.12, output_shape=3.0,
                    description="long stuffed-context prompts, short "
                                "grounded answers (prefill-heavy)"),
    "agent": Scenario("agent", prompt_frac=0.20, prompt_shape=3.0,
                      output_frac=0.12, output_shape=3.0, turns_mean=4.0,
                      description="many-turn tool loops: sessions of "
                                  "short correlated requests"),
    "batch": Scenario("batch", prompt_frac=0.30, prompt_shape=3.0,
                      output_frac=0.50, output_shape=2.0,
                      description="offline summarize: long outputs "
                                  "(decode/page-growth-heavy)"),
}

ARRIVALS = ("poisson", "bursty", "diurnal")


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Everything a trace is a function of."""

    scenario: str = "chat"
    arrival: str = "poisson"
    rate: float = 0.5              # nominal arrivals per tick
    horizon: int = 64              # ticks of arrivals (tail may run longer)
    seed: int = 0
    max_len: int = 48              # engine geometry the lengths fit in
    vocab_size: int = 64

    def __post_init__(self):
        if self.scenario not in SCENARIOS:
            raise ValueError(f"unknown scenario {self.scenario!r}; "
                             f"one of {sorted(SCENARIOS)}")
        if self.arrival not in ARRIVALS:
            raise ValueError(f"unknown arrival {self.arrival!r}; "
                             f"one of {ARRIVALS}")
        if self.rate <= 0:
            raise ValueError(f"rate must be positive, got {self.rate}")
        if self.horizon < 1:
            raise ValueError(f"horizon must be >= 1, got {self.horizon}")
        if self.max_len < 2:
            raise ValueError(f"max_len must be >= 2, got {self.max_len}")


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One arrival: uid order == arrival order (FIFO-stable)."""

    uid: int
    tick: int
    prompt: np.ndarray             # (plen,) int32
    max_new_tokens: int
    session: int = 0               # arrival group (agent turns share one)


@dataclasses.dataclass(frozen=True)
class Trace:
    spec: WorkloadSpec
    requests: tuple[TraceRequest, ...]

    def fingerprint(self) -> str:
        """Content digest for bit-identical replay comparison."""
        h = hashlib.sha256(repr(self.spec).encode())
        for r in self.requests:
            h.update(f"{r.uid},{r.tick},{r.max_new_tokens},{r.session};"
                     .encode())
            h.update(np.ascontiguousarray(r.prompt).tobytes())
        return h.hexdigest()

    def stats(self) -> dict:
        """The characterization the capacity planner consumes: measured
        (not nominal) arrival rate and mean lengths, so bursty and
        session-expanded traces are priced by what actually arrives."""
        n = len(self.requests)
        span = max(self.spec.horizon,
                   (max(r.tick for r in self.requests) + 1) if n else 1)
        return {
            "requests": n,
            "span_ticks": span,
            "arrival_per_tick": n / span,
            "mean_prompt": (sum(len(r.prompt) for r in self.requests) / n)
            if n else 0.0,
            "mean_new": (sum(r.max_new_tokens for r in self.requests) / n)
            if n else 0.0,
            "total_tokens": sum(len(r.prompt) + r.max_new_tokens
                                for r in self.requests),
            "sessions": len({r.session for r in self.requests}),
        }


def _draw_len(rng: np.random.Generator, mean: float, shape: float,
              lo: int, hi: int) -> int:
    """One gamma length draw, rounded and clipped to [lo, hi]."""
    val = int(round(float(rng.gamma(shape, mean / shape))))
    return max(lo, min(hi, val))


def _arrival_count(rng: np.random.Generator, spec: WorkloadSpec,
                   tick: int, state: dict) -> int:
    """Arrivals due this tick.  Each branch consumes a FIXED per-tick
    draw pattern, so the stream position is a function of the tick
    index alone — the property that makes traces replayable."""
    if spec.arrival == "poisson":
        return int(rng.poisson(spec.rate))
    if spec.arrival == "bursty":
        u = float(rng.random())
        if state["on"]:
            state["on"] = u >= BURST_OFF_P
        else:
            state["on"] = u < BURST_ON_P
        rate = spec.rate * (BURST_FACTOR if state["on"] else 1.0)
        return int(rng.poisson(rate))
    # diurnal: one sinusoidal "day" every DIURNAL_PERIOD ticks
    rate = spec.rate * (1.0 + DIURNAL_AMPLITUDE
                        * math.sin(2.0 * math.pi * tick / DIURNAL_PERIOD))
    return int(rng.poisson(max(0.0, rate)))


def generate_trace(spec: WorkloadSpec) -> Trace:
    """One seeded RNG stream, consumed strictly in tick order (then in
    arrival order within a tick, then turn order within a session) —
    the whole trace is a pure function of ``spec``."""
    sc = SCENARIOS[spec.scenario]
    rng = np.random.default_rng(spec.seed)
    state = {"on": False}
    births: list[tuple[int, int, np.ndarray, int, int]] = []
    seq = 0
    session = 0
    for tick in range(spec.horizon):
        for _ in range(_arrival_count(rng, spec, tick, state)):
            turns = (1 if sc.turns_mean <= 1.0
                     else 1 + int(rng.poisson(sc.turns_mean - 1.0)))
            at = tick
            for turn in range(turns):
                plen = _draw_len(rng, sc.mean_prompt(spec.max_len),
                                 sc.prompt_shape, 1, spec.max_len - 1)
                n_new = _draw_len(rng, sc.mean_output(spec.max_len),
                                  sc.output_shape, 1, spec.max_len - plen)
                prompt = rng.integers(spec.vocab_size,
                                      size=plen).astype(np.int32)
                births.append((at, seq, prompt, n_new, session))
                seq += 1
                if turn + 1 < turns:   # next turn lands a few ticks out
                    at += 1 + int(rng.integers(TURN_GAP_MAX))
            session += 1
    births.sort(key=lambda b: (b[0], b[1]))
    return Trace(spec, tuple(
        TraceRequest(uid, at, prompt, n_new, sess)
        for uid, (at, _, prompt, n_new, sess) in enumerate(births)))


# ---------------------------------------------------------------------------
# driving a frontend through a trace on its arrival schedule
# ---------------------------------------------------------------------------


def replay_trace(front, trace: Trace, *, max_ticks: int = 10_000,
                 on_token=None) -> dict[int, object]:
    """Submit every trace request at its arrival tick and run the loop
    dry.  Backpressured arrivals re-queue FIFO and retry each tick;
    their TTFT keeps counting from the ORIGINAL arrival tick (the
    ``arrival_tick`` pass-through below), so shed-and-retry latency is
    reported, not hidden.  Returns ``{uid: StreamHandle}``; the latency
    rows land in ``front.slo``.
    """
    from repro.serve.frontend import Backpressure
    pending = deque(trace.requests)
    deferred: deque[TraceRequest] = deque()
    handles: dict[int, object] = {}

    def try_submit(tr: TraceRequest) -> bool:
        try:
            handles[tr.uid] = front.submit(
                tr.prompt, tr.max_new_tokens, uid=tr.uid,
                on_token=on_token, arrival_tick=tr.tick)
            return True
        except Backpressure:
            return False

    while True:
        now = front.fleet.ticks
        while deferred and try_submit(deferred[0]):
            deferred.popleft()
        if not deferred:               # FIFO: nothing jumps the retry queue
            while pending and pending[0].tick <= now:
                if try_submit(pending[0]):
                    pending.popleft()
                else:
                    deferred.append(pending.popleft())
                    break
        live = sum(1 for h in front.handles.values() if not h.settled)
        if not (pending or deferred or live):
            return handles
        if front.fleet.ticks >= max_ticks:
            raise RuntimeError(
                f"trace did not drain within {max_ticks} ticks "
                f"({len(pending)} pending, {len(deferred)} deferred, "
                f"{live} live)")
        front.tick()
