"""Seeded fault injection for the serving fleet — the chaos tier.

The shape is a scripted **fault campaign** (the SPHINCS+ fault-analysis
model: seeded campaigns, classified outcomes, offline analysis of the
outcome distribution): a :class:`FaultInjector` schedules fault events
against a :class:`~repro.serve.fleet.FleetEngine` run and the fleet's
deterministic event loop turns every one of them into an auditable,
replayable transition.  Three fault classes:

* **kill** — replica death mid-decode/mid-prefill.  The fleet evacuates
  the replica copy-free (zero leaked pages, asserted) and the stranded
  requests re-home through the SAME ``_migrate`` machinery that moves
  preemption rollbacks; greedy re-runs keep unaffected and re-homed
  streams byte-stable.
* **corrupt** — page-table/allocator corruption.  The injector breaks
  the replica's *bookkeeping only* (owner map, page list, page-table
  mirror) — never the bytes the jitted model computes with — and the
  fleet's per-tick integrity poll (``PagedServeEngine
  .check_invariants``) catches it before any dispatch or decode can
  consume the corrupt books, sending the replica through the
  quarantine → heal → readmit lifecycle.
* **degrade** — latency-spike degradation of a replica's profile
  (CUTHERMO's degraded-memory regime as a first-class fault, not just
  death): the spec is re-priced through ``decode_cell_cost`` so the
  router organically drains load from the sick replica; a paired
  **recover** restores the base spec.

Everything is deterministic: a scripted schedule is deterministic by
construction, and a seeded campaign (:meth:`FaultInjector.campaign`)
draws exactly one ``np.random.default_rng(seed)`` stream in tick order —
same seed, same fleet, same workload ⇒ bit-identical merged decision log
(routing decisions + fault events on one sequence), identical outcome
classification, and byte-identical token streams for unaffected
requests.  :func:`run_campaign` is the harness the tests, the
``serve_faults`` experiment and ``launch/serve.py --faults`` all share.
"""

from __future__ import annotations

import dataclasses
from collections import Counter

import numpy as np

from repro.serve import fleet as fleet_mod
from repro.serve.fleet import OUTCOME_CLASSES, FleetEngine  # noqa: F401

#: the injectable fault kinds (quarantine/readmit/lost are *responses*,
#: recorded by the fleet, never injected directly)
FAULT_KINDS = ("kill", "corrupt", "degrade", "recover")

#: default latency-spike severity (bandwidth and FLOPs /k, latency *k) —
#: far outside the router margin, so a spiked replica only wins a
#: decision when every healthy replica is saturated
DEGRADE_FACTOR = 4.0

#: ticks of fault exposure in a seeded campaign (faults only fire while
#: ``fleet.ticks < horizon``, so every campaign has a clean drain phase)
CAMPAIGN_HORIZON = 200

#: page-table corruption variants the injector can apply (all pure
#: bookkeeping, all caught by ``check_invariants``):
#: 0 = owner-map entry zapped, 1 = a free page aliased into a live page
#: list, 2 = nonzero tail in a live slot's page-table row
CORRUPT_VARIANTS = 3


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault.  ``replica=None`` defers target choice to
    apply time: the most-loaded eligible replica (deterministic — fleet
    state is deterministic), which is what makes seeded campaigns land
    faults where they bite without knowing the schedule a priori."""

    tick: int
    kind: str                       # one of FAULT_KINDS
    replica: int | None = None
    factor: float = DEGRADE_FACTOR  # degrade severity
    variant: int = 0                # corruption variant (mod CORRUPT_VARIANTS)

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")


class FaultInjector:
    """Applies a fault schedule to a fleet, one tick at a time.

    Two modes, combinable:

    * **scripted** — pass an explicit ``schedule`` of :class:`Fault`
      entries (the campaign-file shape);
    * **seeded** — :meth:`campaign` draws faults online from one seeded
      RNG: per tick, fire with probability ``rate`` while ``ticks <
      horizon``, kind chosen uniformly from ``kinds``.  The RNG is
      consumed in strict tick order, so the draw stream — and therefore
      the whole run — is a pure function of the seed.

    ``max_kills`` bounds replica deaths (default: fleet size − 1, so a
    campaign can never kill the last replica and lose everything by
    construction).  An injector is single-use: it is consumed by the run
    it is attached to — build a fresh one (same seed/schedule) to
    replay.
    """

    def __init__(self, schedule: "tuple[Fault, ...] | list[Fault]" = (),
                 *, max_kills: int | None = None):
        self.schedule = tuple(sorted(schedule, key=lambda f: f.tick))
        self.max_kills = max_kills
        self._rng: np.random.Generator | None = None
        self.rate = 0.0
        self.kinds: tuple[str, ...] = ()
        self.horizon = CAMPAIGN_HORIZON
        self.seed: int | None = None
        self.applied: list[Fault] = []

    @classmethod
    def campaign(cls, seed: int, *, rate: float = 0.05,
                 kinds: tuple[str, ...] = ("kill", "corrupt", "degrade"),
                 horizon: int = CAMPAIGN_HORIZON,
                 max_kills: int | None = None,
                 schedule: "tuple[Fault, ...]" = ()) -> "FaultInjector":
        """A seeded campaign (optionally on top of a scripted base)."""
        inj = cls(schedule, max_kills=max_kills)
        for k in kinds:
            if k not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {k!r}")
        inj._rng = np.random.default_rng(seed)
        inj.seed = seed
        inj.rate = rate
        inj.kinds = tuple(kinds)
        inj.horizon = horizon
        return inj

    # -- per-tick application ------------------------------------------------

    def on_tick(self, fleet: FleetEngine) -> None:
        """Apply every fault due at the fleet's CURRENT tick (called by
        ``FleetEngine.step`` before dispatch)."""
        due = [f for f in self.schedule if f.tick == fleet.ticks]
        if self._rng is not None and fleet.ticks < self.horizon:
            # one draw per tick keeps the stream a function of tick count
            u = float(self._rng.random())
            if u < self.rate:
                kind = self.kinds[int(self._rng.integers(len(self.kinds)))]
                factor = float(self._rng.choice((2.0, 4.0, 8.0)))
                variant = int(self._rng.integers(CORRUPT_VARIANTS))
                due.append(Fault(fleet.ticks, kind, None, factor, variant))
        for f in due:
            self._apply(fleet, f)

    def _apply(self, fleet: FleetEngine, f: Fault) -> None:
        target = self._target(fleet, f)
        if target is None:
            fleet.record_event("skip", -1, (f.kind, "no eligible target"))
            return
        self.applied.append(dataclasses.replace(f, replica=target.index))
        if f.kind == "kill":
            fleet.kill(target.index, reason="injected")
        elif f.kind == "corrupt":
            detail = self._corrupt(target, f.variant)
            fleet.record_event("corrupt", target.index, detail)
        elif f.kind == "degrade":
            fleet.degrade(target.index, f.factor)
        elif f.kind == "recover":
            fleet.recover(target.index)

    def _target(self, fleet: FleetEngine, f: Fault):
        """Deterministic apply-time target choice (see :class:`Fault`)."""
        if f.kind == "kill":
            kills_left = ((len(fleet.replicas) - 1 - fleet.deaths)
                          if self.max_kills is None
                          else (self.max_kills - fleet.deaths))
            if kills_left <= 0:
                return None
            pool = [r for r in fleet.replicas if r.dispatchable]
        elif f.kind == "corrupt":
            # corruption needs live books to corrupt
            pool = [r for r in fleet.replicas
                    if r.dispatchable and r.engine.alloc.allocated_pages]
        elif f.kind == "degrade":
            pool = [r for r in fleet.replicas if r.dispatchable]
        else:                          # recover
            pool = [r for r in fleet.replicas
                    if r.state == fleet_mod.DEGRADED]
        if f.replica is not None:
            pool = [r for r in pool if r.index == f.replica]
        if not pool:
            return None
        # most-loaded first (live requests, then held pages), index tie-break
        return max(pool, key=lambda r: (r.engine.live_count(),
                                        r.engine.alloc.allocated_pages,
                                        -r.index))

    def _corrupt(self, replica, variant: int) -> tuple:
        """Break the replica's paging BOOKKEEPING (never page contents —
        detection fires before any token could be affected, and the
        quarantine heal re-runs everything from scratch anyway)."""
        eng = replica.engine
        alloc = eng.alloc
        uid = sorted(alloc.pages)[0]
        pages = alloc.pages[uid]
        v = variant % CORRUPT_VARIANTS
        if v == 1 and not alloc.free:
            v = 0                      # no free page to alias: fall back
        if v == 2:
            req = next((r for r in eng._live() if r.uid == uid), None)
            if req is None or len(pages) >= eng.pages_per_seq:
                v = 0                  # row full / uid not live: fall back
        if v == 0:
            alloc.owner[pages[0]] = -1           # stale owner map
        elif v == 1:
            alloc.pages[uid] = pages + [alloc.free[0]]   # aliases a free page
        else:
            eng.page_tables[req.slot][len(pages)] = pages[0]  # mirror tail
        return ("variant", v, "uid", uid)

    def stats(self) -> dict:
        by_kind = Counter(f.kind for f in self.applied)
        return {"applied": len(self.applied),
                **{f"applied_{k}": by_kind.get(k, 0) for k in FAULT_KINDS}}


# ---------------------------------------------------------------------------
# the campaign harness (shared by tests, the experiment, and the launcher)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CampaignReport:
    """Everything a campaign's offline analysis consumes — all of it
    deterministic accounting, none of it wall clock."""

    outcomes: dict[int, str]           # uid -> OUTCOME_CLASSES entry
    streams: dict[int, tuple[int, ...]]  # uid -> streamed tokens
    log: list[tuple]                   # merged decision+event log keys
    event_counts: dict[str, int]       # FaultEvent kind -> count
    stats: dict                        # FleetEngine.stats()

    def outcome_counts(self) -> dict[str, int]:
        return dict(Counter(self.outcomes.values()))

    def uids(self, outcome: str) -> list[int]:
        return sorted(u for u, c in self.outcomes.items() if c == outcome)


def run_campaign(fleet: FleetEngine, work, injector: FaultInjector | None
                 = None, *, max_ticks: int = 10_000) -> CampaignReport:
    """Stream ``work`` (``[(prompt, max_new_tokens), ...]``, uid =
    position) through a :class:`~repro.serve.frontend.FleetFrontend`
    over ``fleet`` with ``injector`` attached, then classify every uid.

    A submission rejected because its capacity died mid-campaign is
    classified ``lost`` — every uid ends classified, nothing is silently
    dropped."""
    from repro.serve.frontend import FleetFrontend
    if injector is not None:
        fleet.attach_injector(injector)
    front = FleetFrontend(fleet)
    rejected: list[int] = []
    for uid, (prompt, n_new) in enumerate(work):
        try:
            front.submit_blocking(prompt, n_new, uid=uid)
        except ValueError:             # unservable: capacity died
            rejected.append(uid)
    front.run(max_ticks)
    outcomes = fleet.classify()
    for uid in rejected:
        outcomes[uid] = "lost"
    return CampaignReport(
        outcomes=outcomes,
        streams={uid: tuple(h.tokens)
                 for uid, h in sorted(front.handles.items())},
        log=fleet.decision_log(),
        event_counts=dict(Counter(e.kind for e in fleet.events)),
        stats=fleet.stats())
